from .sharding import (
    ShardingPolicy,
    sharding_policy,
    current_policy,
    constrain,
    dp_axes,
    tp_axis,
    active_mesh,
    param_pspec,
    param_shardings,
    batch_pspec,
    cache_pspec,
    cache_shardings,
)

__all__ = [
    "ShardingPolicy",
    "sharding_policy",
    "current_policy",
    "constrain",
    "dp_axes",
    "tp_axis",
    "active_mesh",
    "param_pspec",
    "param_shardings",
    "batch_pspec",
    "cache_pspec",
    "cache_shardings",
]
