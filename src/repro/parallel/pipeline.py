"""Pipeline parallelism over the ``pod`` axis (GPipe-style microbatching).

At 2 pods the default posture is DP over ``pod`` (bubble-free, and the
cross-pod gradient traffic can be PVQ-compressed — see optim/grad_compress);
this module provides the PP alternative for deeper pod counts or models whose
layers do not fit a single pod even fully sharded.

Schedule: the L layer-groups are split into S stages (one per pod rank);
microbatches flow stage-to-stage with ``jax.lax.ppermute`` inside a
``shard_map`` over the ``pod`` axis.  GPipe schedule: all microbatches
forward, then backward (handled by jax.grad through the scan); bubble
fraction = (S-1)/(S-1+M) for M microbatches.

The implementation is deliberately generic: ``stage_fn(stage_params, x)``
is any per-stage function; weights are expected pre-partitioned with a
leading stage axis (one stage per pod rank via P('pod', ...)).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map with the 0.4.x fallback (jax.experimental.shard_map)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as sm

    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # leaves with leading [n_stages] axis, sharded P('pod')
    x_microbatches: jax.Array,  # (n_micro, mb, ...) input microbatches
    *,
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run the pipeline; returns the final-stage outputs (n_micro, mb, ...).

    Implemented as a shard_map over ``axis``: each rank holds one stage's
    params; a rotating buffer carries microbatch activations rank-to-rank
    with ppermute.  Total ticks = n_micro + n_stages - 1.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_microbatches.shape[0]

    def per_pod(params_local, x_local):
        # params_local: stage params with leading axis 1 (this rank's stage);
        # x_local: the full (n_micro, mb, ...) batch (replicated input)
        params_here = jax.tree.map(lambda t: t[0], params_local)
        stage_idx = jax.lax.axis_index(axis)
        total = n_micro + n_stages - 1

        def tick(carry, t):
            buf, outputs = carry  # buf: (mb, ...) activation from prev stage
            # stage 0 injects microbatch t (when in range)
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jnp.where((stage_idx == 0) & (t < n_micro), x_local[inject], buf)
            y = stage_fn(params_here, x_in)
            # last stage collects its result for microbatch (t - S + 1)
            out_slot = t - (n_stages - 1)
            write = (stage_idx == n_stages - 1) & (out_slot >= 0)
            outputs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_slot, 0), 0
                ),
                lambda o: o,
                outputs,
            )
            # rotate activations to the next stage
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (buf, outputs), None

        buf0 = jnp.zeros_like(x_local[0])
        outputs0 = jnp.zeros((n_micro,) + x_local.shape[1:], x_local.dtype)
        (buf, outputs), _ = jax.lax.scan(tick, (buf0, outputs0), jnp.arange(total))
        # rotate once more: rank 0 ends up holding the last stage's outputs
        outputs = jax.lax.ppermute(
            outputs, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
        )
        return outputs[None]

    fn = _shard_map(
        per_pod,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_params), P(None)),
        out_specs=P(axis),
    )
    out = fn(stage_params, x_microbatches)  # (n_stages, n_micro, mb, ...)
    return out[0]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble overhead: idle ticks / total ticks."""
    return (n_stages - 1) / (n_stages - 1 + n_micro)
