"""Sharding rules: logical activation/parameter axes -> NamedSharding specs.

Mesh axes (see launch/mesh.py):
    pod    — inter-pod axis (DP by default; pipeline stage axis in PP mode)
    data   — intra-pod data parallel + FSDP (params/optimizer sharded here)
    model  — tensor parallel (heads / ffn hidden / experts) + optional SP

Activation constraints are expressed through :func:`constrain` with symbolic
axes ('dp', 'tp', 'cp', None) resolved against the *active* mesh, so model
code is mesh-agnostic and runs unchanged on CPU tests (constraints no-op when
no mesh is active).
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Active mesh + policy
# ---------------------------------------------------------------------------


def active_mesh() -> Optional[Mesh]:
    """The mesh installed by ``with mesh:`` (None outside)."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """How activations/params are sharded for the current step function.

    The `opt_*` fields are the §Perf hillclimb levers; defaults reproduce the
    recorded baseline.  See EXPERIMENTS.md §Perf for measured effects.
    """

    seq_shard: bool = False  # Megatron-style sequence parallelism on residuals
    context_parallel: bool = False  # shard decode KV cache along sequence (data axis)
    fsdp_pod: bool = False  # extend FSDP over the pod axis too (ZeRO across pods)
    # --- opt levers ---
    serve_params: bool = False  # serving layout: no FSDP on params (TP/EP only;
    #                              expert ffn dim sharded over data instead)
    cache_seq_tp: bool = False  # decode KV cache sequence axis sharded over model
    moe_light_combine: bool = False  # slot-gate combine (no f32 (g,s,e,c) tensor)
    remat: str = "full"  # 'full' | 'dots' (save matmul outputs: no recomputed
    #                       TP psums in the backward pass, more live memory)


_local = threading.local()


def current_policy() -> ShardingPolicy:
    return getattr(_local, "policy", ShardingPolicy())


@contextlib.contextmanager
def sharding_policy(policy: ShardingPolicy):
    old = current_policy()
    _local.policy = policy
    try:
        yield
    finally:
        _local.policy = old


# ---------------------------------------------------------------------------
# Symbolic axis resolution
# ---------------------------------------------------------------------------


def dp_axes(mesh: Optional[Mesh] = None):
    mesh = mesh or active_mesh()
    if mesh is None:
        return None
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes or None


def tp_axis(mesh: Optional[Mesh] = None):
    mesh = mesh or active_mesh()
    if mesh is None:
        return None
    return "model" if "model" in mesh.axis_names else None


def _resolve(sym, mesh: Mesh, policy: ShardingPolicy):
    if sym is None:
        return None
    if sym == "dp":
        return dp_axes(mesh)
    if sym == "tp":
        return tp_axis(mesh)
    if sym == "sp":  # sequence-parallel position: only when policy enables it
        return tp_axis(mesh) if policy.seq_shard else None
    if sym == "cp":  # context-parallel (decode KV seq axis)
        return "data" if (policy.context_parallel and "data" in mesh.axis_names) else None
    if sym == "seq":  # decode cache sequence axis: cp (data) and/or tp (model)
        axes = []
        if policy.context_parallel and "data" in mesh.axis_names:
            axes.append("data")
        if policy.cache_seq_tp and "model" in mesh.axis_names:
            axes.append("model")
        return tuple(axes) if axes else None
    if sym in ("pod", "data", "model"):
        return sym if sym in mesh.axis_names else None
    raise ValueError(f"unknown symbolic axis {sym!r}")


def constrain(x: jax.Array, *syms) -> jax.Array:
    """with_sharding_constraint with symbolic axes; no-op without a mesh.

    Mesh axes claimed by an earlier dim are dropped from later dims (e.g.
    batch='dp' uses 'data', so a 'seq'=('data','model') KV axis degrades to
    ('model',) — the context-parallel long-decode case)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    policy = current_policy()
    used: set = set()
    resolved = []
    for s in syms:
        axes = _resolve(s, mesh, policy)
        if axes is None:
            resolved.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        ax_tuple = tuple(a for a in ax_tuple if a not in used)
        used.update(ax_tuple)
        resolved.append(ax_tuple if ax_tuple else None)
    return jax.lax.with_sharding_constraint(x, P(*resolved))


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# (path_regex, spec_by_rank) — first match wins. Specs are written for the
# UNSTACKED tensor; scan-stacked params (path contains 'blocks/') get a
# leading None prepended automatically when rank exceeds the rule's.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    # --- packed PVQ artifact children (PackedPVQ pytree nodes flatten to
    # <param>/pulses + <param>/scales; see repro.core.packed) ---
    # flat-layout embedding: groups are row-major over vocab, so the leading
    # axis shards like the vocab axis (vocab-parallel logits)
    (r"embedding/pulses$", ("tp", None)),
    (r"embedding/scales$", ("tp",)),
    # row-parallel matmul layout: contraction (k_pad) axis on model; the
    # scales' group axis tiles the same contraction dim
    (r"(wo|out|out_proj)/kernel/pulses$", ("tp", "fsdp")),
    (r"(wo|out|out_proj)/kernel/scales$", ("tp", "fsdp")),
    # packed MoE expert banks (expert-stacked matmul layout): E on model
    # (EP); wi pulses (E, d_pad, f) shard the contraction dim on data
    # (FSDP), wo pulses (E, f_pad, d) shard the output dim on data.  The
    # scales' group axes (d_pad/group, f_pad/group) are short and stay
    # unsharded unless divisible (wo scales tile the model dim n=d).
    (r"wi_(up|gate)_experts/pulses$", ("tp", "fsdp", None)),
    (r"wi_(up|gate)_experts/scales$", ("tp", None, None)),
    (r"wo_experts/pulses$", ("tp", None, "fsdp")),
    (r"wo_experts/scales$", ("tp", None, "fsdp")),
    # column-parallel / generic matmul layout: FSDP in, TP out (scales'
    # group axis is short — k_pad/group — so only the n axis shards)
    (r"kernel/pulses$", ("fsdp", "tp")),
    (r"kernel/scales$", (None, "tp")),
    # embeddings: vocab on model (vocab-parallel logits), d on data (FSDP)
    (r"embedding$", ("tp", "fsdp")),
    (r"pos_embedding$", (None, "fsdp")),
    # MoE stacked experts: E on model (EP), d_model on data (FSDP)
    (r"wi_(up|gate)_experts$", ("tp", "fsdp", None)),
    (r"wo_experts$", ("tp", None, "fsdp")),
    (r"router/kernel$", (None, None)),
    # row-parallel outputs (contract dim is model-sharded)
    (r"(wo|out|out_proj)/kernel$", ("tp", "fsdp")),
    # column-parallel inputs
    (r"(wq|wk|wv|wg|wr|wi_gate|wi_up|wq_a|wq_b|wkv_a|wk_rope|wk_b|wv_b|in_proj|x_proj|dt_proj|wi)/kernel$", ("fsdp", "tp")),
    # generic dense kernels: FSDP in, TP out
    (r"kernel$", ("fsdp", "tp")),
    # mamba recurrence params: d_inner is model-sharded
    (r"a_log$", ("tp", None)),
    (r"d_skip$", ("tp",)),
    (r"conv_kernel$", (None, "tp")),
    (r"conv_bias$", ("tp",)),
    # rwkv head-structured params
    (r"time_faaaa$", ("tp", None)),
    # biases / norm scales / small vectors: replicated
    (r"(bias|scale|base|w1|w2)$", None),
)


def _fsdp_axes(mesh: Mesh, policy: ShardingPolicy):
    if policy.serve_params:
        return None  # serving: params replicated over data (TP/EP shards only)
    axes = []
    if policy.fsdp_pod and "pod" in mesh.axis_names:
        axes.append("pod")
    if "data" in mesh.axis_names:
        axes.append("data")
    return tuple(axes) if axes else None


# Serving layout for MoE expert weights: EP over model, and the expert FFN
# hidden dim sharded over data (keeps the 472GB of DeepSeek-236B experts at
# ~1.8GB/chip without per-step weight all-gathers; the down-proj contraction
# psums a tokens-sized tensor instead — tiny at decode batch sizes).
_SERVE_EXPERT_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    # packed children first (their paths end in /pulses|/scales, so the
    # dense rules below can never shadow them): EP over model; the expert
    # FFN hidden dim (f on wi's output axis, f_pad on wo's group-padded
    # contraction axis) shards over data, exactly like the dense bank
    (r"wi_(up|gate)_experts/pulses$", ("tp", None, "data")),
    (r"wi_(up|gate)_experts/scales$", ("tp", None, "data")),
    (r"wo_experts/pulses$", ("tp", "data", None)),
    (r"wo_experts/scales$", ("tp", "data", None)),
    (r"wi_(up|gate)_experts$", ("tp", None, "data")),
    (r"wo_experts$", ("tp", "data", None)),
)


def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh, policy: ShardingPolicy) -> P:
    """PartitionSpec for one parameter leaf."""
    rules = _PARAM_RULES
    if policy.serve_params:
        rules = _SERVE_EXPERT_RULES + _PARAM_RULES
    for pat, spec in rules:
        if re.search(pat, path):
            if spec is None:
                return P()
            resolved = []
            for s in spec:
                if s == "fsdp":
                    resolved.append(_fsdp_axes(mesh, policy))
                elif s == "tp":
                    resolved.append(tp_axis(mesh))
                else:
                    resolved.append(s)
            # scan-stacked tensors carry extra leading axes
            extra = len(shape) - len(spec)
            if extra > 0:
                resolved = [None] * extra + resolved
            elif extra < 0:
                return P()  # rank mismatch: fall back to replicated
            # never shard an axis that isn't divisible by its mesh extent
            final = []
            for dim, axes in zip(shape, resolved):
                if axes is None:
                    final.append(None)
                    continue
                ax_tuple = axes if isinstance(axes, tuple) else (axes,)
                extent = int(np.prod([mesh.shape[a] for a in ax_tuple]))
                final.append(axes if dim % extent == 0 else None)
            return P(*final)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_shape: Any, mesh: Mesh, policy: ShardingPolicy = ShardingPolicy()):
    """NamedSharding tree for a params pytree (of arrays or ShapeDtypeStructs)."""

    def one(path, leaf):
        spec = param_pspec(_path_str(path), tuple(leaf.shape), mesh, policy)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_pspec(mesh: Mesh, *, context_parallel: bool = False) -> P:
    """Spec for (batch, seq, ...) inputs."""
    if context_parallel:
        return P(None, "data")
    return P(dp_axes(mesh))


# ---------------------------------------------------------------------------
# Decode-cache sharding rules
# ---------------------------------------------------------------------------

# Leaf paths look like  seg0/b3/kv/k  with shapes (repeats, batch, seq, ...).
# The 'seq' symbol shards the cache sequence axis over data (context
# parallel) and/or model (cache_seq_tp) per the active policy; KV heads are
# deliberately NOT model-sharded (n_kv < tp extent for every assigned GQA
# arch — head-sharding would force per-step cache all-gathers).
_CACHE_RULES: Tuple[Tuple[str, Tuple[Any, ...]], ...] = (
    (r"kv/(k|v)$", (None, "dp", "seq", None, None)),
    # PVQ-packed cache children (PackedKV flattens with DictKeys): the
    # pulse/scale planes are seq-indexed exactly like dense k/v; the
    # block-length tail ring is replicated along seq (it is one block).
    (r"kv/(k|v)_pulses$", (None, "dp", "seq", None, None)),
    (r"kv/(k|v)_scales$", (None, "dp", "seq", None, None)),
    (r"kv/tail_(k|v)$", (None, "dp", None, None, None)),
    # Paged slot-pool cache (PagedKV): the physical page pool is shared by
    # every slot — pages from different sequences interleave freely — so it
    # has no batch axis and must be replicated.  Slot-indexed children
    # (page table, write heads; the tail ring reuses the tail rule above)
    # shard their slot axis over data exactly like a batch axis.
    (r"kv/(k|v)_pages$", (None, None, None, None, None)),
    (r"kv/(k|v)_page_scales$", (None, None, None, None, None)),
    (r"kv/page_table$", (None, "dp", None)),
    (r"kv/write_page$", (None, "dp")),
    (r"cross/(k|v)$", (None, "dp", "seq", None, None)),
    (r"mla/c_kv$", (None, "dp", "seq", None)),
    (r"mla/k_rope$", (None, "dp", "seq", None)),
    (r"mamba/conv$", (None, "dp", None, "tp")),
    (r"mamba/ssm$", (None, "dp", "tp", None)),
    (r"rwkv_state$", (None, "dp", "tp", None, None)),
    (r"rwkv_shift_(att|ffn)$", (None, "dp", None)),
)


def cache_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh, policy: ShardingPolicy) -> P:
    for pat, spec in _CACHE_RULES:
        if re.search(pat, path):
            resolved = [_resolve(s, mesh, policy) for s in spec]
            if len(resolved) != len(shape):
                return P()
            final = []
            for dim, axes in zip(shape, resolved):
                if axes is None:
                    final.append(None)
                    continue
                ax_tuple = axes if isinstance(axes, tuple) else (axes,)
                extent = int(np.prod([mesh.shape[a] for a in ax_tuple]))
                final.append(axes if dim % extent == 0 else None)
            return P(*final)
    return P()


def cache_shardings(cache_shape: Any, mesh: Mesh, policy: ShardingPolicy):
    def one(path, leaf):
        spec = cache_pspec(_path_str(path), tuple(leaf.shape), mesh, policy)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)
