"""Pallas TPU kernels for the PVQ hot spots the paper optimizes with custom
CUDA: the fused dequant matmul and the batched encoder.

Callers should import :mod:`repro.kernels.ops` (backend + autotuned-tile
dispatch) rather than the kernel modules directly; :mod:`repro.kernels.ref`
holds the pure-jnp oracles and :mod:`repro.kernels.autotune` the persistent
tile-tuning cache.  See README.md in this package for the cache format.
"""

from . import autotune, ops, ref  # noqa: F401
