"""Public jit'd wrappers for the Pallas kernels.

On a CPU host (this container) kernels run with ``interpret=True`` — the
kernel body executes in Python on CPU, validating logic against ref.py; on a
TPU backend the same calls compile to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .pvq_encode import pvq_encode_batch as _encode_kernel
from .pvq_matmul import pvq_matmul as _matmul_kernel
from . import ref as ref_lib


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pvq_matmul(x, w_pulses, scales, *, group: int = 128, interpret: bool | None = None, **tiles):
    """Fused dequant matmul; see kernels.pvq_matmul for the tiling contract."""
    if interpret is None:
        interpret = not _on_tpu()
    return _matmul_kernel(x, w_pulses, scales, group=group, interpret=interpret, **tiles)


def pvq_encode(w, *, k_pulses: int, bg: int = 8, interpret: bool | None = None):
    """Batched exact greedy PVQ projection onto P(N, K)."""
    if interpret is None:
        interpret = not _on_tpu()
    return _encode_kernel(w, k_pulses=k_pulses, bg=bg, interpret=interpret)


# re-export oracles for test convenience
pvq_matmul_ref = ref_lib.pvq_matmul_ref
pvq_encode_ref = ref_lib.pvq_encode_ref
