"""Public jit'd dispatch layer for the Pallas kernels.

Every caller (nn layers, serve, QAT, grad-compress, checkpointer) goes through
this module rather than the kernel files, so backend selection, tile
autotuning, and the int8 pulse contract live in exactly one place:

* backend: on a CPU host (this container) kernels run with ``interpret=True``
  — the kernel body executes in Python on CPU, validating logic against
  ref.py; on a TPU backend the same calls compile to Mosaic.
* tiles: ``pvq_matmul`` consults the persistent autotune cache
  (``repro.kernels.autotune``); explicit tiles still win, and
  ``REPRO_PVQ_AUTOTUNE=1`` enables search-on-miss.
* dtypes: the encoder emits int32 pulses (the pyramid L1 bound can exceed
  int8 for extreme K/N); the matmul consumes int8.  :func:`pulses_to_int8`
  is the one sanctioned cast/clamp boundary, and
  :func:`encode_weight_matrix` produces matmul-ready (int8 pulses, scales)
  directly.
* packed artifacts: :func:`packed_matmul` streams a
  ``repro.core.packed.PackedPVQ`` (matmul layout) straight into the kernel —
  the int8 pulses and f32 scales go to VMEM as-is; no dequantized weight
  matrix ever exists.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import autotune as autotune_lib
from . import ref as ref_lib
from .pvq_encode import pvq_encode_batch as _encode_kernel
from .pvq_matmul import pvq_attn_q as _attn_kernel_q
from .pvq_matmul import pvq_matmul as _matmul_kernel
from .pvq_matmul import pvq_matmul_batched as _matmul_kernel_batched
from .pvq_matmul import pvq_matmul_q as _matmul_kernel_q
from .pvq_matmul import pvq_matmul_q_batched as _matmul_kernel_q_batched


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _quantize_x(x, act_quant, act_scale, group=None):
    """Resolve the ActQuant contract for a matmul entry point.

    Returns ``(x, act_scale)`` where either both are None-quantized (f32
    path) or ``x`` is int8 with f32 scales (v3 path): ``(..., 1)`` per-row,
    or ``(..., k//group)`` per-tile when ``act_quant.mode == "per_tile"`` —
    the tile width is the *weight* PVQ group, so each activation scale lines
    up with exactly one rho group in the kernel.  Per-tile therefore needs
    ``x`` already aligned to a group multiple (callers pad to k_pad first).
    ``act_scale is not None`` means the caller already quantized (the MoE
    dispatch buffer is quantized ONCE and its scales reused across the
    up/gate expert matmuls) — ``x`` must then be int8 already.
    """
    if act_scale is not None:
        if x.dtype != jnp.int8:
            raise ValueError(
                f"pre-quantized dispatch (act_scale given) needs int8 x, got {x.dtype}"
            )
        return x, jnp.asarray(act_scale, jnp.float32)
    if act_quant is None:
        return x, None
    from repro.core.quantize import quantize_activations

    if act_quant.mode == "per_tile":
        if group is None:
            raise ValueError("per_tile activation quantization needs the weight group")
        return quantize_activations(x, act_quant, tile=group)
    return quantize_activations(x, act_quant)


# ---------------------------------------------------------------------------
# dequant matmul
# ---------------------------------------------------------------------------


def pvq_matmul(
    x,
    w_pulses,
    scales,
    *,
    group: int = 128,
    bias=None,
    activation: str = "none",
    act_quant=None,
    act_scale=None,
    interpret: bool | None = None,
    tune: bool | None = None,
    **tiles,
):
    """Fused dequant matmul ``act(x @ (pulses * rho) + bias)``.

    Tile sizes come from (in priority order) explicit ``bm``/``bn``/``bk``
    kwargs, the persistent autotune cache, a timed search when ``tune=True``
    (or ``REPRO_PVQ_AUTOTUNE=1``), else the MXU heuristic.  Ragged shapes are
    padded internally; see kernels.pvq_matmul for the tiling contract.

    ``act_quant`` (a ``repro.core.quantize.ActQuant``) switches to kernel v3:
    x is quantized to symmetric int8 here and contracted int8 x int8 with an
    int32 MXU accumulator — no f32 activation tensor reaches the kernel.
    ``act_scale`` instead marks ``x`` as *already* quantized (int8) with the
    given per-row scales; tiles are then keyed on the int8 activation dtype.
    """
    if interpret is None:
        interpret = not _on_tpu()
    out_dtype = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
    x, act_scale = _quantize_x(x, act_quant, act_scale, group=group)
    if not tiles:
        m, k = x.shape
        n = w_pulses.shape[1]
        bm, bn, bk = autotune_lib.get_tiles(
            m, k, n, group=group, dtype=x.dtype, search=tune, interpret=interpret
        )
        tiles = {"bm": bm, "bn": bn, "bk": bk}
    if act_scale is not None:
        return _matmul_kernel_q(
            x,
            w_pulses,
            scales,
            act_scale,
            bias,
            group=group,
            activation=activation,
            out_dtype=out_dtype,
            interpret=interpret,
            **tiles,
        )
    return _matmul_kernel(
        x,
        w_pulses,
        scales,
        bias,
        group=group,
        activation=activation,
        interpret=interpret,
        **tiles,
    )


def packed_matmul(
    x,
    packed,
    *,
    bias=None,
    activation: str = "none",
    act_quant=None,
    act_scale=None,
    interpret: bool | None = None,
    tune: bool | None = None,
):
    """``act(x @ dequant(packed) + bias)`` on a matmul-layout ``PackedPVQ``
    without ever dequantizing: pulses/scales stream into the int8-native
    kernel and rho lands on the accumulator.

    ``x``: (m, d_in) with ``d_in <= packed.k_pad``; the group-padding columns
    are zero-filled here (zero lanes meet zero pulses — int8 zeros on the
    quantized-activation path).  ``act_quant``/``act_scale`` follow the
    :func:`pvq_matmul` contract (kernel v3, int8 x int8).
    """
    if packed.layout != "matmul":
        raise ValueError(f"packed_matmul needs layout='matmul', got {packed.layout!r}")
    if packed.pulses.ndim != 2:
        raise ValueError(
            f"packed_matmul takes one matrix; got stacked pulses {packed.pulses.shape} "
            "(slice the leading stack axis, e.g. inside lax.scan)"
        )
    k_pad = packed.pulses.shape[0]
    d_in = int(packed.shape[-2])
    if x.shape[-1] not in (d_in, k_pad):
        raise ValueError(
            f"x feature dim {x.shape[-1]} matches neither the packed leaf's "
            f"logical d_in {d_in} nor its padded k_pad {k_pad}"
        )
    if x.shape[-1] != k_pad:
        x = jnp.pad(x, ((0, 0), (0, k_pad - x.shape[-1])))
    return pvq_matmul(
        x,
        packed.pulses,
        packed.scales,
        group=packed.group,
        bias=bias,
        activation=activation,
        act_quant=act_quant,
        act_scale=act_scale,
        interpret=interpret,
        tune=tune,
    )


def packed_matmul_stacked(
    x,
    packed,
    *,
    activation: str = "none",
    act_quant=None,
    act_scale=None,
    interpret: bool | None = None,
    tune: bool | None = None,
):
    """Batched ``act(x[e] @ dequant(packed[e]))`` over an expert-stacked
    matmul-layout ``PackedPVQ`` — the MoE expert-bank contraction.

    ``x``: (E, m, d_in) per-expert dispatch buffers (``moe_forward`` folds
    its (g, E, C, d) buffer to this shape); ``packed.pulses``: (E, k_pad, n).
    Tile sizes are resolved ONCE from the shared per-expert (m, k_pad, n)
    problem through the persistent autotune cache, then every expert step
    of the scan reuses them — the int8 pulse planes stream into the kernel
    as stored, no dense expert tensor is ever materialized.

    ``act_quant`` quantizes the dispatch buffers here (per-row int8, kernel
    v3); ``act_scale`` (E, m, 1) marks ``x`` as already-quantized int8 —
    ``moe_forward`` quantizes its dispatch buffer ONCE and reuses the same
    int8 buffer + scales across the up AND gate expert matmuls.
    """
    if packed.layout != "matmul":
        raise ValueError(
            f"packed_matmul_stacked needs layout='matmul', got {packed.layout!r}"
        )
    if packed.pulses.ndim != 3:
        raise ValueError(
            f"packed_matmul_stacked takes one stacked expert bank; got pulses "
            f"{packed.pulses.shape} (expected (E, k_pad, n) — slice any extra "
            "leading scan axes first, e.g. inside lax.scan)"
        )
    e, k_pad, n = packed.pulses.shape
    if x.ndim != 3 or x.shape[0] != e:
        raise ValueError(
            f"x must be (E={e}, m, d_in) matching the expert axis, got {x.shape}"
        )
    if interpret is None:
        interpret = not _on_tpu()
    d_in = int(packed.shape[-2])
    if x.shape[-1] not in (d_in, k_pad):
        # only the structural group-padding columns may be zero-filled here;
        # any other width is a wrong buffer, not a padding request
        raise ValueError(
            f"x feature dim {x.shape[-1]} matches neither the packed bank's "
            f"logical d_in {d_in} nor its padded k_pad {k_pad}"
        )
    out_dtype = x.dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.float32
    # pad BEFORE quantizing so per-tile scale groups align with the weight
    # rho groups of the padded bank (zero lanes quantize to int8 zeros)
    if x.shape[-1] != k_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, k_pad - x.shape[-1])))
    x, act_scale = _quantize_x(x, act_quant, act_scale, group=packed.group)
    bm, bn, bk = autotune_lib.get_tiles(
        x.shape[1], k_pad, n, group=packed.group, dtype=x.dtype,
        search=tune, interpret=interpret,
    )
    if act_scale is not None:
        return _matmul_kernel_q_batched(
            x,
            packed.pulses,
            packed.scales,
            act_scale,
            group=packed.group,
            bm=bm,
            bn=bn,
            bk=bk,
            activation=activation,
            out_dtype=out_dtype,
            interpret=interpret,
        )
    return _matmul_kernel_batched(
        x,
        packed.pulses,
        packed.scales,
        group=packed.group,
        bm=bm,
        bn=bn,
        bk=bk,
        activation=activation,
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# attention decode over a packed KV cache (kernel v4)
# ---------------------------------------------------------------------------


def pvq_attn_decode(
    q,
    kv,
    kv_len,
    *,
    sm_scale: float,
    interpret: bool | None = None,
    tune: bool | None = None,
    bs: int | None = None,
):
    """Flash decode contraction of queries against a ``PackedKV``'s packed
    planes (kernel v4, ``pvq_attn_q``).

    ``q``: (b, q_len, n_heads, hd) float queries; ``kv``: a
    ``repro.core.packed.PackedKV`` — or a ``PagedKV`` slot-pool, which is
    gathered through its page table into the slot-major ``PackedKV`` view
    right here at the dispatch boundary (a fused paged kernel would consume
    the page table directly; until then the gather lives next to the kernel
    it feeds).  ``kv_len``: (b,) int32 count of *packed* positions valid per
    batch row (the caller clamps to ``min(packed_end(filled), length)`` —
    the f32 tail block is the caller's exact side leg, merged via
    logsumexp).

    Queries are quantized to per-row symmetric int8 here; the kernel
    contracts int8 q x int8 K pulses and int8 probs x int8 V pulses on the
    MXU with int32 accumulation, applying each rho once per group.  The
    grouped-query layout is preserved end to end: the packed planes stay at
    ``n_kv`` heads and the ``n_heads // n_kv`` query group rides the kernel's
    row axis — the cache is never expanded to ``n_heads``.

    Returns UNNORMALIZED ``(acc, m, l)`` shaped ``(b, q_len, n_kv, gpr, hd)``
    / ``(..., 1)`` / ``(..., 1)`` for the caller's online-softmax merge:
    ``out = (acc + exp(m_t - M) * acc_tail) / (l * exp(m - M) + ...)`` — see
    ``nn.attention``.  Rows with ``kv_len == 0`` come back with ``l == 0``
    (tail-only merge stays exact).
    """
    from repro.core.packed import is_paged_kv
    from repro.core.quantize import ActQuant, quantize_activations

    if is_paged_kv(kv):
        kv = kv.gather()
    if interpret is None:
        interpret = not _on_tpu()
    b, q_len, n_heads, hd = q.shape
    n_kv = kv.k_pulses.shape[2]
    if n_heads % n_kv:
        raise ValueError(f"n_heads {n_heads} not a multiple of n_kv {n_kv}")
    gpr = n_heads // n_kv
    m = q_len * gpr
    s = kv.k_pulses.shape[1]

    # (b, q_len, n_kv, gpr, hd) -> (b*n_kv, q_len*gpr, hd): each kernel row
    # block holds all query rows sharing one kv head
    qg = q.reshape(b, q_len, n_kv, gpr, hd).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(b * n_kv, m, hd)
    q_i8, a_scale = quantize_activations(qg, ActQuant(mode="per_row"))

    def to_bh(plane):  # (b, S, n_kv, X) -> (b*n_kv, S, X)
        return plane.transpose(0, 2, 1, 3).reshape(b * n_kv, s, plane.shape[-1])

    kv_len_bh = jnp.repeat(jnp.asarray(kv_len, jnp.int32), n_kv)
    if bs is None:
        bs = autotune_lib.get_attn_tiles(
            m, hd, s, group=kv.group, dtype=jnp.int8,
            search=tune, interpret=interpret,
        )
    acc, m_run, l_run = _attn_kernel_q(
        q_i8,
        a_scale,
        to_bh(kv.k_pulses),
        to_bh(kv.k_scales),
        to_bh(kv.v_pulses),
        to_bh(kv.v_scales),
        kv_len_bh,
        group=kv.group,
        sm_scale=sm_scale,
        bs=bs,
        interpret=interpret,
    )

    def from_bh(x):  # (b*n_kv, m, X) -> (b, q_len, n_kv, gpr, X)
        x = x.reshape(b, n_kv, q_len, gpr, x.shape[-1])
        return x.transpose(0, 2, 1, 3, 4)

    return from_bh(acc), from_bh(m_run), from_bh(l_run)


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def _encode_sort_impl(sort_impl: str | None) -> str:
    """``'argsort'`` (default) or ``'bisect'`` — the Mosaic fallback when a
    toolchain rejects in-kernel ``jnp.argsort``; overridable per-process via
    ``REPRO_PVQ_ENCODE_SORT=bisect``."""
    from .pvq_encode import default_sort_impl

    return sort_impl if sort_impl is not None else default_sort_impl()


def pvq_encode(
    w,
    *,
    k_pulses: int,
    bg: int | None = None,
    delta_max: int | None = None,
    interpret: bool | None = None,
    sort_impl: str | None = None,
):
    """Batched PVQ projection onto P(N, K) (sort-based, bounded correction).

    Returns (pulses i32 (g, n), rho_ls f32 (g,)).  ``delta_max >= k_pulses``
    reproduces the exact greedy search.  ``bg``/``delta_max`` default to the
    persistent autotune cache (tuned entries win; ``REPRO_PVQ_AUTOTUNE=1``
    enables search-on-miss; else the heuristic defaults) — explicit values
    always win, exactly like the matmul tile dispatch.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if bg is None or delta_max is None:
        tuned_bg, tuned_delta = autotune_lib.get_encode_params(
            w.shape[0], w.shape[1], k_pulses, dtype=w.dtype, interpret=interpret
        )
        bg = bg if bg is not None else tuned_bg
        delta_max = delta_max if delta_max is not None else tuned_delta
    return _encode_kernel(
        w,
        k_pulses=k_pulses,
        bg=bg,
        delta_max=delta_max,
        interpret=interpret,
        sort_impl=_encode_sort_impl(sort_impl),
    )


def pulses_to_int8(pulses: jax.Array) -> jax.Array:
    """The sanctioned int32 -> int8 pulse boundary for the matmul kernel.

    A P(N, K) coordinate is bounded by K, so K <= 127 is always lossless.
    For K > 127 a coordinate may legally exceed the int8 range and the clamp
    is lossy — callers that persist the clamped code MUST refit the scale
    against the clamped pulses (``core.packed`` does) so the stored artifact
    stays self-consistent; the clamp here just makes the boundary explicit
    rather than a silent overflow wrap.
    """
    return jnp.clip(pulses, -127, 127).astype(jnp.int8)


def encode_weight_matrix(
    w: jax.Array,  # (k, n) float weight matrix, k the contraction dim
    *,
    group: int = 128,
    k_pulses: int,
    bg: int | None = None,
    delta_max: int | None = None,
    interpret: bool | None = None,
):
    """Encode a dense weight matrix into matmul-kernel format.

    Each (group-slice, output-column) gets its own pyramid code: returns
    ``(pulses int8 (k_pad, n), scales f32 (k_pad//group, n), k_pad)`` where
    ``k_pad`` rounds k up to a group multiple (padded rows are zero weights
    and receive zero pulses).  Feed the result straight to :func:`pvq_matmul`
    with x zero-padded to ``k_pad`` columns (``pvq_dense`` in nn.layers does
    this for you).
    """
    k, n = w.shape
    pad = (-k) % group
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad, n), w.dtype)], axis=0)
    k_pad = k + pad
    # (k_pad, n) -> columns-major groups: (n * k_pad/group, group)
    wg = w.T.reshape(n * (k_pad // group), group)
    pulses, rho = pvq_encode(
        wg, k_pulses=k_pulses, bg=bg, delta_max=delta_max, interpret=interpret
    )
    pulses = pulses_to_int8(pulses)
    pulses = jnp.transpose(
        pulses.reshape(n, k_pad // group, group), (1, 2, 0)
    ).reshape(k_pad, n)
    scales = rho.reshape(n, k_pad // group).T.astype(jnp.float32)
    return pulses, scales, k_pad


def pvq_encode_grouped_fast(
    flat: jax.Array,
    group: int,
    k: int,
    delta_max: int | None = None,
    scale_mode: str = "ls",
):
    """Grouped encode of a flat vector on the fast sorted path.

    Dispatches to the Pallas kernel on TPU and the jnp sorted encoder
    elsewhere (interpret-mode Pallas is a correctness proxy, not a fast path
    on CPU).  Returns (pulses i32 (G, group), rho f32 (G,)); trailing
    zero-padding never receives pulses.  The kernel natively emits the ``ls``
    scale; other scale modes are recomputed from the pulses.
    ``delta_max=None`` resolves through the encoder autotune cache (both
    backends use the resolved value, so results agree across them).
    """
    from repro.core.pvq import _scales, pvq_quantize_direction_fast

    n = flat.shape[0]
    pad = (-n) % group
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    wg = flat.reshape(-1, group)
    if delta_max is None:
        _, delta_max = autotune_lib.get_encode_params(
            wg.shape[0], group, k, dtype=wg.dtype, interpret=not _on_tpu()
        )
    if _on_tpu():
        pulses, rho = pvq_encode(wg, k_pulses=k, delta_max=delta_max)
        if scale_mode != "ls":
            rho = _scales(wg, pulses, scale_mode)
        return pulses, rho
    pulses = pvq_quantize_direction_fast(wg, k, delta_max=delta_max)
    return pulses, _scales(wg, pulses, scale_mode)


# re-export oracles for test convenience
pvq_matmul_ref = ref_lib.pvq_matmul_ref
pvq_encode_ref = ref_lib.pvq_encode_ref
