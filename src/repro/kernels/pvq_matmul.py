"""Fused PVQ dequant-matmul Pallas TPU kernel.

Computes ``y = x @ (w_pulses * rho)`` where ``w_pulses`` is the int8 PVQ
pulse tensor (K-sparse per group, |pulse| small) and ``rho`` holds one f32
scale per (contraction-group, output-column).  This is the TPU-native form of
the paper's "K-1 adds + ONE multiplication" dot product: the integer pulse
matrix streams from HBM at 1 byte/weight (2-4x less than bf16/f32 — the win
for weight-memory-bound decode/MoE ops), is dequantized in VMEM, and the
single rho multiply is fused per group before the MXU contraction.

Tiling: grid (m/bm, n/bn, k/bk); x tile (bm,bk) VMEM, w tile (bk,bn) int8
VMEM, rho tile (bk/group, bn) f32 VMEM, f32 accumulator scratch (bm,bn).
MXU-aligned defaults bm=bn=bk=128 (group must divide bk).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, group: int, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bm, bk)
    w = w_ref[...]  # (bk, bn) int8
    s = s_ref[...]  # (bk // group, bn) f32
    bk, bn = w.shape
    # dequantize in VMEM: per-group rho applied to the pulse block
    w_f = w.astype(jnp.float32).reshape(bk // group, group, bn) * s[:, None, :]
    w_f = w_f.reshape(bk, bn).astype(x.dtype)
    acc_ref[...] += jax.lax.dot_general(
        x, w_f, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "bm", "bn", "bk", "interpret"))
def pvq_matmul(
    x: jax.Array,  # (m, k)
    w_pulses: jax.Array,  # (k, n) int8
    scales: jax.Array,  # (k // group, n) f32
    *,
    group: int = 128,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w_pulses.shape
    assert k == k2 and k % group == 0
    assert scales.shape == (k // group, n), (scales.shape, (k // group, n))
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert bk % group == 0, "group must divide the k-tile"
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_kernel, group=group, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(x, w_pulses, scales)
