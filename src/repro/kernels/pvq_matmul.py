"""Fused PVQ int8-native matmul Pallas TPU kernel.

Computes ``y = act(x @ (w_pulses * rho) + bias)`` where ``w_pulses`` is the
int8 PVQ pulse tensor (K-sparse per group, |pulse| small) and ``rho`` holds
one f32 scale per (contraction-group, output-column).  This is the TPU-native
form of the paper's "K-1 adds + ONE multiplication" dot product: the integer
pulse matrix streams from HBM at 1 byte/weight (2-4x less than bf16/f32 — the
win for weight-memory-bound decode/MoE ops) and feeds the MXU *as integers*.

Int8-native contraction (kernel v2): the old body materialized a dequantized
``(bk, bn)`` f32/bf16 weight tile in VMEM (``w * rho`` expanded per element)
before a single big dot.  The v2 body never builds that tile — it contracts
each ``(bm, group) x (group, bn)`` slice with the raw int8 pulses (the cast
to the MXU input dtype fuses into the matmul feed; on v5e+ the MXU consumes
int8 directly) and applies rho to the ``(bm, bn)`` f32 *accumulator*, i.e.
ONE multiply per group exactly as the paper counts it.  VMEM traffic per
tile drops by the dequantized-weight materialization (4 bytes/weight).

Quantized-activation body (kernel v3): :func:`pvq_matmul_q` takes the
activations *already quantized* to symmetric int8 (per-row scales — the
``ActQuant`` contract in ``repro.core.quantize``) and contracts int8 x tiles
against int8 pulse tiles with ``preferred_element_type=int32`` — the MXU
accumulates in int32, the paper's fully integer dot.  The group's rho then
multiplies the int32 group partial once (ONE multiply per group, unchanged
from v2) and the per-row activation scale is applied once per output element
in the epilogue (amortized over all k-groups, not per group).  No f32
activation tensor is ever fed to the MXU on this path.

Double-buffered DMA pulse streaming: for big-FFN tiles (large ``bk * bn``)
the v3 path hand-rolls the HBM->VMEM pulse transfer with
``pltpu.make_async_copy`` into a 2-deep int8 scratch — the next (bk, bn)
pulse tile lands while the MXU chews the current one.  Small tiles keep the
automatic Pallas pipeline (grid over k), which already double-buffers block
operands.

Epilogue fusion: an optional bias add and activation run inside the final
``@pl.when`` store, so a quantized dense layer costs one HBM round-trip for
the output instead of three (matmul out + bias + act).

Tiling: grid (m/bm, n/bn, k/bk); x tile (bm,bk) VMEM, w tile (bk,bn) int8
VMEM, rho tile (bk/group, bn) f32 VMEM, f32 accumulator scratch (bm,bn).
MXU-aligned defaults bm=bn=bk=128 (group must divide bk).  Non-tile-divisible
("ragged") shapes are zero-padded up to the tile grid and the output sliced
back — no caller-visible shape constraints beyond ``k % group == 0``.  Tile
sizes are normally chosen by ``repro.kernels.autotune`` via ``kernels.ops``.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

#: bumped whenever the kernel body changes materially; feeds the autotune
#: cache key so stale tile timings from an older body never win dispatch.
KERNEL_VERSION = 4  # v4: pvq_attn_q flash decode + per-tile act scales (v3: int8 x int8 matmul body)

ACTIVATIONS = ("none", "relu", "relu2", "gelu", "silu")


def _apply_activation(y: jax.Array, activation: str) -> jax.Array:
    if activation == "none":
        return y
    if activation == "relu":
        return jax.nn.relu(y)
    if activation == "relu2":
        r = jax.nn.relu(y)
        return r * r
    if activation == "gelu":
        return jax.nn.gelu(y, approximate=True)
    if activation == "silu":
        return jax.nn.silu(y)
    raise ValueError(f"unknown activation {activation!r}; expected one of {ACTIVATIONS}")


#: beyond this many groups per k-tile the unrolled per-group dot chain costs
#: more than the dequantized-tile materialization it avoids (and bloats the
#: interpret-mode proxy); fall back to the v1 dequant-in-VMEM body there.
_MAX_UNROLL_GROUPS = 8


def _accumulate_int8(x, w, s, group: int, acc_ref) -> None:
    """Int8-native tile contraction: per group-slice, contract the raw int8
    pulses against x (the dtype convert fuses into the MXU feed — no
    dequantized (bk, bn) weight tile is ever materialized in VMEM) and apply
    the group's rho row to the f32 accumulator: ONE multiply per group."""
    bk, bn = w.shape
    n_groups = bk // group
    if n_groups > _MAX_UNROLL_GROUPS:
        # v1 fallback: one big dot on a dequantized tile — bounded unroll
        w_f = w.astype(jnp.float32).reshape(n_groups, group, bn) * s[:, None, :]
        acc_ref[...] += jax.lax.dot_general(
            x, w_f.reshape(bk, bn).astype(x.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return
    for g in range(n_groups):
        xg = x[:, g * group : (g + 1) * group]  # (bm, group)
        wg = w[g * group : (g + 1) * group, :]  # (group, bn) int8
        part = jax.lax.dot_general(
            xg, wg.astype(x.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] += part * s[g, :][None, :]


def _kernel(
    x_ref, w_ref, s_ref, o_ref, acc_ref, *, group: int, n_k: int, activation: str
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # x (bm, bk) / w (bk, bn) int8 / s (bk // group, bn) f32
    _accumulate_int8(x_ref[...], w_ref[...], s_ref[...], group, acc_ref)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = _apply_activation(acc_ref[...], activation).astype(o_ref.dtype)


def _kernel_bias(
    x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref, *, group: int, n_k: int, activation: str
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate_int8(x_ref[...], w_ref[...], s_ref[...], group, acc_ref)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)  # (bm,bn) + (1,bn)
        o_ref[...] = _apply_activation(y, activation).astype(o_ref.dtype)


def normalize_tiles(
    m: int, k: int, n: int, group: int, bm: int, bn: int, bk: int
) -> tuple[int, int, int]:
    """Clamp and align a tile request to the (m,k,n,group) problem.

    bk is rounded to a multiple of ``group`` (the dequant reshape needs it);
    all tiles are clamped to the padded problem extent.  Any remainder is
    handled by zero-padding in :func:`pvq_matmul`, not by the caller.
    """
    def _round_up(v: int, mult: int) -> int:
        return -(-v // mult) * mult

    # sublane (8) / lane (128) alignment: never tile wider than the padded
    # problem, never narrower than one aligned vector register row
    bm = max(min(bm, _round_up(m, 8)), 1)
    bn = max(min(bn, _round_up(n, 128)), min(n, 128))
    bk = max(min(bk, k), 1)
    # bk must be a group multiple for the (bk//group, group, bn) dequant view
    if bk % group:
        bk = max((bk // group) * group, min(group, k))
    return bm, bn, bk


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("group", "bm", "bn", "bk", "activation", "interpret"),
)
def pvq_matmul(
    x: jax.Array,  # (m, k)
    w_pulses: jax.Array,  # (k, n) int8
    scales: jax.Array,  # (k // group, n) f32
    bias: jax.Array | None = None,  # (n,) optional fused epilogue bias
    *,
    group: int = 128,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    activation: str = "none",
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w_pulses.shape
    assert k == k2, (k, k2)
    assert k % group == 0, f"contraction dim {k} must be a group ({group}) multiple"
    assert scales.shape == (k // group, n), (scales.shape, (k // group, n))
    assert activation in ACTIVATIONS, f"activation {activation!r} not in {ACTIVATIONS}"
    if bias is not None:
        assert bias.shape == (n,), (bias.shape, n)

    bm, bn, bk = normalize_tiles(m, k, n, group, bm, bn, bk)

    # Ragged shapes: zero-pad up to the tile grid, slice the output back.
    # Zero x-columns / zero pulse-rows contribute nothing to the contraction,
    # and padded n-columns are dead lanes sliced off below.
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_pulses, 0, bk), 1, bn)
    sp = _pad_to(_pad_to(scales, 0, bk // group), 1, bn)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    n_k = kp // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [xp, wp, sp]
    if bias is None:
        kernel = functools.partial(_kernel, group=group, n_k=n_k, activation=activation)
    else:
        kernel = functools.partial(
            _kernel_bias, group=group, n_k=n_k, activation=activation
        )
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(_pad_to(bias.astype(jnp.float32)[None, :], 1, bn))

    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(*operands)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@functools.partial(
    jax.jit,
    static_argnames=("group", "bm", "bn", "bk", "activation", "interpret"),
)
def pvq_matmul_batched(
    x: jax.Array,  # (B, m, k)
    w_pulses: jax.Array,  # (B, k, n) int8
    scales: jax.Array,  # (B, k // group, n) f32
    *,
    group: int = 128,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    activation: str = "none",
    interpret: bool = False,
) -> jax.Array:
    """Batched fused matmul over a shared leading stack axis (MoE experts).

    ``lax.scan`` over the batch axis invokes the 2-D kernel once per slice
    with ONE shared tile configuration — the kernel body is traced/compiled
    a single time regardless of the expert count, and every expert's
    ``(m, k) x (k, n)`` problem reuses the same (bm, bn, bk) tiles (callers
    key the autotune lookup on the per-expert shape).  Per-expert bias has
    no consumer (MoE expert FFNs are bias-free); activation still fuses
    into each slice's epilogue.
    """
    assert x.ndim == 3 and w_pulses.ndim == 3 and scales.ndim == 3, (
        x.shape, w_pulses.shape, scales.shape,
    )
    assert x.shape[0] == w_pulses.shape[0] == scales.shape[0], (
        x.shape, w_pulses.shape, scales.shape,
    )

    def body(_, operands):
        xb, wb, sb = operands
        y = pvq_matmul(
            xb, wb, sb, None, group=group, bm=bm, bn=bn, bk=bk,
            activation=activation, interpret=interpret,
        )
        return None, y

    _, out = jax.lax.scan(body, None, (x, w_pulses, scales))
    return out


# ---------------------------------------------------------------------------
# Kernel v3: quantized activations — int8 x int8, int32 MXU accumulation
# ---------------------------------------------------------------------------


def _contract_int8_q(x, w, s, group: int, a_tile=None) -> jax.Array:
    """Fully integer tile contraction: per group-slice, one int8 x int8 dot
    with ``preferred_element_type=int32`` (the MXU accumulates in int32),
    then the group's rho row multiplies the int32 partial once — ONE
    multiply per group, now with integer feeds on BOTH operands.

    ``a_tile`` (bm, bk // group), if given, carries per-tile activation
    scales (``ActQuant(granularity="tile")``): group g's partial is scaled
    by ``rho_g * a_tile[:, g]`` — still one rho multiply plus one act-scale
    multiply per group partial, and the epilogue then skips its per-row
    multiply.

    Returns the f32 (bm, bn) partial sum for this (bk, bn) tile.  Beyond
    ``_MAX_UNROLL_GROUPS`` the per-group dots run as one batched
    ``dot_general`` over the group axis instead of an unrolled chain —
    still int8 x int8 / int32, never a dequantized operand.
    """
    bk, bn = w.shape
    bm = x.shape[0]
    n_groups = bk // group
    if n_groups > _MAX_UNROLL_GROUPS:
        xg = jnp.swapaxes(x.reshape(bm, n_groups, group), 0, 1)  # (G, bm, group)
        wg = w.reshape(n_groups, group, bn)
        part = jax.lax.dot_general(
            xg, wg, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )  # (G, bm, bn) int32
        part = part.astype(jnp.float32) * s[:, None, :]
        if a_tile is not None:
            part = part * jnp.swapaxes(a_tile, 0, 1)[:, :, None]  # (G, bm, 1)
        return jnp.sum(part, axis=0)
    acc = jnp.zeros((bm, bn), jnp.float32)
    for g in range(n_groups):
        xg = x[:, g * group : (g + 1) * group]  # (bm, group) int8
        wg = w[g * group : (g + 1) * group, :]  # (group, bn) int8
        part = jax.lax.dot_general(
            xg, wg, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        part = part.astype(jnp.float32) * s[g, :][None, :]
        if a_tile is not None:
            part = part * a_tile[:, g : g + 1]
        acc = acc + part
    return acc


def _q_epilogue(acc, a, bias, activation: str) -> jax.Array:
    """v3 epilogue: the per-row activation scale multiplies the accumulated
    (rho-weighted) integer sums ONCE per output element, then bias + act.
    ``a=None`` when the scale was already applied per tile in the body."""
    y = acc if a is None else acc * a  # (bm, bn) * (bm, 1)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return _apply_activation(y, activation)


def _kernel_q(
    x_ref, w_ref, s_ref, a_ref, o_ref, acc_ref, *, group: int, n_k: int,
    activation: str, per_tile: bool = False,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # x (bm, bk) int8 / w (bk, bn) int8 / s (bk//group, bn) f32
    # a (bm, 1) f32 per-row | (bm, bk//group) f32 per-tile
    acc_ref[...] += _contract_int8_q(
        x_ref[...], w_ref[...], s_ref[...], group,
        a_tile=a_ref[...] if per_tile else None,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = _q_epilogue(
            acc_ref[...], None if per_tile else a_ref[...], None, activation
        ).astype(o_ref.dtype)


def _kernel_q_bias(
    x_ref, w_ref, s_ref, a_ref, b_ref, o_ref, acc_ref, *, group: int, n_k: int,
    activation: str, per_tile: bool = False,
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _contract_int8_q(
        x_ref[...], w_ref[...], s_ref[...], group,
        a_tile=a_ref[...] if per_tile else None,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = _q_epilogue(
            acc_ref[...], None if per_tile else a_ref[...], b_ref[...], activation
        ).astype(o_ref.dtype)


#: hand-rolled DMA streaming pays off when one (bk, bn) pulse tile is big
#: enough that its HBM->VMEM transfer rivals the MXU time (big-FFN shapes);
#: below this the automatic k-grid pipeline is already optimal.
_DMA_MIN_TILE_ELEMS = 64 * 1024
#: the DMA body holds the full (bm, k_pad) int8 x row-block in VMEM — cap it
#: (plus 2 pulse-tile slots) well under the per-core budget.
_DMA_MAX_X_BYTES = 4 * 1024 * 1024


def _dma_streaming_wanted(
    mp: int, kp: int, np_: int, bm: int, bn: int, bk: int
) -> bool:
    if os.environ.get("REPRO_PVQ_DMA", "") in ("0", "off", "false"):
        return False
    n_chunks = kp // bk
    return (
        n_chunks >= 2  # something to overlap
        and bk * bn >= _DMA_MIN_TILE_ELEMS  # transfer worth hiding
        and bm * kp <= _DMA_MAX_X_BYTES  # whole x row-block fits VMEM
    )


def _kernel_q_dma(
    x_ref, w_hbm_ref, s_ref, a_ref, b_ref, o_ref, wbuf, sems, *, group: int,
    bk: int, n_chunks: int, activation: str, has_bias: bool,
):
    """v3 body with hand-rolled double-buffered pulse streaming.

    Grid is (m/bm, n/bn) — no k grid dimension.  The int8 pulse operand
    stays in HBM (``memory_space=ANY``); the kernel walks the contraction
    dim in ``bk`` chunks, DMA-ing chunk ``i+1`` into one slot of a 2-deep
    VMEM scratch while the MXU contracts chunk ``i`` from the other
    (``pltpu.make_async_copy`` + per-slot DMA semaphores).  x / scales /
    act-scale row blocks are small and ride the automatic pipeline.
    """
    bn = o_ref.shape[1]
    col0 = pl.program_id(1) * bn
    gpc = bk // group  # scale rows per chunk

    def _dma(slot, idx):
        return pltpu.make_async_copy(
            w_hbm_ref.at[pl.ds(idx * bk, bk), pl.ds(col0, bn)],
            wbuf.at[slot],
            sems.at[slot],
        )

    _dma(0, 0).start()
    x = x_ref[...]  # (bm, k_pad) int8
    s = s_ref[...]  # (k_pad // group, bn) f32

    def body(idx, acc):
        slot = idx % 2

        @pl.when(idx + 1 < n_chunks)
        def _prefetch():
            _dma((idx + 1) % 2, idx + 1).start()

        _dma(slot, idx).wait()
        xc = jax.lax.dynamic_slice(x, (0, idx * bk), (x.shape[0], bk))
        sc = jax.lax.dynamic_slice(s, (idx * gpc, 0), (gpc, bn))
        return acc + _contract_int8_q(xc, wbuf[slot], sc, group)

    acc = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros(o_ref.shape, jnp.float32)
    )
    bias = b_ref[...] if has_bias else None
    o_ref[...] = _q_epilogue(acc, a_ref[...], bias, activation).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "bm", "bn", "bk", "activation", "out_dtype", "dma_streaming",
        "interpret",
    ),
)
def pvq_matmul_q(
    x_q: jax.Array,  # (m, k) int8 quantized activations
    w_pulses: jax.Array,  # (k, n) int8
    scales: jax.Array,  # (k // group, n) f32
    act_scale: jax.Array,  # (m, 1) / (1, 1) per-row | (m, k//group) per-tile f32
    bias: jax.Array | None = None,  # (n,) optional fused epilogue bias
    *,
    group: int = 128,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    activation: str = "none",
    out_dtype=jnp.float32,
    dma_streaming: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Kernel v3: fused quantized-activation matmul
    ``act(act_scale * (x_q @int32 (pulses * rho)) + bias)``.

    Both MXU operands are int8 and the per-group dot accumulates in int32
    (``preferred_element_type=int32``); rho multiplies each int32 group
    partial once.  A ``(m, 1)`` per-row ``act_scale`` multiplies the final
    accumulator once in the epilogue; a ``(m, k // group)`` per-tile scale
    (``ActQuant(granularity="tile")`` with the tile = the weight group)
    instead multiplies each group's int32 partial alongside rho — one extra
    scalar multiply per group, no per-element work.  ``dma_streaming=None``
    auto-selects the hand-rolled double-buffered HBM->VMEM pulse path for
    big tiles and the automatic k-grid pipeline otherwise (per-tile scales
    always use the k-grid pipeline); True/False force it.
    """
    m, k = x_q.shape
    k2, n = w_pulses.shape
    assert k == k2, (k, k2)
    assert k % group == 0, f"contraction dim {k} must be a group ({group}) multiple"
    assert x_q.dtype == jnp.int8, f"x_q must be pre-quantized int8, got {x_q.dtype}"
    assert w_pulses.dtype == jnp.int8, w_pulses.dtype
    assert scales.shape == (k // group, n), (scales.shape, (k // group, n))
    per_tile = act_scale.shape == (m, k // group) and k > group
    assert per_tile or act_scale.shape in ((m, 1), (1, 1)), (act_scale.shape, m)
    assert activation in ACTIVATIONS, f"activation {activation!r} not in {ACTIVATIONS}"
    if bias is not None:
        assert bias.shape == (n,), (bias.shape, n)

    bm, bn, bk = normalize_tiles(m, k, n, group, bm, bn, bk)

    xp = _pad_to(_pad_to(x_q, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_pulses, 0, bk), 1, bn)
    sp = _pad_to(_pad_to(scales, 0, bk // group), 1, bn)
    if per_tile:
        # padded k-groups carry zero pulses; their (zero-padded) scales are
        # inert, so the tile grid sees a consistent (mp, kp//group) matrix
        ap = _pad_to(
            _pad_to(act_scale.astype(jnp.float32), 0, bm), 1, bk // group
        )
    else:
        ap = _pad_to(
            jnp.broadcast_to(act_scale.astype(jnp.float32), (m, 1)), 0, bm
        )
    mp, kp = xp.shape
    np_ = wp.shape[1]
    n_k = kp // bk

    if dma_streaming is None:
        dma_streaming = _dma_streaming_wanted(mp, kp, np_, bm, bn, bk)
    if per_tile:
        dma_streaming = False  # the DMA body only threads the per-row scale
    if dma_streaming and kp // bk >= 2:
        kernel = functools.partial(
            _kernel_q_dma, group=group, bk=bk, n_chunks=n_k,
            activation=activation, has_bias=bias is not None,
        )
        in_specs = [
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # pulses stay in HBM
            pl.BlockSpec((kp // group, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ]
        operands = [xp, wp, sp, ap]
        if bias is not None:
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
            operands.append(_pad_to(bias.astype(jnp.float32)[None, :], 1, bn))
        else:
            # keep the kernel arity fixed: a dead (1, bn) zero bias block
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
            operands.append(jnp.zeros((1, np_), jnp.float32))
        out = pl.pallas_call(
            kernel,
            grid=(mp // bm, np_ // bn),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.dtype(out_dtype)),
            scratch_shapes=[
                pltpu.VMEM((2, bk, bn), jnp.int8),
                pltpu.SemaphoreType.DMA((2,)),
            ],
            interpret=interpret,
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel")
            ),
        )(*operands)
        if (mp, np_) != (m, n):
            out = out[:m, :n]
        return out

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk // group, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bm, bk // group), lambda i, j, kk: (i, kk))
        if per_tile
        else pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
    ]
    operands = [xp, wp, sp, ap]
    if bias is None:
        kernel = functools.partial(
            _kernel_q, group=group, n_k=n_k, activation=activation,
            per_tile=per_tile,
        )
    else:
        kernel = functools.partial(
            _kernel_q_bias, group=group, n_k=n_k, activation=activation,
            per_tile=per_tile,
        )
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(_pad_to(bias.astype(jnp.float32)[None, :], 1, bn))

    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.dtype(out_dtype)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(*operands)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@functools.partial(
    jax.jit,
    static_argnames=(
        "group", "bm", "bn", "bk", "activation", "out_dtype", "interpret"
    ),
)
def pvq_matmul_q_batched(
    x_q: jax.Array,  # (B, m, k) int8
    w_pulses: jax.Array,  # (B, k, n) int8
    scales: jax.Array,  # (B, k // group, n) f32
    act_scale: jax.Array,  # (B, m, 1) f32
    *,
    group: int = 128,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    activation: str = "none",
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Batched kernel v3 over a shared leading stack axis (MoE experts):
    ``lax.scan`` of :func:`pvq_matmul_q` with ONE shared tile config, one
    compiled body regardless of the expert count — the quantized dispatch
    buffer's per-expert scales ride the scan alongside the pulse planes."""
    assert x_q.ndim == 3 and w_pulses.ndim == 3 and scales.ndim == 3, (
        x_q.shape, w_pulses.shape, scales.shape,
    )
    assert act_scale.ndim == 3 and act_scale.shape[0] == x_q.shape[0], (
        act_scale.shape, x_q.shape,
    )
    assert x_q.shape[0] == w_pulses.shape[0] == scales.shape[0], (
        x_q.shape, w_pulses.shape, scales.shape,
    )

    def body(_, operands):
        xb, wb, sb, ab = operands
        y = pvq_matmul_q(
            xb, wb, sb, ab, None, group=group, bm=bm, bn=bn, bk=bk,
            activation=activation, out_dtype=out_dtype, interpret=interpret,
        )
        return None, y

    _, out = jax.lax.scan(body, None, (x_q, w_pulses, scales, act_scale))
    return out


# ---------------------------------------------------------------------------
# Kernel v4: pvq_attn_q — flash attention decode over the packed KV cache
# ---------------------------------------------------------------------------

#: finite mask value (matches nn.attention.NEG_INF).  Finite on purpose:
#: a fully-masked seq block merges out with weight exp(-1e30 - m) == 0
#: instead of the NaNs that -inf arithmetic would produce.
_ATTN_NEG_INF = -1e30


def _attn_kernel_q(
    q_ref,  # (1, m, hd) int8 quantized queries (m = heads per kv head)
    a_ref,  # (1, m, 1) f32 per-row query scales
    kp_ref,  # (1, bs, hd) int8 packed K pulses
    ks_ref,  # (1, bs, ng) f32 per-group K rho
    vp_ref,  # (1, bs, hd) int8 packed V pulses
    vs_ref,  # (1, bs, ng) f32 per-group V rho
    len_ref,  # (1, 1) int32 valid kv length for this (batch, kv-head)
    o_ref,  # (1, m, hd) f32 out: UNNORMALIZED output accumulator
    mo_ref,  # (1, m, 1) f32 out: running row max
    lo_ref,  # (1, m, 1) f32 out: running softmax denominator
    acc_ref, m_ref, l_ref,  # scratch: (m, hd) f32, (m, 1) f32, (m, 1) f32
    *, group: int, n_s: int, sm_scale: float,
):
    """One (batch x kv-head, seq-block) step of the packed flash decode.

    Scores: per sub-head group, int8 query x int8 K-pulse ``dot_general``
    with int32 MXU accumulation; the group's K rho multiplies the int32
    partial once, the per-row query scale and softmax scale apply once per
    score.  Online softmax keeps running (max, denom) per row.  Output: V's
    rho folds into the probabilities per group (one multiply per group),
    the scaled probs requantize to int8 on a per-row dynamic scale, and a
    second int8 x int8 / int32 dot accumulates the output.  The caller
    merges (acc, m, l) with the exact-f32 tail block via logsumexp.
    """
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _ATTN_NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    bs, hd = kp_ref.shape[1], kp_ref.shape[2]
    ng = hd // group
    kv_len = len_ref[0, 0]
    m_rows = q_ref.shape[1]
    cols = si * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = cols < kv_len  # (1, bs)

    q = q_ref[0]  # (m, hd) int8
    scores = jnp.zeros((m_rows, bs), jnp.float32)
    for g in range(ng):
        qg = q[:, g * group : (g + 1) * group]  # (m, group) int8
        kg = kp_ref[0, :, g * group : (g + 1) * group]  # (bs, group) int8
        part = jax.lax.dot_general(
            qg, kg, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (m, bs) int32
        scores = scores + part.astype(jnp.float32) * ks_ref[0, :, g][None, :]
    scores = scores * a_ref[0] * sm_scale
    scores = jnp.where(valid, scores, _ATTN_NEG_INF)

    m_prev = m_ref[...]  # (m, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    # NEG_INF is finite: exp(scores - m_new) on an all-masked block would be
    # exp(0) = 1 — zero masked probabilities through the mask, never the value
    p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)  # (m, bs)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    m_ref[...] = m_new

    outs = []
    for g in range(ng):
        pg = p * vs_ref[0, :, g][None, :]  # V rho folded: ONE multiply per group
        pmax = jnp.max(jnp.abs(pg), axis=-1, keepdims=True)
        s_p = pmax / 127.0
        inv = jnp.where(s_p > 0, 1.0 / jnp.maximum(s_p, 1e-30), 0.0)
        pq = jnp.clip(jnp.round(pg * inv), -127, 127).astype(jnp.int8)
        vg = vp_ref[0, :, g * group : (g + 1) * group]  # (bs, group) int8
        out_g = jax.lax.dot_general(
            pq, vg, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )  # (m, group) int32
        outs.append(out_g.astype(jnp.float32) * s_p)
    acc_ref[...] = acc_ref[...] * alpha + (
        outs[0] if ng == 1 else jnp.concatenate(outs, axis=-1)
    )

    @pl.when(si == n_s - 1)
    def _done():
        o_ref[0] = acc_ref[...]
        mo_ref[0] = m_ref[...]
        lo_ref[0] = l_ref[...]


@functools.partial(
    jax.jit, static_argnames=("group", "sm_scale", "bs", "interpret")
)
def pvq_attn_q(
    q_i8: jax.Array,  # (BH, m, hd) int8 — BH = batch * n_kv, m = q heads / kv head
    act_scale: jax.Array,  # (BH, m, 1) f32 per-row query scales
    k_pulses: jax.Array,  # (BH, S, hd) int8
    k_scales: jax.Array,  # (BH, S, ng) f32
    v_pulses: jax.Array,  # (BH, S, hd) int8
    v_scales: jax.Array,  # (BH, S, ng) f32
    kv_len: jax.Array,  # (BH,) int32 — packed positions valid per (batch, kv-head)
    *,
    group: int,
    sm_scale: float,
    bs: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel v4: packed-KV flash attention decode contraction.

    Grid ``(BH, S/bs)``: each (batch x kv-head) row walks its sequence in
    ``bs``-token blocks with an online softmax.  Returns the UNNORMALIZED
    triple ``(acc (BH, m, hd) f32, m (BH, m, 1), l (BH, m, 1))`` so the
    caller can logsumexp-merge the exact-f32 tail block (the in-flight
    partial cache block lives outside the pulse planes):

        m_tot = max(m_packed, m_tail)
        out = (acc_p * e^(m_p - m_tot) + acc_t * e^(m_t - m_tot))
              / (l_p * e^(m_p - m_tot) + l_t * e^(m_t - m_tot))

    ``kv_len`` rows may be 0 (nothing packed yet): every block masks out,
    l = 0 and m = -1e30, and the merge reduces to the tail alone.
    """
    bh, m, hd = q_i8.shape
    s = k_pulses.shape[1]
    ng = hd // group
    assert hd % group == 0, (hd, group)
    assert q_i8.dtype == jnp.int8 and k_pulses.dtype == jnp.int8
    assert v_pulses.dtype == jnp.int8
    assert k_scales.shape == (bh, s, ng), (k_scales.shape, (bh, s, ng))
    assert v_scales.shape == (bh, s, ng)
    assert act_scale.shape == (bh, m, 1), (act_scale.shape, (bh, m, 1))
    assert kv_len.shape == (bh,), kv_len.shape

    bs = max(min(bs, -(-s // 128) * 128), 128) if s > 128 else max(s, 8)
    mp = -(-m // 8) * 8  # sublane-align the tiny head-group row count

    qp = _pad_to(q_i8, 1, mp)
    ap = _pad_to(act_scale.astype(jnp.float32), 1, mp)
    kpp = _pad_to(k_pulses, 1, bs)
    ksp = _pad_to(k_scales.astype(jnp.float32), 1, bs)
    vpp = _pad_to(v_pulses, 1, bs)
    vsp = _pad_to(v_scales.astype(jnp.float32), 1, bs)
    sp = kpp.shape[1]
    n_s = sp // bs

    kernel = functools.partial(
        _attn_kernel_q, group=group, n_s=n_s, sm_scale=float(sm_scale)
    )
    acc, m_run, l_run = pl.pallas_call(
        kernel,
        grid=(bh, n_s),
        in_specs=[
            pl.BlockSpec((1, mp, hd), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((1, mp, 1), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, bs, ng), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, bs, hd), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, bs, ng), lambda b, si: (b, si, 0)),
            pl.BlockSpec(
                (1, 1), lambda b, si: (b, 0), memory_space=pltpu.SMEM
            ),
        ],
        out_specs=(
            pl.BlockSpec((1, mp, hd), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((1, mp, 1), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((1, mp, 1), lambda b, si: (b, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bh, mp, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, mp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, mp, 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((mp, hd), jnp.float32),
            pltpu.VMEM((mp, 1), jnp.float32),
            pltpu.VMEM((mp, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(qp, ap, kpp, ksp, vpp, vsp, kv_len.astype(jnp.int32)[:, None])
    if mp != m:
        acc, m_run, l_run = acc[:, :m], m_run[:, :m], l_run[:, :m]
    return acc, m_run, l_run
