"""Batched PVQ encoding Pallas TPU kernel (sort-based O(N log N + ΔK) search).

The paper needed a CUDA implementation to PVQ-encode million-dimensional
layers with the exact greedy O(NK) search; the follow-up work (PVQ for LLMs,
van der Ouderaa et al. 2024) observes that floor allocation + largest-
remainder completion reaches the same pyramid point up to a bounded
correction.  This kernel implements that fast path:

  1. floor-init:  y = floor(K * |w| / ||w||_1)              (O(N))
  2. largest-remainder: give all but the last ``delta_max`` missing pulses to
     the coordinates with the biggest fractional parts (one sort, O(N log N))
  3. bounded greedy correction: place the final ``min(remaining, delta_max)``
     pulses with the exact cosine-maximizing argmax step (O(N * delta_max))

The L1 = K pyramid constraint is exact by construction; the output matches the
exact greedy search bit-for-bit whenever the floor allocation leaves at most
``delta_max`` pulses (always true for K <= delta_max, and the common case for
K >> N), and within ~1e-4 cosine correlation otherwise.  The exact oracle
stays in ``repro.kernels.ref`` / ``repro.core.pvq``.

The flattened weight vector is viewed as G groups of N dims, a tile of BG
groups is held in VMEM, and every step is vectorized across the N lanes and
BG sublanes.  Used by: offline weight encoding, the QAT projection step, and
the gradient compressor's hot path (via ``kernels.ops``).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

#: bump on material kernel-body changes — the encoder autotune cache keys
#: carry ``ekv{ENCODE_KERNEL_VERSION}`` so stale (bg, delta_max) timings miss.
ENCODE_KERNEL_VERSION = 1


def _bulk_mask_argsort(frac, bulk):
    """0/1 mask of the ``bulk`` largest fracs per row (ties -> lower lane),
    via one stable sort — the default bulk-allocation path."""
    order = jnp.argsort(-frac, axis=-1, stable=True)
    rank = jnp.argsort(order, axis=-1, stable=True)  # rank 0 = biggest frac
    return (rank < bulk[:, None]).astype(jnp.float32)


def _bulk_mask_bisect(frac, bulk):
    """Same mask, no sort: threshold-count binary search over the IEEE bit
    patterns of ``frac`` (>= 0, so int32 bit patterns order like the floats).

    Elementwise compares + lane reductions + a cumsum only — the Mosaic
    fallback for toolchain versions that reject ``jnp.argsort`` inside a
    kernel body.  Tie-break (equal fracs -> lower lane first) matches the
    stable argsort bit-for-bit.
    """
    fb = jax.lax.bitcast_convert_type(frac.astype(jnp.float32), jnp.int32)
    r = bulk[:, None]
    lo = jnp.full((frac.shape[0], 1), -1, jnp.int32)
    hi = jnp.full((frac.shape[0], 1), jnp.int32(0x7F7FFFFF))

    def body(_, state):
        # invariant: count(fb > lo) > r fails, count(fb > hi) <= r holds
        lo, hi = state
        mid = lo + (hi - lo) // 2
        cnt = jnp.sum((fb > mid).astype(jnp.int32), axis=-1, keepdims=True)
        ok = cnt <= r
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    _, hi = jax.lax.fori_loop(0, 32, body, (lo, hi))
    gt = fb > hi
    extra = r - jnp.sum(gt.astype(jnp.int32), axis=-1, keepdims=True)
    eq = fb == hi
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1)
    return (gt | (eq & (eq_rank <= extra))).astype(jnp.float32)


_BULK_MASKS = {"argsort": _bulk_mask_argsort, "bisect": _bulk_mask_bisect}


def default_sort_impl() -> str:
    """Process-wide bulk-allocation lowering: ``REPRO_PVQ_ENCODE_SORT``
    (``bisect`` = the no-argsort Mosaic fallback) or ``argsort``.  Every
    defaulted dispatch — ``ops.pvq_encode`` and the autotune timing runs —
    resolves through this, so tuned timings measure the lowering that
    production will actually run."""
    return os.environ.get("REPRO_PVQ_ENCODE_SORT", "").strip() or "argsort"


def _kernel(w_ref, p_ref, rho_ref, *, k_pulses: int, delta_max: int,
            sort_impl: str = "argsort"):
    w = w_ref[...].astype(jnp.float32)  # (bg, n)
    bg, n = w.shape
    absw = jnp.abs(w)
    l1 = jnp.sum(absw, axis=-1, keepdims=True)
    safe = jnp.where(l1 > 0, l1, 1.0)
    target = absw * (k_pulses / safe)  # real-valued pyramid allocation
    y = jnp.where(l1 > 0, jnp.floor(target), 0.0)

    # ---- largest-remainder bulk allocation (one sort — or, for Mosaic
    # versions without in-kernel argsort, a bit-space binary search)
    remaining = (k_pulses - jnp.sum(y, axis=-1)).astype(jnp.int32)  # (bg,)
    bulk = jnp.maximum(remaining - delta_max, 0)
    frac = target - y
    bump = _BULK_MASKS[sort_impl](frac, bulk)
    y = y + jnp.where(l1 > 0, bump, 0.0)

    # ---- bounded greedy correction: exact argmax placement of the last few
    corr = jnp.sum(absw * y, axis=-1)  # (bg,)
    energy = jnp.sum(y * y, axis=-1)
    remaining = jnp.minimum(remaining, delta_max)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bg, n), 1)

    def body(_, state):
        y, corr, energy, remaining = state
        num = (corr[:, None] + absw) ** 2
        den = energy[:, None] + 2.0 * y + 1.0
        score = num / den
        best = jnp.max(score, axis=-1, keepdims=True)
        # first-lane-wins one-hot of the argmax
        is_best = (score == best).astype(jnp.int32)
        first = jnp.argmax(is_best, axis=-1)
        onehot = (lanes == first[:, None]).astype(jnp.float32)
        do = (remaining > 0).astype(jnp.float32)[:, None]
        upd = onehot * do
        y = y + upd
        corr = corr + jnp.sum(absw * upd, axis=-1)
        energy = energy + jnp.sum((2.0 * y - 1.0) * upd, axis=-1)
        remaining = remaining - (remaining > 0).astype(jnp.int32)
        return (y, corr, energy, remaining)

    n_iter = min(delta_max, k_pulses)
    y, _, _, _ = jax.lax.fori_loop(0, n_iter, body, (y, corr, energy, remaining))
    pulses = jnp.sign(w) * y
    p_ref[...] = pulses.astype(jnp.int32)
    ynorm2 = jnp.sum(pulses * pulses, axis=-1)
    rho = jnp.sum(w * pulses, axis=-1) / jnp.where(ynorm2 > 0, ynorm2, 1.0)
    rho_ref[...] = jnp.where(ynorm2 > 0, jnp.maximum(rho, 0.0), 0.0)[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("k_pulses", "bg", "delta_max", "interpret", "sort_impl"),
)
def pvq_encode_batch(
    w: jax.Array,  # (g, n) f32/bf16 groups to encode
    *,
    k_pulses: int,
    bg: int = 8,
    delta_max: int = 32,
    interpret: bool = False,
    sort_impl: str = "argsort",
):
    """Returns (pulses i32 (g, n), rho_ls f32 (g,)).

    ``delta_max`` bounds the exact greedy correction after the sort-based
    allocation; ``delta_max >= k_pulses`` degenerates to the exact greedy
    search.  Group counts that don't tile by ``bg`` are zero-padded (zero rows
    encode to zero pulses / zero rho) and sliced back.  ``sort_impl``
    selects the bulk-allocation lowering: ``'argsort'`` (default) or
    ``'bisect'`` (elementwise + reductions only; bit-identical output) for
    Mosaic versions that reject in-kernel ``jnp.argsort``.
    """
    if sort_impl not in _BULK_MASKS:
        raise ValueError(f"sort_impl must be one of {tuple(_BULK_MASKS)}, got {sort_impl!r}")
    g, n = w.shape
    bg = min(bg, g)
    pad = (-g) % bg
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad, n), w.dtype)], axis=0)
    gp = g + pad
    pulses, rho = pl.pallas_call(
        functools.partial(
            _kernel, k_pulses=k_pulses, delta_max=delta_max, sort_impl=sort_impl
        ),
        grid=(gp // bg,),
        in_specs=[pl.BlockSpec((bg, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bg, n), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((gp, n), jnp.int32),
            jax.ShapeDtypeStruct((gp, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
    )(w)
    return pulses[:g], rho[:g, 0]
