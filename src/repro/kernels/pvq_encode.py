"""Batched PVQ encoding Pallas TPU kernel (exact greedy O(NK) pulse search).

The paper needed a CUDA implementation to PVQ-encode million-dimensional
layers; this is the TPU adaptation: the flattened weight vector is viewed as
G groups of N dims, a tile of BG groups is held in VMEM, and the per-pulse
argmax (the O(N) inner step of the exact greedy search) is vectorized across
both the N lanes and the BG sublanes.  The pulse loop runs K iterations (a
static bound), with rows that have exhausted their budget masked to no-ops —
identical semantics to repro.core.pvq / kernels.ref.pvq_encode_ref.

Used by: offline weight encoding, the QAT projection step, and the gradient
compressor's hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(w_ref, p_ref, rho_ref, *, k_pulses: int):
    w = w_ref[...].astype(jnp.float32)  # (bg, n)
    bg, n = w.shape
    absw = jnp.abs(w)
    l1 = jnp.sum(absw, axis=-1, keepdims=True)
    safe = jnp.where(l1 > 0, l1, 1.0)
    y = jnp.floor(absw * (k_pulses / safe))
    y = jnp.where(l1 > 0, y, 0.0)

    corr = jnp.sum(absw * y, axis=-1)  # (bg,)
    energy = jnp.sum(y * y, axis=-1)
    remaining = (k_pulses - jnp.sum(y, axis=-1)).astype(jnp.int32)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bg, n), 1)

    def body(_, state):
        y, corr, energy, remaining = state
        num = (corr[:, None] + absw) ** 2
        den = energy[:, None] + 2.0 * y + 1.0
        score = num / den
        best = jnp.max(score, axis=-1, keepdims=True)
        # first-lane-wins one-hot of the argmax
        is_best = (score == best).astype(jnp.int32)
        first = jnp.argmax(is_best, axis=-1)
        onehot = (lanes == first[:, None]).astype(jnp.float32)
        do = (remaining > 0).astype(jnp.float32)[:, None]
        upd = onehot * do
        y = y + upd
        corr = corr + jnp.sum(absw * upd, axis=-1)
        energy = energy + jnp.sum((2.0 * y - 1.0) * upd, axis=-1)
        remaining = remaining - (remaining > 0).astype(jnp.int32)
        return (y, corr, energy, remaining)

    y, _, _, _ = jax.lax.fori_loop(0, k_pulses, body, (y, corr, energy, remaining))
    pulses = jnp.sign(w) * y
    p_ref[...] = pulses.astype(jnp.int32)
    ynorm2 = jnp.sum(pulses * pulses, axis=-1)
    rho = jnp.sum(w * pulses, axis=-1) / jnp.where(ynorm2 > 0, ynorm2, 1.0)
    rho_ref[...] = jnp.where(ynorm2 > 0, jnp.maximum(rho, 0.0), 0.0)[:, None]


@functools.partial(jax.jit, static_argnames=("k_pulses", "bg", "interpret"))
def pvq_encode_batch(
    w: jax.Array,  # (g, n) f32/bf16 groups to encode
    *,
    k_pulses: int,
    bg: int = 8,
    interpret: bool = False,
):
    """Returns (pulses i32 (g, n), rho_ls f32 (g,))."""
    g, n = w.shape
    bg = min(bg, g)
    assert g % bg == 0, f"group count {g} must tile by {bg}"
    pulses, rho = pl.pallas_call(
        functools.partial(_kernel, k_pulses=k_pulses),
        grid=(g // bg,),
        in_specs=[pl.BlockSpec((bg, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bg, n), lambda i: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, n), jnp.int32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(dimension_semantics=("parallel",)),
    )(w)
    return pulses, rho[:, 0]
