"""Autotuner for the PVQ Pallas kernels: matmul tiles + encoder knobs.

``pvq_matmul`` takes (bm, bn, bk) tile sizes; the best choice depends on the
GEMM shape (an m=8 decode step wants a skinny bm, a 236B-config FFN block
wants full MXU 128x128 tiles), the dtype, and the backend.  This module
searches a small MXU/VPU-aligned candidate grid, times each candidate with
``block_until_ready``, and persists the winner in a JSON cache so the search
runs once per (shape, dtype, backend) — ever.  ``pvq_encode``'s
(bg, delta_max) knobs go through the same cache (ROADMAP "autotune the
encoder too"): ``get_encode_params`` mirrors ``get_tiles`` dispatch.

Cache
-----
* location: ``$REPRO_PVQ_TUNE_CACHE`` if set, else
  ``~/.cache/repro/pvq_tune_cache.json``
* matmul key: ``"m x k x n : g<group> : <dtype> : <backend> : kv<N> : v3"``
  (no spaces) — ``kv<N>`` is ``pvq_matmul.KERNEL_VERSION``, so a material
  kernel body change (e.g. the v3 quantized-activation contraction)
  invalidates every tile timing measured against the old body instead of
  silently serving it.  ``<dtype>`` is the *activation* dtype: ``int8`` keys
  time the int8 x int8 kernel v3 body (``launch/serve.py --tune --act-int8``
  pre-tunes them), float keys the f32-activation body.
* matmul value: ``{"bm":…, "bn":…, "bk":…, "us":…, "candidates":…}``
* attention key (kernel v4 decode): ``"attn m x hd x s : g<group> : <dtype>
  : <backend> : kv<N> : v3"`` with value ``{"bs":…, "us":…, "candidates":…}``
  — the v3->v4 ``KERNEL_VERSION`` bump means every entry tuned against the
  pre-attention kernel body misses for v4 dispatch.
* encoder key: ``"enc g x n : k<K> : <dtype> : <backend> : ekv<N> : v2"``
  with ``ekv<N>`` = ``pvq_encode.ENCODE_KERNEL_VERSION``; value
  ``{"bg":…, "delta_max":…, "us":…, "candidates":…}``.  ``delta_max``
  candidates never drop below the heuristic default — tuning may only make
  the encoder *more* exact, never less.

Dispatch contract (used by ``kernels.ops.pvq_matmul``):

* explicit tiles from the caller always win;
* else a cache hit wins (never re-times);
* else, if searching is enabled (``search=True`` or ``REPRO_PVQ_AUTOTUNE=1``),
  run the search and persist;
* else fall back to :func:`heuristic_tiles` (no timing, no I/O).

Delete the cache file (or point the env var somewhere fresh) to regenerate —
see ``src/repro/kernels/README.md``.
"""

from __future__ import annotations

import json
import logging
import os
import tempfile
import time
from pathlib import Path
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from .pvq_encode import ENCODE_KERNEL_VERSION, default_sort_impl, pvq_encode_batch
from .pvq_matmul import (
    KERNEL_VERSION,
    normalize_tiles,
    pvq_attn_q,
    pvq_matmul,
    pvq_matmul_q,
)

# v2: keys carry the kernel-body version tag (ROADMAP "tuned-tile
# invalidation") — entries tuned against an older kernel body miss.
# v3: the activation dtype in the key is now load-bearing (int8 keys time
# the quantized-activation kernel v3 body, float keys the f32-act v2 body),
# so the schema bump guarantees v2-era tiles can never collide with v3
# dispatch even for entries whose kv tag a hand-edited cache got wrong.
_SCHEMA = "v3"
# process-local mirror of the JSON file: avoids re-reading per dispatch
_MEM: Dict[str, dict] = {}
_MEM_LOADED_FROM: Optional[str] = None

# keep the interpret-mode (CPU proxy) search cheap; Mosaic search can afford
# a wider sweep since compile+run is milliseconds per candidate
MAX_CANDIDATES_INTERPRET = 6
MAX_CANDIDATES_COMPILED = 24
_VMEM_BUDGET_BYTES = 12 * 1024 * 1024  # leave headroom below the ~16MB/core


def cache_path() -> Path:
    env = os.environ.get("REPRO_PVQ_TUNE_CACHE", "").strip()
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "pvq_tune_cache.json"


def cache_key(m: int, k: int, n: int, group: int, dtype, backend: str) -> str:
    return (
        f"{m}x{k}x{n}:g{group}:{jnp.dtype(dtype).name}:{backend}"
        f":kv{KERNEL_VERSION}:{_SCHEMA}"
    )


def _load() -> Dict[str, dict]:
    """Read-through memory cache of the JSON file."""
    global _MEM, _MEM_LOADED_FROM
    path = cache_path()
    if _MEM_LOADED_FROM == str(path):
        return _MEM
    entries: Dict[str, dict] = {}
    try:
        with open(path) as f:
            raw = json.load(f)
        if isinstance(raw, dict):
            entries = {k: v for k, v in raw.items() if isinstance(v, dict)}
    except (OSError, json.JSONDecodeError):
        entries = {}
    _MEM = entries
    _MEM_LOADED_FROM = str(path)
    return _MEM


def _persist(key: str, entry: dict) -> None:
    """Read-modify-write with an atomic replace (tuning may run concurrently)."""
    path = cache_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    current: Dict[str, dict] = {}
    try:
        with open(path) as f:
            current = json.load(f)
        if not isinstance(current, dict):
            current = {}
    except (OSError, json.JSONDecodeError):
        current = {}
    current[key] = entry
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(current, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass
    _MEM.update({key: entry})
    global _MEM_LOADED_FROM
    _MEM_LOADED_FROM = str(path)


def clear_memory_cache() -> None:
    """Forget the in-process mirror (tests point REPRO_PVQ_TUNE_CACHE around)."""
    global _MEM, _MEM_LOADED_FROM
    _MEM = {}
    _MEM_LOADED_FROM = None


# ---------------------------------------------------------------------------
# tuning observability: hit/miss/search counts + search wall-time, per key
# ---------------------------------------------------------------------------

_LOG = logging.getLogger("repro.autotune")
_PLURAL = {"hit": "hits", "miss": "misses", "search": "searches"}


def _fresh_stats() -> Dict[str, object]:
    return {"hits": 0, "misses": 0, "searches": 0, "search_s": 0.0, "by_key": {}}


_TUNE_STATS: Dict[str, object] = _fresh_stats()


def tune_stats() -> Dict[str, object]:
    """Copy of the process tuning stats: total/per-key hit, miss, and
    completed-search counts plus accumulated search wall-time (seconds)."""
    out = dict(_TUNE_STATS)
    out["search_s"] = round(float(out["search_s"]), 4)
    out["by_key"] = {k: dict(v) for k, v in _TUNE_STATS["by_key"].items()}
    return out


def reset_tune_stats() -> None:
    global _TUNE_STATS
    _TUNE_STATS = _fresh_stats()


def _note(key: str, outcome: str, search_s: float = 0.0) -> None:
    """Record one cache lookup outcome (``hit``/``miss``) or completed
    ``search``; logs it and mirrors into the telemetry registry.  Runs on
    dispatch paths that execute at trace time — host-side only, cheap."""
    word = _PLURAL[outcome]
    _TUNE_STATS[word] += 1
    if search_s:
        _TUNE_STATS["search_s"] += search_s
    per = _TUNE_STATS["by_key"].setdefault(
        key, {"hits": 0, "misses": 0, "searches": 0}
    )
    per[word] += 1
    if outcome == "search":
        _LOG.info("search done for %s in %.3fs", key, search_s)
    else:
        _LOG.debug("cache %s: %s", outcome, key)

    from repro.runtime import obs

    if obs.enabled():
        if outcome != "search":
            obs.counter("autotune.lookups").inc()
        obs.counter(f"autotune.{outcome}").inc()
        if outcome == "search":
            obs.histogram("autotune.search_s").record(search_s)


def heuristic_tiles(m: int, k: int, n: int, group: int) -> Tuple[int, int, int]:
    """Static MXU-aligned guess: full 128 tiles clamped to the problem, with a
    deeper bk when the k extent dwarfs the MXU (fewer grid steps, same VMEM
    order) and a skinny bm for decode-like m."""
    bk = 128 if k <= 1024 else 256
    return normalize_tiles(m, k, n, group, bm=128, bn=128, bk=bk)


def candidate_tiles(
    m: int, k: int, n: int, group: int, max_candidates: int
) -> Tuple[Tuple[int, int, int], ...]:
    """MXU/VPU-aligned (bm, bn, bk) grid, deduped after clamping to the shape.

    bm sweeps sublane-aligned powers of two (8..256) — decode steps live at
    the small end; bn sweeps lane multiples (128..512); bk sweeps group
    multiples (group..512).  Candidates whose VMEM working set exceeds the
    budget are dropped.  The heuristic default is always candidate #0 so a
    truncated search can never be worse than no search.
    """
    cands: list[Tuple[int, int, int]] = [heuristic_tiles(m, k, n, group)]
    for bm in (8, 16, 32, 64, 128, 256):
        for bn in (128, 256, 512):
            for bk in (group, 2 * group, 4 * group, 128, 256, 512):
                t = normalize_tiles(m, k, n, group, bm, bn, bk)
                bm_, bn_, bk_ = t
                vmem = (
                    bm_ * bk_ * 4  # x tile f32
                    + bk_ * bn_  # int8 pulses
                    + (bk_ // group) * bn_ * 4  # scales
                    + 2 * bm_ * bn_ * 4  # out + acc
                )
                if vmem > _VMEM_BUDGET_BYTES:
                    continue
                if t not in cands:
                    cands.append(t)
    return tuple(cands[:max_candidates])


def _time_candidate(
    x, w, s, group: int, tiles: Tuple[int, int, int], reps: int, interpret: bool,
    act_scale=None,
) -> float:
    bm, bn, bk = tiles
    if act_scale is not None:
        # int8 activation dtype: time the quantized-activation kernel v3
        # body — the body these tiles will actually dispatch to
        def call():
            return pvq_matmul_q(
                x, w, s, act_scale, group=group, bm=bm, bn=bn, bk=bk,
                interpret=interpret,
            )
    else:
        def call():
            return pvq_matmul(
                x, w, s, group=group, bm=bm, bn=bn, bk=bk, interpret=interpret
            )
    call().block_until_ready()  # warmup: trace + compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(reps):
        call().block_until_ready()
    return (time.perf_counter() - t0) / reps


def autotune(
    m: int,
    k: int,
    n: int,
    *,
    group: int = 128,
    dtype=jnp.float32,
    reps: int = 3,
    interpret: Optional[bool] = None,
    max_candidates: Optional[int] = None,
) -> dict:
    """Search the candidate grid for (m,k,n,group,dtype); persist + return the
    winning entry ``{"bm","bn","bk","us","candidates"}``.  A cache hit skips
    the search entirely."""
    backend = jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    key = cache_key(m, k, n, group, dtype, backend)
    hit = _load().get(key)
    if hit is not None:
        _note(key, "hit")
        return hit
    _note(key, "miss")
    t_search = time.perf_counter()

    if max_candidates is None:
        max_candidates = (
            MAX_CANDIDATES_INTERPRET if interpret else MAX_CANDIDATES_COMPILED
        )
    cands = candidate_tiles(m, k, n, group, max_candidates)

    kx, kw, ks = jax.random.split(jax.random.PRNGKey(0), 3)
    act_scale = None
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        # int8 activation key: quantized operands + per-row scales (v3 body)
        x = jax.random.randint(kx, (m, k), -127, 128, jnp.int8)
        act_scale = jnp.full((m, 1), 0.01, jnp.float32)
    else:
        x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    w = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
    s = (jnp.abs(jax.random.normal(ks, (k // group, n))) * 0.05).astype(jnp.float32)

    best: Optional[Tuple[int, int, int]] = None
    best_t = float("inf")
    for t in cands:
        dt = _time_candidate(x, w, s, group, t, reps, interpret, act_scale=act_scale)
        if dt < best_t:
            best, best_t = t, dt
    assert best is not None
    entry = {
        "bm": best[0],
        "bn": best[1],
        "bk": best[2],
        "us": round(1e6 * best_t, 2),
        "candidates": len(cands),
    }
    _persist(key, entry)
    _note(key, "search", time.perf_counter() - t_search)
    return entry


# ---------------------------------------------------------------------------
# encoder autotune: pvq_encode's (bg, delta_max) knobs (ROADMAP satellite)
# ---------------------------------------------------------------------------

#: heuristic defaults (the kernel's historical hardcoded values)
ENCODE_DEFAULTS: Tuple[int, int] = (8, 32)
#: bg sweeps the VMEM sublane-tile height; delta_max never drops below the
#: default so a tuned encoder is at least as accurate as an untuned one
#: (delta_max bounds the exact-greedy correction, i.e. output quality).
ENCODE_BG_CANDIDATES = (4, 8, 16, 32)
ENCODE_DELTA_CANDIDATES = (32, 64)
MAX_ENCODE_CANDIDATES_INTERPRET = 4
MAX_ENCODE_CANDIDATES_COMPILED = 8


def encode_cache_key(g: int, n: int, k_pulses: int, dtype, backend: str) -> str:
    """Same store/schema as the matmul tiles; ``ekv<N>`` tags the encoder
    kernel body so a version bump invalidates stale (bg, delta_max) timings."""
    return (
        f"enc{g}x{n}:k{k_pulses}:{jnp.dtype(dtype).name}:{backend}"
        f":ekv{ENCODE_KERNEL_VERSION}:{_SCHEMA}"
    )


def encode_candidates(
    g: int, n: int, max_candidates: int
) -> Tuple[Tuple[int, int], ...]:
    """(bg, delta_max) grid, deduped after clamping bg to the group count.
    The heuristic default is always candidate #0 (so a truncated search can
    never be worse than no search); VMEM gating applies to the *clamped* bg
    actually dispatched."""
    cands: list[Tuple[int, int]] = [(min(ENCODE_DEFAULTS[0], g), ENCODE_DEFAULTS[1])]
    for delta in ENCODE_DELTA_CANDIDATES:
        for bg in ENCODE_BG_CANDIDATES:
            t = (min(bg, g), delta)
            if t[0] * n * 4 * 6 > _VMEM_BUDGET_BYTES:  # ~6 (bg, n) f32 live arrays
                continue
            if t not in cands:
                cands.append(t)
    return tuple(cands[:max_candidates])


def _time_encode_candidate(
    w, k_pulses: int, cand: Tuple[int, int], reps: int, interpret: bool
) -> float:
    # time the same bulk-allocation lowering production dispatch will use
    # (REPRO_PVQ_ENCODE_SORT=bisect tunes — and works — on Mosaic versions
    # whose argsort path doesn't lower at all)
    bg, delta_max = cand
    sort_impl = default_sort_impl()
    p, _ = pvq_encode_batch(
        w, k_pulses=k_pulses, bg=bg, delta_max=delta_max, interpret=interpret,
        sort_impl=sort_impl,
    )
    p.block_until_ready()  # warmup: trace + compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(reps):
        pvq_encode_batch(
            w, k_pulses=k_pulses, bg=bg, delta_max=delta_max,
            interpret=interpret, sort_impl=sort_impl,
        )[0].block_until_ready()
    return (time.perf_counter() - t0) / reps


def autotune_encode(
    g: int,
    n: int,
    k_pulses: int,
    *,
    dtype=jnp.float32,
    reps: int = 3,
    interpret: Optional[bool] = None,
    max_candidates: Optional[int] = None,
) -> dict:
    """Search (bg, delta_max) for a (g, n, K) encode shape; persist + return
    ``{"bg","delta_max","us","candidates"}``.  A cache hit skips the search."""
    backend = jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    key = encode_cache_key(g, n, k_pulses, dtype, backend)
    hit = _load().get(key)
    if hit is not None:
        _note(key, "hit")
        return hit
    _note(key, "miss")
    t_search = time.perf_counter()
    if max_candidates is None:
        max_candidates = (
            MAX_ENCODE_CANDIDATES_INTERPRET
            if interpret
            else MAX_ENCODE_CANDIDATES_COMPILED
        )
    cands = encode_candidates(g, n, max_candidates)
    w = jax.random.laplace(jax.random.PRNGKey(0), (g, n), jnp.float32).astype(dtype)
    best: Optional[Tuple[int, int]] = None
    best_t = float("inf")
    for c in cands:
        dt = _time_encode_candidate(w, k_pulses, c, reps, interpret)
        if dt < best_t:
            best, best_t = c, dt
    assert best is not None
    entry = {
        "bg": best[0],
        "delta_max": best[1],
        "us": round(1e6 * best_t, 2),
        "candidates": len(cands),
    }
    _persist(key, entry)
    _note(key, "search", time.perf_counter() - t_search)
    return entry


def get_encode_params(
    g: int,
    n: int,
    k_pulses: int,
    *,
    dtype=jnp.float32,
    search: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Tuple[int, int]:
    """(bg, delta_max) dispatch for ``ops.pvq_encode``: cache hit > search >
    heuristic default.  ``search=None`` defers to ``REPRO_PVQ_AUTOTUNE``,
    exactly like the matmul tile dispatch."""
    backend = jax.default_backend()
    key = encode_cache_key(g, n, k_pulses, dtype, backend)
    hit = _load().get(key)
    if hit is not None:
        _note(key, "hit")
        return (hit["bg"], hit["delta_max"])
    if search is None:
        search = os.environ.get("REPRO_PVQ_AUTOTUNE", "") not in ("", "0", "false")
    if search:
        # autotune_encode records the miss + search itself
        e = autotune_encode(g, n, k_pulses, dtype=dtype, interpret=interpret)
        return (e["bg"], e["delta_max"])
    _note(key, "miss")
    return (min(ENCODE_DEFAULTS[0], g), ENCODE_DEFAULTS[1])


# ---------------------------------------------------------------------------
# attention decode autotune: pvq_attn_q's sequence-block size (kernel v4)
# ---------------------------------------------------------------------------

#: bs sweeps lane-aligned KV block widths; 128 is the MXU-native floor
ATTN_BS_CANDIDATES = (128, 256, 512)


def attn_cache_key(m: int, hd: int, s: int, group: int, dtype, backend: str) -> str:
    """Key for the kernel-v4 attention decode contraction.  Carries
    ``kv{KERNEL_VERSION}`` exactly like the matmul keys, so the v3->v4 bump
    structurally invalidates every pre-v4 entry — a kv3-tagged tile can never
    be served for v4 dispatch (the kv3 suffix simply never matches)."""
    return (
        f"attn{m}x{hd}x{s}:g{group}:{jnp.dtype(dtype).name}:{backend}"
        f":kv{KERNEL_VERSION}:{_SCHEMA}"
    )


def heuristic_attn_bs(s: int) -> int:
    """Lane-aligned default KV block: one 128 block, or the whole (short)
    padded sequence when it fits a single grid step."""
    return 128 if s >= 128 else max(s, 8)


def attn_candidates(s: int, max_candidates: int) -> Tuple[int, ...]:
    """bs grid clamped to the padded sequence; heuristic first (a truncated
    search can never be worse than no search)."""
    cands: list[int] = [heuristic_attn_bs(s)]
    for bs in ATTN_BS_CANDIDATES:
        if bs <= max(s, 128) and bs not in cands:
            cands.append(bs)
    return tuple(cands[:max_candidates])


def autotune_attn(
    m: int,
    hd: int,
    s: int,
    *,
    group: int = 32,
    dtype=jnp.int8,
    reps: int = 3,
    interpret: Optional[bool] = None,
    max_candidates: Optional[int] = None,
) -> dict:
    """Search the KV-block grid for a (m, hd, s) decode-attention shape;
    persist + return ``{"bs","us","candidates"}``.  ``m`` is query rows per
    kv head (q_len * group_size), ``s`` the packed cache extent."""
    backend = jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"
    key = attn_cache_key(m, hd, s, group, dtype, backend)
    hit = _load().get(key)
    if hit is not None:
        _note(key, "hit")
        return hit
    _note(key, "miss")
    t_search = time.perf_counter()
    if max_candidates is None:
        max_candidates = (
            MAX_CANDIDATES_INTERPRET if interpret else MAX_CANDIDATES_COMPILED
        )
    cands = attn_candidates(s, max_candidates)

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    bh = 2
    ng = max(hd // group, 1)
    q = jax.random.randint(kq, (bh, m, hd), -127, 128, jnp.int8)
    a = jnp.full((bh, m, 1), 0.01, jnp.float32)
    kp = jax.random.randint(kk, (bh, s, hd), -5, 6, jnp.int8)
    vp = jax.random.randint(kv, (bh, s, hd), -5, 6, jnp.int8)
    ks = jnp.full((bh, s, ng), 0.05, jnp.float32)
    vs = jnp.full((bh, s, ng), 0.05, jnp.float32)
    kv_len = jnp.full((bh,), s, jnp.int32)

    best: Optional[int] = None
    best_t = float("inf")
    for bs in cands:
        def call():
            return pvq_attn_q(
                q, a, kp, ks, vp, vs, kv_len,
                group=min(group, hd), sm_scale=1.0, bs=bs, interpret=interpret,
            )
        call()[0].block_until_ready()  # warmup
        t0 = time.perf_counter()
        for _ in range(reps):
            call()[0].block_until_ready()
        dt = (time.perf_counter() - t0) / reps
        if dt < best_t:
            best, best_t = bs, dt
    assert best is not None
    entry = {"bs": best, "us": round(1e6 * best_t, 2), "candidates": len(cands)}
    _persist(key, entry)
    _note(key, "search", time.perf_counter() - t_search)
    return entry


def get_attn_tiles(
    m: int,
    hd: int,
    s: int,
    *,
    group: int = 32,
    dtype=jnp.int8,
    search: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> int:
    """KV block-size dispatch for ``ops.pvq_attn_decode``: cache hit >
    search (``REPRO_PVQ_AUTOTUNE=1``) > heuristic, mirroring ``get_tiles``."""
    backend = jax.default_backend()
    key = attn_cache_key(m, hd, s, group, dtype, backend)
    hit = _load().get(key)
    if hit is not None:
        _note(key, "hit")
        return int(hit["bs"])
    if search is None:
        search = os.environ.get("REPRO_PVQ_AUTOTUNE", "") not in ("", "0", "false")
    if search:
        # autotune_attn records the miss + search itself
        return int(
            autotune_attn(m, hd, s, group=group, dtype=dtype, interpret=interpret)["bs"]
        )
    _note(key, "miss")
    return heuristic_attn_bs(s)


def get_tiles(
    m: int,
    k: int,
    n: int,
    *,
    group: int = 128,
    dtype=jnp.float32,
    search: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Tuple[int, int, int]:
    """Tile dispatch for ``ops.pvq_matmul``: cache hit > search > heuristic.

    ``search=None`` defers to the ``REPRO_PVQ_AUTOTUNE`` env var, so a whole
    serving/training job can opt in to first-call tuning without threading a
    flag through every layer."""
    backend = jax.default_backend()
    key = cache_key(m, k, n, group, dtype, backend)
    hit = _load().get(key)
    if hit is not None:
        _note(key, "hit")
        return (hit["bm"], hit["bn"], hit["bk"])
    if search is None:
        search = os.environ.get("REPRO_PVQ_AUTOTUNE", "") not in ("", "0", "false")
    if search:
        # autotune records the miss + search itself
        e = autotune(m, k, n, group=group, dtype=dtype, interpret=interpret)
        return (e["bm"], e["bn"], e["bk"])
    _note(key, "miss")
    return heuristic_tiles(m, k, n, group)


def tune_shapes(
    shapes: Iterable[Tuple[int, int, int]],
    *,
    group: int = 128,
    dtype=jnp.float32,
    reps: int = 3,
    interpret: Optional[bool] = None,
) -> Dict[str, dict]:
    """Pre-tune a batch of GEMM shapes (serve/train warmup). Returns key->entry."""
    out = {}
    for m, k, n in shapes:
        out[cache_key(m, k, n, group, dtype, jax.default_backend())] = autotune(
            m, k, n, group=group, dtype=dtype, reps=reps, interpret=interpret
        )
    return out


def tune_attn_shapes(
    shapes: Iterable[Tuple[int, int, int]],
    *,
    group: int = 32,
    dtype=jnp.int8,
    interpret: Optional[bool] = None,
) -> Dict[str, dict]:
    """Pre-tune a batch of ``(m, hd, s)`` decode-attention shapes.

    The continuous-batching engine keys its kernel-v4 dispatch on the
    SLOT-POOL geometry, not the per-request one: ``m`` is query rows per kv
    head and ``s`` the pool extent ``max_pages_per_slot * page`` — every
    decode step of the engine hits the same (m, hd, s) entry regardless of
    how many requests are in flight.  Returns key->entry like
    :func:`tune_shapes`.
    """
    out = {}
    backend = jax.default_backend()
    for m, hd, s in shapes:
        out[attn_cache_key(m, hd, s, group, dtype, backend)] = autotune_attn(
            m, hd, s, group=group, dtype=dtype, interpret=interpret
        )
    return out
