"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pvq_matmul_ref(
    x: jax.Array,  # (m, k) activations
    w_pulses: jax.Array,  # (k, n) int8 PVQ pulses
    scales: jax.Array,  # (k // group, n) f32 per-group rho
    *,
    group: int,
) -> jax.Array:
    """y = x @ (scales-expanded * pulses). Groups tile the contraction dim."""
    k, n = w_pulses.shape
    assert k % group == 0
    w = w_pulses.astype(jnp.float32) * jnp.repeat(scales, group, axis=0)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def pvq_encode_ref(w: jax.Array, k_pulses: int) -> tuple[jax.Array, jax.Array]:
    """Batched exact greedy PVQ projection; returns (pulses i32 (g,n), rho_ls f32 (g,)).

    Same algorithm as repro.core.pvq (presearch + greedy top-up), kept
    dependency-free here as the kernel oracle.
    """
    from repro.core.pvq import _greedy_topup, _presearch, _scales

    absw = jnp.abs(w.astype(jnp.float32))
    y = _presearch(absw, k_pulses)
    y = _greedy_topup(absw, y, k_pulses)
    pulses = (jnp.sign(w) * y).astype(jnp.int32)
    rho = _scales(w, pulses, "ls")
    return pulses, rho
