"""Sequential MLP/CNN substrate for the paper's own experiments (§VII).

Reproduces the Keras-example topologies the paper uses (nets A-D) in pure
JAX: fully connected stacks with ReLU or bsign activations, and the small
CIFAR CNN (conv/maxpool).  Supports the paper's per-layer PVQ procedure
(flatten weights+bias into ONE vector per layer, single rho), rho-folding
verification, and integer-only inference.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PVQCode, pvq_encode, k_for
from repro.core.qat import bsign
from repro.nn.layers import pvq_dense, pvq_quantize_dense


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str  # 'fc' | 'conv' | 'maxpool' | 'flatten' | 'dropout'
    out: int = 0  # fc units or conv channels
    kernel: int = 3  # conv kernel size
    pool: int = 2
    rate: float = 0.0  # dropout
    activation: str = "relu"  # 'relu' | 'bsign' | 'none'
    n_over_k: Optional[float] = None  # paper's N/K for this layer (None = skip PVQ)


@dataclasses.dataclass(frozen=True)
class SequentialConfig:
    name: str
    input_shape: Tuple[int, ...]  # e.g. (784,) or (32, 32, 3)
    layers: Tuple[LayerSpec, ...]
    n_classes: int = 10


def _act(name: str, x):
    if name == "relu":
        return jax.nn.relu(x)
    if name == "bsign":
        return bsign(x)
    if name == "none":
        return x
    raise ValueError(name)


class SequentialNet:
    def __init__(self, cfg: SequentialConfig):
        self.cfg = cfg

    def init(self, key) -> Dict[str, Any]:
        params: Dict[str, Any] = {}
        shape = self.cfg.input_shape
        ki = 0
        keys = jax.random.split(key, len(self.cfg.layers))
        for i, spec in enumerate(self.cfg.layers):
            if spec.kind == "fc":
                d_in = int(np.prod(shape))
                w = jax.random.normal(keys[i], (d_in, spec.out)) * (2.0 / d_in) ** 0.5
                params[f"layer{i}"] = {"kernel": w, "bias": jnp.zeros(spec.out)}
                shape = (spec.out,)
            elif spec.kind == "conv":
                cin = shape[-1]
                w = jax.random.normal(keys[i], (spec.kernel, spec.kernel, cin, spec.out))
                w = w * (2.0 / (spec.kernel * spec.kernel * cin)) ** 0.5
                params[f"layer{i}"] = {"kernel": w, "bias": jnp.zeros(spec.out)}
                shape = (shape[0], shape[1], spec.out)  # SAME padding
            elif spec.kind == "maxpool":
                shape = (shape[0] // spec.pool, shape[1] // spec.pool, shape[2])
            elif spec.kind == "flatten":
                shape = (int(np.prod(shape)),)
        return params

    def apply(
        self,
        params: Dict[str, Any],
        x: jax.Array,
        *,
        train: bool = False,
        dropout_key=None,
    ) -> jax.Array:
        cfg = self.cfg
        for i, spec in enumerate(cfg.layers):
            if spec.kind == "fc":
                if x.ndim > 2:
                    x = x.reshape(x.shape[0], -1)
                p = params[f"layer{i}"]
                x = _act(spec.activation, x @ p["kernel"] + p["bias"])
            elif spec.kind == "conv":
                p = params[f"layer{i}"]
                x = jax.lax.conv_general_dilated(
                    x, p["kernel"], window_strides=(1, 1), padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )
                x = _act(spec.activation, x + p["bias"])
            elif spec.kind == "maxpool":
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max,
                    (1, spec.pool, spec.pool, 1), (1, spec.pool, spec.pool, 1), "VALID",
                )
            elif spec.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
            elif spec.kind == "dropout":
                if train and dropout_key is not None:
                    dropout_key, sub = jax.random.split(dropout_key)
                    keep = jax.random.bernoulli(sub, 1.0 - spec.rate, x.shape)
                    x = jnp.where(keep, x / (1.0 - spec.rate), 0.0)
        return x  # logits (last fc has activation 'none')

    # ------------------------------------------------------------------ PVQ

    def pvq_encode_layers(
        self, params: Dict[str, Any], scale_mode: str = "paper"
    ) -> Tuple[Dict[str, Any], Dict[str, PVQCode], Dict[str, Dict]]:
        """The paper's §VII procedure: per weight-layer, flatten kernel,
        concat bias, PVQ as ONE vector with K = N / (N/K ratio), split back."""
        new_params = dict(params)
        codes: Dict[str, PVQCode] = {}
        stats: Dict[str, Dict] = {}
        for i, spec in enumerate(self.cfg.layers):
            pname = f"layer{i}"
            if pname not in params or spec.n_over_k is None:
                continue
            p = params[pname]
            wflat = p["kernel"].reshape(-1)
            flat = jnp.concatenate([wflat, p["bias"]])
            n = flat.shape[0]
            k = k_for(n, spec.n_over_k)
            code = pvq_encode(flat, k, scale_mode)
            deq = code.dequantize()
            new_params[pname] = {
                "kernel": deq[: wflat.shape[0]].reshape(p["kernel"].shape),
                "bias": deq[wflat.shape[0] :],
            }
            codes[pname] = code
            stats[pname] = {"N": n, "K": k, "n_over_k": spec.n_over_k}
        return new_params, codes, stats

    def pvq_kernel_encode(
        self, params: Dict[str, Any], *, group: int = 128
    ) -> Dict[str, Any]:
        """Encode every PVQ-eligible fc layer into kernel serving format.

        Unlike :meth:`pvq_encode_layers` (the paper's whole-layer single-rho
        procedure), this is the TPU serving variant: each (group, out-column)
        slice gets its own pyramid code, stored as the unified ``PackedPVQ``
        artifact (``{"kernel": PackedPVQ, "bias"}``) that
        ``repro.kernels.ops.packed_matmul`` streams.  K per group comes from
        the layer's N/K ratio.  Returns {layer_name: packed params}.
        """
        kparams: Dict[str, Any] = {}
        for i, spec in enumerate(self.cfg.layers):
            pname = f"layer{i}"
            if spec.kind != "fc" or pname not in params or spec.n_over_k is None:
                continue
            k_pulses = k_for(group, spec.n_over_k)
            kparams[pname] = pvq_quantize_dense(
                params[pname], group=group, k_pulses=k_pulses
            )
        return kparams

    def kernel_apply(
        self,
        params: Dict[str, Any],
        kparams: Dict[str, Any],
        x: jax.Array,
        *,
        group: int = 128,
        act_quant=None,
    ) -> jax.Array:
        """Forward pass with fc layers running the fused Pallas kernel.

        Quantized fc layers stream int8 pulses through ``ops.pvq_matmul`` with
        the bias+activation epilogue fused (bsign stays outside the kernel —
        it is not an MXU epilogue); unquantized/conv layers fall back to
        :meth:`apply` semantics.  ``act_quant`` (an ``ActQuant``, default the
        process-wide contract) runs the quantized fc layers int8 x int8
        through kernel v3.
        """
        for i, spec in enumerate(self.cfg.layers):
            pname = f"layer{i}"
            if spec.kind == "fc":
                if x.ndim > 2:
                    x = x.reshape(x.shape[0], -1)
                if pname in kparams:
                    fused = spec.activation if spec.activation in ("relu", "none") else "none"
                    y = pvq_dense(
                        kparams[pname], x, activation=fused, act_quant=act_quant
                    )
                    x = y if fused == spec.activation else _act(spec.activation, y)
                else:
                    p = params[pname]
                    x = _act(spec.activation, x @ p["kernel"] + p["bias"])
            elif spec.kind == "conv":
                p = params[pname]
                x = jax.lax.conv_general_dilated(
                    x, p["kernel"], window_strides=(1, 1), padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )
                x = _act(spec.activation, x + p["bias"])
            elif spec.kind == "maxpool":
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max,
                    (1, spec.pool, spec.pool, 1), (1, spec.pool, spec.pool, 1), "VALID",
                )
            elif spec.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
        return x

    def integer_forward(
        self, params: Dict[str, Any], codes: Dict[str, PVQCode], x: jax.Array
    ) -> Tuple[jax.Array, float]:
        """Paper §V: integer-pulse-only forward; single output scale.

        Valid for all-ReLU nets (homogeneous) — biases are rescaled into the
        integer domain of each layer (bias pulses enter at the layer's own
        rho but the running input scale divides them; exactness is asserted
        in tests).  Returns (logits_integer_path, cumulative_scale).
        """
        run_scale = 1.0
        for i, spec in enumerate(self.cfg.layers):
            pname = f"layer{i}"
            if spec.kind == "fc":
                if x.ndim > 2:
                    x = x.reshape(x.shape[0], -1)
                if pname in codes:
                    code = codes[pname]
                    rho = float(np.asarray(code.scale))
                    deq = code.pulses.astype(jnp.float32)
                    wflat_n = params[pname]["kernel"].size
                    w = deq[:wflat_n].reshape(params[pname]["kernel"].shape)
                    b = deq[wflat_n:]
                    # integer weights; bias divided by the incoming scale so
                    # that rho can be factored out of the whole layer
                    x = _act(spec.activation, x @ w + b / run_scale)
                    run_scale = run_scale * rho
                    if spec.activation == "bsign":
                        run_scale = 1.0  # absorbed (eq. 16)
                else:
                    p = params[pname]
                    x = _act(spec.activation, x @ p["kernel"] + p["bias"] / run_scale)
            elif spec.kind == "conv":
                if pname in codes:
                    code = codes[pname]
                    rho = float(np.asarray(code.scale))
                    deq = code.pulses.astype(jnp.float32)
                    wn = params[pname]["kernel"].size
                    w = deq[:wn].reshape(params[pname]["kernel"].shape)
                    b = deq[wn:]
                    x = jax.lax.conv_general_dilated(
                        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
                    )
                    x = _act(spec.activation, x + b / run_scale)
                    run_scale = run_scale * rho
                    if spec.activation == "bsign":
                        run_scale = 1.0
            elif spec.kind == "maxpool":
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max,
                    (1, spec.pool, spec.pool, 1), (1, spec.pool, spec.pool, 1), "VALID",
                )
            elif spec.kind == "flatten":
                x = x.reshape(x.shape[0], -1)
        return x, run_scale


# ---------------------------------------------------------------------------
# Training helpers (used by the paper-repro example + tests)
# ---------------------------------------------------------------------------


def xent_loss(net: SequentialNet, params, batch, dropout_key=None):
    logits = net.apply(params, batch["x"], train=dropout_key is not None, dropout_key=dropout_key)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    return jnp.mean(logz - tgt)


def accuracy(net: SequentialNet, params, x, y) -> float:
    logits = net.apply(params, x)
    return float(jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32)))
