"""Attention: GQA/MQA/MHA with RoPE, chunked-causal (flash-style) training
path, KV-cache decode, cross-attention, and context-parallel-friendly
shardings (the KV sequence axis may be sharded; softmax normalization is
expressed with max/sum reductions XLA SPMD can lower to collectives).
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import Params, init_dense, dense

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2).astype(jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (..., seq, 1, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    *,
    bias: bool = False,
    dtype=jnp.float32,
) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, bias=bias, dtype=dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, bias=bias, dtype=dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, bias=bias, dtype=dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, bias=bias, dtype=dtype),
    }


def KVCache(k: jax.Array, v: jax.Array) -> dict:
    """KV cache as a plain dict: stable pytree key paths ('kv/k', 'kv/v')
    are what the sharding rules match on (NamedTuples flatten to positional
    keys, which silently bypassed the cache sharding rules — see §Perf)."""
    return {"k": k, "v": v}


def init_kv_cache(
    batch: int,
    max_len: int,
    n_kv: int,
    head_dim: int,
    dtype=None,
    *,
    quantized=None,
) -> Any:
    """Zero decode cache: a dense ``KVCache`` dict or a PVQ-packed
    ``core.packed.PackedKV``.

    dtype: cache storage dtype; ``None`` means bf16.  The dtype stored here
      is authoritative — every append in ``attention_decode`` casts the new
      K/V rows to the *cache* dtype, so an explicitly f32 cache stays f32
      even when the projections compute in bf16 (and vice versa).  For a
      packed cache, ``dtype`` governs the exact tail ring; the pulse planes
      are int8/f32 by construction.
    quantized: ``None`` defers to ``core.quantize.default_kv_quant()`` (set
      process-wide by ``serve --kv-pvq`` / ``kv_quant_scope``); ``False``
      forces a dense cache regardless of the default (cross-attention KV is
      read in full every step and never appended — it stays dense); ``True``
      uses the default ``KVQuant()``; a ``KVQuant`` instance wins outright.
    """
    from repro.core.quantize import KVQuant, default_kv_quant

    if dtype is None:
        dtype = jnp.bfloat16
    if quantized is None:
        quantized = default_kv_quant()
    if quantized is True:
        quantized = KVQuant()
    if quantized:
        from repro.core.packed import PackedKV

        return PackedKV.init(
            batch, max_len, n_kv, head_dim, kvq=quantized, dtype=dtype
        )
    shape = (batch, max_len, n_kv, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(b, s, n_kv, hd) -> (b, s, n_heads, hd) by repeating each kv head.

    Kept only as a reference path; the attention functions below use grouped
    einsums instead (materializing the expanded KV forces SPMD resharding
    copies and n_heads/n_kv x the HBM traffic — confirmed in the §Perf log).
    """
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def _group_q(q: jax.Array, n_kv: int) -> jax.Array:
    """(b, s, h, hd) -> (b, s, n_kv, h//n_kv, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _prefer_grouped(h: int, n_kv: int) -> bool:
    """Grouped-q einsums avoid n_heads/n_kv x KV traffic, BUT splitting the
    head axis (h -> n_kv x g) makes an h-divisible model sharding
    inexpressible, forcing SPMD to all-reduce the full q tensor (measured
    +4.3GB/layer on granite prefill, §Perf).  Prefer the expanded-KV path
    exactly when h shards cleanly and n_kv does not."""
    from repro.parallel import active_mesh

    mesh = active_mesh()
    if mesh is None:
        return True
    tp = mesh.shape.get("model", 1)
    if h % tp == 0 and n_kv % tp != 0:
        return False
    return True


def full_causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float, q_offset: int = 0,
    prefix_len: int = 0,
) -> jax.Array:
    """Reference attention with grouped-query einsums (no KV expansion).

    q: (b, s, h, hd); k/v: (b, s, n_kv, hd) with n_kv | h.
    prefix_len > 0 gives a prefix-LM mask (bidirectional over the first
    ``prefix_len`` keys, causal after) — used by the VLM prefix.
    """
    b, sq, h, hd = q.shape
    sk, n_kv = k.shape[1], k.shape[2]
    if not _prefer_grouped(h, n_kv):
        k, v = _expand_kv(k, h), _expand_kv(v, h)
        n_kv = h
    qg = _group_q(q, n_kv)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = kpos[None, :] <= qpos[:, None]
    if prefix_len:
        mask = mask | (kpos[None, :] < prefix_len)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


def chunked_causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float,
    q_chunk: int = 512,
    prefix_len: int = 0,
) -> jax.Array:
    """Flash-style attention: scan over query chunks; per chunk, masked
    softmax over all keys <= chunk end.  Peak memory O(q_chunk * seq) instead
    of O(seq^2).  Exact (not an approximation).
    """
    b, s, h, hd = q.shape
    if s % q_chunk != 0:
        # largest divisor of s that is <= q_chunk and a multiple of 128 —
        # e.g. the VLM's 4096+256-patch sequence picks 256 instead of
        # silently falling back to full O(s^2) attention (9.7TB of scores on
        # the paligemma train cell, §Perf)
        q_chunk = next(
            (c for c in range(q_chunk - q_chunk % 128, 127, -128) if s % c == 0), 0
        )
    if not q_chunk or s <= q_chunk:
        return full_causal_attention(q, k, v, scale=scale, prefix_len=prefix_len)
    n_kv = k.shape[2]
    if not _prefer_grouped(h, n_kv):
        k, v = _expand_kv(k, h), _expand_kv(v, h)
        n_kv = h
    g = h // n_kv
    nq = s // q_chunk
    qc = _group_q(q, n_kv).reshape(b, nq, q_chunk, n_kv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    kpos = jnp.arange(s)

    def one_chunk(i, qi):
        # qi: (b, c, n_kv, g, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qi, k, preferred_element_type=jnp.float32) * scale
        qpos = i * q_chunk + jnp.arange(q_chunk)
        mask = kpos[None, :] <= qpos[:, None]
        if prefix_len:
            mask = mask | (kpos[None, :] < prefix_len)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)

    out = jax.lax.map(lambda iv: one_chunk(iv[0], iv[1]), (jnp.arange(nq), qc))
    dv = v.shape[-1]  # may differ from the qk head dim (MLA)
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dv)


def decode_attention(
    q: jax.Array,  # (b, 1, h, hd)
    cache_k: jax.Array,  # (b, S, n_kv, hd)  (may be seq-sharded)
    cache_v: jax.Array,
    *,
    scale: float,
    length: Optional[jax.Array] = None,
) -> jax.Array:
    """One-token attention against a cache of S entries.

    Softmax over the (possibly sharded) S axis is written with explicit
    max/exp/sum so SPMD inserts all-reduce(max) + all-reduce(sum) when the
    cache is context/sequence-parallel sharded.  Grouped-query einsums: the
    KV cache is never expanded to n_heads.

    When the cache sequence axis is sharded (cp over data / cache_seq_tp
    over model), q and the scores are explicitly constrained so the S-axis
    sharding wins — without this SPMD resolves the model-axis conflict
    between head-sharded q and seq-sharded KV by all-gathering the entire
    cache per token (measured: 1.3TB/step on granite-8b decode, §Perf).
    """
    from repro.parallel import constrain, current_policy

    b, sq, h, hd = q.shape
    n_kv = cache_k.shape[2]
    qg = _group_q(q, n_kv)
    seq_sharded = current_policy().cache_seq_tp or current_policy().context_parallel
    if seq_sharded:
        qg = constrain(qg, "dp", None, None, None, None)  # replicate q over model
    scores = jnp.einsum(
        "bqhgd,bkhd->bqhgk", qg, cache_k, preferred_element_type=jnp.float32
    ) * scale
    if seq_sharded:
        scores = constrain(scores, "dp", None, None, None, "seq")
    if length is not None:
        valid = jnp.arange(cache_k.shape[1])[None, :] < length[:, None]  # (b, S)
        scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    probs = (e / denom).astype(cache_v.dtype)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", probs, cache_v)
    if seq_sharded:
        out = constrain(out, "dp", None, None, None, None)
    return out.reshape(b, sq, h, cache_v.shape[-1])


def decode_attention_packed(
    q: jax.Array,  # (b, sq, h, hd) float queries
    kv,  # core.packed.PackedKV | core.packed.PagedKV
    *,
    scale: float,
    length: jax.Array,  # (b,) int: valid cache rows per batch (ragged mask)
    filled: Optional[jax.Array] = None,  # scalar | (b,) int: physical fill
    exact: Optional[bool] = None,
) -> jax.Array:
    """Decode attention over a PVQ-packed KV cache (kernel v4 fast path).

    Two legs merged by online softmax:

    * packed leg — positions ``< packed_end(length)`` via
      ``ops.pvq_attn_decode``: int8 queries x int8 K pulses for scores,
      int8 probs x int8 V pulses for outputs, int32 MXU accumulation, each
      rho applied once per group.  The kernel returns UNNORMALIZED
      ``(acc, m, l)`` per query row.
    * tail leg — the in-flight partial block, exact in f32 against the tail
      ring (ring slot of position ``p`` is ``p % block``; since
      ``packed_end`` is block-aligned, tail position ``packed_end + t``
      lives at slot ``t``).

    ``out = (acc_p * e^(m_p - M) + acc_t) / (l_p * e^(m_p - M) + l_t)`` with
    ``M = max(m_p, m_t)`` — exactly the flash-attention merge, so the split
    point is invisible in the output.  The grouped-query layout is preserved
    throughout (the packed cache is never expanded to n_heads).

    ``filled`` is the PHYSICAL fill count (uniform across the batch on the
    streaming decode path: ``pos + 1``) — it alone determines where the
    packed planes end and the tail ring begins.  ``length`` is the per-row
    validity mask and may be ragged (``length <= filled``): positions in
    ``[packed_end(length), min(length, packed_end(filled)))`` live in the
    *planes*, so the kernel masks on ``min(length, packed_end(filled))``
    while the tail leg masks on ``length - packed_end(filled)``.  When
    ``filled`` is omitted it defaults to ``max(length)`` — correct whenever
    the cache was filled exactly up to the longest row.  On the slot-pool
    engine path ``filled`` is per-slot ``(b,)`` (every slot fills its own
    pages at its own position) and ``kv`` is a ``PagedKV`` whose planes are
    gathered through the page table at the ``ops`` dispatch boundary; the
    tail ring is slot-indexed in both containers and is read directly.

    ``exact=True`` (or env ``REPRO_KV_PVQ_EXACT=1``) instead dequantizes the
    whole cache through ``PackedKV.dense_kv`` and runs the dense
    ``decode_attention`` — the debugging/ablation oracle for the kernel.
    """
    import os

    from repro.kernels import ops

    if filled is None:
        filled = jnp.max(length)
    if exact is None:
        exact = os.environ.get("REPRO_KV_PVQ_EXACT", "") not in ("", "0", "false")
    if exact:
        kd, vd = kv.dense_kv(filled, dtype=jnp.float32)
        return decode_attention(q, kd, vd, scale=scale, length=length)

    b, sq, h, hd = q.shape
    n_kv = kv.tail_k.shape[-2]
    blk = kv.block
    pe = kv.packed_end(filled)  # scalar block-aligned packed extent
    kv_len = jnp.minimum(pe, length)  # (b,) packed rows visible per batch

    acc_p, m_p, l_p = ops.pvq_attn_decode(q, kv, kv_len, sm_scale=scale)
    # shapes: (b, sq, n_kv, g, hd) / (b, sq, n_kv, g, 1) x2

    # exact tail leg over the f32 ring: slot t holds position pe + t,
    # valid while pe + t < length
    qg = _group_q(q, n_kv).astype(jnp.float32)
    tk = kv.tail_k.astype(jnp.float32)  # (b, blk, n_kv, hd)
    tv = kv.tail_v.astype(jnp.float32)
    s_t = jnp.einsum(
        "bqhgd,bthd->bqhgt", qg, tk, preferred_element_type=jnp.float32
    ) * scale
    valid = jnp.arange(blk)[None, :] < (length - pe)[:, None]  # (b, blk)
    s_t = jnp.where(valid[:, None, None, None, :], s_t, NEG_INF)
    m_t = jnp.max(s_t, axis=-1, keepdims=True)

    m_tot = jnp.maximum(m_p, m_t)
    # NEG_INF is finite: zero masked probs via the mask, never via exp()
    p_t = jnp.where(
        valid[:, None, None, None, :], jnp.exp(s_t - m_tot), 0.0
    )
    l_t = jnp.sum(p_t, axis=-1, keepdims=True)
    acc_t = jnp.einsum("bqhgt,bthd->bqhgd", p_t, tv)

    alpha = jnp.exp(m_p - m_tot)  # 0 when the packed leg is empty (m_p=NEG_INF)
    out = (acc_p * alpha + acc_t) / (l_p * alpha + l_t)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full module apply
# ---------------------------------------------------------------------------


def attention_forward(
    p: Params,
    x: jax.Array,  # (b, s, d)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float] = 10000.0,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    q_chunk: int = 512,
    head_constraint=None,
    softmax_scale: Optional[float] = None,
    prefix_len: int = 0,
) -> jax.Array:
    """Training/prefill self-attention (full sequence)."""
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = dense(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if head_constraint is not None:
        q, k, v = head_constraint(q), head_constraint(k), head_constraint(v)
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(head_dim)
    if causal:
        out = chunked_causal_attention(q, k, v, scale=scale, q_chunk=q_chunk, prefix_len=prefix_len)
    else:
        qg = _group_q(q, n_kv_heads)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32) * scale
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v).reshape(b, s, n_heads, head_dim)
    return dense(p["wo"], out.reshape(b, s, n_heads * head_dim))


def attention_prefill_cache(
    p: Params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float] = 10000.0,
    quantized=None,
) -> Any:
    """Prompt-time KV cache.  ``quantized`` follows the
    :func:`init_kv_cache` contract — when a KVQuant is active the prompt's
    full blocks are PVQ-encoded immediately (``PackedKV.from_dense``) and
    only the ragged remainder lands in the f32 tail ring."""
    b, s, _ = x.shape
    k = dense(p["wk"], x).reshape(b, s, n_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(b, s, n_kv_heads, head_dim)
    if rope_theta is not None:
        k = apply_rope(k, jnp.arange(s)[None, :], rope_theta)
    from repro.core.quantize import KVQuant, default_kv_quant

    if quantized is None:
        quantized = default_kv_quant()
    if quantized is True:
        quantized = KVQuant()
    if quantized:
        from repro.core.packed import PackedKV

        return PackedKV.from_dense(k, v, kvq=quantized)
    return KVCache(k=k, v=v)


def attention_decode(
    p: Params,
    x: jax.Array,  # (b, 1, d)
    cache: dict,
    pos: jax.Array,  # scalar int32 (lockstep batch) | (b,) int32 (slot pool)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float] = 10000.0,
    update_cache: bool = True,
    softmax_scale: Optional[float] = None,
) -> tuple[jax.Array, dict]:
    """Single-token decode with cache append at ``pos``.

    ``pos`` may be a per-slot vector ``(b,)`` — the continuous-batching
    engine's slot pool, where every batch row sits at its own sequence
    position.  RoPE, the cache append, and the attention length mask are
    all per-row in that case; the scalar form is the fixed-batch lockstep
    special case.
    """
    b = x.shape[0]
    q = dense(p["wq"], x).reshape(b, 1, n_heads, head_dim)
    k = dense(p["wk"], x).reshape(b, 1, n_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(b, 1, n_kv_heads, head_dim)
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    posb = jnp.broadcast_to(pos.reshape(-1, 1), (b, 1))
    if rope_theta is not None:
        q = apply_rope(q, posb, rope_theta)
        k = apply_rope(k, posb, rope_theta)
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(head_dim)
    length = posb[:, 0] + 1  # (b,)
    from repro.core.packed import is_packed_kv, is_paged_kv

    if is_paged_kv(cache):
        # slot-pool fast path: per-slot tail-ring append with masked
        # block-encode scatter to the allocator's pre-assigned write_page,
        # then the kernel-v4 contraction through the page table.  Each
        # slot's physical fill IS its own position count.
        if update_cache:
            cache = cache.append(k, v, posb[:, 0])
        out = decode_attention_packed(
            q, cache, scale=scale, length=length, filled=length
        )
        y = dense(p["wo"], out.reshape(b, 1, n_heads * head_dim))
        return y, cache
    if is_packed_kv(cache):
        if per_slot:
            raise NotImplementedError(
                "per-slot positions need the paged slot-pool cache (PagedKV); "
                "PackedKV appends are lockstep (scalar pos)"
            )
        # packed fast path: append into the tail ring (encode-on-block-fill
        # happens inside PackedKV.append), then the kernel-v4 contraction
        if update_cache:
            cache = cache.append(k, v, pos)
        out = decode_attention_packed(
            q, cache, scale=scale, length=length, filled=pos + 1
        )
        y = dense(p["wo"], out.reshape(b, 1, n_heads * head_dim))
        return y, cache
    if update_cache:
        # the cast follows the CACHE dtype, never the projection dtype: an
        # explicitly f32 cache must not be silently downcast to bf16 here
        if per_slot:
            upd = jax.vmap(
                lambda c, row, pp: jax.lax.dynamic_update_slice_in_dim(
                    c, row, pp, axis=0
                )
            )
            ck = upd(cache["k"], k.astype(cache["k"].dtype), pos)
            cv = upd(cache["v"], v.astype(cache["v"].dtype), pos)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        cache = KVCache(k=ck, v=cv)
    out = decode_attention(q, cache["k"], cache["v"], scale=scale, length=length)
    y = dense(p["wo"], out.reshape(b, 1, n_heads * head_dim))
    return y, cache


def attention_prefill_chunk(
    p: Params,
    x: jax.Array,  # (1, C, d) — one slot's chunk of C = page-multiple tokens
    cache,  # core.packed.PagedKV (unstacked layer slice)
    *,
    slot: jax.Array,
    start: jax.Array,  # page-aligned absolute position of the chunk's first token
    page_ids: jax.Array,  # (C // page,) physical destinations, trash-padded
    real_len: jax.Array,  # total context length (absolute)
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: Optional[float] = 10000.0,
    softmax_scale: Optional[float] = None,
) -> tuple[jax.Array, Any]:
    """Chunked prefill over a partially-packed paged context.

    The chunked-prefill scheduler streams a long prompt through the paged
    slot pool ``C`` tokens at a time, interleaved with decode steps.  Each
    chunk call:

    1. projects/ropes the chunk at absolute positions
       ``start .. start + C - 1``,
    2. PVQ-grafts its complete blocks straight into the allocator's
       pre-assigned pages (:meth:`PagedKV.graft_chunk` — bit-identical to
       the whole-prompt graft; the final chunk's ragged remainder lands
       exactly in the slot's f32 tail ring),
    3. attends with two legs merged by online softmax (the same
       flash-style merge ``decode_attention_packed`` uses):

       * **packed leg** — the slot's prior chunks ``[0, start)`` read
         through the page table via the kernel-v4 contraction
         (``ops.pvq_attn_decode`` on a single-slot gather; ``start`` is
         page-aligned, so there is never a partial tail to read), and
       * **chunk leg** — exact causal f32 attention within the chunk
         (padded rows past ``real_len`` compute garbage that stays
         behind the engine's masks, same as bucketed prefill padding).

    Returns ``(y (1, C, d), updated cache)``.
    """
    from repro.kernels import ops

    b, C, _ = x.shape
    q = dense(p["wq"], x).reshape(b, C, n_heads, head_dim)
    k = dense(p["wk"], x).reshape(b, C, n_kv_heads, head_dim)
    v = dense(p["wv"], x).reshape(b, C, n_kv_heads, head_dim)
    start = jnp.asarray(start, jnp.int32)
    positions = start + jnp.arange(C)[None, :]
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(head_dim)

    cache = cache.graft_chunk(k, v, slot, page_ids, start, real_len)

    # packed leg: prior context [0, start) — pages the earlier chunks (or
    # shared-prefix mappings) already wrote.  kv_len == start masks out
    # this chunk's own freshly-grafted pages and any unwritten ones.
    acc_p, m_p, l_p = ops.pvq_attn_decode(
        q, cache.gather_slot(slot), jnp.reshape(start, (1,)), sm_scale=scale
    )  # (1, C, n_kv, gpr, hd) / (..., 1) / (..., 1)

    # exact causal intra-chunk leg (every query row sees at least its own
    # diagonal, so the merged denominator is never zero)
    qg = _group_q(q, n_kv_heads).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s_c = jnp.einsum(
        "bqhgd,bkhd->bqhgk", qg, kf, preferred_element_type=jnp.float32
    ) * scale
    causal = jnp.arange(C)[None, :] <= jnp.arange(C)[:, None]  # (q, k)
    mask = causal[None, :, None, None, :]
    s_c = jnp.where(mask, s_c, NEG_INF)
    m_c = jnp.max(s_c, axis=-1, keepdims=True)
    m_tot = jnp.maximum(m_p, m_c)
    p_c = jnp.where(mask, jnp.exp(s_c - m_tot), 0.0)
    l_c = jnp.sum(p_c, axis=-1, keepdims=True)
    acc_c = jnp.einsum("bqhgk,bkhd->bqhgd", p_c, vf)
    alpha = jnp.exp(m_p - m_tot)  # 0 for the first chunk (m_p == NEG_INF)
    out = (acc_p * alpha + acc_c) / (l_p * alpha + l_c)
    out = out.reshape(b, C, n_heads, head_dim).astype(q.dtype)
    y = dense(p["wo"], out.reshape(b, C, n_heads * head_dim))
    return y, cache


# ---------------------------------------------------------------------------
# Cross attention (enc-dec)
# ---------------------------------------------------------------------------


def cross_attention_forward(
    p: Params,
    x: jax.Array,  # decoder states (b, s, d)
    enc_kv: dict,  # precomputed from encoder output
    *,
    n_heads: int,
    head_dim: int,
) -> jax.Array:
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, n_heads, head_dim)
    scale = 1.0 / math.sqrt(head_dim)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, enc_kv["k"], preferred_element_type=jnp.float32) * scale
    probs = jax.nn.softmax(scores, axis=-1).astype(enc_kv["v"].dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, enc_kv["v"])
    return dense(p["wo"], out.reshape(b, s, n_heads * head_dim))


def cross_kv(p: Params, enc_out: jax.Array, *, n_heads: int, head_dim: int) -> dict:
    b, s, _ = enc_out.shape
    k = dense(p["wk"], enc_out).reshape(b, s, n_heads, head_dim)
    v = dense(p["wv"], enc_out).reshape(b, s, n_heads, head_dim)
    return KVCache(k=k, v=v)
