"""Block assembly and scan-over-layers stacks for every assigned family.

A model is a list of *segments*; each segment is (repeats, pattern) where the
pattern is a tuple of BlockSpecs.  Per-segment parameters are stacked along a
leading ``repeats`` axis and executed with ``lax.scan`` (+ remat in training),
keeping the lowered HLO compact regardless of depth — essential for the
512-device dry-run compiles.

Families:
    dense / vlm      -> [(L, (attn+ffn,))]
    moe (DeepSeek)   -> [(first_dense, (mla+dense0,)), (L-k, (mla+moe,))]
    hybrid (Jamba)   -> [(L/p, (p-long super-block: attn at p/2, mamba else,
                          MoE on odd slots))]
    ssm (RWKV6)      -> [(L, (rwkv+cmix,))]
    encdec (Whisper) -> encoder [(Le, (attn_nc+ffn,))] + decoder
                        [(Ld, (attn+cross+ffn,))]
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.parallel import constrain
from repro.configs.base import ModelConfig

from . import attention as attn_lib
from . import layers as L
from . import mamba as mamba_lib
from . import mla as mla_lib
from . import moe as moe_lib
from . import rwkv as rwkv_lib
from .attention import KVCache
from .layers import Params


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str  # 'attn' | 'mla' | 'mamba' | 'rwkv'
    ffn: str  # 'dense' | 'dense0' | 'moe' | 'cmix' | 'none'
    causal: bool = True
    cross: bool = False


Segment = Tuple[int, Tuple[BlockSpec, ...]]


def segment_plan(cfg: ModelConfig, role: str = "decoder") -> List[Segment]:
    if role == "encoder":
        return [(cfg.encoder_layers, (BlockSpec("attn", "dense", causal=False),))]
    if cfg.rwkv is not None:
        return [(cfg.n_layers, (BlockSpec("rwkv", "cmix"),))]
    if cfg.hybrid_period:
        p = cfg.hybrid_period
        pat = tuple(
            BlockSpec(
                "attn" if i == p // 2 else "mamba",
                "moe" if (cfg.moe is not None and i % cfg.moe_period == cfg.moe_period - 1) else "dense",
            )
            for i in range(p)
        )
        assert cfg.n_layers % p == 0, "hybrid layers must divide the super-block"
        return [(cfg.n_layers // p, pat)]
    mixer = "mla" if cfg.mla is not None else "attn"
    if cfg.moe is not None:
        segs: List[Segment] = []
        if cfg.first_dense:
            segs.append((cfg.first_dense, (BlockSpec(mixer, "dense0"),)))
        segs.append((cfg.n_layers - cfg.first_dense, (BlockSpec(mixer, "moe"),)))
        return segs
    cross = cfg.encoder_layers > 0
    return [(cfg.n_layers, (BlockSpec(mixer, "dense", cross=cross),))]


# ---------------------------------------------------------------------------
# Norm dispatch
# ---------------------------------------------------------------------------


def _init_norm(cfg: ModelConfig, dtype) -> Params:
    return L.init_layernorm(cfg.d_model, dtype) if cfg.norm == "layernorm" else L.init_rmsnorm(cfg.d_model, dtype)


def _norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    return L.layernorm(p, x) if cfg.norm == "layernorm" else L.rmsnorm(p, x)


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, spec: BlockSpec) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {"ln_mix": _init_norm(cfg, dtype)}
    if spec.mixer == "attn":
        p["mixer"] = attn_lib.init_attention(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
            bias=cfg.attn_bias, dtype=dtype,
        )
    elif spec.mixer == "mla":
        p["mixer"] = mla_lib.init_mla(ks[0], d, cfg.n_heads, cfg.mla, dtype=dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_lib.init_mamba(ks[0], d, cfg.ssm, dtype=dtype)
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv_lib.init_rwkv_time_mix(ks[0], d, cfg.rwkv, dtype=dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.cross:
        p["ln_cross"] = _init_norm(cfg, dtype)
        p["cross"] = attn_lib.init_attention(
            ks[1], d, cfg.n_heads, cfg.n_heads, cfg.resolved_head_dim,
            bias=cfg.attn_bias, dtype=dtype,
        )
    if spec.ffn != "cmix":
        p["ln_ffn"] = _init_norm(cfg, dtype)
    if spec.ffn == "dense":
        p["ffn"] = L.init_ffn(ks[2], d, cfg.d_ff, cfg.ffn_activation, bias=cfg.attn_bias, dtype=dtype)
    elif spec.ffn == "dense0":
        p["ffn"] = L.init_ffn(ks[2], d, cfg.d_ff_dense or cfg.d_ff, cfg.ffn_activation, dtype=dtype)
    elif spec.ffn == "moe":
        p["ffn"] = moe_lib.init_moe(ks[2], d, cfg.moe, dtype=dtype)
    elif spec.ffn == "cmix":
        p["ln_ffn"] = _init_norm(cfg, dtype)
        p["ffn"] = rwkv_lib.init_rwkv_channel_mix(ks[2], d, cfg.d_ff, dtype=dtype)
    elif spec.ffn != "none":
        raise ValueError(spec.ffn)
    return p


# ---------------------------------------------------------------------------
# Block apply: forward (train/prefill)
# ---------------------------------------------------------------------------


def _head_constraint(t):
    return constrain(t, "dp", None, "tp", None)


def _ffn_hidden_constraint(t):
    return constrain(t, "dp", None, "tp")


def _expert_constraint(t):
    return constrain(t, "dp", "tp", None, None)


def block_forward(
    cfg: ModelConfig,
    spec: BlockSpec,
    p: Params,
    x: jax.Array,
    *,
    mode: str,  # 'train' | 'prefill'
    enc_out: Optional[jax.Array] = None,
    prefix_len: int = 0,
    q_chunk: int = 512,
    rng: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, Optional[Dict[str, Any]]]:
    """Returns (x, aux_loss, cache_entry_or_None).

    ``rng`` (train only) feeds stochastic layer features — currently the
    MoE router jitter; None keeps every layer deterministic."""
    aux = jnp.zeros((), jnp.float32)
    cache: Dict[str, Any] = {}
    h = _norm(cfg, p["ln_mix"], x)

    if spec.mixer == "attn":
        y = attn_lib.attention_forward(
            p["mixer"], h,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, causal=spec.causal, q_chunk=q_chunk,
            head_constraint=_head_constraint, prefix_len=prefix_len,
        )
        if mode == "prefill":
            cache["kv"] = attn_lib.attention_prefill_cache(
                p["mixer"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            )
    elif spec.mixer == "mla":
        y = mla_lib.mla_forward(p["mixer"], h, n_heads=cfg.n_heads, cfg=cfg.mla, q_chunk=q_chunk)
        if mode == "prefill":
            cache["mla"] = mla_lib.mla_prefill_cache(p["mixer"], h, cfg.mla)
    elif spec.mixer == "mamba":
        if mode == "prefill":
            y, mc = mamba_lib.mamba_forward(p["mixer"], h, cfg.ssm, return_state=True)
            cache["mamba"] = mc
        else:
            y = mamba_lib.mamba_forward(p["mixer"], h, cfg.ssm)
    elif spec.mixer == "rwkv":
        if mode == "prefill":
            y, state = rwkv_lib.rwkv_time_mix(p["mixer"], h, cfg.rwkv, return_state=True)
            cache["rwkv_state"] = state
            cache["rwkv_shift_att"] = h[:, -1, :]
        else:
            y = rwkv_lib.rwkv_time_mix(p["mixer"], h, cfg.rwkv)
    else:
        raise ValueError(spec.mixer)
    # name the (TP-psum'd) mixer output so the 'collectives' remat policy can
    # save exactly these — recomputing them in the bwd pass repeats their
    # all-reduces (measured +50% collective bytes on the 236B cell, §Perf).
    # Under SP, constrain the psum'd output itself to the seq-sharded layout
    # so the partitioner lowers dot+psum as a reduce-scatter instead of
    # all-reduce-then-slice (+all-gather) — measured 1.7TB of redundant
    # gathers otherwise.
    y = constrain(y, "dp", "sp", None)
    y = jax.ad_checkpoint.checkpoint_name(y, "mixer_out")
    x = x + y
    x = constrain(x, "dp", "sp", None)

    if spec.cross:
        h = _norm(cfg, p["ln_cross"], x)
        enc_kv = attn_lib.cross_kv(p["cross"], enc_out, n_heads=cfg.n_heads, head_dim=cfg.resolved_head_dim)
        y = attn_lib.cross_attention_forward(p["cross"], h, enc_kv, n_heads=cfg.n_heads, head_dim=cfg.resolved_head_dim)
        x = x + y
        if mode == "prefill":
            cache["cross"] = enc_kv

    if spec.ffn != "none":
        h = _norm(cfg, p["ln_ffn"], x)
        if spec.ffn in ("dense", "dense0"):
            y = L.ffn(p["ffn"], h, cfg.ffn_activation, hidden_constraint=_ffn_hidden_constraint)
        elif spec.ffn == "moe":
            y, aux_moe = moe_lib.moe_forward(
                p["ffn"], h, cfg.moe, expert_constraint=_expert_constraint,
                train=(mode == "train"), rng=rng,
            )
            aux = aux + aux_moe
        elif spec.ffn == "cmix":
            y = rwkv_lib.rwkv_channel_mix(p["ffn"], h)
            if mode == "prefill":
                cache["rwkv_shift_ffn"] = h[:, -1, :]
        y = constrain(y, "dp", "sp", None)
        y = jax.ad_checkpoint.checkpoint_name(y, "ffn_out")
        x = x + y
        x = constrain(x, "dp", "sp", None)

    return x, aux, (cache if mode == "prefill" else None)


# ---------------------------------------------------------------------------
# Block apply: single-token decode
# ---------------------------------------------------------------------------


def block_decode(
    cfg: ModelConfig,
    spec: BlockSpec,
    p: Params,
    x: jax.Array,  # (b, 1, d)
    cache: Dict[str, Any],
    pos: jax.Array,
) -> Tuple[jax.Array, Dict[str, Any]]:
    new_cache = dict(cache)
    h = _norm(cfg, p["ln_mix"], x)

    if spec.mixer == "attn":
        y, kv = attn_lib.attention_decode(
            p["mixer"], h, cache["kv"], pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        )
        new_cache["kv"] = kv
    elif spec.mixer == "mla":
        y, mc = mla_lib.mla_decode(p["mixer"], h, cache["mla"], pos, n_heads=cfg.n_heads, cfg=cfg.mla)
        new_cache["mla"] = mc
    elif spec.mixer == "mamba":
        y, mc = mamba_lib.mamba_decode(p["mixer"], h, cache["mamba"], cfg.ssm)
        new_cache["mamba"] = mc
    elif spec.mixer == "rwkv":
        y, state = rwkv_lib.rwkv_time_mix(
            p["mixer"], h, cfg.rwkv,
            x_prev=cache["rwkv_shift_att"].astype(h.dtype), state=cache["rwkv_state"],
            return_state=True,
        )
        new_cache["rwkv_state"] = state
        new_cache["rwkv_shift_att"] = h[:, -1, :]
    else:
        raise ValueError(spec.mixer)
    x = x + y

    if spec.cross:
        h = _norm(cfg, p["ln_cross"], x)
        y = attn_lib.cross_attention_forward(
            p["cross"], h, cache["cross"], n_heads=cfg.n_heads, head_dim=cfg.resolved_head_dim
        )
        x = x + y

    if spec.ffn != "none":
        h = _norm(cfg, p["ln_ffn"], x)
        if spec.ffn in ("dense", "dense0"):
            y = L.ffn(p["ffn"], h, cfg.ffn_activation)
        elif spec.ffn == "moe":
            y, _ = moe_lib.moe_forward(p["ffn"], h, cfg.moe)
        elif spec.ffn == "cmix":
            y = rwkv_lib.rwkv_channel_mix(p["ffn"], h, x_prev=cache["rwkv_shift_ffn"].astype(h.dtype))
            new_cache["rwkv_shift_ffn"] = h[:, -1, :]
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# Cache zero-init (decode entry point without a prefill)
# ---------------------------------------------------------------------------


def init_block_cache(
    cfg: ModelConfig, spec: BlockSpec, batch: int, cache_len: int, enc_len: int = 0,
    *, paged: Optional[Tuple[int, int]] = None,
) -> Dict[str, Any]:
    """``paged=(n_pages, max_pages)`` builds the continuous-batching
    slot-pool cache instead: the attention KV cache becomes a
    ``core.packed.PagedKV`` physical-page pool (``batch`` is the slot
    count; requires an active ``KVQuant`` default because pages are PVQ
    encode blocks).  Mixers without a paged representation (ssm/rwkv/mla
    recurrent state, cross-attention) are rejected — the engine is
    attention-family only for now."""
    dtype = jnp.dtype(cfg.compute_dtype)
    c: Dict[str, Any] = {}
    if paged is not None and (spec.mixer != "attn" or spec.cross):
        raise NotImplementedError(
            f"paged slot-pool cache supports plain attention blocks only, "
            f"got mixer={spec.mixer!r} cross={spec.cross}"
        )
    if spec.mixer == "attn":
        if paged is not None:
            from repro.core.packed import PagedKV
            from repro.core.quantize import default_kv_quant

            kvq = default_kv_quant()
            if kvq is None:
                raise ValueError(
                    "paged slot-pool cache needs an active KVQuant default "
                    "(pages are PVQ blocks) — set_default_kv_quant(...) first"
                )
            n_pages, max_pages = paged
            c["kv"] = PagedKV.init(
                batch, n_pages, max_pages, cfg.n_kv_heads,
                cfg.resolved_head_dim, kvq=kvq, dtype=dtype,
            )
            return c
        c["kv"] = attn_lib.init_kv_cache(batch, cache_len, cfg.n_kv_heads, cfg.resolved_head_dim, dtype)
    elif spec.mixer == "mla":
        c["mla"] = mla_lib.MLACache(
            c_kv=jnp.zeros((batch, cache_len, cfg.mla.kv_lora_rank), dtype),
            k_rope=jnp.zeros((batch, cache_len, cfg.mla.rope_head_dim), dtype),
        )
    elif spec.mixer == "mamba":
        c["mamba"] = mamba_lib.init_mamba_cache(batch, cfg.d_model, cfg.ssm, dtype)
    elif spec.mixer == "rwkv":
        h = cfg.d_model // cfg.rwkv.head_size
        c["rwkv_state"] = jnp.zeros((batch, h, cfg.rwkv.head_size, cfg.rwkv.head_size), jnp.float32)
        c["rwkv_shift_att"] = jnp.zeros((batch, cfg.d_model), dtype)
    if spec.cross:
        # cross KV is written once from the encoder and read in full every
        # step (no append stream) — always dense, even under --kv-pvq
        c["cross"] = attn_lib.init_kv_cache(
            batch, enc_len, cfg.n_heads, cfg.resolved_head_dim, dtype, quantized=False
        )
    if spec.ffn == "cmix":
        c["rwkv_shift_ffn"] = jnp.zeros((batch, cfg.d_model), dtype)
    return c


# ---------------------------------------------------------------------------
# Segment runners (scan over stacked repeats)
# ---------------------------------------------------------------------------


def init_segment(key, cfg: ModelConfig, seg: Segment) -> Params:
    repeats, pattern = seg

    def init_one(k):
        kb = jax.random.split(k, len(pattern))
        return {f"b{i}": init_block(kb[i], cfg, spec) for i, spec in enumerate(pattern)}

    return jax.vmap(init_one)(jax.random.split(key, repeats))


def run_segment(
    cfg: ModelConfig,
    seg: Segment,
    seg_params: Params,
    x: jax.Array,
    *,
    mode: str,
    enc_out: Optional[jax.Array] = None,
    prefix_len: int = 0,
    q_chunk: int = 512,
    remat: bool = True,
    rng: Optional[jax.Array] = None,
):
    repeats, pattern = seg
    # per-layer keys ride the scan as xs (None is an empty pytree: the scan
    # signature is identical with or without stochastic layer features)
    keys = jax.random.split(rng, repeats) if rng is not None else None

    def body(carry, xs):
        x, aux = carry
        p_r, key_r = xs
        caches = {}
        for i, spec in enumerate(pattern):
            x, aux_i, c = block_forward(
                cfg, spec, p_r[f"b{i}"], x, mode=mode,
                enc_out=enc_out, prefix_len=prefix_len, q_chunk=q_chunk,
                rng=(None if key_r is None else jax.random.fold_in(key_r, i)),
            )
            aux = aux + aux_i
            if c is not None:
                caches[f"b{i}"] = c
        return (x, aux), (caches if mode == "prefill" else None)

    if mode == "train" and remat:
        from repro.parallel import current_policy

        rp = current_policy().remat
        if rp == "dots":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_saveable,
            )
        elif rp == "collectives":
            # save exactly the TP-psum'd block outputs (cheap (b,s,d) bf16);
            # attention scores / ffn hiddens still rematerialize
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "mixer_out", "ffn_out"
                ),
            )
        else:
            body = jax.checkpoint(body, prevent_cse=False)
    carry0 = (x, jnp.zeros((), jnp.float32))
    if cfg.unroll_layers:
        carry = carry0
        cache_list = []
        for r in range(repeats):
            p_r = jax.tree.map(lambda t: t[r], seg_params)
            carry, c = body(carry, (p_r, None if keys is None else keys[r]))
            cache_list.append(c)
        (x, aux) = carry
        caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
            if mode == "prefill"
            else None
        )
        return x, aux, caches
    (x, aux), caches = jax.lax.scan(body, carry0, (seg_params, keys))
    return x, aux, caches


def decode_segment(
    cfg: ModelConfig,
    seg: Segment,
    seg_params: Params,
    seg_cache: Params,
    x: jax.Array,
    pos: jax.Array,
):
    repeats, pattern = seg

    def body(x, pc):
        p_r, c_r = pc
        new_c = {}
        for i, spec in enumerate(pattern):
            x, c_i = block_decode(cfg, spec, p_r[f"b{i}"], x, c_r[f"b{i}"], pos)
            new_c[f"b{i}"] = c_i
        return x, new_c

    if cfg.unroll_layers:
        cache_list = []
        for r in range(repeats):
            p_r = jax.tree.map(lambda t: t[r], seg_params)
            c_r = jax.tree.map(lambda t: t[r], seg_cache)
            x, c = body(x, (p_r, c_r))
            cache_list.append(c)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
        return x, new_cache
    x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
    return x, new_cache


def block_chunk(
    cfg: ModelConfig,
    spec: BlockSpec,
    p: Params,
    x: jax.Array,  # (1, C, d)
    cache: Dict[str, Any],
    slot: jax.Array,
    start: jax.Array,
    page_ids: jax.Array,
    real_len: jax.Array,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Chunked-prefill twin of :func:`block_decode`: attention-family only
    (``init_block_cache(paged=...)`` already rejects every other mixer, so
    a chunk call can only ever see ``attn`` blocks)."""
    if spec.mixer != "attn" or spec.cross:
        raise NotImplementedError(
            f"chunked prefill supports plain attention blocks only, "
            f"got mixer={spec.mixer!r} cross={spec.cross}"
        )
    new_cache = dict(cache)
    h = _norm(cfg, p["ln_mix"], x)
    y, kv = attn_lib.attention_prefill_chunk(
        p["mixer"], h, cache["kv"], slot=slot, start=start,
        page_ids=page_ids, real_len=real_len,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
    )
    new_cache["kv"] = kv
    x = x + y

    if spec.ffn != "none":
        h = _norm(cfg, p["ln_ffn"], x)
        if spec.ffn in ("dense", "dense0"):
            y = L.ffn(p["ffn"], h, cfg.ffn_activation)
        elif spec.ffn == "moe":
            y, _ = moe_lib.moe_forward(p["ffn"], h, cfg.moe)
        else:
            raise NotImplementedError(f"chunked prefill: ffn {spec.ffn!r}")
        x = x + y
    return x, new_cache


def chunk_segment(
    cfg: ModelConfig,
    seg: Segment,
    seg_params: Params,
    seg_cache: Params,
    x: jax.Array,
    slot: jax.Array,
    start: jax.Array,
    page_ids: jax.Array,
    real_len: jax.Array,
):
    repeats, pattern = seg

    def body(x, pc):
        p_r, c_r = pc
        new_c = {}
        for i, spec in enumerate(pattern):
            x, c_i = block_chunk(
                cfg, spec, p_r[f"b{i}"], x, c_r[f"b{i}"],
                slot, start, page_ids, real_len,
            )
            new_c[f"b{i}"] = c_i
        return x, new_c

    if cfg.unroll_layers:
        cache_list = []
        for r in range(repeats):
            p_r = jax.tree.map(lambda t: t[r], seg_params)
            c_r = jax.tree.map(lambda t: t[r], seg_cache)
            x, c = body(x, (p_r, c_r))
            cache_list.append(c)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
        return x, new_cache
    x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
    return x, new_cache


def init_plan_cache(
    cfg: ModelConfig, plan: List[Segment], batch: int, cache_len: int, enc_len: int = 0,
    *, paged: Optional[Tuple[int, int]] = None,
):
    out = {}
    for si, (repeats, pattern) in enumerate(plan):
        entry = {
            f"b{i}": init_block_cache(cfg, spec, batch, cache_len, enc_len, paged=paged)
            for i, spec in enumerate(pattern)
        }
        out[f"seg{si}"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf[None], (repeats,) + leaf.shape), entry
        )
    return out
