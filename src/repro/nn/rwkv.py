"""RWKV-6 "Finch" block: attention-free time mixing with data-dependent decay.

Per head (head size M): state S in R^{M x M},
    y_t = r_t^T (S_t + diag(u) k_t v_t^T)
    S_{t+1} = diag(w_t) S_t + k_t v_t^T
where the decay w_t = exp(-exp(w0 + lora_w(x~_t))) is *data-dependent* (the
Finch contribution) and token-shift interpolation coefficients are themselves
produced by a small LoRA ("ddlerp").

The decay/bonus parameters (w0, u, loras) parameterize the recurrence, not a
dot product, so they are excluded from PVQ quantization (DESIGN.md
§Arch-applicability); the r/k/v/g/out projections and the channel-mix FFN are
PVQ-quantizable dense layers.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense, init_dense, init_layernorm, layernorm


class RWKVConfig(NamedTuple):
    head_size: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


class RWKVCache(NamedTuple):
    shift_att: jax.Array  # (b, d) last input to time-mix
    shift_ffn: jax.Array  # (b, d) last input to channel-mix
    wkv: jax.Array  # (b, h, m, m) state


def init_rwkv_time_mix(key, d_model: int, cfg: RWKVConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 12)
    h = d_model // cfg.head_size
    p: Params = {
        # ddlerp token-shift: 5 targets (r, w, k, v, g)
        "time_mix_base": (jnp.zeros((5, d_model)) + 0.5).astype(jnp.float32),
        "time_mix_w1": (jax.random.normal(ks[0], (d_model, 5 * cfg.mix_lora)) * 0.01).astype(dtype),
        "time_mix_w2": (jax.random.normal(ks[1], (5, cfg.mix_lora, d_model)) * 0.01).astype(dtype),
        # data-dependent decay lora
        "time_decay_base": jnp.zeros((d_model,), jnp.float32) - 6.0,
        "time_decay_w1": (jax.random.normal(ks[2], (d_model, cfg.decay_lora)) * 0.01).astype(dtype),
        "time_decay_w2": (jax.random.normal(ks[3], (cfg.decay_lora, d_model)) * 0.01).astype(dtype),
        "time_faaaa": jnp.zeros((h, cfg.head_size), jnp.float32) + 0.1,  # u bonus
        "wr": init_dense(ks[4], d_model, d_model, dtype=dtype),
        "wk": init_dense(ks[5], d_model, d_model, dtype=dtype),
        "wv": init_dense(ks[6], d_model, d_model, dtype=dtype),
        "wg": init_dense(ks[7], d_model, d_model, dtype=dtype),
        "out": init_dense(ks[8], d_model, d_model, dtype=dtype),
        "ln_x": init_layernorm(d_model, dtype),
    }
    return p


def init_rwkv_channel_mix(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "cmix_base": (jnp.zeros((2, d_model)) + 0.5).astype(jnp.float32),
        "wk": init_dense(ks[0], d_model, d_ff, dtype=dtype),
        "wv": init_dense(ks[1], d_ff, d_model, dtype=dtype),
        "wr": init_dense(ks[2], d_model, d_model, dtype=dtype),
    }


def _ddlerp(p: Params, x: jax.Array, x_prev: jax.Array, cfg: RWKVConfig):
    """Data-dependent token-shift mix for the 5 targets. Returns (5, b, s, d)."""
    dx = x_prev - x
    base = p["time_mix_base"].astype(jnp.float32)  # (5, d)
    xx = x + dx * base[0]  # seed mix (use the first row as the seed coeff)
    lora = jnp.tanh(dense({"kernel": p["time_mix_w1"]}, xx))  # (b,s,5*L)
    b, s, _ = x.shape
    lora = lora.reshape(b, s, 5, cfg.mix_lora)
    delta = jnp.einsum("bsfl,fld->fbsd", lora, p["time_mix_w2"].astype(lora.dtype))
    mixed = x[None] + dx[None] * (base[:, None, None, :] + delta.astype(jnp.float32))
    return mixed  # (5, b, s, d) f32


def _decay(p: Params, xw: jax.Array) -> jax.Array:
    """w_t in (0,1): exp(-exp(w0 + lora(xw))). xw: (b, s, d)."""
    lora = dense({"kernel": p["time_decay_w2"]}, jnp.tanh(dense({"kernel": p["time_decay_w1"]}, xw)))
    logw = p["time_decay_base"] + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def rwkv_time_mix(
    p: Params, x: jax.Array, cfg: RWKVConfig, *, x_prev: jax.Array | None = None,
    state: jax.Array | None = None, return_state: bool = False
):
    """x: (b, s, d).  x_prev: (b, d) last token of the previous segment."""
    b, s, d = x.shape
    m = cfg.head_size
    h = d // m
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    mixed = _ddlerp(p, x.astype(jnp.float32), shifted.astype(jnp.float32), cfg)
    xr, xw, xk, xv, xg = [mixed[i].astype(x.dtype) for i in range(5)]

    r = dense(p["wr"], xr).reshape(b, s, h, m)
    k = dense(p["wk"], xk).reshape(b, s, h, m)
    v = dense(p["wv"], xv).reshape(b, s, h, m)
    g = jax.nn.silu(dense(p["wg"], xg))
    w = _decay(p, xw).reshape(b, s, h, m)  # f32 in (0,1)
    u = p["time_faaaa"]  # (h, m)

    def step(s_state, inp):
        r_t, k_t, v_t, w_t = inp  # (b,h,m) each
        kv = k_t[..., :, None] * v_t[..., None, :]  # (b,h,m,m)
        y = jnp.einsum("bhm,bhmn->bhn", r_t, s_state + u[None, :, :, None] * kv)
        s_state = w_t[..., :, None] * s_state + kv
        return s_state, y

    if state is None:
        state = jnp.zeros((b, h, m, m), jnp.float32)
    xs = (
        jnp.moveaxis(r.astype(jnp.float32), 1, 0),
        jnp.moveaxis(k.astype(jnp.float32), 1, 0),
        jnp.moveaxis(v.astype(jnp.float32), 1, 0),
        jnp.moveaxis(w, 1, 0),
    )
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = layernorm(p["ln_x"], y)  # group-norm proxy over channels
    out = dense(p["out"], y * g)
    if return_state:
        return out, state
    return out


def rwkv_channel_mix(
    p: Params, x: jax.Array, *, x_prev: jax.Array | None = None
) -> jax.Array:
    b, s, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((b, d), x.dtype)
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    base = p["cmix_base"].astype(jnp.float32)
    dx = (shifted - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32) + dx * base[0]).astype(x.dtype)
    xr = (x.astype(jnp.float32) + dx * base[1]).astype(x.dtype)
    k = jax.nn.relu(dense(p["wk"], xk))
    k = k * k
    return jax.nn.sigmoid(dense(p["wr"], xr)) * dense(p["wv"], k)
