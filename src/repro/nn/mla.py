"""Multi-head Latent Attention (DeepSeek-V2) with compressed-KV decode.

Train/prefill: decompress the latent kv to per-head K/V and run standard
causal attention.  Decode: the *absorbed* formulation — W_uk is folded into
the query and W_uv into the output, so the KV cache holds only the
``kv_lora_rank + rope_dim`` latent per token (the whole point of MLA: 576
floats/token for the 236b config instead of 2*128*128=32768).

The MLA latent cache deliberately stays DENSE under ``--kv-pvq``: it is
already a learned compression (64x for the 236b config), and the absorbed
decode contracts the latent directly against query-folded weights — there
are no per-head K/V rows for ``core.packed.PackedKV`` to block-encode.
Packed-KV compression applies to the standard attention cache only.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import NEG_INF, apply_rope, chunked_causal_attention
from .layers import Params, dense, init_dense, init_rmsnorm, rmsnorm


class MLAConfig(NamedTuple):
    kv_lora_rank: int = 512
    q_lora_rank: Optional[int] = None  # None -> direct q projection
    nope_head_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128


def MLACache(c_kv: jax.Array, k_rope: jax.Array) -> dict:
    """Latent cache as a dict (stable 'mla/c_kv' paths for sharding rules)."""
    return {"c_kv": c_kv, "k_rope": k_rope}


def init_mla(key, d_model: int, n_heads: int, cfg: MLAConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    qk_head = cfg.nope_head_dim + cfg.rope_head_dim
    p: Params = {}
    if cfg.q_lora_rank:
        p["wq_a"] = init_dense(ks[0], d_model, cfg.q_lora_rank, dtype=dtype)
        p["q_norm"] = init_rmsnorm(cfg.q_lora_rank, dtype)
        p["wq_b"] = init_dense(ks[1], cfg.q_lora_rank, n_heads * qk_head, dtype=dtype)
    else:
        p["wq"] = init_dense(ks[0], d_model, n_heads * qk_head, dtype=dtype)
    p["wkv_a"] = init_dense(ks[2], d_model, cfg.kv_lora_rank, dtype=dtype)
    p["kv_norm"] = init_rmsnorm(cfg.kv_lora_rank, dtype)
    p["wk_rope"] = init_dense(ks[3], d_model, cfg.rope_head_dim, dtype=dtype)
    p["wk_b"] = init_dense(ks[4], cfg.kv_lora_rank, n_heads * cfg.nope_head_dim, dtype=dtype)
    p["wv_b"] = init_dense(ks[5], cfg.kv_lora_rank, n_heads * cfg.v_head_dim, dtype=dtype)
    p["wo"] = init_dense(ks[6], n_heads * cfg.v_head_dim, d_model, dtype=dtype)
    return p


def _queries(p: Params, x: jax.Array, n_heads: int, cfg: MLAConfig, positions):
    b, s, _ = x.shape
    qk_head = cfg.nope_head_dim + cfg.rope_head_dim
    if "wq_a" in p:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(b, s, n_heads, qk_head)
    q_nope, q_rope = q[..., : cfg.nope_head_dim], q[..., cfg.nope_head_dim :]
    q_rope = apply_rope(q_rope, positions)
    return q_nope, q_rope


def _latents(p: Params, x: jax.Array, cfg: MLAConfig, positions):
    c_kv = rmsnorm(p["kv_norm"], dense(p["wkv_a"], x))  # (b, s, r)
    k_rope = dense(p["wk_rope"], x)  # (b, s, rope_dim) shared across heads
    k_rope = apply_rope(k_rope[:, :, None, :], positions)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(
    p: Params,
    x: jax.Array,
    *,
    n_heads: int,
    cfg: MLAConfig,
    q_chunk: int = 512,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Training/prefill: decompressed attention."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _queries(p, x, n_heads, cfg, positions)
    c_kv, k_rope = _latents(p, x, cfg, positions)
    k_nope = dense(p["wk_b"], c_kv).reshape(b, s, n_heads, cfg.nope_head_dim)
    v = dense(p["wv_b"], c_kv).reshape(b, s, n_heads, cfg.v_head_dim)
    # concat nope+rope into a single head dim so one attention call suffices
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], q_rope.shape[:2] + (n_heads, cfg.rope_head_dim))], axis=-1)
    scale = 1.0 / math.sqrt(cfg.nope_head_dim + cfg.rope_head_dim)
    # pad v to qk_head so chunked attention can run on one fused tensor? No —
    # chunked_causal_attention supports distinct v dim via separate call.
    out = chunked_causal_attention(q, k, v, scale=scale, q_chunk=q_chunk)
    return dense(p["wo"], out.reshape(b, s, n_heads * cfg.v_head_dim))


def mla_prefill_cache(p: Params, x: jax.Array, cfg: MLAConfig) -> dict:
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    c_kv, k_rope = _latents(p, x, cfg, positions)
    return MLACache(c_kv=c_kv, k_rope=k_rope)


def mla_decode(
    p: Params,
    x: jax.Array,  # (b, 1, d)
    cache: dict,
    pos: jax.Array,
    *,
    n_heads: int,
    cfg: MLAConfig,
    update_cache: bool = True,
) -> Tuple[jax.Array, dict]:
    """Absorbed decode: scores and values live in the latent space."""
    b = x.shape[0]
    posb = jnp.full((b, 1), pos)
    q_nope, q_rope = _queries(p, x, n_heads, cfg, posb)  # (b,1,h,*)
    c_new, kr_new = _latents(p, x, cfg, posb)
    if update_cache:
        c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)
        cache = MLACache(c_kv=c_kv, k_rope=k_rope)
    from repro.parallel import constrain, current_policy

    r = cfg.kv_lora_rank
    seq_sharded = current_policy().cache_seq_tp or current_policy().context_parallel
    # absorb W_uk:  q_abs[b,h,r] = sum_d q_nope[b,h,d] * W_uk[r, h, d]
    # (materialize: the b-projections are reshaped per head here, so the
    # default pack policy leaves them dense; a packed leaf still works)
    from repro.core.packed import materialize

    wk_b = materialize(p["wk_b"]["kernel"]).reshape(r, n_heads, cfg.nope_head_dim)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk_b.astype(q_nope.dtype))
    if seq_sharded:
        # the S axis sharding must win over head-sharded queries (see
        # attention.decode_attention — same SPMD conflict, same fix)
        q_abs = constrain(q_abs, "dp", None, None)
    scores_nope = jnp.einsum("bhr,bsr->bhs", q_abs, cache["c_kv"].astype(q_abs.dtype), preferred_element_type=jnp.float32)
    scores_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], cache["k_rope"].astype(q_rope.dtype), preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(cfg.nope_head_dim + cfg.rope_head_dim)
    scores = (scores_nope + scores_rope) * scale
    if seq_sharded:
        scores = constrain(scores, "dp", None, "seq")
    length = jnp.full((b,), pos + 1)
    valid = jnp.arange(cache["c_kv"].shape[1])[None, :] < length[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(cache["c_kv"].dtype)
    out_lat = jnp.einsum("bhs,bsr->bhr", probs, cache["c_kv"])  # (b, h, r)
    if seq_sharded:
        out_lat = constrain(out_lat, "dp", None, None)
    wv_b = materialize(p["wv_b"]["kernel"]).reshape(r, n_heads, cfg.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", out_lat.astype(x.dtype), wv_b.astype(x.dtype))
    y = dense(p["wo"], out.reshape(b, 1, n_heads * cfg.v_head_dim))
    return y, cache
