"""Mixture-of-Experts: shared + routed experts, top-k routing, GShard-style
capacity dispatch expressed as einsums (SPMD-friendly: the dispatch/combine
einsums reshard token-sharded activations to expert-sharded buffers, and XLA
inserts the all-to-all).

Dispatch tensors are built per routing *group* (a contiguous slice of
tokens); smaller groups shrink the (tokens, experts, capacity) one-hot at the
cost of tighter per-group load balance.  Capacity per group:
    C = ceil(group_size * top_k * capacity_factor / n_experts)
Tokens over capacity are dropped (standard GShard semantics); the residual
path carries them unchanged.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, _act, init_dense


class MoEConfig(NamedTuple):
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0  # DeepSeek shared experts (always-on)
    d_expert: int = 1024  # expert FFN hidden dim
    capacity_factor: float = 1.25
    group_size: int = 4096  # routing group (tokens)
    activation: str = "swiglu"
    # multiplicative router-logit noise, active only when moe_forward gets
    # train=True AND an rng key: logits *= U(1-jitter, 1+jitter) (Switch
    # Transformer recipe — decorrelates expert choice early in training)
    router_jitter: float = 0.0


def init_moe(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    e, f = cfg.n_experts, cfg.d_expert
    glu = cfg.activation in ("swiglu", "geglu")
    std = 1.0 / math.sqrt(d_model)
    p: Params = {
        "router": {"kernel": (jax.random.normal(ks[0], (d_model, e)) * std).astype(jnp.float32)},
        # stacked expert weights: (E, d_model, f) / (E, f, d_model)
        "wi_up_experts": (jax.random.normal(ks[1], (e, d_model, f)) * std).astype(dtype),
        "wo_experts": (jax.random.normal(ks[2], (e, f, d_model)) * (1.0 / math.sqrt(f))).astype(dtype),
    }
    if glu:
        p["wi_gate_experts"] = (jax.random.normal(ks[3], (e, d_model, f)) * std).astype(dtype)
    if cfg.n_shared:
        from .layers import init_ffn

        p["shared"] = init_ffn(ks[4], d_model, cfg.d_expert * cfg.n_shared, cfg.activation, dtype=dtype)
    return p


def routing_group_size(cfg: MoEConfig, t: int) -> int:
    """Tokens per routing group for a t-token batch (groups of
    ``cfg.group_size``, shrunk to the batch when smaller)."""
    return min(cfg.group_size, t)


def routing_capacity(cfg: MoEConfig, s: int) -> int:
    """Capacity slots per (group, expert) for group size ``s`` — THE formula
    ``_routing`` dispatches with; anything pre-computing dispatch-GEMM
    shapes (e.g. ``launch/serve.py --tune``) must go through it."""
    return max(int(math.ceil(s * cfg.top_k * cfg.capacity_factor / cfg.n_experts)), 1)


def dispatch_gemm_rows(cfg: MoEConfig, t: int) -> int:
    """Rows (m = groups * capacity) of the per-expert dispatch-buffer GEMM
    that ``moe_forward`` hands to ``ops.packed_matmul_stacked`` for a
    t-token batch — the shape the shared expert tiles are keyed on."""
    gs = routing_group_size(cfg, t)
    return (-(-t // gs)) * routing_capacity(cfg, gs)


def _topk_argmax(probs: jax.Array, k: int):
    """top-k via k argmax+mask rounds.

    ``lax.top_k`` is not partitioned by SPMD — it replicates its operand
    (measured: 671MB f32 all-gathers per MoE layer on the 236B train cell,
    §Perf).  argmax/max/one_hot partition trivially along the token dims, so
    k small rounds stay entirely local.
    """
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        oh = jax.nn.one_hot(i, probs.shape[-1], dtype=probs.dtype)
        vals.append(jnp.sum(p * oh, axis=-1))
        idxs.append(i)
        p = p * (1.0 - oh)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _routing(
    logits: jax.Array, cfg: MoEConfig, *, light: bool = False,
    token_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[jax.Array], Optional[jax.Array], jax.Array]:
    """logits: (g, s, E).

    Returns (dispatch (g,s,E,C) bf16, combine (g,s,E,C) f32 | None,
    slot_gate (g,E,C) f32 | None, aux_loss).

    ``light=True`` (§Perf opt): instead of a second f32 (g,s,E,C) combine
    tensor, fold the gate values into per-slot scalars (g,E,C) — each slot
    holds exactly one token, so combine == dispatch * slot_gate broadcast.
    Saves a full f32 dispatch-sized tensor per MoE layer (8GB/layer on the
    236B train cell) and reuses the bf16 dispatch for the return trip.

    ``token_mask`` (g, s) bool marks the real tokens: padding appended by
    ``moe_forward`` to reach a group multiple is excluded from dispatch,
    never claims a capacity slot, and does not enter the Switch aux-loss
    statistics (padding otherwise inflates f_e/P_e toward uniform and
    silently eats capacity from real tokens).
    """
    g, s, e = logits.shape
    c = routing_capacity(cfg, s)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = _topk_argmax(probs, cfg.top_k)  # (g, s, k)
    # renormalize selected gates (DeepSeek-V2 style)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch): E * sum_e f_e * P_e — over REAL tokens
    onehot_top1 = jax.nn.one_hot(gate_idx[..., 0], e)
    if token_mask is None:
        me = jnp.mean(probs, axis=(0, 1))  # (E,)
        ce = jnp.mean(onehot_top1, axis=(0, 1))
    else:
        mask_f = token_mask.astype(jnp.float32)  # (g, s)
        denom = jnp.maximum(jnp.sum(mask_f), 1.0)
        me = jnp.sum(probs * mask_f[..., None], axis=(0, 1)) / denom
        ce = jnp.sum(onehot_top1 * mask_f[..., None], axis=(0, 1)) / denom
    aux = e * jnp.sum(me * ce)

    dispatch = jnp.zeros((g, s, e, c), jnp.bfloat16)
    combine = None if light else jnp.zeros((g, s, e, c), jnp.float32)
    slot_gate = jnp.zeros((g, e, c), jnp.float32) if light else None
    # running per-expert fill count across the k choices
    fill = jnp.zeros((g, e), jnp.int32)
    for j in range(cfg.top_k):
        idx = gate_idx[..., j]  # (g, s)
        oh = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (g, s, E)
        if token_mask is not None:
            # padded tokens select no expert: zero contribution AND zero
            # cumsum increment, so they never occupy a capacity slot
            oh = oh * token_mask[..., None].astype(jnp.int32)
        pos = jnp.cumsum(oh, axis=1) - 1 + fill[:, None, :]  # (g, s, E)
        pos_tok = jnp.sum(pos * oh, axis=-1)  # (g, s) position for this token
        keep = pos_tok < c
        slot_oh = jax.nn.one_hot(pos_tok, c, dtype=jnp.float32) * keep[..., None]
        contrib = oh[..., None].astype(jnp.float32) * slot_oh[:, :, None, :]  # (g,s,E,C)
        dispatch = dispatch + contrib.astype(jnp.bfloat16)
        if light:
            slot_gate = slot_gate + jnp.einsum(
                "gsec,gs->gec", contrib, gate_vals[..., j]
            )
        else:
            combine = combine + contrib * gate_vals[..., j][..., None, None]
        fill = fill + jnp.sum(oh * keep[..., None].astype(jnp.int32), axis=1)
    return dispatch, combine, slot_gate, aux


#: MoE activation -> fused matmul-epilogue name (repro.kernels ACTIVATIONS)
_KERNEL_ACT = {"swiglu": "silu", "silu": "silu", "geglu": "gelu",
               "gelu": "gelu", "relu": "relu", "relu2": "relu2"}


def _fold_dispatch(buf: jax.Array) -> jax.Array:
    """(g, E, C, d) dispatch buffer -> per-expert matrices (E, g*C, d) f32 —
    the shape ``ops.packed_matmul_stacked`` contracts."""
    g, e, c, d = buf.shape
    return jnp.transpose(buf, (1, 0, 2, 3)).reshape(e, g * c, d).astype(jnp.float32)


def _quantize_dispatch(buf: jax.Array, act_quant):
    """Quantize the folded dispatch buffer ONCE (per-row symmetric int8).

    The returned ``(int8 buffer (E, g*C, d), scales (E, g*C, 1))`` pair is
    reused by both the up and gate expert matmuls — one quantization pass
    for two contractions.  All-zero rows (empty capacity slots) get zero
    scales and quantize to exact zeros, so they stay inert in the experts.
    """
    from repro.core.quantize import quantize_activations

    return quantize_activations(_fold_dispatch(buf), act_quant)


def _expert_matmul(
    buf: jax.Array, w, *, activation: str = "none", act_quant=None, x_quant=None
) -> jax.Array:
    """Contract the (g, E, C, d) dispatch buffer against a stacked expert
    weight bank (E, d, f) — dense einsum, or the batched int8-native kernel
    when the bank is a ``PackedPVQ`` (expert-stacked matmul layout).

    The packed path folds the buffer to per-expert matrices (E, g*C, d) and
    streams each expert's pulse plane straight into the Pallas kernel with
    one shared autotuned tile config (keyed on the per-expert (g*C, d_pad, f)
    shape); ``activation`` (kernel epilogue name) fuses into the store either
    way.  No dense expert tensor is ever materialized on the packed path.

    ``x_quant`` is a pre-quantized ``(int8 (E, g*C, d), scales (E, g*C, 1))``
    pair from :func:`_quantize_dispatch` (the quantize-once contract);
    ``act_quant`` quantizes here instead (the ``wo`` contraction, whose
    input ``h`` exists only after the up/gate matmuls).  Either engages the
    int8 x int8 kernel v3.  Dense banks ignore both.
    """
    from repro.core.packed import is_packed

    if not is_packed(w):
        y = jnp.einsum("gecd,edf->gecf", buf, w.astype(buf.dtype))
        return _act(activation, y) if activation != "none" else y
    from repro.kernels import ops

    g, e, c, d = buf.shape
    if x_quant is not None:
        xb, act_scale = x_quant
        y = ops.packed_matmul_stacked(
            xb, w, activation=activation, act_scale=act_scale
        )
    else:
        y = ops.packed_matmul_stacked(
            _fold_dispatch(buf), w, activation=activation, act_quant=act_quant
        )
    f = y.shape[-1]
    return jnp.transpose(y.reshape(e, g, c, f), (1, 0, 2, 3)).astype(buf.dtype)


def moe_forward(
    p: Params,
    x: jax.Array,  # (b, s, d)
    cfg: MoEConfig,
    *,
    expert_constraint=None,
    train: bool = False,
    rng: Optional[jax.Array] = None,
    act_quant=None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (b,s,d), aux_loss).

    Expert weights may be dense ``(E, d, f)`` tensors or ``PackedPVQ`` banks
    (expert-stacked matmul layout, see ``repro.core.packed``) — the three
    expert contractions dispatch transparently, like ``dense``/``embed``.
    ``train=True`` with an ``rng`` key enables router-jitter noise (when
    ``cfg.router_jitter > 0``).

    ``act_quant`` (default: the process-wide ``ActQuant`` contract) runs the
    packed expert contractions int8 x int8: the (g, E, C, d) dispatch buffer
    is quantized ONCE and its int8 buffer + per-row scales are reused by the
    up AND gate matmuls; the hidden ``h`` is quantized once for ``wo``.  The
    router always consumes raw f32 logits — routing is never quantized.
    """
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    gs = routing_group_size(cfg, t)
    # pad to a multiple of the group size (dropped tokens pass via residual)
    pad = (-t) % gs
    if pad:
        tokens = jnp.concatenate([tokens, jnp.zeros((pad, d), tokens.dtype)])
    g = tokens.shape[0] // gs
    xg = tokens.reshape(g, gs, d)
    # mask the structural padding out of routing: padded tokens must not
    # receive logits' capacity slots nor skew the aux statistics
    token_mask = None
    if pad:
        token_mask = (jnp.arange(g * gs) < t).reshape(g, gs)

    from repro.parallel import current_policy

    light = current_policy().moe_light_combine
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"]["kernel"])
    if train and cfg.router_jitter > 0.0 and rng is not None:
        logits = logits * jax.random.uniform(
            rng, logits.shape, jnp.float32,
            1.0 - cfg.router_jitter, 1.0 + cfg.router_jitter,
        )
    dispatch, combine, slot_gate, aux = _routing(
        logits, cfg, light=light, token_mask=token_mask
    )

    # dispatch: tokens -> expert buffers (all-to-all under SPMD)
    buf = jnp.einsum("gsd,gsec->gecd", xg, dispatch.astype(xg.dtype))
    if expert_constraint is not None:
        buf = expert_constraint(buf)

    # expert FFN on (g, E, C, d): three stacked matmuls (packed or dense)
    from repro.core.packed import is_packed
    from repro.core.quantize import default_act_quant

    if act_quant is None:
        act_quant = default_act_quant()
    glu = "wi_gate_experts" in p
    act = _KERNEL_ACT[cfg.activation]
    # quantize the dispatch buffer ONCE; up and gate reuse buffer + scales
    xq = (
        _quantize_dispatch(buf, act_quant)
        if act_quant is not None and is_packed(p["wi_up_experts"])
        else None
    )
    if glu:
        up = _expert_matmul(buf, p["wi_up_experts"], x_quant=xq)
        h = _expert_matmul(buf, p["wi_gate_experts"], activation=act, x_quant=xq) * up
    else:
        h = _expert_matmul(buf, p["wi_up_experts"], activation=act, x_quant=xq)
    out_buf = _expert_matmul(
        h, p["wo_experts"],
        act_quant=act_quant if is_packed(p["wo_experts"]) else None,
    )

    # combine: expert buffers -> tokens (second all-to-all)
    if light:
        out_buf = out_buf * slot_gate[..., None].astype(out_buf.dtype)
        out = jnp.einsum("gecd,gsec->gsd", out_buf, dispatch.astype(out_buf.dtype))
    else:
        out = jnp.einsum("gecd,gsec->gsd", out_buf, combine.astype(out_buf.dtype))
    out = out.reshape(-1, d)[:t].reshape(b, s, d)

    if cfg.n_shared:
        from .layers import ffn

        out = out + ffn(p["shared"], x, cfg.activation)
    return out, aux
