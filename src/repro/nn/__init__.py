"""NN substrate: layers, attention variants, MoE, SSMs, model assembly.

Import submodules directly (``repro.nn.models``); this package init stays
empty to avoid import cycles with ``repro.configs``.
"""


def __getattr__(name):
    if name in ("Model", "build_model"):
        from . import models

        return getattr(models, name)
    raise AttributeError(name)
