"""Mamba-1 selective SSM block (Jamba's mixer), pure JAX.

Recurrence (per channel i, state dim n):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t
with input-dependent dt, B, C (the "selective" part).  Training uses
``lax.scan`` over time (compact HLO under the layer scan); decode carries
(conv_state, ssm_state) caches.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense, init_dense


class SSMConfig(NamedTuple):
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


def MambaCache(conv: jax.Array, ssm: jax.Array) -> dict:
    """SSM cache as a dict (stable 'mamba/conv', 'mamba/ssm' paths)."""
    return {"conv": conv, "ssm": ssm}


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> Params:
    d_inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or math.ceil(d_model / 16)
    ks = jax.random.split(key, 6)
    p: Params = {
        "in_proj": init_dense(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_kernel": (jax.random.normal(ks[1], (cfg.d_conv, d_inner)) * 0.1).astype(dtype),
        "conv_bias": jnp.zeros((d_inner,), dtype),
        "x_proj": init_dense(ks[2], d_inner, dt_rank + 2 * cfg.d_state, dtype=dtype),
        "dt_proj": init_dense(ks[3], dt_rank, d_inner, bias=True, dtype=dtype),
        # A_log/D kept fp32: they parameterize the recurrence (PVQ-skipped)
        "a_log": jnp.log(jnp.broadcast_to(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (d_inner, cfg.d_state)) + 0.0),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": init_dense(ks[4], d_inner, d_model, dtype=dtype),
    }
    # dt bias init so softplus(dt) starts around 0.001..0.1
    p["dt_proj"]["bias"] = jnp.log(jnp.expm1(0.01)) * jnp.ones((d_inner,), dtype)
    return p


def _split_xz(p: Params, x: jax.Array, d_inner: int):
    xz = dense(p["in_proj"], x)
    return xz[..., :d_inner], xz[..., d_inner:]


def _conv_causal(p: Params, u: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. u: (b, s, d_inner)."""
    k = p["conv_kernel"].astype(u.dtype)  # (w, d)
    w = k.shape[0]
    pad = jnp.pad(u, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(w):  # tiny unrolled loop (w=4)
        out = out + pad[:, i : i + u.shape[1], :] * k[i]
    return out + p["conv_bias"].astype(u.dtype)


def _ssm_params(p: Params, u: jax.Array, cfg: SSMConfig, d_inner: int):
    dt_rank = p["dt_proj"]["kernel"].shape[0]
    proj = dense(p["x_proj"], u)
    dt, b_mat, c_mat = jnp.split(proj, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt).astype(jnp.float32))  # (b,s,d_inner)
    a = -jnp.exp(p["a_log"])  # (d_inner, n)
    return dt, a, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32)


def mamba_forward(p: Params, x: jax.Array, cfg: SSMConfig, *, return_state: bool = False):
    """Training/prefill. x: (b, s, d_model)."""
    d_inner = p["out_proj"]["kernel"].shape[0]
    u_pre, z = _split_xz(p, x, d_inner)
    u = jax.nn.silu(_conv_causal(p, u_pre))
    dt, a, b_mat, c_mat = _ssm_params(p, u, cfg, d_inner)

    # exp(dt*A) and dt*B*x are computed INSIDE the scan body: materializing
    # them up-front costs (b,s,d_inner,n) f32 tensors — measured 8.6GB/chip
    # per layer on the jamba train cell, ~60% of its memory term (§Perf)
    def step(h, inp):
        dt_t, b_t, c_t, u_t = inp  # (b,d), (b,n), (b,n), (b,d)
        da_t = jnp.exp(dt_t[..., None] * a)  # (b, d_inner, n)
        dbx_t = (dt_t * u_t)[..., None] * b_t[:, None, :]
        h = da_t * h + dbx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    b, s, _ = x.shape
    h0 = jnp.zeros((b, d_inner, cfg.d_state), jnp.float32)
    xs = (
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(b_mat, 1, 0),
        jnp.moveaxis(c_mat, 1, 0),
        jnp.moveaxis(u.astype(jnp.float32), 1, 0),
    )
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (b, s, d_inner)
    y = y + u.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    if return_state:
        w = cfg.d_conv
        window = jnp.pad(u_pre, ((0, 0), (w - 1, 0), (0, 0)))[:, -(w - 1) :, :]
        return out, MambaCache(conv=window, ssm=h_final)
    return out


def init_mamba_cache(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    d_inner = cfg.expand * d_model
    return MambaCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, d_inner), dtype),
        ssm=jnp.zeros((batch, d_inner, cfg.d_state), jnp.float32),
    )


def mamba_decode(
    p: Params, x: jax.Array, cache: dict, cfg: SSMConfig
) -> Tuple[jax.Array, dict]:
    """One-token step. x: (b, 1, d_model)."""
    d_inner = p["out_proj"]["kernel"].shape[0]
    u, z = _split_xz(p, x, d_inner)  # (b,1,d_inner)
    window = jnp.concatenate([cache["conv"], u], axis=1)  # (b, w, d_inner)
    k = p["conv_kernel"].astype(u.dtype)
    u_conv = jnp.einsum("bwd,wd->bd", window, k)[:, None, :] + p["conv_bias"].astype(u.dtype)
    u_act = jax.nn.silu(u_conv)
    dt, a, b_mat, c_mat = _ssm_params(p, u_act, cfg, d_inner)
    da = jnp.exp(dt[:, 0, :, None] * a)  # (b, d_inner, n)
    dbx = dt[:, 0, :, None] * b_mat[:, 0, None, :] * u_act.astype(jnp.float32)[:, 0, :, None]
    h = da * cache["ssm"] + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_mat[:, 0])[:, None, :]
    y = y + u_act.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    return out, MambaCache(conv=window[:, 1:], ssm=h)
