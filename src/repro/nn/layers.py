"""Basic NN layers: norms, dense, FFN variants, embeddings.

Pure-functional style: every module is an ``init_*`` returning a param dict
plus an ``apply`` function.  Parameters use a naming convention consumed by
the sharding rules (repro.parallel.sharding) and the PVQ quantization policy
(kernels are PVQ-quantizable, ``*_norm/scale`` are skipped).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / (fan_in**0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"rms_scale": jnp.ones((d,), dtype)}


@jax.custom_vjp
def _rmsnorm_core(x: jax.Array, scale: jax.Array) -> jax.Array:
    """f32-internal RMSNorm with bf16 boundaries on BOTH passes.

    The optimization barrier stops XLA hoisting the f32 convert across the
    upstream TP all-reduce (which doubles its bytes — measured 2x on the
    236B train cell, §Perf); the custom vjp returns cotangents in the input
    dtype so the *backward* TP all-reduce stays bf16 as well.
    """
    x = jax.lax.optimization_barrier(x)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(x.dtype)


def _rmsnorm_fwd(x, scale):
    x = jax.lax.optimization_barrier(x)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + 1e-6)
    y = (xf * inv * scale.astype(jnp.float32)).astype(x.dtype)
    return y, (x, inv, scale)


def _rmsnorm_bwd(res, g):
    x, inv, scale = res
    d = x.shape[-1]
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32) * scale.astype(jnp.float32)
    xhat = xf * inv
    dot = jnp.mean(gf * xhat, axis=-1, keepdims=True)
    dx = inv * (gf - xhat * dot)
    dscale = jnp.sum(
        g.astype(jnp.float32) * xhat,
        axis=tuple(range(x.ndim - 1)),
    )
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


_rmsnorm_core.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    return _rmsnorm_core(x, p["rms_scale"])


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"ln_scale": jnp.ones((d,), dtype), "ln_bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32, scale: float = 1.0) -> Params:
    p = {"kernel": truncated_normal_init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array, *, act_quant=None) -> jax.Array:
    """Dense layer; accepts a float ``kernel`` or a packed PVQ one.

    A ``PackedPVQ`` kernel (the unified quantized artifact, see
    ``repro.core.packed``) dispatches to the int8-native Pallas kernel —
    the pulses are streamed as stored, never expanded to a dense matrix.
    ``act_quant`` (an ``ActQuant``, defaulting to the process-wide setting
    from ``serve --act-int8``) additionally quantizes the activations to
    int8 on the packed path — kernel v3, int8 x int8 with int32 MXU
    accumulation.  Float kernels ignore it (there is no integer operand to
    pair the quantized activations with).
    """
    from repro.core.packed import is_packed

    if is_packed(p["kernel"]):
        return pvq_dense(p, x, act_quant=act_quant)
    y = jnp.einsum("...d,df->...f", x, p["kernel"].astype(x.dtype))
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


def pvq_quantize_dense(p: Params, *, group: int = 128, k_pulses: int) -> Params:
    """Convert a float dense param dict to the packed serving artifact.

    Returns ``{"kernel": PackedPVQ (matmul layout) [, "bias"]}`` — the same
    param-dict shape as the float layer, so ``dense``/``pvq_dense`` apply it
    transparently.  The bias stays float: it rides the kernel's fused
    epilogue instead of being folded into the pyramid code.
    """
    from repro.core.packed import pack_matmul

    q: Params = {
        "kernel": pack_matmul(
            p["kernel"].astype(jnp.float32), group=group, k=k_pulses
        )
    }
    if "bias" in p:
        q["bias"] = p["bias"]
    return q


def pvq_dense(
    p: Params, x: jax.Array, *, activation: str = "none", act_quant=None
) -> jax.Array:
    """Dense layer on packed params (``{"kernel": PackedPVQ [, "bias"]}``).

    Runs the fused int8-native Pallas kernel with the bias + activation
    epilogue; tiles come from the persistent autotune cache via kernels.ops.
    Inputs whose feature dim is smaller than the encoded (group-padded)
    contraction dim are zero-padded — zero lanes meet zero pulses.

    ``act_quant=None`` resolves the process default
    (``core.quantize.default_act_quant``); with an ``ActQuant`` in effect
    the activations are quantized to per-row int8 and the contraction runs
    the int8 x int8 kernel v3 — no f32 activation tensor reaches the MXU.
    """
    from repro.core.quantize import default_act_quant
    from repro.kernels import ops

    if act_quant is None:
        act_quant = default_act_quant()
    packed = p["kernel"]
    lead, k_in = x.shape[:-1], x.shape[-1]
    xf = x.reshape(-1, k_in).astype(jnp.float32)
    y = ops.packed_matmul(
        xf, packed, bias=p.get("bias"), activation=activation,
        act_quant=act_quant,
    )
    return y.reshape(*lead, y.shape[-1]).astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, kind: str, *, bias: bool = False, dtype=jnp.float32) -> Params:
    """kind: 'swiglu' | 'geglu' | 'gelu' | 'relu' | 'relu2'.

    NOTE: params hold only arrays (scan/vmap-stackable); the kind is passed
    to :func:`ffn` at apply time.
    """
    ks = jax.random.split(key, 3)
    p: Params = {}
    if kind in ("swiglu", "geglu"):
        p["wi_gate"] = init_dense(ks[0], d_model, d_ff, bias=bias, dtype=dtype)
        p["wi_up"] = init_dense(ks[1], d_model, d_ff, bias=bias, dtype=dtype)
    else:
        p["wi_up"] = init_dense(ks[1], d_model, d_ff, bias=bias, dtype=dtype)
    p["wo"] = init_dense(ks[2], d_ff, d_model, bias=bias, dtype=dtype)
    return p


def _act(kind: str, x: jax.Array) -> jax.Array:
    if kind in ("swiglu", "silu"):
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def ffn(p: Params, x: jax.Array, kind: str, *, hidden_constraint=None) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        h = _act(kind, dense(p["wi_gate"], x)) * dense(p["wi_up"], x)
    else:
        h = _act(kind, dense(p["wi_up"], x))
    if hidden_constraint is not None:
        h = hidden_constraint(h)
    return dense(p["wo"], h)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"embedding": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def _packed_embed_rows(table, tokens: jax.Array) -> jax.Array:
    """Gather + dequantize ONLY the token rows of a packed embedding.

    Flat-layout packing aligns groups to the embedding dim (``group | d``),
    so a token row is exactly ``d // group`` whole codes — the lookup
    touches ``d`` int8 pulses + ``d/group`` scales per token instead of ever
    expanding the (vocab, d) table.
    """
    vocab, d = table.shape
    g = table.group
    pp = table.pulses.reshape(vocab, d // g, g)
    sc = table.scales.reshape(vocab, d // g)
    rows = pp[tokens].astype(jnp.float32) * sc[tokens][..., None]
    return rows.reshape(*tokens.shape, d)


def _packed_unembed(table, x: jax.Array, act_quant=None) -> jax.Array:
    """Tied-head logits against a packed embedding without dequantizing it.

    ``lax.scan`` over group slices: one int8 matmul ``x_g @ pulses_g^T``
    (the cast feeds the MXU) and one rho multiply on the (…, vocab)
    accumulator per step — the paper's adds + ONE multiply structure, never
    a (vocab, d) f32 matrix and never a (…, G, vocab) intermediate, with
    compact HLO (no per-group unroll on the decode hot path).

    With an ``ActQuant`` in effect the ``x`` operand is quantized to
    per-row int8 once and every group dot runs int8 x int8 with an int32
    accumulator (``preferred_element_type``); rho still lands per group and
    the per-row activation scale multiplies the final logits once.
    """
    vocab, d = table.shape
    g = table.group
    n_groups = d // g
    act_scale = None
    if act_quant is not None:
        from repro.core.quantize import quantize_activations

        x, act_scale = quantize_activations(x, act_quant)  # int8, (..., 1)
        xs = jnp.moveaxis(x.reshape(*x.shape[:-1], n_groups, g), -2, 0)
    else:
        xs = jnp.moveaxis(
            x.astype(jnp.float32).reshape(*x.shape[:-1], n_groups, g), -2, 0
        )
    pp = jnp.moveaxis(table.pulses.reshape(vocab, n_groups, g), 1, 0)
    sc = jnp.moveaxis(table.scales.reshape(vocab, n_groups), 1, 0).astype(jnp.float32)

    def body(acc, inp):
        xg, pg, sg = inp
        if act_scale is not None:
            dot = jnp.einsum(
                "...p,vp->...v", xg, pg, preferred_element_type=jnp.int32
            ).astype(jnp.float32)
        else:
            dot = jnp.einsum("...p,vp->...v", xg, pg.astype(jnp.float32))
        return acc + dot * sg, None

    logits0 = jnp.zeros(x.shape[:-1] + (vocab,), jnp.float32)
    logits, _ = jax.lax.scan(body, logits0, (xs, pp, sc))
    if act_scale is not None:
        logits = logits * act_scale
    return logits


def embed(p: Params, tokens: jax.Array, dtype=None) -> jax.Array:
    from repro.core.packed import is_packed

    table = p["embedding"]
    if is_packed(table):
        out = _packed_embed_rows(table, tokens)
    else:
        out = jnp.take(table, tokens, axis=0)
    return out.astype(dtype) if dtype is not None else out


def unembed(p: Params, x: jax.Array, *, act_quant=None) -> jax.Array:
    """Tied output head: logits in f32 for loss stability.

    On a packed table, ``act_quant`` (defaulting to the process-wide
    contract) runs the int8 x int8 integer logits path; ``embed`` itself is
    a gather — there is no activation operand to quantize there.
    """
    from repro.core.packed import is_packed
    from repro.core.quantize import default_act_quant

    table = p["embedding"]
    if is_packed(table):
        if act_quant is None:
            act_quant = default_act_quant()
        return _packed_unembed(table, x, act_quant)
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32)
    )


def init_positional(key, max_len: int, d: int, dtype=jnp.float32) -> Params:
    return {"pos_embedding": (jax.random.normal(key, (max_len, d)) * 0.02).astype(dtype)}


def sinusoidal_positions(length: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * 2.0 * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
