"""Unified model API over all assigned architectures.

    model = Model(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)                 # train
    logits, cache = model.prefill(params, batch, cache_len)   # inference prefill
    logits, cache = model.decode_step(params, cache, token, pos)

Batch keys:  tokens/targets (b, s) int32 always; ``frames`` (b, s_enc, d)
for enc-dec (stub audio frontend); ``patches`` (b, p, d) for VLM (stub
vision frontend).
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.parallel import constrain

from . import layers as L
from . import transformer as T
from .layers import Params


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = T.segment_plan(cfg, "decoder")
        self.enc_plan = T.segment_plan(cfg, "encoder") if cfg.encoder_layers else None

    # ------------------------------------------------------------------ init

    def init(self, key, max_seq: int = 0) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        keys = jax.random.split(key, 16)
        params: Params = {"embed": L.init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
        if cfg.learned_positions:
            params["pos"] = L.init_positional(keys[1], cfg.max_position or max_seq or 4096, cfg.d_model, dtype)
        params["segments"] = {
            f"seg{i}": T.init_segment(keys[2 + i], cfg, seg) for i, seg in enumerate(self.plan)
        }
        params["final_norm"] = T._init_norm(cfg, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = L.init_dense(keys[10], cfg.d_model, cfg.vocab_size, dtype=dtype)
        if self.enc_plan:
            params["encoder"] = {
                "segments": {
                    f"seg{i}": T.init_segment(keys[11 + i], cfg, seg)
                    for i, seg in enumerate(self.enc_plan)
                },
                "final_norm": T._init_norm(cfg, dtype),
            }
        return params

    # ----------------------------------------------------------------- embed

    def _embed_tokens(self, params: Params, tokens: jax.Array, pos_offset: int = 0) -> jax.Array:
        cfg = self.cfg
        x = L.embed(params["embed"], tokens, dtype=jnp.dtype(cfg.compute_dtype))
        if cfg.family in ("vlm",) or cfg.name.startswith("gemma"):
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if cfg.learned_positions:
            s = tokens.shape[1]
            pe = jax.lax.dynamic_slice_in_dim(params["pos"]["pos_embedding"], pos_offset, s, axis=0)
            x = x + pe.astype(x.dtype)
        return x

    def _encode(self, params: Params, frames: jax.Array, mode: str) -> jax.Array:
        """Whisper-style encoder over precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        x = frames.astype(jnp.dtype(cfg.compute_dtype))
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
        for i, seg in enumerate(self.enc_plan):
            x, _, _ = T.run_segment(
                cfg, seg, params["encoder"]["segments"][f"seg{i}"], x,
                mode="train", remat=(mode == "train"),
            )
        return T._norm(cfg, params["encoder"]["final_norm"], x)

    # --------------------------------------------------------------- forward

    def forward(
        self, params: Params, batch: Dict[str, jax.Array], *, mode: str = "train",
        rng: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array, Optional[Any]]:
        """Full-sequence forward. Returns (logits, aux_loss, caches|None).

        ``rng`` enables train-time stochastic features (MoE router jitter);
        omit it for deterministic eval/prefill."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        prefix_len = 0
        enc_out = None
        if cfg.family == "vlm":
            patches = batch["patches"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = patches.shape[1]
        if self.enc_plan:
            enc_out = self._encode(params, batch["frames"], mode)
        x = constrain(x, "dp", "sp", None)

        aux = jnp.zeros((), jnp.float32)
        caches = {}
        for i, seg in enumerate(self.plan):
            x, aux_i, c = T.run_segment(
                cfg, seg, params["segments"][f"seg{i}"], x,
                mode=mode, enc_out=enc_out, prefix_len=prefix_len,
                remat=(mode == "train"),
                rng=(None if rng is None else jax.random.fold_in(rng, i)),
            )
            aux = aux + aux_i
            if c is not None:
                caches[f"seg{i}"] = c
        x = T._norm(cfg, params["final_norm"], x)
        if prefix_len:
            x = x[:, prefix_len:, :]
        logits = self._head(params, x)
        return logits, aux, (caches if mode == "prefill" else None)

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        if self.cfg.tie_embeddings:
            logits = L.unembed(params["embed"], x)
        else:
            logits = L.dense(params["lm_head"], x.astype(jnp.float32))
        return constrain(logits, "dp", None, "tp")

    # ------------------------------------------------------------------ loss

    def loss(
        self, params: Params, batch: Dict[str, jax.Array],
        rng: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        logits, aux, _ = self.forward(params, batch, mode="train", rng=rng)
        targets = batch["targets"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        ce = jnp.mean(logz - tgt_logit)
        total = ce + cfg.moe_aux_coef * aux
        if cfg.z_loss_coef:
            total = total + cfg.z_loss_coef * jnp.mean(logz**2)
        acc = jnp.mean((jnp.argmax(logits, -1) == targets).astype(jnp.float32))
        return total, {"ce": ce, "aux": aux, "accuracy": acc}

    # --------------------------------------------------------------- serving

    def prefill(
        self, params: Params, batch: Dict[str, jax.Array], cache_len: int = 0
    ) -> Tuple[jax.Array, Any]:
        """Run the prompt, return (last-position logits, decode cache)."""
        logits, _, caches = self.forward(params, batch, mode="prefill")
        s = batch["tokens"].shape[1]
        if cache_len and cache_len > s:
            pad = cache_len - s

            def pad_seq(path, leaf):
                # sequence-indexed cache tensors have shape (..., s, tail);
                # cross-attention KV is over the (fixed) encoder length and
                # must NOT be padded — zero keys would join the softmax.
                # PackedKV pulse/scale planes are seq-indexed at the same
                # axis (zero pulses/scales stay inert behind the length
                # mask); its block-length tail ring is NOT seq-indexed and
                # must keep its shape.
                names = [getattr(p, "name", getattr(p, "key", "")) for p in path]
                if "cross" in names:
                    return leaf
                seq_names = (
                    "k", "v", "c_kv", "k_rope",
                    "k_pulses", "v_pulses", "k_scales", "v_scales",
                )
                if any(n in seq_names for n in names) and leaf.ndim >= 3:
                    cfgpad = [(0, 0)] * leaf.ndim
                    cfgpad[2] = (0, pad)  # (repeats, batch, seq, ...)
                    return jnp.pad(leaf, cfgpad)
                return leaf

            caches = jax.tree_util.tree_map_with_path(pad_seq, caches)
        return logits[:, -1:, :], caches

    def init_cache(self, batch: int, cache_len: int, enc_len: int = 0) -> Any:
        return T.init_plan_cache(self.cfg, self.plan, batch, cache_len, enc_len or cache_len)

    def init_paged_cache(self, n_slots: int, n_pages: int, max_pages: int) -> Any:
        """Slot-pool decode cache for the continuous-batching engine: every
        attention layer's KV cache is a ``core.packed.PagedKV`` page pool
        (requires an active ``KVQuant`` default — pages are PVQ blocks)."""
        return T.init_plan_cache(
            self.cfg, self.plan, n_slots, max_pages, 0,
            paged=(n_pages, max_pages),
        )

    def prefill_bucketed(
        self, params: Params, batch: Dict[str, jax.Array], real_len: jax.Array
    ) -> Tuple[jax.Array, Any]:
        """Disaggregated-prefill step: the prompt is padded up to a static
        page-aligned bucket length, and the logits are read at the true
        last position ``real_len - 1`` per row (causal attention makes the
        padded suffix invisible to every position below ``real_len``).
        Returns ``(next-token logits (b, 1, vocab), caches)`` — the caches
        cover the full bucket length; rows at/after ``real_len`` are
        garbage and must stay behind the engine's per-slot length mask.
        """
        logits, _, caches = self.forward(params, batch, mode="prefill")
        idx = (jnp.asarray(real_len, jnp.int32) - 1).reshape(-1, 1, 1)
        last = jnp.take_along_axis(
            logits, jnp.broadcast_to(idx, (logits.shape[0], 1, logits.shape[-1])),
            axis=1,
        )
        return last, caches

    def prefill_chunk(
        self, params: Params, cache: Any, tokens: jax.Array, slot: jax.Array,
        start: jax.Array, page_ids: jax.Array, real_len: jax.Array,
    ) -> Tuple[jax.Array, Any]:
        """Chunked-prefill step over the paged slot pool: run ``tokens``
        ``(1, C)`` (``C`` a page multiple, ``start`` page-aligned) at
        absolute positions ``start .. start + C - 1`` for decode slot
        ``slot``, attending to the slot's already-packed context
        ``[0, start)`` through the page table and PVQ-grafting this
        chunk's blocks into ``page_ids``.  One static chunk shape serves
        every prompt length, so the whole run compiles the chunk step
        ONCE.  Returns ``(logits (1, 1, vocab), cache)`` — the logits are
        read at ``real_len - 1 - start`` clamped into the chunk and are
        only meaningful on the FINAL chunk of a context."""
        cfg = self.cfg
        b, s = tokens.shape
        x = self._embed_tokens(params, tokens, pos_offset=0)
        if cfg.learned_positions:
            # replace the offset-0 slice with the true chunk positions
            tab = params["pos"]["pos_embedding"]
            pe0 = jax.lax.dynamic_slice_in_dim(tab, 0, s, axis=0)
            posv = jnp.asarray(start, jnp.int32) + jnp.arange(s)
            pe_t = jnp.take(tab, posv, axis=0)
            x = x - pe0.astype(x.dtype)[None] + pe_t.astype(x.dtype)[None]
        new_cache = {}
        for i, seg in enumerate(self.plan):
            x, c = T.chunk_segment(
                cfg, seg, params["segments"][f"seg{i}"], cache[f"seg{i}"],
                x, slot, start, page_ids, real_len,
            )
            new_cache[f"seg{i}"] = c
        x = T._norm(cfg, params["final_norm"], x)
        logits = self._head(params, x)
        idx = jnp.clip(
            jnp.asarray(real_len, jnp.int32) - 1 - jnp.asarray(start, jnp.int32),
            0, s - 1,
        ).reshape(1, 1, 1)
        last = jnp.take_along_axis(
            logits, jnp.broadcast_to(idx, (b, 1, logits.shape[-1])), axis=1
        )
        return last, new_cache

    def decode_step(
        self, params: Params, cache: Any, token: jax.Array, pos: jax.Array
    ) -> Tuple[jax.Array, Any]:
        """token: (b, 1) int32; pos: scalar int32 (next position index,
        lockstep batch) or (b,) int32 (per-slot positions — the
        continuous-batching engine's slot pool, threaded through attention
        as per-row RoPE/append/length)."""
        cfg = self.cfg
        x = self._embed_tokens(params, token, pos_offset=0)
        if cfg.learned_positions:
            # replace the offset-0 slice with the true position embedding
            tab = params["pos"]["pos_embedding"]
            pe = jax.lax.dynamic_slice_in_dim(tab, 0, 1, axis=0)
            if jnp.ndim(pos):
                pe_t = jnp.take(tab, jnp.asarray(pos, jnp.int32), axis=0)[:, None, :]
            else:
                pe_t = jax.lax.dynamic_slice_in_dim(tab, pos, 1, axis=0)
            x = x - pe.astype(x.dtype) + pe_t.astype(x.dtype)
        new_cache = {}
        for i, seg in enumerate(self.plan):
            x, c = T.decode_segment(cfg, seg, params["segments"][f"seg{i}"], cache[f"seg{i}"], x, pos)
            new_cache[f"seg{i}"] = c
        x = T._norm(cfg, params["final_norm"], x)
        return self._head(params, x), new_cache

    # ------------------------------------------------------------- accounting

    def param_count(self, params: Params) -> int:
        """Logical parameter count; PackedPVQ leaves count their dense shape
        (the artifact's pulses/scales are an encoding, not extra params)."""
        from repro.core.packed import is_packed

        total = 0
        for x in jax.tree.leaves(params, is_leaf=is_packed):
            if is_packed(x):
                lead = x.pulses.shape[: x.pulses.ndim - 2]
                total += int(math.prod(lead)) * int(math.prod(x.shape))
            else:
                total += int(x.size)
        return total

    def active_param_count(self, params: Params) -> int:
        """MoE-aware active parameters per token (for MODEL_FLOPS = 6*N_active*D)."""
        cfg = self.cfg
        if cfg.moe is None:
            return self.param_count(params)
        total = 0
        active_frac = (cfg.moe.top_k + cfg.moe.n_shared) / max(cfg.moe.n_experts, 1)

        def visit(path, leaf):
            nonlocal total
            pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            n = int(leaf.size)
            if "experts" in pstr and "shared" not in pstr:
                # routed experts: only top_k of n_experts active
                n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
            total += n
            return leaf

        jax.tree_util.tree_map_with_path(visit, params)
        return total


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
