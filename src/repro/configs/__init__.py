"""Config registry: ``get_config(arch_id)`` for every assigned architecture."""

from typing import Dict

from .base import ModelConfig, ShapeConfig, SHAPES, shape_by_name, cell_applicable

from . import (
    whisper_small,
    deepseek_v2_236b,
    deepseek_v2_lite_16b,
    granite_8b,
    smollm_360m,
    starcoder2_15b,
    gemma_2b,
    jamba_1_5_large_398b,
    paligemma_3b,
    rwkv6_1_6b,
)

ARCHS: Dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        whisper_small,
        deepseek_v2_236b,
        deepseek_v2_lite_16b,
        granite_8b,
        smollm_360m,
        starcoder2_15b,
        gemma_2b,
        jamba_1_5_large_398b,
        paligemma_3b,
        rwkv6_1_6b,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "ARCHS",
    "get_config",
    "shape_by_name",
    "cell_applicable",
]
