"""starcoder2-15b [dense]: 40L, d=6144, 48H (kv=4), d_ff=24576, vocab=49152,
GQA + RoPE. [arXiv:2402.19173]"""

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    ffn_activation="gelu",  # starcoder2 uses a non-gated gelu MLP
    attn_bias=True,
    tie_embeddings=True,
    supports_decode=True,
    subquadratic=False,
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
