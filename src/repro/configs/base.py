"""Model / run configuration schema.

Every assigned architecture is a ``ModelConfig``; input-shape cells are
``ShapeConfig``s.  ``reduced()`` produces the CPU smoke-test variant of any
config (same family/topology, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.nn.mla import MLAConfig
from repro.nn.moe import MoEConfig
from repro.nn.mamba import SSMConfig
from repro.nn.rwkv import RWKVConfig


@dataclasses.dataclass(frozen=True)
class PVQConfig:
    """How PVQ applies to this model's weights (paper §IV + DESIGN.md §2)."""

    enabled: bool = True
    # N/K ratio for matmul weights; first-layer/embedding get gentler ratios
    # per the paper's observation (first layer needs K ~= 1.5-3x N).
    n_over_k: float = 1.0
    n_over_k_embed: float = 0.5  # K = 2N for embeddings (first "layer")
    group: Optional[int] = 256  # per-group rho (None = paper whole-tensor)
    scale_mode: str = "paper"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'hybrid' | 'ssm' | 'encdec' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    ffn_activation: str = "swiglu"
    norm: str = "rmsnorm"
    rope_theta: Optional[float] = 10000.0
    tie_embeddings: bool = True
    attn_bias: bool = False
    learned_positions: bool = False
    max_position: int = 0  # for learned positions; 0 -> max_seq at init time
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    moe_period: int = 1  # MoE FFN every `moe_period` layers (others dense)
    first_dense: int = 0  # first k layers always dense FFN (DeepSeek)
    d_ff_dense: int = 0  # hidden dim of those dense FFNs (0 -> d_ff)
    # --- MLA ---
    mla: Optional[MLAConfig] = None
    # --- hybrid / ssm ---
    hybrid_period: int = 0  # jamba: super-block length (attn at idx 0, mamba else)
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    # --- vlm ---
    prefix_len: int = 0  # patch tokens prepended (stub embeddings)
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- capability flags ---
    supports_decode: bool = True
    subquadratic: bool = False  # can run long_500k
    # unroll the layer scan into straight-line HLO (used by the dry-run's
    # depth-extrapolated cost analysis; scan bodies are counted once by XLA)
    unroll_layers: bool = False
    # --- PVQ ---
    pvq: PVQConfig = dataclasses.field(default_factory=PVQConfig)
    # --- loss ---
    moe_aux_coef: float = 0.01
    z_loss_coef: float = 0.0  # logits z-loss (beyond-paper stability option)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small_moe = None
        if self.moe is not None:
            small_moe = self.moe._replace(
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=32,
                group_size=64,
                n_shared=min(self.moe.n_shared, 1),
            )
        small_mla = None
        if self.mla is not None:
            small_mla = MLAConfig(
                kv_lora_rank=16,
                q_lora_rank=(16 if self.mla.q_lora_rank else None),
                nope_head_dim=8,
                rope_head_dim=4,
                v_head_dim=8,
            )
        n_heads = min(self.n_heads, 4)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, self.hybrid_period or 2),
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=max(1, min(self.n_kv_heads, n_heads)),
            head_dim=16,
            d_ff=96,
            d_ff_dense=96 if self.d_ff_dense else 0,
            vocab_size=128,
            moe=small_moe,
            mla=small_mla,
            ssm=SSMConfig(d_state=4, d_conv=4, expand=2) if self.ssm else None,
            rwkv=RWKVConfig(head_size=16, decay_lora=8, mix_lora=4) if self.rwkv else None,
            encoder_layers=2 if self.encoder_layers else 0,
            prefix_len=4 if self.prefix_len else 0,
            first_dense=min(self.first_dense, 1),
            param_dtype="float32",
            compute_dtype="float32",
            max_position=256,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if not."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention (DESIGN.md §4)"
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    return True, ""
