"""gemma-2b [dense]: 18L, d=2048, 8H MQA (kv=1), head_dim=256, d_ff=16384
GeGLU, vocab=256000. [arXiv:2403.08295]"""

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    ffn_activation="geglu",
    tie_embeddings=True,
    supports_decode=True,
    subquadratic=False,
    pvq=PVQConfig(n_over_k=1.0, n_over_k_embed=0.5, group=256),
)
