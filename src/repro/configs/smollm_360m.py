"""smollm-360m [dense]: llama-arch small. 32L, d=960, 15H (kv=5), d_ff=2560,
vocab=49152. [hf:HuggingFaceTB/SmolLM-360M]"""

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    ffn_activation="swiglu",
    tie_embeddings=True,
    supports_decode=True,
    subquadratic=False,
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
