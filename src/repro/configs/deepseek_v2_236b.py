"""deepseek-v2-236b [moe]: 60L, d=5120, 128H, MLA (kv_lora=512, q_lora=1536),
expert d_ff=1536, 160 routed experts top-6 + 2 shared, vocab=102400.
First layer dense FFN (d_ff=12288) per the HF config. [arXiv:2405.04434]"""

from repro.nn.mla import MLAConfig
from repro.nn.moe import MoEConfig

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,  # v head dim (MLA nope=128/rope=64 handled by MLAConfig)
    d_ff=1536,     # routed expert hidden
    d_ff_dense=12288,
    first_dense=1,
    vocab_size=102400,
    ffn_activation="swiglu",
    tie_embeddings=False,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, nope_head_dim=128, rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(
        n_experts=160, top_k=6, n_shared=2, d_expert=1536,
        capacity_factor=1.25, group_size=1024, activation="swiglu",
    ),
    moe_period=1,
    supports_decode=True,
    subquadratic=False,
    # PVQ sweet spot: weight-memory-bound routed experts (DESIGN.md §4)
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
