"""jamba-1.5-large-398b [hybrid]: 72L, d=8192, 64H (kv=8), d_ff=24576,
MoE 16e top-2, Mamba:attn 7:1 interleave (attn at slot 4 of each 8-layer
super-block, MoE on odd slots). [arXiv:2403.19887]"""

from repro.nn.mamba import SSMConfig
from repro.nn.moe import MoEConfig

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    ffn_activation="swiglu",
    tie_embeddings=False,
    hybrid_period=8,
    moe_period=2,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(
        n_experts=16, top_k=2, n_shared=0, d_expert=24576,
        capacity_factor=1.25, group_size=1024, activation="swiglu",
    ),
    supports_decode=True,
    subquadratic=True,  # mamba layers are O(1)/token; runs long_500k
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
