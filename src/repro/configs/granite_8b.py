"""granite-8b [dense]: llama-arch code model. 36L, d=4096, 32H (kv=8),
d_ff=14336, vocab=49152. [arXiv:2405.04324]"""

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    ffn_activation="swiglu",
    tie_embeddings=True,
    supports_decode=True,
    subquadratic=False,
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
