"""rwkv6-1.6b [ssm] "Finch": 24L, d=2048, attn-free wkv6 with data-dependent
decay, d_ff=7168, vocab=65536.  Decay/bonus params are PVQ-exempt
(recurrence params, not dot products — DESIGN.md §4). [arXiv:2404.05892]"""

from repro.nn.rwkv import RWKVConfig

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,  # d_model / head_size
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    ffn_activation="relu2",
    norm="layernorm",
    rope_theta=None,
    tie_embeddings=False,
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
    supports_decode=True,
    subquadratic=True,  # O(1) state per token; runs long_500k
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
