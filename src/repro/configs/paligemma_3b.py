"""paligemma-3b [vlm]: SigLIP frontend STUB (input_specs provides patch
embeddings) + gemma-2b backbone: 18L, d=2048, 8H MQA (kv=1), d_ff=16384,
vocab=257216, prefix-LM mask over 256 patch tokens. [arXiv:2407.07726]"""

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    ffn_activation="geglu",
    tie_embeddings=True,
    prefix_len=256,
    supports_decode=True,
    subquadratic=False,
    pvq=PVQConfig(n_over_k=1.0, n_over_k_embed=0.5, group=256),
)
