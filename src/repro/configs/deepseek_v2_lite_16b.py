"""deepseek-v2-lite-16b [moe]: 27L, d=2048, 16H, MLA kv_lora=512 (no q lora),
expert d_ff=1408, 64 routed experts top-6 + 2 shared, vocab=102400.
First layer dense FFN (d_ff=10944) per the HF config. [arXiv:2405.04434]"""

from repro.nn.mla import MLAConfig
from repro.nn.moe import MoEConfig

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    d_ff_dense=10944,
    first_dense=1,
    vocab_size=102400,
    ffn_activation="swiglu",
    tie_embeddings=False,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=None, nope_head_dim=128, rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(
        n_experts=64, top_k=6, n_shared=2, d_expert=1408,
        capacity_factor=1.25, group_size=1024, activation="swiglu",
    ),
    moe_period=1,
    supports_decode=True,
    subquadratic=False,
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
