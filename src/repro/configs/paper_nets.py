"""The paper's own experiment networks (§VII Tables 1-4).

A: MNIST MLP,  ReLU      (Table 1: FC 512-512-10, N/K = 5/5/5)
B: CIFAR CNN,  ReLU      (Table 2: conv 32,32,64,64 + FC 512-10)
C: MNIST MLP,  bsign+STE (Table 3: N/K = 5/2, 5, 4)
D: CIFAR CNN,  bsign+STE (Table 4)
"""

from repro.nn.sequential import LayerSpec, SequentialConfig

NET_A = SequentialConfig(
    name="mnist-mlp-A",
    input_shape=(784,),
    layers=(
        LayerSpec("fc", out=512, activation="relu", n_over_k=5.0),
        LayerSpec("dropout", rate=0.2),
        LayerSpec("fc", out=512, activation="relu", n_over_k=5.0),
        LayerSpec("dropout", rate=0.2),
        LayerSpec("fc", out=10, activation="none", n_over_k=5.0),
    ),
)

NET_B = SequentialConfig(
    name="cifar-cnn-B",
    input_shape=(32, 32, 3),
    layers=(
        LayerSpec("conv", out=32, kernel=3, activation="relu", n_over_k=1.0 / 3.0),
        LayerSpec("conv", out=32, kernel=3, activation="relu", n_over_k=1.0),
        LayerSpec("maxpool", pool=2),
        LayerSpec("dropout", rate=0.25),
        LayerSpec("conv", out=64, kernel=3, activation="relu", n_over_k=1.0),
        LayerSpec("conv", out=64, kernel=3, activation="relu", n_over_k=1.0),
        LayerSpec("maxpool", pool=2),
        LayerSpec("dropout", rate=0.25),
        LayerSpec("flatten"),
        LayerSpec("fc", out=512, activation="relu", n_over_k=4.0),
        LayerSpec("dropout", rate=0.5),
        LayerSpec("fc", out=10, activation="none", n_over_k=1.0),
    ),
)

NET_C = SequentialConfig(
    name="mnist-mlp-C",
    input_shape=(784,),
    layers=(
        LayerSpec("fc", out=512, activation="bsign", n_over_k=2.5),
        LayerSpec("fc", out=512, activation="bsign", n_over_k=5.0),
        LayerSpec("fc", out=10, activation="none", n_over_k=4.0),
    ),
)

NET_D = SequentialConfig(
    name="cifar-cnn-D",
    input_shape=(32, 32, 3),
    layers=(
        LayerSpec("conv", out=32, kernel=3, activation="bsign", n_over_k=0.4),
        LayerSpec("conv", out=32, kernel=3, activation="bsign", n_over_k=1.0),
        LayerSpec("maxpool", pool=2),
        LayerSpec("conv", out=64, kernel=3, activation="bsign", n_over_k=1.5),
        LayerSpec("conv", out=64, kernel=3, activation="bsign", n_over_k=2.0),
        LayerSpec("maxpool", pool=2),
        LayerSpec("flatten"),
        LayerSpec("fc", out=512, activation="bsign", n_over_k=5.0),
        LayerSpec("fc", out=10, activation="none", n_over_k=1.0),
    ),
)

PAPER_NETS = {"A": NET_A, "B": NET_B, "C": NET_C, "D": NET_D}
