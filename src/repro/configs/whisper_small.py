"""whisper-small [audio]: enc-dec, 12L, d=768, 12H (kv=12), d_ff=3072,
vocab=51865. Conv audio frontend is a STUB: input_specs provides precomputed
frame embeddings (b, s, d). [arXiv:2212.04356]"""

from .base import ModelConfig, PVQConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,          # decoder layers
    encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    ffn_activation="gelu",
    norm="layernorm",
    attn_bias=True,
    rope_theta=None,          # whisper uses absolute positions
    learned_positions=True,
    max_position=65536,       # sized for the assigned 32k shapes
    tie_embeddings=True,
    supports_decode=True,
    subquadratic=False,
    pvq=PVQConfig(n_over_k=1.0, group=256),
)
