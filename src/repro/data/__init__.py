from .synthetic import TokenTask, ClassifyTask
from .pipeline import TokenLoader, Prefetcher

__all__ = ["TokenTask", "ClassifyTask", "TokenLoader", "Prefetcher"]
