"""Sharded host data pipeline: deterministic per-step batches, background
prefetch, and device placement matching the step's batch sharding.

At 1000+ node scale each host generates/loads only its slice
(``jax.process_index``-keyed RNG streams); in this single-process container
the same code path produces the full batch and ``jax.device_put`` scatters it
across the mesh according to the batch NamedSharding.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np


class Prefetcher:
    """Background-thread prefetch of host batches (depth-bounded)."""

    def __init__(self, make_batch: Callable[[int], Any], depth: int = 2, start_step: int = 0):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> Any:
        step, batch = self._q.get()
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


class TokenLoader:
    """Deterministic, restart-safe loader: batch(step) is a pure function of
    (seed, step), so restoring a checkpoint at step S resumes the exact
    stream — required for reproducible fault recovery."""

    def __init__(self, task, batch: int, seq: int, seed: int = 0, sharding=None, prefetch: int = 2):
        self.task = task
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.sharding = sharding
        self._prefetcher: Optional[Prefetcher] = None
        self.prefetch_depth = prefetch

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        return self.task.sample(rng, self.batch, self.seq)

    def device_batch(self, step: int) -> Dict[str, jax.Array]:
        hb = self.host_batch(step)
        if self.sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in hb.items()}
        return {k: jax.device_put(v, self.sharding) for k, v in hb.items()}

    def start(self, start_step: int = 0):
        self._prefetcher = Prefetcher(self.device_batch, self.prefetch_depth, start_step)
        return self

    def next(self):
        assert self._prefetcher is not None, "call start() first"
        return self._prefetcher.next()

    def close(self):
        if self._prefetcher:
            self._prefetcher.close()
            self._prefetcher = None
