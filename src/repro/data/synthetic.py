"""Deterministic synthetic datasets (offline container: no downloads).

* ``TokenTask``: a learnable synthetic language — a random order-2 Markov
  chain over the vocab with Zipfian marginals.  Cross-entropy is reducible
  from log(V) toward the chain's conditional entropy, so training curves are
  meaningful (loss decreases monotonically for a working trainer).
* ``ClassifyTask``: MNIST/CIFAR-like classification — K class prototypes +
  structured noise, image-shaped.  Linearly separable at high SNR, genuinely
  learnable; used by the paper-reproduction experiments (nets A-D).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class TokenTask:
    vocab_size: int
    seed: int = 0
    branch: int = 8  # plausible successors per context

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # Zipfian unigram
        ranks = np.arange(1, v + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # order-1 successor table: each token has `branch` likely successors
        self.successors = rng.integers(0, v, size=(v, self.branch))
        self.mix = 0.85  # prob of following the chain vs unigram sample

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> Dict[str, np.ndarray]:
        v = self.vocab_size
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.choice(v, size=batch, p=self.unigram)
        for t in range(seq):
            follow = rng.random(batch) < self.mix
            succ_idx = rng.integers(0, self.branch, size=batch)
            chain_next = self.successors[toks[:, t], succ_idx]
            rand_next = rng.choice(v, size=batch, p=self.unigram)
            toks[:, t + 1] = np.where(follow, chain_next, rand_next)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


@dataclasses.dataclass
class ClassifyTask:
    """K-class prototype images + noise (MNIST-like when shape=(784,))."""

    input_shape: Tuple[int, ...]
    n_classes: int = 10
    noise: float = 0.7
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        dim = int(np.prod(self.input_shape))
        # smooth prototypes (low-frequency structure, like digit strokes)
        raw = rng.normal(size=(self.n_classes, dim)).astype(np.float32)
        kernel = np.ones(9) / 9.0
        self.prototypes = np.stack(
            [np.convolve(r, kernel, mode="same") for r in raw]
        ) * 3.0

    def sample(self, rng: np.random.Generator, batch: int) -> Dict[str, np.ndarray]:
        y = rng.integers(0, self.n_classes, size=batch).astype(np.int32)
        x = self.prototypes[y] + rng.normal(
            scale=self.noise, size=(batch, self.prototypes.shape[1])
        ).astype(np.float32)
        return {"x": x.reshape((batch,) + tuple(self.input_shape)), "y": y}

    def test_set(self, n: int = 2048, seed: int = 10_000) -> Dict[str, np.ndarray]:
        return self.sample(np.random.default_rng(seed), n)
