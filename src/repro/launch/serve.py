"""Batched serving driver: prefill a batch of prompts, then decode tokens
autoregressively with the KV/SSM cache — optionally with PVQ-quantized
weights (the paper's inference-cost story).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --gen 16 [--pvq]

``--pvq`` serves the *packed* artifact: the model pytree is encoded ONCE
into ``PackedPVQ`` leaves (int8 pulses + f32 group scales) and the decode
loop streams those codes straight into the int8-native Pallas matmul —
no per-layer re-encode, no full-matrix f32 dequantization anywhere on the
hot path.  ``--pvq-sim`` keeps the old dequantize-back-to-f32 simulation
(same numerics as the paper tables, none of the memory win) for A/B runs.

``--artifact model.pvqz`` skips the encode entirely: the entropy-coded
container (written by ``repro.launch.export``) is decoded leaf-by-leaf
straight into ``PackedPVQ`` — bit-exact pulses/scales, no re-encode, peak
decode memory bounded by one leaf — and served through the same int8-native
path, so logits are identical to the in-memory ``--pvq`` artifact it was
exported from.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.packed import expert_leaves, packed_stats, quantize_params
from repro.core.quantize import QuantPolicy, quantize_tree, total_bits
from repro.nn.models import build_model


def _expert_report(params) -> dict:
    """Weight-bytes report for the packed MoE expert bank (if any)."""
    ex = expert_leaves(params)
    if not ex:
        return {}
    packed_bytes = sum(leaf.nbytes_packed for leaf in ex.values())
    dense_bytes = sum(leaf.nbytes_dense for leaf in ex.values())
    return {
        "packed_expert_tensors": len(ex),
        "packed_expert_bytes": packed_bytes,
        "dense_expert_bytes": dense_bytes,
        "expert_compression_ratio": round(dense_bytes / max(packed_bytes, 1), 3),
    }


def generate(model, params, tokens, *, gen: int, cache_len: int, extra_batch=None):
    """Greedy decode. tokens: (b, s) prompt. Returns (b, s+gen)."""
    batch = {"tokens": tokens}
    if extra_batch:
        batch.update(extra_batch)
    logits, cache = model.prefill(params, batch, cache_len=cache_len)
    out = [tokens]
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)

    step = jax.jit(model.decode_step)
    pos0 = tokens.shape[1]
    for i in range(gen):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(pos0 + i))
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument(
        "--pvq",
        action="store_true",
        help="serve the packed PVQ artifact (int8 pulses streamed into the "
        "int8-native kernel; encode once, zero dequant on the hot path)",
    )
    ap.add_argument(
        "--pvq-sim",
        action="store_true",
        help="legacy dequantized simulation: encode then expand back to f32 "
        "(paper-table numerics, no memory win)",
    )
    ap.add_argument(
        "--artifact",
        default=None,
        metavar="MODEL.PVQZ",
        help="serve a .pvqz compressed artifact (repro.launch.export): "
        "entropy-coded pulses stream-decode leaf-by-leaf into PackedPVQ "
        "with no re-encode, then serve int8-native",
    )
    ap.add_argument("--n-over-k", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--tune",
        action="store_true",
        help="pre-tune pvq_matmul tiles for this config's decode/prefill GEMM "
        "shapes and persist them (REPRO_PVQ_TUNE_CACHE); later PVQ-kernel "
        "dispatch through kernels.ops picks the tuned tiles up transparently",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), max_seq=args.prompt_len + args.gen)

    report = {}
    if args.tune:
        from repro.core.packed import matmul_plan
        from repro.kernels import autotune

        d_model = cfg.d_model
        d_ff = getattr(cfg, "d_ff", 0) or 4 * d_model
        group = cfg.pvq.group or 128
        tuned = {}
        # decode (m=batch) and prefill (m=batch*prompt) GEMMs of the block,
        # keyed exactly as the packed artifact will dispatch them (same
        # effective group + group-padded contraction dim via matmul_plan) —
        # otherwise the pre-tuned entries can never be cache hits
        shapes = {
            (args.batch, d_model, d_model),
            (args.batch, d_model, d_ff),
            (args.batch, d_ff, d_model),
            (args.batch * args.prompt_len, d_model, d_ff),
        }
        if cfg.moe is not None:
            # per-expert dispatch-buffer GEMMs (m = groups * capacity): the
            # batched expert matmul keys its shared tiles on exactly these
            from repro.nn.moe import dispatch_gemm_rows

            mo = cfg.moe
            for t in (args.batch, args.batch * args.prompt_len):
                m_exp = dispatch_gemm_rows(mo, t)
                shapes.add((m_exp, d_model, mo.d_expert))
                shapes.add((m_exp, mo.d_expert, d_model))
        for m, k, n in sorted(shapes):
            g, k_pad = matmul_plan(group, k)
            e = autotune.autotune(m, k_pad, n, group=g)
            tuned[f"{m}x{k_pad}x{n}"] = {kk: e[kk] for kk in ("bm", "bn", "bk", "us")}
        report["tuned_tiles"] = tuned
        report["tune_cache"] = str(autotune.cache_path())
    if args.artifact:
        import os

        from repro.checkpoint.artifact import load_pvqz, read_toc

        t0 = time.time()
        params = load_pvqz(args.artifact, target=params)
        # entropy=False: the at-rest bits/weight is already in the export
        # report / TOC; don't re-price every pulse stream on serve startup
        st = packed_stats(params, entropy=False)
        toc = read_toc(args.artifact)
        report["pvq_mode"] = "artifact"
        report["artifact"] = args.artifact
        report["artifact_bytes"] = os.path.getsize(args.artifact)
        report["artifact_meta"] = toc.get("meta", {})
        report["pvq_tensors"] = st["packed_tensors"]
        report["artifact_decode_s"] = round(time.time() - t0, 2)
        report.update(_expert_report(params))
    elif args.pvq or args.pvq_sim:
        policy = QuantPolicy(
            rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
                   ("kernel|experts", args.n_over_k, cfg.pvq.group)),
            scale_mode="ls",
        )
        t0 = time.time()
        if args.pvq_sim:
            params, codes, _ = quantize_tree(params, policy)
            report["pvq_mode"] = "dequant-sim"
            report["pvq_tensors"] = len(codes)
            report.update({k: round(v, 3) for k, v in total_bits(codes).items()
                           if "ratio" in k or "bits_per" in k})
        else:
            params = quantize_params(params, policy)
            st = packed_stats(params, entropy=False)
            report["pvq_mode"] = "packed"
            report["pvq_tensors"] = st["packed_tensors"]
            report["packed_bytes"] = st["packed_bytes"]
            report["weight_compression_ratio"] = round(st["weight_compression_ratio"], 3)
            report.update(_expert_report(params))
        report["pvq_encode_s"] = round(time.time() - t0, 1)

    key = jax.random.PRNGKey(args.seed + 1)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model))
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(key, (args.batch, cfg.prefix_len, cfg.d_model))

    t0 = time.time()
    out = generate(model, params, tokens, gen=args.gen,
                   cache_len=args.prompt_len + args.gen, extra_batch=extra)
    dt = time.time() - t0
    report.update({
        "arch": cfg.name, "batch": args.batch,
        "generated_shape": list(out.shape),
        "tokens_per_s": round(args.batch * args.gen / dt, 1),
        "wall_s": round(dt, 2),
    })
    print(json.dumps(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
