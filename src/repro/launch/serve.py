"""Batched serving driver: prefill a batch of prompts, then decode tokens
autoregressively with the KV/SSM cache — optionally with PVQ-quantized
weights (the paper's inference-cost story).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --gen 16 [--pvq]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.quantize import QuantPolicy, quantize_tree, total_bits
from repro.nn.models import build_model


def generate(model, params, tokens, *, gen: int, cache_len: int, extra_batch=None):
    """Greedy decode. tokens: (b, s) prompt. Returns (b, s+gen)."""
    batch = {"tokens": tokens}
    if extra_batch:
        batch.update(extra_batch)
    logits, cache = model.prefill(params, batch, cache_len=cache_len)
    out = [tokens]
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)

    step = jax.jit(model.decode_step)
    pos0 = tokens.shape[1]
    for i in range(gen):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(pos0 + i))
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--pvq", action="store_true", help="serve PVQ-quantized weights")
    ap.add_argument("--n-over-k", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--tune",
        action="store_true",
        help="pre-tune pvq_matmul tiles for this config's decode/prefill GEMM "
        "shapes and persist them (REPRO_PVQ_TUNE_CACHE); later PVQ-kernel "
        "dispatch through kernels.ops picks the tuned tiles up transparently",
    )
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), max_seq=args.prompt_len + args.gen)

    report = {}
    if args.tune:
        from repro.kernels import autotune

        d_model = cfg.d_model
        d_ff = getattr(cfg, "d_ff", 0) or 4 * d_model
        group = cfg.pvq.group or 128
        tuned = {}
        # decode (m=batch) and prefill (m=batch*prompt) GEMMs of the block
        for m, k, n in sorted(
            {
                (args.batch, d_model, d_model),
                (args.batch, d_model, d_ff),
                (args.batch, d_ff, d_model),
                (args.batch * args.prompt_len, d_model, d_ff),
            }
        ):
            g = group
            while k % g:  # group must divide the contraction dim
                g //= 2
            e = autotune.autotune(m, k, n, group=g)
            tuned[f"{m}x{k}x{n}"] = {kk: e[kk] for kk in ("bm", "bn", "bk", "us")}
        report["tuned_tiles"] = tuned
        report["tune_cache"] = str(autotune.cache_path())
    if args.pvq:
        policy = QuantPolicy(
            rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
                   ("kernel|experts", args.n_over_k, cfg.pvq.group)),
            scale_mode="ls",
        )
        t0 = time.time()
        params, codes, _ = quantize_tree(params, policy)
        report["pvq_encode_s"] = round(time.time() - t0, 1)
        report["pvq_tensors"] = len(codes)
        report.update({k: round(v, 3) for k, v in total_bits(codes).items() if "ratio" in k or "bits_per" in k})

    key = jax.random.PRNGKey(args.seed + 1)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model))
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(key, (args.batch, cfg.prefix_len, cfg.d_model))

    t0 = time.time()
    out = generate(model, params, tokens, gen=args.gen,
                   cache_len=args.prompt_len + args.gen, extra_batch=extra)
    dt = time.time() - t0
    report.update({
        "arch": cfg.name, "batch": args.batch,
        "generated_shape": list(out.shape),
        "tokens_per_s": round(args.batch * args.gen / dt, 1),
        "wall_s": round(dt, 2),
    })
    print(json.dumps(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
