"""Batched serving driver: prefill a batch of prompts, then decode tokens
autoregressively with the KV/SSM cache — optionally with PVQ-quantized
weights (the paper's inference-cost story).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --gen 16 [--pvq]

``--pvq`` serves the *packed* artifact: the model pytree is encoded ONCE
into ``PackedPVQ`` leaves (int8 pulses + f32 group scales) and the decode
loop streams those codes straight into the int8-native Pallas matmul —
no per-layer re-encode, no full-matrix f32 dequantization anywhere on the
hot path.  ``--pvq-sim`` keeps the old dequantize-back-to-f32 simulation
(same numerics as the paper tables, none of the memory win) for A/B runs.

``--artifact model.pvqz`` skips the encode entirely: the entropy-coded
container (written by ``repro.launch.export``) is decoded leaf-by-leaf
straight into ``PackedPVQ`` — bit-exact pulses/scales, no re-encode, peak
decode memory bounded by one leaf — and served through the same int8-native
path, so logits are identical to the in-memory ``--pvq`` artifact it was
exported from.

``--act-int8`` (with ``--pvq`` or ``--artifact``) sets the process-wide
``ActQuant`` contract: every packed matmul on the hot path quantizes its
activations to per-row symmetric int8 and runs the int8 x int8 kernel v3
(int32 MXU accumulation) — the all-integer contraction of the paper plus
Liguori's follow-up, with an activation-bandwidth win on top of the weight
one.  ``--agreement-min T`` additionally serves the same prompts on the
f32 reference path (f32 activations, dense f32 KV cache) and fails
(exit 1) if greedy top-1 token agreement drops below T — the CI gate.

``--kv-pvq`` sets the process-wide ``KVQuant`` contract: every attention
layer's decode cache becomes a ``core.packed.PackedKV`` — completed blocks
of K/V rows are PVQ-encoded (int8 pulse planes + per-group rho), decode
contracts them with the int8 attention kernel v4, and only the in-flight
partial block stays exact f32.  This is the decode *bandwidth* half: after
``--pvq --act-int8`` shrank weights and activations, re-reading the KV
cache every token dominates; packed KV cuts those bytes ~3.6x vs f32.
"""

from __future__ import annotations

import argparse
import json
import time
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.packed import expert_leaves, packed_stats, quantize_params
from repro.core.quantize import QuantPolicy, quantize_tree, total_bits
from repro.launch.engine import bucket_len
from repro.nn.models import build_model
from repro.runtime import obs

# Actual XLA trace counts of the shared decode step (incremented by a
# Python side effect that only runs while tracing).  The regression tests
# read this to prove cache-length bucketing + the shared jit keep
# generate() from recompiling per (batch, cache_len).
TRACE_COUNTS: dict = {"decode_step": 0}
_STEP_JITS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _jit_step(model):
    """One shared jitted ``decode_step`` per Model.

    The old pattern — a fresh ``jax.jit(model.decode_step)`` inside every
    ``generate()`` call — gave each call its own empty compile cache, so
    EVERY call retraced (and every distinct ``(batch, cache_len)`` pair
    recompiled even across a shared wrapper).  One wrapper per model plus
    kv-block cache-length bucketing bounds compiles by shape buckets."""
    fn = _STEP_JITS.get(model)
    if fn is None:
        def counted_step(params, cache, tok, pos):
            # both side effects run at TRACE time only (host-side python;
            # nothing lands inside the compiled body): the test dict, and
            # the same watcher promoted to a first-class metric
            TRACE_COUNTS["decode_step"] += 1
            obs.counter("serve.decode_step_traces").inc()
            return model.decode_step(params, cache, tok, pos)

        fn = jax.jit(counted_step)
        _STEP_JITS[model] = fn
    return fn


def _decode_bucket() -> int:
    """Cache-length bucket: the active KVQuant block (packed planes must
    cover whole blocks anyway) or 32 for dense caches."""
    from repro.core.quantize import default_kv_quant

    kvq = default_kv_quant()
    return int(kvq.block) if kvq else 32


def _expert_report(params) -> dict:
    """Weight-bytes report for the packed MoE expert bank (if any)."""
    ex = expert_leaves(params)
    if not ex:
        return {}
    packed_bytes = sum(leaf.nbytes_packed for leaf in ex.values())
    dense_bytes = sum(leaf.nbytes_dense for leaf in ex.values())
    return {
        "packed_expert_tensors": len(ex),
        "packed_expert_bytes": packed_bytes,
        "dense_expert_bytes": dense_bytes,
        "expert_compression_ratio": round(dense_bytes / max(packed_bytes, 1), 3),
    }


def generate(model, params, tokens, *, gen: int, cache_len: int, extra_batch=None):
    """Greedy decode. tokens: (b, s) prompt. Returns (b, s+gen).

    ``cache_len`` is rounded up to the kv-block bucket so nearby lengths
    share one compiled decode step (positions past the true length stay
    behind the attention length mask)."""
    cache_len = bucket_len(cache_len, _decode_bucket())
    with obs.span("serve/generate", args={
        "batch": int(tokens.shape[0]), "gen": int(gen), "cache_len": cache_len,
    }):
        batch = {"tokens": tokens}
        if extra_batch:
            batch.update(extra_batch)
        with obs.span("serve/prefill"):
            logits, cache = model.prefill(params, batch, cache_len=cache_len)
        out = [tokens]
        tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)

        step = _jit_step(model)
        pos0 = tokens.shape[1]
        for i in range(gen):
            out.append(tok)
            logits, cache = step(params, cache, tok, jnp.int32(pos0 + i))
            tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
        return jnp.concatenate(out, axis=1)


def teacher_forced_logits(
    model, params, seq, *, prompt_len: int, extra_batch=None
):
    """Per-position next-token logits along a FIXED sequence, through the
    decode path (prefill on the prompt, then ``decode_step`` fed the given
    tokens).  Returns (b, seq_len - prompt_len, vocab) logits predicting
    positions ``prompt_len..seq_len-1``."""
    with obs.span("serve/teacher_forced", args={
        "batch": int(seq.shape[0]), "seq_len": int(seq.shape[1]),
    }):
        batch = {"tokens": seq[:, :prompt_len]}
        if extra_batch:
            batch.update(extra_batch)
        cache_len = bucket_len(seq.shape[1], _decode_bucket())
        logits, cache = model.prefill(params, batch, cache_len=cache_len)
        steps = [logits[:, -1, :]]
        step = _jit_step(model)
        for i in range(seq.shape[1] - prompt_len - 1):
            tok = seq[:, prompt_len + i : prompt_len + i + 1]
            logits, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
            steps.append(logits[:, -1, :])
        return jnp.stack(steps, axis=1)


def top1_agreement(logits_a, logits_b) -> dict:
    """Top-1 agreement between two logit tensors over the same contexts.

    Returns ``{"top1_agreement", "top1_agreement_strict", "ties_excused"}``.
    Strict agreement is plain argmax equality.  The headline number
    additionally excuses *sub-noise ties*: a disagreeing position counts as
    agreeing only when BOTH

    * the reference margin ``logits_a[argmax_a] - logits_a[argmax_b]`` is at
      most the MEASURED logit perturbation ``max_v |a - b|`` at that very
      position — the paths differ by less than the gap they disagree over;
    * that margin is also below 5% of the reference logits' own spread at
      the position — the reference itself calls the two candidates a
      near-tie, so no int8 kernel (indeed no reordered f32 kernel) could
      reproduce the pick deterministically.

    The second condition keeps the excuse from laundering a broken kernel:
    gross perturbations produce disagreements with LARGE reference margins,
    which are never excused.  On a trained model margins dwarf the noise
    and the two metrics coincide; the excuse exists for random-init smoke
    models whose near-tie margins are coin flips.
    """
    a = jnp.asarray(logits_a, jnp.float32)
    b = jnp.asarray(logits_b, jnp.float32)
    pa = jnp.argmax(a, -1)
    pb = jnp.argmax(b, -1)
    strict = pa == pb
    noise = jnp.max(jnp.abs(a - b), axis=-1)  # (b, t)
    margin = jnp.take_along_axis(a, pa[..., None], -1)[..., 0] - jnp.take_along_axis(
        a, pb[..., None], -1
    )[..., 0]
    tie_cap = 0.05 * jnp.std(a, axis=-1)
    agree = strict | ((margin <= noise) & (margin <= tie_cap))
    out = {
        "top1_agreement": float(jnp.mean(agree.astype(jnp.float32))),
        "top1_agreement_strict": float(jnp.mean(strict.astype(jnp.float32))),
        "ties_excused": int(jnp.sum((agree & ~strict).astype(jnp.int32))),
    }
    if obs.enabled():
        # agreement as a streaming metric, not just one gate number
        total = int(np.prod(np.asarray(strict.shape)))
        obs.counter("quality.tokens_total").add(total)
        obs.counter("quality.tokens_agree").add(int(jnp.sum(agree)))
        obs.counter("quality.ties_excused").add(out["ties_excused"])
        obs.histogram("quality.ref_margin").record_many(
            np.asarray(margin, np.float64).ravel()
        )
    return out


def engine_token_agreement(model, params, requests, outputs) -> dict:
    """Token-level agreement of the continuous-batching engine against the
    fixed-batch decode oracle.

    For every request, the engine's full output sequence is teacher-forced
    through the fixed-batch path (prefill + lockstep ``decode_step``, same
    quantized contracts) and each engine token is compared against the
    oracle's argmax *given the identical context* — no free-running
    cascade, so one near-tie flip can't rewrite a suffix.  A disagreeing
    token is excused only when the oracle itself calls it a near-tie (its
    margin over the engine's pick is under 5% of the logits' spread —
    the ``top1_agreement`` tie rule with the oracle as its own reference).
    """
    agree = total = excused = 0
    for req in requests:
        gen = outputs.get(req.rid)
        if not gen:
            continue
        seq = jnp.asarray([list(req.prompt) + list(gen)], jnp.int32)
        lg = teacher_forced_logits(model, params, seq, prompt_len=len(req.prompt))
        lg = jnp.asarray(lg[0], jnp.float32)  # (len(gen), vocab)
        oracle = np.asarray(jnp.argmax(lg, -1))
        toks = np.asarray(gen)
        match = oracle == toks
        margin = np.asarray(
            jnp.take_along_axis(lg, jnp.asarray(oracle)[:, None], -1)[:, 0]
            - jnp.take_along_axis(lg, jnp.asarray(toks)[:, None], -1)[:, 0]
        )
        tie = margin <= 0.05 * np.asarray(jnp.std(lg, axis=-1))
        agree += int(np.sum(match | tie))
        excused += int(np.sum(~match & tie))
        total += len(gen)
        if obs.enabled():
            # per-request streaming counters + the running agreement level
            obs.counter("quality.tokens_total").add(len(gen))
            obs.counter("quality.tokens_agree").add(int(np.sum(match | tie)))
            obs.counter("quality.ties_excused").add(int(np.sum(~match & tie)))
            obs.gauge("quality.agreement_running").set(agree / max(total, 1))
    return {
        "engine_token_agreement": agree / max(total, 1),
        "engine_tokens_compared": total,
        "engine_ties_excused": excused,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument(
        "--pvq",
        action="store_true",
        help="serve the packed PVQ artifact (int8 pulses streamed into the "
        "int8-native kernel; encode once, zero dequant on the hot path)",
    )
    ap.add_argument(
        "--pvq-sim",
        action="store_true",
        help="legacy dequantized simulation: encode then expand back to f32 "
        "(paper-table numerics, no memory win)",
    )
    ap.add_argument(
        "--artifact",
        default=None,
        metavar="MODEL.PVQZ",
        help="serve a .pvqz compressed artifact (repro.launch.export): "
        "entropy-coded pulses stream-decode leaf-by-leaf into PackedPVQ "
        "with no re-encode, then serve int8-native",
    )
    ap.add_argument(
        "--act-int8",
        action="store_true",
        help="quantize activations to per-row symmetric int8 and run every "
        "packed matmul through the int8 x int8 kernel v3 (int32 MXU "
        "accumulation); requires --pvq or --artifact",
    )
    ap.add_argument(
        "--kv-pvq",
        action="store_true",
        help="PVQ-compress the decode KV cache: completed blocks are stored "
        "as int8 pulse planes + per-group rho and contracted by the int8 "
        "attention kernel v4; the in-flight partial block stays exact f32",
    )
    ap.add_argument(
        "--kv-block",
        type=int,
        default=32,
        help="with --kv-pvq: tokens per encoded cache block (the f32 tail "
        "ring is this long)",
    )
    ap.add_argument(
        "--kv-group",
        type=int,
        default=32,
        help="with --kv-pvq: sub-head PVQ group width (fitted down when it "
        "does not divide head_dim)",
    )
    ap.add_argument(
        "--max-kv-bytes-ratio",
        type=float,
        default=0.35,
        metavar="R",
        help="with --kv-pvq: exit 1 if the packed cache's bytes/token "
        "exceeds R x the f32 cache (the compression the kernel-v4 path "
        "exists to deliver)",
    )
    ap.add_argument(
        "--agreement-min",
        type=float,
        default=None,
        metavar="T",
        help="with --act-int8 and/or --kv-pvq: also serve the same prompts "
        "on the f32 reference path (f32 activations, dense f32 KV cache) "
        "and exit 1 if greedy top-1 token agreement < T",
    )
    ap.add_argument(
        "--engine",
        action="store_true",
        help="serve a Poisson request trace through the continuous-batching "
        "engine (launch.engine): paged PVQ KV cache, async admission, "
        "prefill/decode disaggregation; requires --kv-pvq (pages are PVQ "
        "blocks).  Also times the fixed-batch generate() loop run "
        "sequentially over the same trace for the speedup report",
    )
    ap.add_argument("--engine-slots", type=int, default=4,
                    help="with --engine: decode slot-pool size")
    ap.add_argument("--engine-pages", type=int, default=None,
                    help="with --engine: physical KV pages (default: fully "
                    "provisioned slots*max_pages; smaller oversubscribes "
                    "and exercises eviction)")
    ap.add_argument("--requests", type=int, default=16,
                    help="with --engine: trace length")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="with --engine: Poisson arrival rate (req/s); "
                    "0/inf = all arrive at t=0 (saturate-then-drain)")
    ap.add_argument("--min-speedup", type=float, default=None, metavar="S",
                    help="with --engine: exit 1 if engine tokens/s is not "
                    "at least S x the sequential fixed-batch baseline")
    ap.add_argument("--prefill-chunk", type=int, default=None, metavar="P",
                    help="with --engine: chunked prefill — prompts longer "
                    "than P pages stream in P-page chunks interleaved with "
                    "decode steps (bounds p99 inter-token latency during "
                    "long-prompt admission); also enables the shared-prefix "
                    "page cache")
    ap.add_argument("--prefill-batch", type=int, default=1, metavar="B",
                    help="with --engine: admit up to B same-bucket waiting "
                    "requests per step through ONE multi-row prefill compile")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="with --engine --prefill-chunk: disable the "
                    "shared-prefix page cache (refcounted page reuse)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="with --engine: prepend one common N-token prefix "
                    "to every trace prompt (shared-system-prompt traffic; "
                    "exercises the prefix page cache)")
    ap.add_argument("--min-prefix-hits", type=int, default=None, metavar="H",
                    help="with --engine: exit 1 if the prefix page cache "
                    "recorded fewer than H page hits over the run")
    ap.add_argument("--n-over-k", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--tune",
        action="store_true",
        help="pre-tune pvq_matmul tiles for this config's decode/prefill GEMM "
        "shapes and persist them (REPRO_PVQ_TUNE_CACHE); later PVQ-kernel "
        "dispatch through kernels.ops picks the tuned tiles up transparently",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="DIR",
        help="enable the process telemetry registry (repro.runtime.obs) and "
        "write metrics.jsonl + a perfetto-loadable trace.json into DIR on "
        "exit (every exit path, gate failures included)",
    )
    args = ap.parse_args()
    if args.act_int8 and not (args.pvq or args.artifact):
        ap.error("--act-int8 quantizes the packed matmul activations; "
                 "it requires --pvq or --artifact")
    if args.agreement_min is not None and not (args.act_int8 or args.kv_pvq):
        ap.error("--agreement-min compares a quantized path against the f32 "
                 "reference; it requires --act-int8 and/or --kv-pvq")
    if args.engine and not args.kv_pvq:
        ap.error("--engine pages the PVQ-compressed KV cache (page = kv "
                 "block); it requires --kv-pvq")

    if args.metrics_out:
        obs.set_enabled(True)
    try:
        return _serve(args)
    finally:
        if args.metrics_out:
            obs.write(args.metrics_out)


def _probe_act_rows(params) -> None:
    """Host-side ActQuant quality probe on real weight rows.

    The serving matmuls quantize activations under jit, where the
    eager-only probe in ``quantize_activations`` can't fire; here we run
    the identical transform eagerly on rows of the packed embedding (or
    the first packed leaf) so the clamp/saturation metrics get real data.
    """
    import re

    from repro.core.packed import packed_leaves
    from repro.core.quantize import default_act_quant, quantize_activations

    aq = default_act_quant()
    leaves = packed_leaves(params)
    if aq is None or not leaves:
        return
    pick = next(
        (l for p, l in leaves.items() if re.search(r"(^|/)embedding$", p)),
        next(iter(leaves.values())),
    )
    rows = pick.dequantize(jnp.float32)
    rows = rows.reshape(-1, rows.shape[-1])[:32]
    quantize_activations(rows, aq)


def _serve(args) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), max_seq=args.prompt_len + args.gen)

    report = {}
    if args.metrics_out:
        report["metrics_out"] = args.metrics_out
    if args.tune:
        from repro.core.packed import matmul_plan
        from repro.kernels import autotune

        t_tune = time.time()
        autotune.reset_tune_stats()
        d_model = cfg.d_model
        d_ff = getattr(cfg, "d_ff", 0) or 4 * d_model
        group = cfg.pvq.group or 128
        tuned = {}
        # decode (m=batch) and prefill (m=batch*prompt) GEMMs of the block,
        # keyed exactly as the packed artifact will dispatch them (same
        # effective group + group-padded contraction dim via matmul_plan) —
        # otherwise the pre-tuned entries can never be cache hits
        shapes = {
            (args.batch, d_model, d_model),
            (args.batch, d_model, d_ff),
            (args.batch, d_ff, d_model),
            (args.batch * args.prompt_len, d_model, d_ff),
        }
        if args.engine:
            # slot-pool decode GEMMs: m is the engine's fixed slot count
            shapes |= {
                (args.engine_slots, d_model, d_model),
                (args.engine_slots, d_model, d_ff),
                (args.engine_slots, d_ff, d_model),
            }
            if args.prefill_chunk:
                # chunked-prefill GEMMs: one static row count C per step
                c_tok = args.prefill_chunk * max(args.kv_block, 1)
                shapes |= {
                    (c_tok, d_model, d_model),
                    (c_tok, d_model, d_ff),
                    (c_tok, d_ff, d_model),
                }
            if args.prefill_batch > 1:
                # batched-admission prefill GEMM: B rows x the prompt bucket
                b_tok = args.prefill_batch * bucket_len(
                    max(args.shared_prefix + args.prompt_len, 1),
                    max(args.kv_block, 1),
                )
                shapes.add((b_tok, d_model, d_ff))
        if cfg.moe is not None:
            # per-expert dispatch-buffer GEMMs (m = groups * capacity): the
            # batched expert matmul keys its shared tiles on exactly these
            from repro.nn.moe import dispatch_gemm_rows

            mo = cfg.moe
            for t in (args.batch, args.batch * args.prompt_len):
                m_exp = dispatch_gemm_rows(mo, t)
                shapes.add((m_exp, d_model, mo.d_expert))
                shapes.add((m_exp, mo.d_expert, d_model))
        for m, k, n in sorted(shapes):
            g, k_pad = matmul_plan(group, k)
            e = autotune.autotune(m, k_pad, n, group=g)
            tuned[f"{m}x{k_pad}x{n}"] = {kk: e[kk] for kk in ("bm", "bn", "bk", "us")}
            if args.act_int8:
                # the act dtype is part of the cache key: int8 entries time
                # the quantized-activation kernel v3 body and can never be
                # confused with the f32-activation tiles above
                e8 = autotune.autotune(m, k_pad, n, group=g, dtype=jnp.int8)
                tuned[f"{m}x{k_pad}x{n}:int8"] = {
                    kk: e8[kk] for kk in ("bm", "bn", "bk", "us")
                }
        if args.kv_pvq:
            # kernel-v4 attention decode shape: m = grouped query rows per kv
            # head, s = the packed plane length the serve caches will carry
            # (prefill pads roundup(prompt, block) planes out to cache_len)
            from repro.core.packed import _fit_group

            hd = cfg.resolved_head_dim
            g = _fit_group(args.kv_group, hd)
            blk = max(args.kv_block, 1)
            m_q = max(cfg.n_heads // cfg.n_kv_heads, 1)
            s_planes = -(-args.prompt_len // blk) * blk + args.gen
            ea = autotune.autotune_attn(m_q, hd, s_planes, group=g, dtype=jnp.int8)
            tuned[f"attn{m_q}x{hd}x{s_planes}:int8"] = {
                kk: ea[kk] for kk in ("bs", "us")
            }
            if args.engine:
                # engine decode shapes are keyed on the slot-pool geometry:
                # the gathered plane extent is always max_pages * page,
                # independent of which sequences are resident
                s_pool = bucket_len(
                    args.shared_prefix + args.prompt_len + args.gen, blk
                )
                attn_shapes = [(m_q, hd, s_pool)]
                if args.prefill_chunk:
                    # the chunk step's packed leg: C query rows, each
                    # expanded to grouped rows per kv head, against the
                    # same slot-pool plane extent
                    c_tok = args.prefill_chunk * blk
                    attn_shapes.append((c_tok * m_q, hd, s_pool))
                autotune.tune_attn_shapes(attn_shapes, group=g, dtype=jnp.int8)
                for mm, _, ss in attn_shapes:
                    ent = autotune.autotune_attn(mm, hd, ss, group=g, dtype=jnp.int8)
                    tuned[f"attn{mm}x{hd}x{ss}:int8:engine"] = {
                        kk: ent[kk] for kk in ("bs", "us")
                    }
        report["tuned_tiles"] = tuned
        report["tune_cache"] = str(autotune.cache_path())
        # tuning cost was silent before: total wall time + per-key
        # hit/miss/search counts straight from the autotuner
        report["tune_wall_s"] = round(time.time() - t_tune, 2)
        report["tune_stats"] = autotune.tune_stats()
    if args.artifact:
        import os

        from repro.checkpoint.artifact import load_pvqz, read_toc

        t0 = time.time()
        # blob -> PackedPVQ wall time lands in the trace as one span right
        # next to the engine's time-to-first-token spans; the per-codec
        # decode MB/s histograms underneath come from the artifact layer
        with obs.span("artifact/cold_start", args={"path": args.artifact}):
            params = load_pvqz(args.artifact, target=params)
        cold_s = time.time() - t0
        # entropy=False: the at-rest bits/weight is already in the export
        # report / TOC; don't re-price every pulse stream on serve startup
        st = packed_stats(params, entropy=False)
        toc = read_toc(args.artifact)
        report["pvq_mode"] = "artifact"
        report["artifact"] = args.artifact
        report["artifact_bytes"] = os.path.getsize(args.artifact)
        report["artifact_meta"] = toc.get("meta", {})
        report["pvq_tensors"] = st["packed_tensors"]
        report["artifact_decode_s"] = round(cold_s, 2)
        if obs.enabled():
            obs.gauge("artifact.cold_start_s").set(cold_s)
            # fold the per-codec throughput counters into the startup report
            snap = {
                (m["name"], m["labels"].get("codec")): m["value"]
                for m in obs.registry().snapshot()
                if m["name"].startswith("artifact.decode_") and m["kind"] == "counter"
            }
            mbps = {}
            for (name, codec), sym in snap.items():
                if name != "artifact.decode_symbols":
                    continue
                secs = snap.get(("artifact.decode_s", codec), 0.0)
                if secs:
                    mbps[codec] = round(sym / secs / 1e6, 1)
            if mbps:
                report["artifact_decode_mb_s"] = mbps
        report.update(_expert_report(params))
    elif args.pvq or args.pvq_sim:
        policy = QuantPolicy(
            rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
                   ("kernel|experts", args.n_over_k, cfg.pvq.group)),
            scale_mode="ls",
        )
        t0 = time.time()
        if args.pvq_sim:
            params, codes, _ = quantize_tree(params, policy)
            report["pvq_mode"] = "dequant-sim"
            report["pvq_tensors"] = len(codes)
            report.update({k: round(v, 3) for k, v in total_bits(codes).items()
                           if "ratio" in k or "bits_per" in k})
        else:
            params = quantize_params(params, policy)
            st = packed_stats(params, entropy=False)
            report["pvq_mode"] = "packed"
            report["pvq_tensors"] = st["packed_tensors"]
            report["packed_bytes"] = st["packed_bytes"]
            report["weight_compression_ratio"] = round(st["weight_compression_ratio"], 3)
            report.update(_expert_report(params))
        report["pvq_encode_s"] = round(time.time() - t0, 1)

    from repro.core.quantize import (
        ActQuant,
        KVQuant,
        act_quant_scope,
        kv_quant_scope,
        set_default_act_quant,
        set_default_kv_quant,
    )

    if args.act_int8:
        # one switch sets the process-wide contract: every packed matmul
        # below (dense, unembed, MoE dispatch buffers) quantizes its
        # activations and dispatches kernel v3 — no per-layer threading
        set_default_act_quant(ActQuant(mode="per_row"))
        report["act_quant"] = "int8:per_row"
    if args.kv_pvq:
        # same pattern for the KV cache: init_kv_cache /
        # attention_prefill_cache pick the default up and every attention
        # layer's cache comes out as a PackedKV (kernel-v4 decode)
        kvq = KVQuant(block=args.kv_block, group=args.kv_group)
        set_default_kv_quant(kvq)
        from repro.core.packed import _fit_group

        hd = cfg.resolved_head_dim
        g = _fit_group(kvq.group, hd)
        ng = hd // g
        packed_bpt = 2 * (hd + 4 * ng)  # per kv head: K+V pulses + scales
        dense_bpt = 2 * hd * 4  # f32 reference
        report["kv_quant"] = f"pvq:block{kvq.block}:g{g}:k{kvq.k}"
        report["kv_bytes_per_token_per_head"] = packed_bpt
        report["kv_bytes_ratio_vs_f32"] = round(packed_bpt / dense_bpt, 3)
        if packed_bpt / dense_bpt > args.max_kv_bytes_ratio:
            report["kv_bytes_fail"] = (
                f"packed KV bytes ratio {packed_bpt / dense_bpt:.3f} > "
                f"allowed {args.max_kv_bytes_ratio}"
            )
            print(json.dumps(report))
            return 1

    if obs.enabled() and args.act_int8:
        _probe_act_rows(params)

    if args.engine:
        from repro.launch.engine import PVQEngine, poisson_trace

        max_len = bucket_len(
            args.shared_prefix + args.prompt_len + args.gen, args.kv_block
        )
        trace = poisson_trace(
            args.requests, rate=args.rate, vocab=cfg.vocab_size,
            prompt_lens=(max(args.prompt_len // 2, 1), args.prompt_len),
            max_new=args.gen, seed=args.seed + 2,
            shared_prefix=args.shared_prefix,
        )
        eng = PVQEngine(
            model, params, n_slots=args.engine_slots, max_len=max_len,
            n_pages=args.engine_pages,
            prefill_chunk=args.prefill_chunk,
            prefill_batch=args.prefill_batch,
            prefix_cache=not args.no_prefix_cache,
        )
        eng.warmup(prompt_lens=[len(r.prompt) for r in trace])
        res = eng.run(trace)
        outputs = res.pop("outputs")
        report["arch"] = cfg.name
        report.update({f"engine_{k}": v for k, v in res.items()})

        # baseline: the fixed-batch generate() loop run SEQUENTIALLY over
        # the same trace (one request at a time — what serving without
        # continuous batching degenerates to under ragged arrivals).
        # Warm its compile buckets first so both legs time steady state.
        prompts = {
            r.rid: jnp.asarray([r.prompt], jnp.int32) for r in trace
        }
        for r in trace[:1]:
            generate(model, params, prompts[r.rid], gen=args.gen,
                     cache_len=len(r.prompt) + args.gen)
        t0 = time.time()
        base_tokens = 0
        for r in trace:
            out = generate(model, params, prompts[r.rid], gen=args.gen,
                           cache_len=len(r.prompt) + args.gen)
            base_tokens += out.shape[1] - len(r.prompt)
        base_dt = time.time() - t0
        report["baseline_tokens_per_s"] = round(base_tokens / max(base_dt, 1e-9), 2)
        report["baseline_wall_s"] = round(base_dt, 2)
        speedup = res["tokens_per_s"] / max(report["baseline_tokens_per_s"], 1e-9)
        report["engine_speedup_vs_fixed_batch"] = round(speedup, 3)

        if args.agreement_min is not None:
            ag = engine_token_agreement(model, params, trace, outputs)
            report["engine_token_agreement"] = round(ag["engine_token_agreement"], 4)
            report["engine_tokens_compared"] = ag["engine_tokens_compared"]
            report["engine_ties_excused"] = ag["engine_ties_excused"]
            if ag["engine_token_agreement"] < args.agreement_min:
                report["agreement_fail"] = (
                    f"engine token agreement {ag['engine_token_agreement']:.4f}"
                    f" < required {args.agreement_min}"
                )
                print(json.dumps(report))
                return 1
        if args.min_speedup is not None and speedup < args.min_speedup:
            report["speedup_fail"] = (
                f"engine speedup {speedup:.3f}x < required {args.min_speedup}x"
            )
            print(json.dumps(report))
            return 1
        if (
            args.min_prefix_hits is not None
            and res["prefix_hits"] < args.min_prefix_hits
        ):
            report["prefix_cache_fail"] = (
                f"prefix cache hits {res['prefix_hits']} < required "
                f"{args.min_prefix_hits}"
            )
            print(json.dumps(report))
            return 1
        print(json.dumps(report))
        return 0

    key = jax.random.PRNGKey(args.seed + 1)
    tokens = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "encdec":
        extra["frames"] = jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model))
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(key, (args.batch, cfg.prefix_len, cfg.d_model))

    t0 = time.time()
    out = generate(model, params, tokens, gen=args.gen,
                   cache_len=args.prompt_len + args.gen, extra_batch=extra)
    dt = time.time() - t0
    report.update({
        "arch": cfg.name, "batch": args.batch,
        "generated_shape": list(out.shape),
        "tokens_per_s": round(args.batch * args.gen / dt, 1),
        "wall_s": round(dt, 2),
    })

    if args.agreement_min is not None:
        # A/B legs: identical packed weights; the quantized leg keeps the
        # active ActQuant/KVQuant defaults, the reference leg clears BOTH
        # (f32 activations, dense f32 KV cache).  Contexts AND compute path
        # matched — both walk the same decode loop teacher-forced with the
        # quantized-leg tokens.  (A free-running comparison conflates
        # kernel fidelity with the autoregressive cascade — one near-tie
        # flip rewrites the whole suffix; a prefill re-score changes the
        # tile shapes, which int8 rounding amplifies into whole quanta.)
        lg_q = teacher_forced_logits(
            model, params, out, prompt_len=args.prompt_len, extra_batch=extra
        )
        with act_quant_scope(None), kv_quant_scope(None):
            lg_f = teacher_forced_logits(
                model, params, out, prompt_len=args.prompt_len,
                extra_batch=extra,
            )
        ag = top1_agreement(lg_f, lg_q)
        report["act_int8_top1_agreement"] = round(ag["top1_agreement"], 4)
        report["act_int8_top1_agreement_strict"] = round(
            ag["top1_agreement_strict"], 4
        )
        report["act_int8_ties_excused"] = ag["ties_excused"]
        if ag["top1_agreement"] < args.agreement_min:
            report["agreement_fail"] = (
                f"top-1 agreement {ag['top1_agreement']:.4f} < required "
                f"{args.agreement_min}"
            )
            print(json.dumps(report))
            return 1

    print(json.dumps(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
