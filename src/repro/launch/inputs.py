"""ShapeDtypeStruct stand-ins for every model input — the dry-run never
allocates device memory.  Weak-type-correct and shardable.

``input_specs(cfg, shape)`` returns (kwargs for the step function) keyed by
the step kind:
    train   -> {'batch': {tokens, targets, [frames|patches]}}
    prefill -> {'batch': {tokens, [frames|patches]}}
    decode  -> {'cache': <zeros-shaped cache>, 'token': (b,1), 'pos': scalar}
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.nn.models import Model

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_targets: bool) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.compute_dtype)
    batch: Dict[str, Any] = {"tokens": _sds((b, s), I32)}
    if with_targets:
        batch["targets"] = _sds((b, s), I32)
    if cfg.family == "encdec":
        batch["frames"] = _sds((b, s, cfg.d_model), act)
    if cfg.family == "vlm":
        batch["patches"] = _sds((b, cfg.prefix_len, cfg.d_model), act)
    return batch


def params_shape(model: Model, max_seq: int = 0):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), max_seq=max_seq))


def cache_shape(model: Model, batch: int, cache_len: int, enc_len: int = 0):
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len, enc_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model: Model) -> Dict[str, Any]:
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_targets=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_targets=False)}
    if shape.kind == "decode":
        b, s = shape.global_batch, shape.seq_len
        enc_len = s if cfg.family == "encdec" else 0
        return {
            "cache": cache_shape(model, b, s, enc_len),
            "token": _sds((b, 1), I32),
            "pos": _sds((), I32),
        }
    raise ValueError(shape.kind)
