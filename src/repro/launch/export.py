"""Export CLI: model params -> ``.pvqz`` compressed artifact (paper §VI).

    # a transformer config (packed per the serving quantization policy):
    PYTHONPATH=src python -m repro.launch.export --arch smollm-360m --reduced \
        --out model.pvqz

    # one of the paper's own nets (§VII; FC layers at their Table N/K ratios):
    PYTHONPATH=src python -m repro.launch.export --paper-net A --out a.pvqz \
        --max-bits-per-weight 2.0

Encodes the pytree ONCE into ``PackedPVQ`` leaves (exactly what serving
uses), entropy-codes the pulse streams into the single-file container, and
prints the per-leaf bits/weight report.  ``--max-bits-per-weight`` turns the
report into a gate (exit 1 when the packed artifact misses the budget) —
CI uses it to pin the §VI compression claim to real artifacts.

``repro.launch.serve --artifact model.pvqz`` consumes the file, restoring
the identical pulses/scales with no re-encode.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.checkpoint.artifact import write_pvqz


def export_arch(args) -> tuple:
    """(params pytree with PackedPVQ leaves, meta) for a transformer config."""
    from repro.configs import get_config
    from repro.core.packed import quantize_params
    from repro.core.quantize import QuantPolicy
    from repro.nn.models import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), max_seq=args.max_seq)
    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("kernel|experts", args.n_over_k, cfg.pvq.group)),
        scale_mode="ls",
    )
    qparams = quantize_params(params, policy)
    meta = {"kind": "arch", "arch": cfg.name, "reduced": bool(args.reduced),
            "n_over_k": args.n_over_k, "seed": args.seed}
    return qparams, meta


def export_paper_net(args) -> tuple:
    """(params with packed FC kernels, meta) for one of the §VII nets.

    FC kernels are packed at each layer's Table N/K ratio via the same
    ``pvq_quantize_dense`` path the kernel-serving tests use; conv layers
    (consumed as dense einsums) stay raw.
    """
    from repro.configs.paper_nets import PAPER_NETS
    from repro.nn.sequential import SequentialNet

    net = SequentialNet(PAPER_NETS[args.paper_net])
    params = net.init(jax.random.PRNGKey(args.seed))
    kparams = net.pvq_kernel_encode(params, group=args.group)
    merged = dict(params)
    merged.update(kparams)
    meta = {"kind": "paper_net", "net": args.paper_net, "group": args.group,
            "seed": args.seed}
    return merged, meta


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--arch", default=None, help="transformer config name")
    src.add_argument("--paper-net", default=None, choices=("A", "B", "C", "D"),
                     help="one of the paper's §VII experiment nets")
    ap.add_argument("--out", required=True, help="output .pvqz path")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--n-over-k", type=float, default=1.0,
                    help="kernel N/K ratio (arch mode; paper nets use their "
                    "per-layer Table ratios)")
    ap.add_argument("--group", type=int, default=256,
                    help="PVQ group size for paper-net FC kernels")
    ap.add_argument("--codec", default="auto",
                    help="pulse codec: auto|golomb|rle|enum|nibble|int8")
    ap.add_argument("--chunk", type=int, default=1024,
                    help="symbols per decodable chunk of the entropy streams")
    ap.add_argument("--max-seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-bits-per-weight", type=float, default=None,
                    help="fail (exit 1) if the packed artifact exceeds this")
    ap.add_argument("--max-expert-bits-per-weight", type=float, default=None,
                    help="fail (exit 1) if the MoE expert leaves alone "
                    "(*_experts pulse streams + scales) exceed this")
    args = ap.parse_args()
    if not args.arch and not args.paper_net:
        args.arch = "smollm-360m"

    t0 = time.time()
    if args.paper_net:
        qparams, meta = export_paper_net(args)
    else:
        qparams, meta = export_arch(args)
    encode_s = time.time() - t0

    t0 = time.time()
    report = write_pvqz(args.out, qparams, codec=args.codec, chunk=args.chunk,
                        meta=meta)
    report["encode_s"] = round(encode_s, 2)
    report["write_s"] = round(time.time() - t0, 2)

    # aggregate view of the MoE expert bank (the weight-bytes headline):
    # bits/weight over the expert leaves only, weighted by their numel
    import re

    from repro.core.packed import EXPERT_LEAF_REGEX

    expert = {k: v for k, v in report["leaves"].items()
              if re.search(EXPERT_LEAF_REGEX, k) and v.get("codec") != "raw"}
    if expert:
        numel = sum(v["numel"] for v in expert.values())
        bits = sum(v["bits_per_weight"] * v["numel"] for v in expert.values())
        report["expert_leaves"] = len(expert)
        report["expert_numel"] = numel
        report["expert_bits_per_weight"] = round(bits / max(numel, 1), 4)
    print(json.dumps(report, indent=1))

    if (
        args.max_bits_per_weight is not None
        and report["bits_per_weight"] > args.max_bits_per_weight
    ):
        print(
            f"FAIL: {report['bits_per_weight']} bits/weight exceeds the "
            f"--max-bits-per-weight {args.max_bits_per_weight} gate"
        )
        return 1
    if args.max_expert_bits_per_weight is not None:
        ebpw = report.get("expert_bits_per_weight")
        if ebpw is None:
            print("FAIL: --max-expert-bits-per-weight set but no packed "
                  "*_experts leaves were exported")
            return 1
        if ebpw > args.max_expert_bits_per_weight:
            print(
                f"FAIL: {ebpw} expert bits/weight exceeds the "
                f"--max-expert-bits-per-weight {args.max_expert_bits_per_weight} gate"
            )
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
