import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory/cost/roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--mesh single|multi|both] [--out experiments/dryrun] [--skip-existing]

The two lines above MUST stay the first statements in this module: jax locks
the device count on first init, and the dry-run needs 512 placeholder CPU
devices to build the 16x16 and 2x16x16 meshes.  (Smoke tests / benches never
import this module and keep seeing 1 device.)
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config  # noqa: E402
from repro.launch import roofline as roofline_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_step  # noqa: E402
from repro.nn.models import build_model  # noqa: E402
from repro.parallel import ShardingPolicy  # noqa: E402


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend may not support it
        return {"error": str(e)}
    out = {}
    for field in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def _depth_variants(cfg):
    """Two reduced-depth configs differing by exactly +1 scan repeat in every
    scanned segment, plus the number of additional repeats in the full model.

    XLA's HloCostAnalysis counts while-loop bodies ONCE regardless of trip
    count, so flops/bytes/collective-bytes of the full model are recovered by
    the affine extrapolation  C_full = C_small + extra * (C_big - C_small).
    """
    import dataclasses as dc

    if cfg.encoder_layers:  # enc-dec: both stacks scale together
        small = dc.replace(cfg, n_layers=1, encoder_layers=1, unroll_layers=True)
        big = dc.replace(cfg, n_layers=2, encoder_layers=2, unroll_layers=True)
        extra = cfg.n_layers - 1
    elif cfg.hybrid_period:
        p = cfg.hybrid_period
        small = dc.replace(cfg, n_layers=p, unroll_layers=True)
        big = dc.replace(cfg, n_layers=2 * p, unroll_layers=True)
        extra = cfg.n_layers // p - 1
    elif cfg.moe is not None and cfg.first_dense:
        small = dc.replace(cfg, n_layers=cfg.first_dense + 1, unroll_layers=True)
        big = dc.replace(cfg, n_layers=cfg.first_dense + 2, unroll_layers=True)
        extra = (cfg.n_layers - cfg.first_dense) - 1
    else:
        small = dc.replace(cfg, n_layers=1, unroll_layers=True)
        big = dc.replace(cfg, n_layers=2, unroll_layers=True)
        extra = cfg.n_layers - 1
    return small, big, extra


def _cost_and_coll(cfg, shape, mesh, policy, opt_level=0):
    """(cost dict, collective-bytes dict) for one lowered+compiled step."""
    model = build_model(cfg)
    bundle = make_step(model, mesh, shape, policy, opt_level=opt_level)
    with mesh:
        compiled = bundle.lower().compile()
        cost = {k: float(v) for k, v in compiled.cost_analysis().items()}
        hlo = compiled.as_text()
    coll = roofline_lib.collective_bytes(hlo)
    return cost, coll


def extrapolated_costs(cfg, shape, mesh, policy, opt_level=0):
    """Depth-corrected (flops, hbm_bytes, collective_bytes, coll_detail)."""
    small_cfg, big_cfg, extra = _depth_variants(cfg)
    c_small, k_small = _cost_and_coll(small_cfg, shape, mesh, policy, opt_level)
    c_big, k_big = _cost_and_coll(big_cfg, shape, mesh, policy, opt_level)

    def ext(a, b):
        return a + extra * (b - a)

    flops = ext(c_small.get("flops", 0.0), c_big.get("flops", 0.0))
    hbm = ext(c_small.get("bytes accessed", 0.0), c_big.get("bytes accessed", 0.0))
    coll_total = ext(k_small["total_bytes"], k_big["total_bytes"])
    per_kind = {
        k: ext(k_small["per_kind_bytes"][k], k_big["per_kind_bytes"][k])
        for k in k_small["per_kind_bytes"]
    }
    counts = {
        k: int(ext(k_small["per_kind_counts"][k], k_big["per_kind_counts"][k]))
        for k in k_small["per_kind_counts"]
    }
    return {
        "flops": max(flops, 0.0),
        "hbm_bytes": max(hbm, 0.0),
        "coll": {
            "total_bytes": max(coll_total, 0.0),
            "per_kind_bytes": per_kind,
            "per_kind_counts": counts,
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, policy: ShardingPolicy | None = None, opt_level: int = 0) -> dict:
    cfg = get_config(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    bundle = make_step(model, mesh, shape, policy, opt_level=opt_level)
    with mesh:
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = _memory_analysis_dict(compiled)
        cost = dict(compiled.cost_analysis())
        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
    chips = int(mesh.devices.size)
    # depth-corrected costs (scan bodies counted once by HloCostAnalysis)
    corrected = extrapolated_costs(cfg, shape, mesh, policy, opt_level)

    # MODEL_FLOPS from active params
    pshape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), max_seq=shape.seq_len))
    import numpy as np

    def _leaf_count(t):
        total = 0
        def visit(path, leaf):
            nonlocal total
            pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            n = int(np.prod(leaf.shape))
            if cfg.moe is not None and "experts" in pstr and "shared" not in pstr:
                n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
            total += n
            return leaf
        jax.tree_util.tree_map_with_path(visit, t)
        return total

    n_active = _leaf_count(pshape)
    n_total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshape))
    mf = roofline_lib.model_flops_for(cfg, shape, n_active)
    roof = roofline_lib.analyze_corrected(
        flops=corrected["flops"],
        hbm_bytes=corrected["hbm_bytes"],
        coll=corrected["coll"],
        chips=chips,
        model_flops=mf,
    )

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "cost_flops_per_chip_raw": float(cost.get("flops", 0.0)),
        "cost_bytes_per_chip_raw": float(cost.get("bytes accessed", 0.0)),
        "params_total": n_total,
        "params_active": n_active,
        "roofline": roof.as_dict(),
    }
    if shape.kind == "decode":
        rec["analytic_decode"] = roofline_lib.analytic_decode_memory(cfg, shape, mesh, n_total)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, help="single shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--seq-shard", action="store_true", help="enable sequence parallelism")
    ap.add_argument("--opt-level", type=int, default=0, help="§Perf ladder: 0=baseline 1/2=optimized")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                path = outdir / f"{tag}.json"
                if args.skip_existing and path.exists():
                    print(f"[dryrun] {tag}: exists, skipping")
                    continue
                print(f"[dryrun] {tag}: lowering+compiling ...", flush=True)
                try:
                    policy = ShardingPolicy(seq_shard=args.seq_shard) if args.seq_shard else None
                    rec = run_cell(arch, shape_name, multi, policy, opt_level=args.opt_level)
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape_name,
                        "mesh": "multi" if multi else "single",
                        "status": "failed", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures.append(tag)
                path.write_text(json.dumps(rec, indent=2))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f" compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s"
                        f" coll={r['collective_s']:.3e}s bound={r['bottleneck']}"
                        f" (lower {rec['lower_s']}s compile {rec['compile_s']}s)"
                    )
                elif status == "failed":
                    extra = " " + rec["error"][:200]
                elif status == "skipped":
                    extra = " " + rec["reason"]
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)

    print(f"[dryrun] done; {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
