"""Continuous-batching serve engine over the PVQ-packed artifact.

The fixed-batch ``serve.generate`` loop decodes a lockstep batch: every
sequence starts together, ends together, and a short request pays for the
longest one.  This engine serves a fixed pool of ``n_slots`` decode slots
that sequences join and leave **mid-flight**, with the PVQ-compressed KV
cache paged through a shared physical pool:

admission -> batcher -> page table -> prefill/decode steps

* **Admission** — an asyncio feeder releases :class:`Request`s into the
  pending queue at their (Poisson) arrival times; :meth:`PVQEngine.run`'s
  loop admits from the queue head whenever a slot AND the prompt's full
  pages are available (backpressure is simply "the queue waits").
* **Paged KV** — each attention layer's cache is a
  :class:`core.packed.PagedKV`: PVQ-encoded blocks live in a pool of
  physical pages with **page size = kv block size**, so a page is exactly
  one PVQ encode unit and stays packed at rest (int8 pulse planes +
  per-group rho; an allocator move is an int8 byte move, never a
  re-encode).  The host-side :class:`PageAllocator` owns the free list;
  the device sees only the ``page_table``/``write_page`` arrays refreshed
  every step.
* **Prefill/decode disaggregation** — prompts run through a separately
  compiled prefill step (``model.prefill_bucketed``, prompt length padded
  to a page-multiple bucket so compile count is bounded by buckets, and
  with a DENSE cache via ``kv_quant_scope(None)``), then the prefilled KV
  is **grafted** into the slot pool: complete blocks are PVQ-encoded
  straight into allocator-assigned pages (bit-identical to the
  ``PackedKV.from_dense`` encode the fixed-batch path uses) and the exact
  partial tail block lands in the slot's f32 tail ring.  Decode then runs
  one engine-static compiled step over the whole slot pool with per-slot
  positions.
* **Eviction** — when a decode step needs more pages than the pool has
  free, the youngest active sequence is evicted: its pages return to the
  pool and the request is requeued at the queue head with its
  prompt + generated-so-far as the new prefill context (generated tokens
  are kept; re-admission re-prefills them teacher-forced).
* **Per-sequence stopping** — each slot retires on its own EOS or
  ``max_new_tokens``; a finished slot frees its pages and stops consuming
  batch capacity immediately.

The decode step is **engine-static**: shapes depend only on
``(n_slots, n_pages, max_pages)``, never on which sequences are resident,
so the whole run compiles ONE decode step (plus one prefill/graft pair per
prompt bucket).  ``trace_counts`` records actual traces for the
compile-count regression tests.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packed import is_paged_kv
from repro.core.quantize import default_kv_quant, kv_quant_scope
from repro.runtime import obs
from repro.runtime.telemetry import Histogram


def bucket_len(n: int, multiple: int) -> int:
    """Round ``n`` up to a positive multiple — the static-shape buckets
    that keep XLA compile counts bounded (shared by the engine's prefill
    and by ``serve.generate``'s cache-length bucketing)."""
    m = max(int(multiple), 1)
    return max(m, -(-int(n) // m) * m)


# ---------------------------------------------------------------------------
# Host-side page allocator
# ---------------------------------------------------------------------------


class PageAllocator:
    """Free-list allocator over the physical KV page pool.

    Page ids are ``0 .. n_pages-1``; id ``n_pages`` is the device-side
    *trash page* (masked scatter target / unallocated page-table entries)
    and is never handed out.  Double frees and trash frees raise — the
    tests lean on this to prove no page is ever owned by two sequences.
    """

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"need at least one page, got {n_pages}")
        self.n_pages = int(n_pages)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._used: set = set()

    @property
    def trash(self) -> int:
        return self.n_pages

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def used(self) -> int:
        return len(self._used)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        pid = self._free.pop()
        self._used.add(pid)
        return pid

    def alloc_many(self, n: int) -> Optional[List[int]]:
        if len(self._free) < n:
            return None
        return [self.alloc() for _ in range(n)]

    def free(self, ids: Sequence[int]) -> None:
        for pid in ids:
            pid = int(pid)
            if pid == self.trash:
                raise ValueError("freeing the trash page")
            if pid not in self._used:
                raise ValueError(f"double free of page {pid}")
            self._used.discard(pid)
            self._free.append(pid)


# ---------------------------------------------------------------------------
# Requests and traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-owned progress/timing state.

    After an eviction ``generated`` keeps everything produced so far; the
    re-admission prefills ``prompt + generated[:-1]`` and resumes decoding
    with ``generated[-1]`` as the pending input token, so eviction never
    loses or re-samples a token."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival: float = 0.0  # seconds offset within the trace
    generated: List[int] = dataclasses.field(default_factory=list)
    submit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    evictions: int = 0
    # admission-blocked duration: total seconds spent waiting in the
    # pending queue (initial wait + every post-eviction re-wait)
    queue_wait_s: float = 0.0
    # eviction latency cost: seconds from each eviction to the end of the
    # re-admission (re-queue wait + teacher-forced re-prefill), summed
    evict_cost_s: float = 0.0
    evict_t: Optional[float] = None  # in-flight eviction timestamp

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return (
            bool(self.generated)
            and self.eos_id is not None
            and self.generated[-1] == self.eos_id
        )


def poisson_trace(
    n_requests: int,
    *,
    rate: float,
    vocab: int,
    prompt_lens: Tuple[int, int] = (8, 24),
    max_new: int = 16,
    eos_id: Optional[int] = None,
    seed: int = 0,
) -> List[Request]:
    """Poisson request trace: exponential inter-arrival gaps at ``rate``
    requests/second and uniformly random prompt lengths in
    ``prompt_lens = (lo, hi)``.  ``rate=inf`` (or 0) puts every arrival at
    t=0 — the saturate-then-drain pattern the CI smoke uses."""
    rng = np.random.default_rng(seed)
    lo, hi = prompt_lens
    t = 0.0
    out = []
    for rid in range(n_requests):
        if rate and np.isfinite(rate) and rate > 0:
            t += float(rng.exponential(1.0 / rate))
        plen = int(rng.integers(lo, hi + 1))
        out.append(
            Request(
                rid=rid,
                prompt=[int(x) for x in rng.integers(0, vocab, plen)],
                max_new_tokens=int(max_new),
                eos_id=eos_id,
                arrival=t,
            )
        )
    return out


@dataclasses.dataclass
class _Slot:
    req: Request
    length: int  # cache rows currently filled for this slot
    pages: List[int]  # physical pages owned (in logical-block order)
    admit_order: int


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class PVQEngine:
    """Continuous-batching decode over a paged, PVQ-compressed KV cache.

    Requires an active process-wide ``KVQuant`` default (pages ARE the PVQ
    kv blocks) — the same switch the fixed-batch ``serve --kv-pvq`` path
    uses, so both paths share kernels, encode, and autotune entries.

    Slot invariant: an active slot holds ``length`` cache rows
    (= prompt + all generated tokens except the newest), and the next
    decode step feeds ``req.generated[-1]`` at position ``length``.
    """

    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int = 4,
        max_len: int = 128,
        n_pages: Optional[int] = None,
    ):
        kvq = default_kv_quant()
        if kvq is None:
            raise ValueError(
                "PVQEngine pages the PVQ-compressed cache: set a process-wide "
                "KVQuant first (set_default_kv_quant / kv_quant_scope)"
            )
        self.page = int(kvq.block)
        if self.page < 2:
            raise ValueError("page (= kv block) must be >= 2")
        self.model = model
        self.params = params
        self.n_slots = int(n_slots)
        self.max_pages = bucket_len(max_len, self.page) // self.page
        full = self.n_slots * self.max_pages
        self.n_pages = int(n_pages) if n_pages else full
        if self.n_pages < self.max_pages:
            # a lone sequence must always be able to run to max_len, or
            # eviction could never free enough pages to make progress
            raise ValueError(
                f"n_pages={self.n_pages} < max_pages={self.max_pages}: "
                "one full-length sequence must fit the pool"
            )
        self.alloc = PageAllocator(self.n_pages)
        self.cache = model.init_paged_cache(self.n_slots, self.n_pages, self.max_pages)
        self.slots: List[Optional[_Slot]] = [None] * self.n_slots
        self._page_table = np.full(
            (self.n_slots, self.max_pages), self.alloc.trash, np.int32
        )
        self._admit_seq = 0
        self.pending: deque = deque()
        self.finished: List[Request] = []
        self.trace_counts: Dict[str, int] = {"decode": 0, "prefill": 0, "graft": 0}
        self.stats: Dict[str, int] = {
            "steps": 0, "active_slot_steps": 0, "evictions": 0, "decode_tokens": 0,
        }
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn)
        self._graft = jax.jit(self._graft_fn)
        # sampled KV quality probes: the graft's in-graph encode cannot
        # probe itself (traced), so the first few admissions re-encode one
        # prefilled page eagerly when the registry is on
        self._kv_probe_budget = 8

    # ------------------------------------------------------------- capacity

    @property
    def capacity_tokens(self) -> int:
        return self.max_pages * self.page

    def validate(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if need > self.capacity_tokens:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={need} exceeds per-slot "
                f"capacity {self.capacity_tokens} (= max_pages * page)"
            )

    # --------------------------------------------------------- device steps

    def _decode_fn(self, params, cache, tokens, pos, page_table, write_page):
        # trace-time side effect: counts actual XLA traces, not calls
        self.trace_counts["decode"] += 1
        cache = jax.tree.map(
            lambda c: c.with_tables(page_table, write_page) if is_paged_kv(c) else c,
            cache,
            is_leaf=is_paged_kv,
        )
        logits, cache = self.model.decode_step(params, cache, tokens, pos)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    def _prefill_fn(self, params, tokens, real_len):
        self.trace_counts["prefill"] += 1
        logits, caches = self.model.prefill_bucketed(
            params, {"tokens": tokens}, real_len
        )
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), caches

    def _graft_fn(self, cache, pre, slot, page_ids, real_len):
        self.trace_counts["graft"] += 1

        def walk(c, p):
            if is_paged_kv(c):
                return c.graft(p["k"], p["v"], slot, page_ids, real_len)
            if isinstance(c, dict):
                return {key: walk(v, p[key]) for key, v in c.items()}
            return c

        return walk(cache, pre)

    # ------------------------------------------------------------ admission

    def _free_slot(self) -> Optional[int]:
        for s, st in enumerate(self.slots):
            if st is None:
                return s
        return None

    def try_admit(self, req: Request, t_now: Optional[float] = None) -> bool:
        """Admit one request if a slot and its prompt's full pages are
        available.  Runs the bucketed prefill (dense cache via
        ``kv_quant_scope(None)`` — the graft does the PVQ encode) and
        grafts the result into the slot pool."""
        self.validate(req)
        if req.generated:
            # re-admission after eviction: the last generated token is the
            # pending decode input, everything before it is prefill context
            ctx = list(req.prompt) + req.generated[:-1]
        else:
            ctx = list(req.prompt)
        plen = len(ctx)
        n_full = plen // self.page
        slot = self._free_slot()
        if slot is None or self.alloc.available < n_full:
            return False
        t_adm = time.perf_counter()
        if req.submit_t is None:
            req.submit_t = t_adm if t_now is None else t_now
        # queue wait: submitted (or evicted) -> admission actually starting
        base = req.evict_t if req.evict_t is not None else req.submit_t
        req.queue_wait_s += max(t_adm - base, 0.0)

        lb = bucket_len(plen, self.page)
        toks = np.zeros((1, lb), np.int32)
        toks[0, :plen] = np.asarray(ctx, np.int32)
        with kv_quant_scope(None), obs.span(
            "engine/prefill", args={"rid": req.rid, "bucket": lb, "ctx": plen}
        ):
            tok0, pre = self._prefill(self.params, toks, np.int32(plen))
        if obs.enabled() and self._kv_probe_budget > 0 and plen >= self.page:
            self._kv_probe_budget -= 1
            self._probe_kv_quality(pre)

        ids = self.alloc.alloc_many(n_full) or []
        page_ids = np.full((lb // self.page,), self.alloc.trash, np.int32)
        page_ids[: len(ids)] = ids
        with obs.span("engine/graft", args={"rid": req.rid, "pages": n_full}):
            self.cache = self._graft(
                self.cache, pre, np.int32(slot), page_ids, np.int32(plen)
            )
        if req.evict_t is not None:
            # the eviction's full latency cost lands at re-admission: the
            # re-queue wait plus the teacher-forced re-prefill just done
            req.evict_cost_s += max(time.perf_counter() - req.evict_t, 0.0)
            req.evict_t = None
        if obs.enabled():
            obs.counter("engine.admissions").inc()
            obs.event("engine/admit", args={"rid": req.rid, "ctx": plen})
        if not req.generated:
            req.generated.append(int(tok0[0]))
            req.first_token_t = time.perf_counter()
        if req.done:
            # prefill alone satisfied the request (max_new == 1 / instant
            # EOS): never occupies a slot
            self.alloc.free(ids)
            self._finish(req)
            return True
        self.slots[slot] = _Slot(
            req=req, length=plen, pages=list(ids), admit_order=self._admit_seq
        )
        self._admit_seq += 1
        self._page_table[slot, :] = self.alloc.trash
        self._page_table[slot, :n_full] = ids
        return True

    def _probe_kv_quality(self, pre) -> None:
        """Host-side KV quality probe: eagerly re-encode the first page of
        one prefilled layer with the engine's KVQuant so the eager-only
        probe inside ``_kv_encode_planes`` fires (records SNR/clamp/scale
        metrics).  Sampled — never on the per-token path."""
        from repro.core.packed import _kv_encode_planes

        kvq = default_kv_quant()

        def find(c):
            if isinstance(c, dict):
                if "k" in c and "v" in c:
                    return c
                for v in c.values():
                    hit = find(v)
                    if hit is not None:
                        return hit
            return None

        kv = find(pre)
        if kv is None or kvq is None:
            return
        k = np.asarray(jax.device_get(kv["k"]), np.float32)
        if k.ndim < 2:
            return
        k = k[:, : self.page]
        g, hd = kvq.group, k.shape[-1]
        while g > 1 and hd % g:  # same power-of-two fit the cache init uses
            g //= 2
        _kv_encode_planes(jnp.asarray(k), g, kvq.k)

    def admit_pending(self, t_now: Optional[float] = None) -> int:
        """Admit from the queue head until blocked (FIFO — no request can
        starve behind a later, smaller one)."""
        admitted = 0
        while self.pending and self.try_admit(self.pending[0], t_now):
            self.pending.popleft()
            admitted += 1
        return admitted

    # ----------------------------------------------------- retire and evict

    def _finish(self, req: Request) -> None:
        req.finish_t = time.perf_counter()
        self.finished.append(req)
        if obs.enabled():
            obs.counter("engine.requests_finished").inc()
            if req.submit_t is not None:
                obs.histogram("engine.request_latency_s").record(
                    req.finish_t - req.submit_t
                )
                if req.first_token_t is not None:
                    obs.histogram("engine.ttft_s").record(
                        req.first_token_t - req.submit_t
                    )
            obs.histogram("engine.queue_wait_s").record(req.queue_wait_s)
            if req.evictions:
                obs.histogram("engine.evict_cost_s").record(req.evict_cost_s)
            obs.event("engine/retire", args={"rid": req.rid})

    def _release(self, s: int) -> _Slot:
        st = self.slots[s]
        assert st is not None
        if st.pages:
            self.alloc.free(st.pages)
        self._page_table[s, :] = self.alloc.trash
        self.slots[s] = None
        return st

    def _retire(self, s: int) -> None:
        self._finish(self._release(s).req)

    def _evict(self, s: int) -> None:
        st = self._release(s)
        st.req.evictions += 1
        st.req.evict_t = time.perf_counter()
        self.stats["evictions"] += 1
        if obs.enabled():
            obs.counter("engine.evictions").inc()
            obs.event(
                "engine/evict",
                args={"rid": st.req.rid, "kept_tokens": len(st.req.generated)},
            )
        # queue head: the victim resumes as soon as pages free up
        self.pending.appendleft(st.req)

    # ----------------------------------------------------------- decode step

    def step(self) -> int:
        """One decode step over every active slot.  Returns the number of
        tokens generated (0 when idle).

        Slots completing a PVQ block this step get their destination page
        pre-assigned (``write_page``); if the pool can't cover every
        completing slot, the youngest active sequence is evicted until it
        can (guaranteed to terminate: a lone sequence never needs more
        than ``max_pages`` <= ``n_pages``)."""
        while True:
            active = [(s, st) for s, st in enumerate(self.slots) if st is not None]
            if not active:
                return 0
            needed = sum(
                1 for _, st in active if (st.length + 1) % self.page == 0
            )
            if needed <= self.alloc.available:
                break
            victim = max(active, key=lambda t: t[1].admit_order)[0]
            self._evict(victim)

        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        write_page = np.full((self.n_slots,), self.alloc.trash, np.int32)
        for s, st in active:
            tokens[s, 0] = st.req.generated[-1]
            pos[s] = st.length
            if (st.length + 1) % self.page == 0:
                pid = self.alloc.alloc()
                assert pid is not None  # reserved above
                st.pages.append(pid)
                self._page_table[s, st.length // self.page] = pid
                write_page[s] = pid

        # obs.NOOP when disabled: no span object, no args dict — the
        # telemetry hook adds zero allocations to the disabled decode step
        span = obs.NOOP
        if obs.enabled():
            span = obs.span("engine/decode_step", args={
                "active": len(active), "queue": len(self.pending),
                "free_pages": self.alloc.available,
            })
        with span:
            tok_ids, self.cache = self._decode(
                self.params, self.cache, tokens, pos,
                self._page_table.copy(), write_page,
            )
            tok_host = np.asarray(jax.device_get(tok_ids))
        self.stats["steps"] += 1
        self.stats["active_slot_steps"] += len(active)
        self.stats["decode_tokens"] += len(active)
        if obs.enabled():
            obs.counter("engine.decode_steps").inc()
            obs.counter("engine.decode_tokens").add(len(active))
            obs.gauge("engine.queue_depth").set(len(self.pending))
            obs.gauge("engine.page_pool_free").set(self.alloc.available)
            obs.gauge("engine.active_slots").set(len(active))
            # counter-track events: perfetto renders these as time series
            obs.trace_counter("engine.queue_depth", len(self.pending))
            obs.trace_counter("engine.page_pool_free", self.alloc.available)
            obs.trace_counter("engine.active_slots", len(active))
        for s, st in active:
            st.length += 1
            st.req.generated.append(int(tok_host[s]))
            if st.req.done:
                self._retire(s)
        return len(active)

    # --------------------------------------------------------------- warmup

    def warmup(self, prompt_lens: Sequence[int] = ()) -> None:
        """Compile the decode step and every prefill/graft bucket before
        the timed run (slots must be idle; the dummy graft's writes all
        target the trash page / a tail ring the real graft overwrites)."""
        assert all(st is None for st in self.slots), "warmup needs an idle engine"
        for lb in sorted({bucket_len(max(int(p), 1), self.page) for p in prompt_lens}):
            toks = np.zeros((1, lb), np.int32)
            with kv_quant_scope(None):
                _, pre = self._prefill(self.params, toks, np.int32(1))
            ids = np.full((lb // self.page,), self.alloc.trash, np.int32)
            self.cache = self._graft(
                self.cache, pre, np.int32(0), ids, np.int32(1)
            )
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        wp = np.full((self.n_slots,), self.alloc.trash, np.int32)
        _, self.cache = self._decode(
            self.params, self.cache, tokens, pos, self._page_table.copy(), wp
        )

    # ------------------------------------------------------------- run loop

    async def _feed(self, trace: List[Request], t0: float, time_scale: float):
        loop = asyncio.get_running_loop()
        for req in sorted(trace, key=lambda r: r.arrival):
            delay = (t0 + req.arrival * time_scale) - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            req.submit_t = time.perf_counter()
            self.pending.append(req)

    async def _run_async(self, trace: List[Request], time_scale: float):
        for req in trace:
            self.validate(req)
        t_start = time.perf_counter()
        loop = asyncio.get_running_loop()
        feeder = asyncio.create_task(self._feed(trace, loop.time(), time_scale))
        try:
            while True:
                self.admit_pending()
                n = self.step()
                if n:
                    await asyncio.sleep(0)  # yield to the arrival feeder
                elif feeder.done() and not self.pending:
                    break
                else:
                    await asyncio.sleep(0.0005)  # idle: wait for arrivals
        finally:
            await feeder
        return self.report(time.perf_counter() - t_start)

    def run(self, trace: Sequence[Request], *, time_scale: float = 1.0) -> Dict[str, Any]:
        """Serve a trace to completion; returns the metrics report.
        ``time_scale`` compresses/stretches the trace's arrival times."""
        return asyncio.run(self._run_async(list(trace), time_scale))

    # -------------------------------------------------------------- metrics

    def report(self, wall_s: float) -> Dict[str, Any]:
        done = self.finished
        toks = sum(len(r.generated) for r in done)
        lat = [
            r.finish_t - r.submit_t
            for r in done
            if r.finish_t is not None and r.submit_t is not None
        ]
        ttft = [
            r.first_token_t - r.submit_t
            for r in done
            if r.first_token_t is not None and r.submit_t is not None
        ]
        # the telemetry histogram IS the percentile implementation — one
        # type shared with the benchmarks instead of inline pct() copies
        lat_h = Histogram.from_values(lat)
        ttft_h = Histogram.from_values(ttft)
        qwait_h = Histogram.from_values(r.queue_wait_s for r in done)
        evict_costs = [r.evict_cost_s for r in done if r.evictions]
        evict_h = Histogram.from_values(evict_costs)

        if obs.enabled():
            # trace-count watcher as a first-class metric (one gauge per
            # jitted fn; report() may run repeatedly, so not a counter)
            for fn, n in self.trace_counts.items():
                obs.gauge("engine.trace_count", {"fn": fn}).set(n)

        steps = max(self.stats["steps"], 1)
        return {
            "requests": len(done),
            "generated_tokens": toks,
            "wall_s": round(wall_s, 4),
            "tokens_per_s": round(toks / max(wall_s, 1e-9), 2),
            "latency_p50_s": round(lat_h.percentile(50), 4),
            "latency_p99_s": round(lat_h.percentile(99), 4),
            "ttft_p50_s": round(ttft_h.percentile(50), 4),
            "ttft_p99_s": round(ttft_h.percentile(99), 4),
            "queue_wait_p50_s": round(qwait_h.percentile(50), 4),
            "queue_wait_p99_s": round(qwait_h.percentile(99), 4),
            "eviction_cost_total_s": round(evict_h.total, 4),
            "eviction_cost_p50_s": round(evict_h.percentile(50), 4),
            "slot_utilization": round(
                self.stats["active_slot_steps"] / (steps * self.n_slots), 4
            ),
            "evictions": self.stats["evictions"],
            "decode_steps": self.stats["steps"],
            "n_slots": self.n_slots,
            "n_pages": self.n_pages,
            "page": self.page,
            "trace_counts": dict(self.trace_counts),
            "outputs": {r.rid: list(r.generated) for r in done},
        }
