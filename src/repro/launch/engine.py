"""Continuous-batching serve engine over the PVQ-packed artifact.

The fixed-batch ``serve.generate`` loop decodes a lockstep batch: every
sequence starts together, ends together, and a short request pays for the
longest one.  This engine serves a fixed pool of ``n_slots`` decode slots
that sequences join and leave **mid-flight**, with the PVQ-compressed KV
cache paged through a shared physical pool:

admission -> batcher -> page table -> prefill/decode steps

* **Admission** — an asyncio feeder releases :class:`Request`s into the
  pending queue at their (Poisson) arrival times; :meth:`PVQEngine.run`'s
  loop admits from the queue head whenever a slot AND the prompt's full
  pages are available (backpressure is simply "the queue waits").
* **Paged KV** — each attention layer's cache is a
  :class:`core.packed.PagedKV`: PVQ-encoded blocks live in a pool of
  physical pages with **page size = kv block size**, so a page is exactly
  one PVQ encode unit and stays packed at rest (int8 pulse planes +
  per-group rho; an allocator move is an int8 byte move, never a
  re-encode).  The host-side :class:`PageAllocator` owns the free list;
  the device sees only the ``page_table``/``write_page`` arrays refreshed
  every step.
* **Prefill/decode disaggregation** — prompts run through a separately
  compiled prefill step (``model.prefill_bucketed``, prompt length padded
  to a page-multiple bucket so compile count is bounded by buckets, and
  with a DENSE cache via ``kv_quant_scope(None)``), then the prefilled KV
  is **grafted** into the slot pool: complete blocks are PVQ-encoded
  straight into allocator-assigned pages (bit-identical to the
  ``PackedKV.from_dense`` encode the fixed-batch path uses) and the exact
  partial tail block lands in the slot's f32 tail ring.  Decode then runs
  one engine-static compiled step over the whole slot pool with per-slot
  positions.
* **Eviction** — when a decode step needs more pages than the pool has
  free, the youngest active sequence is evicted: its pages return to the
  pool and the request is requeued at the queue head with its
  prompt + generated-so-far as the new prefill context (generated tokens
  are kept; re-admission re-prefills them teacher-forced).
* **Per-sequence stopping** — each slot retires on its own EOS or
  ``max_new_tokens``; a finished slot frees its pages and stops consuming
  batch capacity immediately.

The decode step is **engine-static**: shapes depend only on
``(n_slots, n_pages, max_pages)``, never on which sequences are resident,
so the whole run compiles ONE decode step (plus one prefill/graft pair per
prompt bucket).  ``trace_counts`` records actual traces for the
compile-count regression tests.
"""

from __future__ import annotations

import asyncio
import dataclasses
import hashlib
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packed import is_paged_kv
from repro.core.quantize import default_kv_quant, kv_quant_scope
from repro.runtime import obs
from repro.runtime.telemetry import Histogram


def bucket_len(n: int, multiple: int) -> int:
    """Round ``n`` up to a positive multiple — the static-shape buckets
    that keep XLA compile counts bounded (shared by the engine's prefill
    and by ``serve.generate``'s cache-length bucketing)."""
    m = max(int(multiple), 1)
    return max(m, -(-int(n) // m) * m)


# ---------------------------------------------------------------------------
# Host-side page allocator
# ---------------------------------------------------------------------------


class PageAllocator:
    """Refcounted free-list allocator over the physical KV page pool, with
    a prompt-prefix hash index for shared-prefix page reuse.

    Page ids are ``0 .. n_pages-1``; id ``n_pages`` is the device-side
    *trash page* (masked scatter target / unallocated page-table entries)
    and is never handed out.  Double frees and trash frees raise — the
    tests lean on this to prove no page is ever freed out from under a
    sequence.

    **Refcounts** — ``alloc`` hands a page out at refcount 1; the prefix
    cache maps an already-written page into another slot's page table via
    ``share`` (refcount += 1).  ``free`` decrements, and only a page
    reaching refcount 0 actually leaves the used set, so evicting or
    retiring one sharer never frees pages a co-sharer still reads.  Pages
    are immutable once written (appends and chunk grafts only ever target
    freshly-allocated pages), which makes the sharing copy-on-write by
    construction: extending a shared prefix writes NEW pages, never the
    shared ones.

    **Prefix index** — ``register`` binds a page to the chain hash of its
    prompt-block content (hash covers every block from position 0, so a
    key encodes content AND absolute position — exactly the condition for
    a packed KV page to be causally valid in another sequence).  A
    registered page whose refcount drops to 0 parks in a *cached* LRU
    pool instead of the free list: still resident, instantly shareable by
    the next request with the same prefix, and reclaimed LRU-first when
    the free list runs dry (``available`` counts both).
    """

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"need at least one page, got {n_pages}")
        self.n_pages = int(n_pages)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._refs: Dict[int, int] = {}
        self._cached: "OrderedDict[int, str]" = OrderedDict()  # pid -> key, LRU order
        self._prefix: Dict[str, int] = {}  # chain hash -> pid
        self._keys: Dict[int, str] = {}  # pid -> registered chain hash

    @property
    def trash(self) -> int:
        return self.n_pages

    @property
    def available(self) -> int:
        """Pages allocatable right now: the free list plus the cached pool
        (cached pages are reclaimed LRU-first when the free list is dry)."""
        return len(self._free) + len(self._cached)

    @property
    def used(self) -> int:
        """Pages with a live owner (refcount >= 1)."""
        return len(self._refs)

    @property
    def cached(self) -> int:
        """Refcount-0 pages parked for prefix reuse."""
        return len(self._cached)

    def refcount(self, pid: int) -> int:
        return self._refs.get(int(pid), 0)

    def alloc(self) -> Optional[int]:
        if self._free:
            pid = self._free.pop()
        elif self._cached:
            # reclaim the least-recently-parked prefix page; its index
            # entry dies with it (the content is about to be overwritten)
            pid, key = self._cached.popitem(last=False)
            self._prefix.pop(key, None)
            self._keys.pop(pid, None)
        else:
            return None
        self._refs[pid] = 1
        return pid

    def alloc_many(self, n: int) -> Optional[List[int]]:
        if self.available < n:
            return None
        return [self.alloc() for _ in range(n)]

    def free(self, ids: Sequence[int]) -> None:
        for pid in ids:
            pid = int(pid)
            if pid == self.trash:
                raise ValueError("freeing the trash page")
            rc = self._refs.get(pid)
            if rc is None:
                raise ValueError(f"double free of page {pid}")
            if rc > 1:
                self._refs[pid] = rc - 1
                continue
            del self._refs[pid]
            key = self._keys.get(pid)
            if key is not None and self._prefix.get(key) == pid:
                self._cached[pid] = key  # park for prefix reuse
            else:
                self._free.append(pid)

    # ------------------------------------------------------- prefix index

    def register(self, pid: int, key: str) -> None:
        """Bind a live page to its prompt-block chain hash.  First writer
        wins: a key already mapped to a different page stays put (both
        pages hold identical content; the duplicate just frees normally)."""
        pid = int(pid)
        if pid == self.trash or pid not in self._refs:
            return
        if key in self._prefix and self._prefix[key] != pid:
            return
        old = self._keys.get(pid)
        if old is not None and old != key:
            self._prefix.pop(old, None)
        self._prefix[key] = pid
        self._keys[pid] = key

    def lookup(self, key: str) -> Optional[int]:
        return self._prefix.get(key)

    def share(self, pid: int) -> bool:
        """Take a reference on an indexed page (live or cached).  Returns
        False if the page was reclaimed in the meantime."""
        pid = int(pid)
        if pid in self._refs:
            self._refs[pid] += 1
            return True
        if pid in self._cached:
            del self._cached[pid]
            self._refs[pid] = 1
            return True
        return False


# ---------------------------------------------------------------------------
# Requests and traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-owned progress/timing state.

    After an eviction ``generated`` keeps everything produced so far; the
    re-admission prefills ``prompt + generated[:-1]`` and resumes decoding
    with ``generated[-1]`` as the pending input token, so eviction never
    loses or re-samples a token."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival: float = 0.0  # seconds offset within the trace
    generated: List[int] = dataclasses.field(default_factory=list)
    submit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    evictions: int = 0
    # admission-blocked duration: total seconds spent waiting in the
    # pending queue (initial wait + every post-eviction re-wait)
    queue_wait_s: float = 0.0
    # eviction latency cost: seconds from each eviction to the end of the
    # re-admission (re-queue wait + teacher-forced re-prefill), summed
    evict_cost_s: float = 0.0
    evict_t: Optional[float] = None  # in-flight eviction timestamp
    # TTFT decomposition (queue_wait_s + prefill_compute_s + chunk_wait_s
    # ~= first_token_t - submit_t): device time actually spent in this
    # request's prefill/graft/chunk calls, and the between-chunk gaps
    # where the scheduler ran decode steps for other slots instead
    admit_t: Optional[float] = None
    prefill_compute_s: float = 0.0
    chunk_wait_s: float = 0.0
    # pages mapped from the shared-prefix cache (zero prefill recompute)
    prefix_hit_pages: int = 0

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return (
            bool(self.generated)
            and self.eos_id is not None
            and self.generated[-1] == self.eos_id
        )


def poisson_trace(
    n_requests: int,
    *,
    rate: float,
    vocab: int,
    prompt_lens: Tuple[int, int] = (8, 24),
    max_new: int = 16,
    eos_id: Optional[int] = None,
    seed: int = 0,
    shared_prefix: int = 0,
) -> List[Request]:
    """Poisson request trace: exponential inter-arrival gaps at ``rate``
    requests/second and uniformly random prompt lengths in
    ``prompt_lens = (lo, hi)``.  ``rate=inf`` (or 0) puts every arrival at
    t=0 — the saturate-then-drain pattern the CI smoke uses.

    ``shared_prefix > 0`` prepends one common random token prefix of that
    length to every prompt (the shared-system-prompt traffic shape the
    prefix page cache is built for); the per-request suffix still draws
    its length from ``prompt_lens``."""
    rng = np.random.default_rng(seed)
    lo, hi = prompt_lens
    prefix = (
        [int(x) for x in rng.integers(0, vocab, int(shared_prefix))]
        if shared_prefix
        else []
    )
    t = 0.0
    out = []
    for rid in range(n_requests):
        if rate and np.isfinite(rate) and rate > 0:
            t += float(rng.exponential(1.0 / rate))
        plen = int(rng.integers(lo, hi + 1))
        out.append(
            Request(
                rid=rid,
                prompt=prefix + [int(x) for x in rng.integers(0, vocab, plen)],
                max_new_tokens=int(max_new),
                eos_id=eos_id,
                arrival=t,
            )
        )
    return out


@dataclasses.dataclass
class _Slot:
    req: Request
    length: int  # cache rows currently filled for this slot
    pages: List[int]  # physical pages owned/shared (in logical-block order)
    admit_order: int
    # chunked-prefill state machine: a slot admitted via the chunked path
    # starts in phase "prefill" (its prompt streams in C tokens per engine
    # step, interleaved with other slots' decode steps) and flips to
    # "decode" when chunk_pos reaches len(ctx)
    phase: str = "decode"
    ctx: Optional[List[int]] = None  # admission context being prefilled
    chunk_pos: int = 0  # next absolute position to compute
    block_keys: Optional[List[str]] = None  # prefix chain hash per full block


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------


class PVQEngine:
    """Continuous-batching decode over a paged, PVQ-compressed KV cache.

    Requires an active process-wide ``KVQuant`` default (pages ARE the PVQ
    kv blocks) — the same switch the fixed-batch ``serve --kv-pvq`` path
    uses, so both paths share kernels, encode, and autotune entries.

    Slot invariant: an active slot holds ``length`` cache rows
    (= prompt + all generated tokens except the newest), and the next
    decode step feeds ``req.generated[-1]`` at position ``length``.
    """

    def __init__(
        self,
        model,
        params,
        *,
        n_slots: int = 4,
        max_len: int = 128,
        n_pages: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        prefill_batch: int = 1,
        prefix_cache: bool = True,
    ):
        kvq = default_kv_quant()
        if kvq is None:
            raise ValueError(
                "PVQEngine pages the PVQ-compressed cache: set a process-wide "
                "KVQuant first (set_default_kv_quant / kv_quant_scope)"
            )
        self.page = int(kvq.block)
        if self.page < 2:
            raise ValueError("page (= kv block) must be >= 2")
        self.model = model
        self.params = params
        self.n_slots = int(n_slots)
        self.max_pages = bucket_len(max_len, self.page) // self.page
        full = self.n_slots * self.max_pages
        self.n_pages = int(n_pages) if n_pages else full
        if self.n_pages < self.max_pages:
            # a lone sequence must always be able to run to max_len, or
            # eviction could never free enough pages to make progress
            raise ValueError(
                f"n_pages={self.n_pages} < max_pages={self.max_pages}: "
                "one full-length sequence must fit the pool"
            )
        # chunked prefill: long prompts stream in C = prefill_chunk * page
        # tokens per engine step (page-multiple chunks -> every chunk start
        # is page-aligned), interleaved with decode steps so active slots'
        # inter-token latency stays bounded during long-prompt admission
        self.prefill_chunk = int(prefill_chunk) if prefill_chunk else None
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.chunk_tokens = (self.prefill_chunk or 0) * self.page
        # batched admission: up to prefill_batch same-bucket waiting
        # requests prefill through ONE multi-row compile per step
        self.prefill_batch = max(int(prefill_batch), 1)
        # the shared-prefix page cache needs the chunk machinery to resume
        # a prompt from a page-aligned hit boundary
        self.prefix_cache = bool(prefix_cache) and self.prefill_chunk is not None
        self.alloc = PageAllocator(self.n_pages)
        self.cache = model.init_paged_cache(self.n_slots, self.n_pages, self.max_pages)
        self.slots: List[Optional[_Slot]] = [None] * self.n_slots
        self._page_table = np.full(
            (self.n_slots, self.max_pages), self.alloc.trash, np.int32
        )
        self._admit_seq = 0
        self.pending: deque = deque()
        self.finished: List[Request] = []
        self.trace_counts: Dict[str, int] = {
            "decode": 0, "prefill": 0, "graft": 0, "chunk": 0,
        }
        self.stats: Dict[str, int] = {
            "steps": 0, "active_slot_steps": 0, "evictions": 0, "decode_tokens": 0,
            "prefill_batches": 0, "prefill_rows": 0, "chunks": 0,
            "prefix_hits": 0, "prefix_misses": 0, "prefix_pages_shared": 0,
        }
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn)
        self._graft = jax.jit(self._graft_fn)
        self._chunk = jax.jit(self._chunk_fn)
        # decode-interference samples: inter-token gaps of steps that
        # shared their scheduler iteration with prefill/chunk work vs
        # pure-decode iterations (the p99 spread IS the head-of-line cost)
        self._itl_decode_s: List[float] = []
        self._itl_with_prefill_s: List[float] = []
        # sampled KV quality probes: the graft's in-graph encode cannot
        # probe itself (traced), so the first few admissions re-encode one
        # prefilled page eagerly when the registry is on
        self._kv_probe_budget = 8

    # ------------------------------------------------------------- capacity

    @property
    def capacity_tokens(self) -> int:
        return self.max_pages * self.page

    def validate(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if need > self.capacity_tokens:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={need} exceeds per-slot "
                f"capacity {self.capacity_tokens} (= max_pages * page)"
            )

    # --------------------------------------------------------- device steps

    def _decode_fn(self, params, cache, tokens, pos, page_table, write_page):
        # trace-time side effect: counts actual XLA traces, not calls
        self.trace_counts["decode"] += 1
        cache = jax.tree.map(
            lambda c: c.with_tables(page_table, write_page) if is_paged_kv(c) else c,
            cache,
            is_leaf=is_paged_kv,
        )
        logits, cache = self.model.decode_step(params, cache, tokens, pos)
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    def _prefill_fn(self, params, tokens, real_len):
        self.trace_counts["prefill"] += 1
        logits, caches = self.model.prefill_bucketed(
            params, {"tokens": tokens}, real_len
        )
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), caches

    def _graft_fn(self, cache, pre, slots, page_ids, real_len):
        """Batched graft: row ``i`` of the prefill batch lands in slot
        ``slots[i]``.  The row count is STATIC (``prefill_batch``; short
        batches duplicate row 0, and the duplicate grafts re-write
        identical bytes to identical destinations), so one trace serves
        every admission batch of a bucket."""
        self.trace_counts["graft"] += 1
        nrows = int(page_ids.shape[0])

        def row(leaf, i):
            # prefill cache leaves are (..., B, L_b, n_kv, hd): the batch
            # axis sits at -4 whether or not a layer-stack axis leads
            return leaf[..., i : i + 1, :, :, :]

        def walk(c, p):
            if is_paged_kv(c):
                for i in range(nrows):
                    c = c.graft(
                        row(p["k"], i), row(p["v"], i),
                        slots[i], page_ids[i], real_len[i],
                    )
                return c
            if isinstance(c, dict):
                return {key: walk(v, p[key]) for key, v in c.items()}
            return c

        return walk(cache, pre)

    def _chunk_fn(self, params, cache, tokens, slot, start, page_ids, real_len, page_table):
        """One chunked-prefill step: C tokens of one slot's context, read
        against its already-packed pages through ``page_table`` and
        grafted into ``page_ids``.  C is static, so the whole run
        compiles this exactly ONCE regardless of prompt lengths."""
        self.trace_counts["chunk"] += 1
        wp = jnp.full((self.n_slots,), self.alloc.trash, jnp.int32)
        cache = jax.tree.map(
            lambda c: c.with_tables(page_table, wp) if is_paged_kv(c) else c,
            cache,
            is_leaf=is_paged_kv,
        )
        logits, cache = self.model.prefill_chunk(
            params, cache, tokens, slot, start, page_ids, real_len
        )
        return jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32), cache

    # ------------------------------------------------------------ admission

    def _free_slot(self, exclude: Optional[set] = None) -> Optional[int]:
        for s, st in enumerate(self.slots):
            if st is None and (exclude is None or s not in exclude):
                return s
        return None

    @staticmethod
    def _ctx_tokens(req: Request) -> List[int]:
        if req.generated:
            # re-admission after eviction: the last generated token is the
            # pending decode input, everything before it is prefill context
            return list(req.prompt) + req.generated[:-1]
        return list(req.prompt)

    def _prefix_keys(self, ctx: Sequence[int]) -> List[str]:
        """Chain hash per full page of the context, from position 0.  The
        running digest makes key ``b`` cover blocks ``0..b``, so a match
        certifies the whole prefix up to and including that page — content
        AND absolute position, the causal-validity condition for mapping a
        packed page into another sequence."""
        h = hashlib.blake2b(digest_size=16)
        out = []
        page = self.page
        for b in range(len(ctx) // page):
            h.update(np.asarray(ctx[b * page : (b + 1) * page], np.int64).tobytes())
            out.append(h.hexdigest())
        return out

    def _start_timing(self, req: Request, t_now: Optional[float]) -> float:
        t_adm = time.perf_counter()
        if req.submit_t is None:
            req.submit_t = t_adm if t_now is None else t_now
        # queue wait: submitted (or evicted) -> admission actually starting
        base = req.evict_t if req.evict_t is not None else req.submit_t
        req.queue_wait_s += max(t_adm - base, 0.0)
        req.admit_t = t_adm
        return t_adm

    def _chunk_routed(self, ctx: List[int]) -> bool:
        """A context takes the chunked path when it is longer than one
        chunk, or when the prefix cache can hand it packed pages (the
        continuation has to resume from a page-aligned boundary, which is
        exactly what the chunk step does)."""
        if self.prefill_chunk is None:
            return False
        if len(ctx) > self.chunk_tokens:
            return True
        if not self.prefix_cache or (len(ctx) - 1) // self.page < 1:
            return False
        keys = self._prefix_keys(ctx)
        return bool(keys) and self.alloc.lookup(keys[0]) is not None

    def admit_pending(self, t_now: Optional[float] = None) -> int:
        """Admit from the queue head until blocked (FIFO — no request can
        starve behind a later, smaller one).  Short same-bucket prompts
        are batch-claimed up to ``prefill_batch`` and prefilled through
        one multi-row compile; long or prefix-hitting prompts enter the
        chunked state machine instead (their prefill streams through
        :meth:`_prefill_step`, interleaved with decode steps)."""
        admitted = 0
        while self.pending:
            req = self.pending[0]
            self.validate(req)
            ctx = self._ctx_tokens(req)
            if self._chunk_routed(ctx):
                n = self._admit_chunked(req, ctx, t_now)
            else:
                n = self._admit_batch(t_now)
            if not n:
                break
            admitted += n
        return admitted

    # ------------------------------------------------- chunked admission

    def _admit_chunked(self, req: Request, ctx: List[int], t_now) -> int:
        """Claim a slot + ALL the context's full-block pages up front
        (prefill then never waits on the pool mid-stream, which rules out
        prefill/decode page deadlock), map any shared-prefix pages into
        the page table, and park the slot in phase "prefill"."""
        plen = len(ctx)
        n_full = plen // self.page
        slot = self._free_slot()
        if slot is None:
            return 0
        keys = self._prefix_keys(ctx) if self.prefix_cache else []
        # never map the block containing the LAST context token: its
        # logits must be recomputed to produce the first generated token
        max_hit = (plen - 1) // self.page
        hits: List[int] = []
        for key in keys[:max_hit]:
            pid = self.alloc.lookup(key)
            if pid is None or not self.alloc.share(pid):
                break
            hits.append(pid)
        ids = self.alloc.alloc_many(n_full - len(hits))
        if ids is None:
            if hits:
                self.alloc.free(hits)  # roll the shares back; try later
            return 0
        self._start_timing(req, t_now)
        req.prefix_hit_pages += len(hits)
        st = _Slot(
            req=req, length=0, pages=hits + ids, admit_order=self._admit_seq,
            phase="prefill", ctx=ctx, chunk_pos=len(hits) * self.page,
            block_keys=keys or None,
        )
        self._admit_seq += 1
        self.slots[slot] = st
        self._page_table[slot, :] = self.alloc.trash
        self._page_table[slot, :n_full] = st.pages
        self.pending.popleft()
        self.stats["prefix_hits"] += len(hits)
        self.stats["prefix_pages_shared"] += len(hits)
        if self.prefix_cache and len(hits) < max_hit:
            self.stats["prefix_misses"] += 1
        if obs.enabled():
            obs.counter("engine.admissions").inc()
            if hits:
                obs.counter("prefix_cache.hit").add(len(hits))
                obs.counter("prefix_cache.pages_shared").add(len(hits))
            if self.prefix_cache and len(hits) < max_hit:
                obs.counter("prefix_cache.miss").inc()
            obs.event("engine/admit", args={
                "rid": req.rid, "ctx": plen, "chunked": 1,
                "prefix_pages": len(hits),
            })
        return 1

    # ------------------------------------------------- batched admission

    def _admit_batch(self, t_now) -> int:
        """Batch-claim slots/pages FIFO from the queue head: every
        consecutive request sharing the head's length bucket joins, up to
        ``prefill_batch`` rows, then ONE bucketed multi-row prefill + one
        batched graft admit them all.  A request that needs the chunked
        path (or a different bucket, or for which resources run out)
        stops the batch — FIFO order is never reordered around."""
        page = self.page
        lb = bucket_len(len(self._ctx_tokens(self.pending[0])), page)
        rows: List[Tuple[Request, List[int], int, List[int]]] = []
        claimed: set = set()
        while self.pending and len(rows) < self.prefill_batch:
            req = self.pending[0]
            self.validate(req)
            ctx = self._ctx_tokens(req)
            if bucket_len(len(ctx), page) != lb or self._chunk_routed(ctx):
                break
            slot = self._free_slot(exclude=claimed)
            if slot is None:
                break
            ids = self.alloc.alloc_many(len(ctx) // page)
            if ids is None:
                break
            claimed.add(slot)
            rows.append((req, ctx, slot, ids))
            self.pending.popleft()
        if not rows:
            return 0
        self._run_batch_prefill(rows, lb, t_now)
        return len(rows)

    def _run_batch_prefill(self, rows, lb: int, t_now) -> None:
        page = self.page
        bsz = self.prefill_batch
        toks = np.zeros((bsz, lb), np.int32)
        real = np.ones((bsz,), np.int32)
        slots_arr = np.zeros((bsz,), np.int32)
        ids_arr = np.full((bsz, lb // page), self.alloc.trash, np.int32)
        for i, (req, ctx, slot, ids) in enumerate(rows):
            toks[i, : len(ctx)] = np.asarray(ctx, np.int32)
            real[i] = len(ctx)
            slots_arr[i] = slot
            ids_arr[i, : len(ids)] = ids
            self._start_timing(req, t_now)
        for i in range(len(rows), bsz):
            # pad rows duplicate row 0: the duplicate graft re-writes the
            # same bytes to the same pages/slot, so padding is idempotent
            # and the compile count stays one per bucket
            toks[i] = toks[0]
            real[i] = real[0]
            slots_arr[i] = slots_arr[0]
            ids_arr[i] = ids_arr[0]
        t0 = time.perf_counter()
        with kv_quant_scope(None), obs.span(
            "engine/prefill",
            args={"bucket": lb, "rows": len(rows), "batch": bsz},
        ):
            tok0, pre = self._prefill(self.params, toks, real)
        if obs.enabled() and self._kv_probe_budget > 0 and int(real[0]) >= page:
            self._kv_probe_budget -= 1
            self._probe_kv_quality(pre)
        with obs.span(
            "engine/graft",
            args={"rows": len(rows), "pages": int((real // page).sum())},
        ):
            self.cache = self._graft(self.cache, pre, slots_arr, ids_arr, real)
        tok_host = np.asarray(jax.device_get(tok0))
        dt = time.perf_counter() - t0
        self.stats["prefill_batches"] += 1
        self.stats["prefill_rows"] += len(rows)
        for i, (req, ctx, slot, ids) in enumerate(rows):
            # each row experienced the whole batch call as its latency
            req.prefill_compute_s += dt
            if req.evict_t is not None:
                # the eviction's full latency cost lands at re-admission:
                # the re-queue wait plus the teacher-forced re-prefill
                req.evict_cost_s += max(time.perf_counter() - req.evict_t, 0.0)
                req.evict_t = None
            if self.prefix_cache:
                for b, key in enumerate(self._prefix_keys(ctx)):
                    self.alloc.register(ids[b], key)
            if not req.generated:
                req.generated.append(int(tok_host[i]))
                req.first_token_t = time.perf_counter()
            if req.done:
                # prefill alone satisfied the request (max_new == 1 /
                # instant EOS): never occupies a slot.  Registered pages
                # park in the allocator's cached pool, still shareable.
                self.alloc.free(ids)
                self._finish(req)
                continue
            self.slots[slot] = _Slot(
                req=req, length=len(ctx), pages=list(ids),
                admit_order=self._admit_seq,
            )
            self._admit_seq += 1
            self._page_table[slot, :] = self.alloc.trash
            self._page_table[slot, : len(ids)] = ids
        if obs.enabled():
            obs.counter("engine.admissions").add(len(rows))
            for req, ctx, _, _ in rows:
                obs.event("engine/admit", args={"rid": req.rid, "ctx": len(ctx)})

    # --------------------------------------------------- chunked prefill

    def _prefill_step(self) -> int:
        """Run the per-step prefill token budget: ONE chunk (C tokens) for
        the oldest slot still in phase "prefill".  Interleaving exactly
        one chunk between decode steps bounds how long any active slot
        waits on admission work — the p99 inter-token latency guarantee
        monolithic prefill cannot make.  Returns tokens of chunk work
        done (0 when no slot is prefilling)."""
        cand = [
            (s, st) for s, st in enumerate(self.slots)
            if st is not None and st.phase == "prefill"
        ]
        if not cand:
            return 0
        s, st = min(cand, key=lambda t: t[1].admit_order)
        req, ctx = st.req, st.ctx
        assert ctx is not None
        plen = len(ctx)
        n_full = plen // self.page
        ctk = self.chunk_tokens
        start = st.chunk_pos
        end = min(start + ctk, plen)
        toks = np.zeros((1, ctk), np.int32)
        toks[0, : end - start] = np.asarray(ctx[start:end], np.int32)
        page_ids = np.full((ctk // self.page,), self.alloc.trash, np.int32)
        b0 = start // self.page
        for j in range(ctk // self.page):
            if b0 + j < n_full:
                page_ids[j] = st.pages[b0 + j]
        t0 = time.perf_counter()
        with obs.span("engine/prefill_chunk", args={
            "rid": req.rid, "start": start, "end": end, "ctx": plen,
        }):
            tok0, self.cache = self._chunk(
                self.params, self.cache, toks, np.int32(s), np.int32(start),
                page_ids, np.int32(plen), self._page_table.copy(),
            )
            tok0.block_until_ready()
        req.prefill_compute_s += time.perf_counter() - t0
        self.stats["chunks"] += 1
        if self.prefix_cache and st.block_keys:
            for b in range(b0, min(end // self.page, n_full)):
                self.alloc.register(st.pages[b], st.block_keys[b])
        st.chunk_pos = end
        if end < plen:
            return end - start
        # final chunk: transition prefill -> decode
        if not req.generated:
            req.generated.append(int(np.asarray(jax.device_get(tok0))[0]))
            req.first_token_t = time.perf_counter()
            if req.admit_t is not None:
                req.chunk_wait_s += max(
                    req.first_token_t - req.admit_t - req.prefill_compute_s, 0.0
                )
        if req.evict_t is not None:
            req.evict_cost_s += max(time.perf_counter() - req.evict_t, 0.0)
            req.evict_t = None
        st.phase = "decode"
        st.ctx = None
        st.length = plen
        if req.done:
            self._retire(s)
        return end - start

    def _probe_kv_quality(self, pre) -> None:
        """Host-side KV quality probe: eagerly re-encode the first page of
        one prefilled layer with the engine's KVQuant so the eager-only
        probe inside ``_kv_encode_planes`` fires (records SNR/clamp/scale
        metrics).  Sampled — never on the per-token path."""
        from repro.core.packed import _kv_encode_planes

        kvq = default_kv_quant()

        def find(c):
            if isinstance(c, dict):
                if "k" in c and "v" in c:
                    return c
                for v in c.values():
                    hit = find(v)
                    if hit is not None:
                        return hit
            return None

        kv = find(pre)
        if kv is None or kvq is None:
            return
        k = np.asarray(jax.device_get(kv["k"]), np.float32)
        if k.ndim < 2:
            return
        k = k[:, : self.page]
        g, hd = kvq.group, k.shape[-1]
        while g > 1 and hd % g:  # same power-of-two fit the cache init uses
            g //= 2
        _kv_encode_planes(jnp.asarray(k), g, kvq.k)

    # ----------------------------------------------------- retire and evict

    def _finish(self, req: Request) -> None:
        req.finish_t = time.perf_counter()
        self.finished.append(req)
        if obs.enabled():
            obs.counter("engine.requests_finished").inc()
            if req.submit_t is not None:
                obs.histogram("engine.request_latency_s").record(
                    req.finish_t - req.submit_t
                )
                if req.first_token_t is not None:
                    obs.histogram("engine.ttft_s").record(
                        req.first_token_t - req.submit_t
                    )
            obs.histogram("engine.queue_wait_s").record(req.queue_wait_s)
            # TTFT decomposition: queue_wait + prefill_compute + chunk_wait
            # ~= first_token_t - submit_t (the residual is host overhead)
            obs.histogram("engine.prefill_compute_s").record(req.prefill_compute_s)
            obs.histogram("engine.chunk_wait_s").record(req.chunk_wait_s)
            if req.evictions:
                obs.histogram("engine.evict_cost_s").record(req.evict_cost_s)
            obs.event("engine/retire", args={"rid": req.rid})

    def _release(self, s: int) -> _Slot:
        st = self.slots[s]
        assert st is not None
        if st.pages:
            self.alloc.free(st.pages)
        self._page_table[s, :] = self.alloc.trash
        self.slots[s] = None
        return st

    def _retire(self, s: int) -> None:
        self._finish(self._release(s).req)

    def _evict(self, s: int) -> None:
        st = self._release(s)
        st.req.evictions += 1
        st.req.evict_t = time.perf_counter()
        self.stats["evictions"] += 1
        if obs.enabled():
            obs.counter("engine.evictions").inc()
            obs.event(
                "engine/evict",
                args={"rid": st.req.rid, "kept_tokens": len(st.req.generated)},
            )
        # queue head: the victim resumes as soon as pages free up
        self.pending.appendleft(st.req)

    # ----------------------------------------------------------- decode step

    def step(self) -> int:
        """One decode step over every active slot.  Returns the number of
        tokens generated (0 when idle).

        Slots completing a PVQ block this step get their destination page
        pre-assigned (``write_page``); if the pool can't cover every
        completing slot, the youngest active sequence is evicted until it
        can (guaranteed to terminate: a lone sequence never needs more
        than ``max_pages`` <= ``n_pages``).  Slots still in phase
        "prefill" neither decode nor get evicted — their pages were fully
        reserved at admission, so they always make progress."""
        while True:
            active = [
                (s, st) for s, st in enumerate(self.slots)
                if st is not None and st.phase == "decode"
            ]
            if not active:
                return 0
            needed = sum(
                1 for _, st in active if (st.length + 1) % self.page == 0
            )
            if needed <= self.alloc.available:
                break
            victim = max(active, key=lambda t: t[1].admit_order)[0]
            self._evict(victim)

        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        write_page = np.full((self.n_slots,), self.alloc.trash, np.int32)
        for s, st in active:
            tokens[s, 0] = st.req.generated[-1]
            pos[s] = st.length
            if (st.length + 1) % self.page == 0:
                pid = self.alloc.alloc()
                assert pid is not None  # reserved above
                st.pages.append(pid)
                self._page_table[s, st.length // self.page] = pid
                write_page[s] = pid

        # obs.NOOP when disabled: no span object, no args dict — the
        # telemetry hook adds zero allocations to the disabled decode step
        span = obs.NOOP
        if obs.enabled():
            span = obs.span("engine/decode_step", args={
                "active": len(active), "queue": len(self.pending),
                "free_pages": self.alloc.available,
            })
        with span:
            tok_ids, self.cache = self._decode(
                self.params, self.cache, tokens, pos,
                self._page_table.copy(), write_page,
            )
            tok_host = np.asarray(jax.device_get(tok_ids))
        self.stats["steps"] += 1
        self.stats["active_slot_steps"] += len(active)
        self.stats["decode_tokens"] += len(active)
        if obs.enabled():
            obs.counter("engine.decode_steps").inc()
            obs.counter("engine.decode_tokens").add(len(active))
            obs.gauge("engine.queue_depth").set(len(self.pending))
            obs.gauge("engine.page_pool_free").set(self.alloc.available)
            obs.gauge("engine.active_slots").set(len(active))
            # counter-track events: perfetto renders these as time series
            obs.trace_counter("engine.queue_depth", len(self.pending))
            obs.trace_counter("engine.page_pool_free", self.alloc.available)
            obs.trace_counter("engine.active_slots", len(active))
        for s, st in active:
            st.length += 1
            st.req.generated.append(int(tok_host[s]))
            if st.req.done:
                self._retire(s)
        return len(active)

    # --------------------------------------------------------------- warmup

    def warmup(self, prompt_lens: Sequence[int] = ()) -> None:
        """Compile the decode step, every prefill/graft bucket (at the
        engine's static prefill batch), and — when chunking is enabled —
        the single chunk shape, before the timed run (slots must be idle;
        the dummy writes all target the trash page / a tail ring the real
        graft overwrites).  Prompts longer than one chunk take the
        chunked path at runtime, so their buckets are skipped."""
        assert all(st is None for st in self.slots), "warmup needs an idle engine"
        buckets = {bucket_len(max(int(p), 1), self.page) for p in prompt_lens}
        if self.prefill_chunk is not None:
            buckets = {lb for lb in buckets if lb <= self.chunk_tokens}
        bsz = self.prefill_batch
        for lb in sorted(buckets):
            toks = np.zeros((bsz, lb), np.int32)
            with kv_quant_scope(None):
                _, pre = self._prefill(self.params, toks, np.ones((bsz,), np.int32))
            ids = np.full((bsz, lb // self.page), self.alloc.trash, np.int32)
            self.cache = self._graft(
                self.cache, pre, np.zeros((bsz,), np.int32), ids,
                np.ones((bsz,), np.int32),
            )
        if self.prefill_chunk is not None:
            ctk = self.chunk_tokens
            toks = np.zeros((1, ctk), np.int32)
            ids = np.full((ctk // self.page,), self.alloc.trash, np.int32)
            _, self.cache = self._chunk(
                self.params, self.cache, toks, np.int32(0), np.int32(0),
                ids, np.int32(1), self._page_table.copy(),
            )
        tokens = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        wp = np.full((self.n_slots,), self.alloc.trash, np.int32)
        _, self.cache = self._decode(
            self.params, self.cache, tokens, pos, self._page_table.copy(), wp
        )

    # ------------------------------------------------------------- run loop

    async def _feed(self, trace: List[Request], t0: float, time_scale: float):
        loop = asyncio.get_running_loop()
        for req in sorted(trace, key=lambda r: r.arrival):
            delay = (t0 + req.arrival * time_scale) - loop.time()
            if delay > 0:
                await asyncio.sleep(delay)
            req.submit_t = time.perf_counter()
            self.pending.append(req)

    async def _run_async(self, trace: List[Request], time_scale: float):
        for req in trace:
            self.validate(req)
        t_start = time.perf_counter()
        loop = asyncio.get_running_loop()
        feeder = asyncio.create_task(self._feed(trace, loop.time(), time_scale))
        last_step_end: Optional[float] = None
        try:
            while True:
                pb0 = self.stats["prefill_batches"]
                self.admit_pending()
                chunked = self._prefill_step()
                n = self.step()
                if n:
                    now = time.perf_counter()
                    if last_step_end is not None:
                        # decode-interference sample: the gap between two
                        # consecutive decode steps, split by whether
                        # prefill work (a chunk or a batch admission) ran
                        # inside it
                        gap = now - last_step_end
                        if chunked or self.stats["prefill_batches"] > pb0:
                            self._itl_with_prefill_s.append(gap)
                        else:
                            self._itl_decode_s.append(gap)
                    last_step_end = now
                prefilling = any(
                    st is not None and st.phase == "prefill" for st in self.slots
                )
                if n or chunked:
                    await asyncio.sleep(0)  # yield to the arrival feeder
                elif feeder.done() and not self.pending and not prefilling:
                    break
                else:
                    last_step_end = None  # idle: gaps are not ITL samples
                    await asyncio.sleep(0.0005)  # wait for arrivals
        finally:
            await feeder
        return self.report(time.perf_counter() - t_start)

    def run(self, trace: Sequence[Request], *, time_scale: float = 1.0) -> Dict[str, Any]:
        """Serve a trace to completion; returns the metrics report.
        ``time_scale`` compresses/stretches the trace's arrival times."""
        return asyncio.run(self._run_async(list(trace), time_scale))

    # -------------------------------------------------------------- metrics

    def report(self, wall_s: float) -> Dict[str, Any]:
        done = self.finished
        toks = sum(len(r.generated) for r in done)
        lat = [
            r.finish_t - r.submit_t
            for r in done
            if r.finish_t is not None and r.submit_t is not None
        ]
        ttft = [
            r.first_token_t - r.submit_t
            for r in done
            if r.first_token_t is not None and r.submit_t is not None
        ]
        # the telemetry histogram IS the percentile implementation — one
        # type shared with the benchmarks instead of inline pct() copies
        lat_h = Histogram.from_values(lat)
        ttft_h = Histogram.from_values(ttft)
        qwait_h = Histogram.from_values(r.queue_wait_s for r in done)
        # TTFT decomposition: queue_wait (scheduler) + prefill_compute
        # (device) + chunk_wait (interleaved-decode delay, chunked only)
        pcomp_h = Histogram.from_values(r.prefill_compute_s for r in done)
        cwait_h = Histogram.from_values(r.chunk_wait_s for r in done)
        evict_costs = [r.evict_cost_s for r in done if r.evictions]
        evict_h = Histogram.from_values(evict_costs)
        itl_h = Histogram.from_values(self._itl_decode_s)
        itl_pf_h = Histogram.from_values(self._itl_with_prefill_s)

        if obs.enabled():
            # trace-count watcher as a first-class metric (one gauge per
            # jitted fn; report() may run repeatedly, so not a counter)
            for fn, n in self.trace_counts.items():
                obs.gauge("engine.trace_count", {"fn": fn}).set(n)
            obs.gauge("engine.itl_p99_s").set(itl_h.percentile(99))
            obs.gauge("engine.itl_with_prefill_p99_s").set(itl_pf_h.percentile(99))

        steps = max(self.stats["steps"], 1)
        return {
            "requests": len(done),
            "generated_tokens": toks,
            "wall_s": round(wall_s, 4),
            "tokens_per_s": round(toks / max(wall_s, 1e-9), 2),
            "latency_p50_s": round(lat_h.percentile(50), 4),
            "latency_p99_s": round(lat_h.percentile(99), 4),
            "ttft_p50_s": round(ttft_h.percentile(50), 4),
            "ttft_p99_s": round(ttft_h.percentile(99), 4),
            "queue_wait_p50_s": round(qwait_h.percentile(50), 4),
            "queue_wait_p99_s": round(qwait_h.percentile(99), 4),
            "prefill_compute_p50_s": round(pcomp_h.percentile(50), 4),
            "prefill_compute_p99_s": round(pcomp_h.percentile(99), 4),
            "chunk_wait_p50_s": round(cwait_h.percentile(50), 4),
            "chunk_wait_p99_s": round(cwait_h.percentile(99), 4),
            "itl_p99_s": round(itl_h.percentile(99), 6),
            "itl_with_prefill_p99_s": round(itl_pf_h.percentile(99), 6),
            "itl_samples": len(self._itl_decode_s),
            "itl_with_prefill_samples": len(self._itl_with_prefill_s),
            "prefill_batches": self.stats["prefill_batches"],
            "prefill_rows": self.stats["prefill_rows"],
            "chunks": self.stats["chunks"],
            "prefix_hits": self.stats["prefix_hits"],
            "prefix_misses": self.stats["prefix_misses"],
            "prefix_pages_shared": self.stats["prefix_pages_shared"],
            "eviction_cost_total_s": round(evict_h.total, 4),
            "eviction_cost_p50_s": round(evict_h.percentile(50), 4),
            "slot_utilization": round(
                self.stats["active_slot_steps"] / (steps * self.n_slots), 4
            ),
            "evictions": self.stats["evictions"],
            "decode_steps": self.stats["steps"],
            "n_slots": self.n_slots,
            "n_pages": self.n_pages,
            "page": self.page,
            "trace_counts": dict(self.trace_counts),
            "outputs": {r.rid: list(r.generated) for r in done},
        }
