"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single CPU device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16x16 = 256 chips/pod; 2 pods = 512 chips.

    Axes: ``pod`` (inter-pod DP / pipeline), ``data`` (DP+FSDP),
    ``model`` (TP/EP/SP).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many devices exist (tests)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_chip_count(mesh) -> int:
    return int(mesh.devices.size)
