"""Render EXPERIMENTS.md tables from dry-run JSON directories.

    PYTHONPATH=src python -m repro.launch.report \
        --baseline experiments/dryrun --optimized experiments/dryrun_opt
"""

from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path
from typing import Dict, Optional


def load(dirpath: str) -> Dict[tuple, dict]:
    out = {}
    for f in sorted(glob.glob(str(Path(dirpath) / "*.json"))):
        r = json.loads(open(f).read())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(cells: Dict[tuple, dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute | memory* | collective | bound | MODEL_FLOPS/HLO | mem_analytic (decode) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, m), r in sorted(cells.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | N/A | — | {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | FAILED | | | | | |")
            continue
        roof = r["roofline"]
        ana = r.get("analytic_decode")
        ana_s = fmt_s(ana["memory_s_analytic"]) if ana else "—"
        lines.append(
            f"| {arch} | {shape} | {fmt_s(roof['compute_s'])} | {fmt_s(roof['memory_s'])} "
            f"| {fmt_s(roof['collective_s'])} | {roof['bottleneck']} "
            f"| {min(roof['useful_ratio'], 99):.2f} | {ana_s} |"
        )
    return "\n".join(lines)


def before_after_table(base: Dict[tuple, dict], opt: Dict[tuple, dict], mesh="single") -> str:
    lines = [
        "| arch | shape | term | baseline | optimized | delta |",
        "|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        arch, shape, m = key
        if m != mesh or key not in opt:
            continue
        b, o = base[key], opt[key]
        if b["status"] != "ok" or o["status"] != "ok":
            continue
        rb, ro = b["roofline"], o["roofline"]
        dom = rb["bottleneck"]
        term = {"compute": "compute_s", "memory": "memory_s", "collective": "collective_s"}[dom]
        delta = (ro[term] - rb[term]) / max(rb[term], 1e-12)
        lines.append(
            f"| {arch} | {shape} | {dom} | {fmt_s(rb[term])} | {fmt_s(ro[term])} | {100*delta:+.1f}% |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="experiments/dryrun")
    ap.add_argument("--optimized", default="experiments/dryrun_opt")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    base = load(args.baseline)
    print("## Baseline roofline (single-pod)\n")
    print(roofline_table(base, args.mesh))
    if Path(args.optimized).exists():
        opt = load(args.optimized)
        if opt:
            print("\n## Optimized roofline (single-pod)\n")
            print(roofline_table(opt, args.mesh))
            print("\n## Before/after on the dominant term\n")
            print(before_after_table(base, opt, args.mesh))


if __name__ == "__main__":
    main()
