"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/run0 [--pvq-qat]

Wires together: config -> model -> AdamW -> sharded step (mesh-aware when
more than one device is present) -> deterministic data pipeline -> async
checkpointing -> fault-tolerant runner.  ``--pvq-qat`` trains with the
paper's mixed-optimization recipe (STE PVQ projection on matmul weights).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import TokenLoader, TokenTask
from repro.nn.models import build_model
from repro.optim import AdamW, cosine_schedule
from repro.runtime.fault_tolerance import StragglerPolicy, TrainingRunner


def make_state_and_step(model, optimizer, *, pvq_qat=False, pvq_k=None, pvq_group=256, seed=0):
    """Returns (state=(params, opt_state), jitted step_fn(state, batch))."""

    params = model.init(jax.random.PRNGKey(seed), max_seq=4096)
    opt_state = optimizer.init(params)

    def maybe_project(p):
        if not pvq_qat:
            return p
        from repro.core.qat import pvq_ste
        from repro.core.quantize import QuantPolicy

        policy = QuantPolicy()

        def visit(path, leaf):
            pstr = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in path)
            if leaf.ndim >= 2 and policy.match(pstr) and leaf.size >= 1024:
                return pvq_ste(leaf, pvq_k or max(leaf.size // 1, 1) and pvq_k, pvq_group)
            return leaf

        return jax.tree_util.tree_map_with_path(visit, p)

    @jax.jit
    def step_fn(state, batch):
        params, opt_state = state
        # per-step rng for stochastic train features (MoE router jitter),
        # seeded by the run and advanced by the optimizer step counter
        rng = jax.random.fold_in(jax.random.PRNGKey(seed), opt_state.step)
        def loss_fn(p):
            return model.loss(maybe_project(p), batch, rng)
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
        return (params, opt_state), dict(metrics, loss=loss, grad_norm=gnorm)

    return (params, opt_state), step_fn


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", help="tiny same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--pvq-qat", action="store_true")
    ap.add_argument("--pvq-k", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    optimizer = AdamW(lr=cosine_schedule(args.lr, warmup=20, total=args.steps))
    state, step_fn = make_state_and_step(
        model, optimizer, pvq_qat=args.pvq_qat, pvq_k=args.pvq_k, seed=args.seed
    )

    task = TokenTask(cfg.vocab_size, seed=args.seed)
    loader = TokenLoader(task, args.batch, args.seq, seed=args.seed)
    ckpt = Checkpointer(args.ckpt_dir, keep=3)
    runner = TrainingRunner(
        step_fn, state, loader, ckpt, ckpt_every=args.ckpt_every,
        straggler=StragglerPolicy(),
    )

    t0 = time.time()
    runner.run(args.steps)
    dt = time.time() - t0
    hist = runner.history
    first = sum(h["loss"] for h in hist[:10]) / max(len(hist[:10]), 1)
    last = sum(h["loss"] for h in hist[-10:]) / max(len(hist[-10:]), 1)
    print(json.dumps({
        "arch": cfg.name, "steps": len(hist), "wall_s": round(dt, 1),
        "loss_first10": round(first, 4), "loss_last10": round(last, 4),
        "stragglers_flagged": len(runner.straggler.flagged),
        "restores": runner.restores,
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
