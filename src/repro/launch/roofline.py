"""Roofline term extraction from compiled dry-run artifacts.

Terms (per chip — the compiled module after SPMD partitioning *is* the
per-chip program, so chips cancel):

    compute    = HLO_FLOPs_per_chip   / PEAK_FLOPS       (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes_per_chip   / HBM_BW           (819 GB/s)
    collective = coll_bytes_per_chip  / ICI_BW           (~50 GB/s/link)

``cost_analysis`` supplies flops/bytes; collective bytes are parsed from the
HLO text: for each all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op we take the largest shape on the line (operand or
result) as the bytes moved, doubled for all-reduce (reduce-scatter +
all-gather phases of a ring).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip (v5e)
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return _DTYPE_BYTES[dtype] * n


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum per-collective bytes from (post-SPMD) HLO text."""
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    counts: Dict[str, int] = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match op lines like: %x = bf16[...] all-reduce(...), or fused variants
        m = re.search(r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z-]+)", stripped)
        if not m:
            continue
        op = m.group(1)
        kind = next((k for k in _COLL_KINDS if op == k or op.startswith(k + ".")), None)
        if kind is None:
            continue
        sizes = [_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(stripped)]
        if not sizes:
            continue
        moved = max(sizes)
        if kind == "all-reduce":
            moved *= 2  # ring all-reduce = reduce-scatter + all-gather
        per_kind[kind] += moved
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"total_bytes": total, "per_kind_bytes": per_kind, "per_kind_counts": counts}


@dataclasses.dataclass
class Roofline:
    flops: float  # per chip
    hbm_bytes: float  # per chip
    coll_bytes: float  # per chip
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float  # global useful flops (6ND / 2ND)
    chips: int
    useful_ratio: float  # model_flops / (flops * chips)
    collectives: Dict[str, Any]

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def analyze(
    cost: Dict[str, float],
    hlo_text: str,
    *,
    chips: int,
    model_flops: float,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll["total_bytes"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        chips=chips,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        collectives=coll,
    )


def analyze_corrected(
    *, flops: float, hbm_bytes: float, coll: Dict[str, Any], chips: int, model_flops: float
) -> Roofline:
    """Roofline from depth-corrected costs (see dryrun.extrapolated_costs)."""
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll["total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        flops=flops,
        hbm_bytes=hbm_bytes,
        coll_bytes=coll["total_bytes"],
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        chips=chips,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        collectives=coll,
    )


def model_flops_for(cfg, shape, n_active_params: int) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for inference forward."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind in ("train", "prefill") else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active_params * tokens


# ---------------------------------------------------------------------------
# Analytic min-traffic model (decode) + PVQ weight streaming
# ---------------------------------------------------------------------------

# PVQ storage cost per weight under the pvq_matmul kernel contract
# (int8 pulses + one f32 scale per `group` weights); nibble variant packs two
# pulses/byte (|pulse| <= 7 — holds for every N/K <= 1 layer measured).
def pvq_bytes_per_weight(group: int = 256, nibble: bool = False) -> float:
    return (0.5 if nibble else 1.0) + 4.0 / group


def analytic_decode_memory(cfg, shape, mesh, n_params_total: int) -> dict:
    """Per-chip min HBM traffic for one decode step, and the PVQ variant.

    weights: every live weight is read once per step (weight-memory-bound
    decode).  Serving layout (opt>=1): experts sharded over all chips,
    non-experts over TP only.  cache: read once + one-token write.
    The XLA-derived memory term is an *unfused upper bound* (CPU backend);
    this analytic floor brackets the truth from below, and is the term the
    PVQ dequant-matmul kernel moves (2B -> ~1.02B or ~0.52B per weight).
    """
    chips = int(mesh.devices.size)
    tp = int(mesh.shape.get("model", 1))
    b = shape.global_batch
    s = shape.seq_len
    dp = max(chips // tp, 1)

    # weights (bf16), serving layout
    if cfg.moe is not None:
        d_exp = cfg.moe.d_expert
        glu = cfg.moe.activation in ("swiglu", "geglu")
        n_per_expert = cfg.d_model * d_exp * (3 if glu else 2)
        n_experts_total = cfg.moe.n_experts * (cfg.n_layers - cfg.first_dense) * (
            1 if cfg.moe_period == 1 else 1.0 / cfg.moe_period
        )
        n_expert_params = int(n_per_expert * n_experts_total)
        n_rest = n_params_total - n_expert_params
        weight_bytes = 2.0 * n_expert_params / chips + 2.0 * n_rest / tp
        n_quantizable = n_params_total
    else:
        weight_bytes = 2.0 * n_params_total / tp
        n_quantizable = n_params_total

    # KV/state cache per chip (batch over dp, seq over tp)
    if cfg.rwkv is not None:
        m = cfg.rwkv.head_size
        cache_bytes = 4.0 * (cfg.d_model // m) * m * m * cfg.n_layers * b / dp
    elif cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        cache_bytes = 2.0 * b * s * per_tok * cfg.n_layers / chips
    elif cfg.hybrid_period:
        attn_layers = cfg.n_layers // cfg.hybrid_period
        kv = 2.0 * b * s * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * attn_layers / chips
        ssm_layers = cfg.n_layers - attn_layers
        d_inner = cfg.ssm.expand * cfg.d_model
        ssm = 4.0 * b * d_inner * cfg.ssm.d_state * ssm_layers / dp
        cache_bytes = kv + ssm
    else:
        cache_bytes = 2.0 * b * s * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * cfg.n_layers / chips

    pvq_w = weight_bytes * pvq_bytes_per_weight(cfg.pvq.group or 256) / 2.0
    pvq_w_nib = weight_bytes * pvq_bytes_per_weight(cfg.pvq.group or 256, nibble=True) / 2.0
    return {
        "weight_bytes_per_chip": weight_bytes,
        "cache_bytes_per_chip": cache_bytes,
        "memory_s_analytic": (weight_bytes + cache_bytes) / HBM_BW,
        "memory_s_analytic_pvq_int8": (pvq_w + cache_bytes) / HBM_BW,
        "memory_s_analytic_pvq_nibble": (pvq_w_nib + cache_bytes) / HBM_BW,
        "pvq_weight_speedup": (weight_bytes + cache_bytes) / (pvq_w + cache_bytes),
        "pvq_nibble_speedup": (weight_bytes + cache_bytes) / (pvq_w_nib + cache_bytes),
    }
