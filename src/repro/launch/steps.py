"""Jittable step functions (train / prefill / serve) with their sharding
assignments.  Used by the real train/serve drivers and by the dry-run."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.nn.models import Model
from repro.optim import AdamW
from repro.parallel import (
    ShardingPolicy,
    batch_pspec,
    cache_shardings,
    param_shardings,
    sharding_policy,
)

from . import inputs as inputs_lib


@dataclasses.dataclass
class StepBundle:
    """A step function plus its in/out shardings and input specs."""

    fn: Callable
    in_shardings: Any
    out_shardings: Any
    specs: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jit().lower(*self.specs)


def _replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def _batch_sharding(mesh: Mesh, batch_size: int) -> NamedSharding:
    """Shard the batch dim over (pod, data) only when divisible."""
    from repro.parallel.sharding import dp_axes
    import numpy as np

    axes = dp_axes(mesh)
    if axes:
        extent = int(np.prod([mesh.shape[a] for a in axes]))
        if batch_size % extent == 0:
            return NamedSharding(mesh, P(axes))
        if batch_size % mesh.shape.get("data", 1) == 0:
            return NamedSharding(mesh, P("data"))
    return NamedSharding(mesh, P())


def _tree_of(sharding, tree):
    return jax.tree.map(lambda _: sharding, tree)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_step(
    model: Model,
    optimizer: AdamW,
    mesh: Mesh,
    shape: ShapeConfig,
    policy: ShardingPolicy = ShardingPolicy(),
    seed: int = 0,
) -> StepBundle:
    cfg = model.cfg

    def train_step(params, opt_state, batch):
        with sharding_policy(policy):
            # per-step rng for stochastic train features (MoE router jitter):
            # seeded by the run, advanced by the optimizer step counter, so
            # the jitted step stays a pure (params, opt_state, batch)
            # function and distinct runs draw distinct noise sequences
            rng = jax.random.fold_in(jax.random.PRNGKey(seed), opt_state.step)
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch, rng
            )
            params, opt_state, gnorm = optimizer.update(grads, opt_state, params)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            return params, opt_state, metrics

    with mesh, sharding_policy(policy):
        pshape = inputs_lib.params_shape(model, max_seq=shape.seq_len)
        oshape = jax.eval_shape(optimizer.init, pshape)
        pshard = param_shardings(pshape, mesh, policy)
        oshard = jax.tree.map(
            lambda leaf, _=None: None, oshape
        )  # placeholder; built below
        # moments share the param sharding; the step counter is replicated
        mu_shard = param_shardings(oshape.mu, mesh, policy)
        nu_shard = param_shardings(oshape.nu, mesh, policy)
        oshard = type(oshape)(step=_replicated(mesh), mu=mu_shard, nu=nu_shard)
        bs = _batch_sharding(mesh, shape.global_batch)
        bshard = jax.tree.map(lambda _: bs, inputs_lib.batch_specs(cfg, shape, with_targets=True))
        metrics_shard = _replicated(mesh)

    specs = (pshape, oshape, inputs_lib.batch_specs(cfg, shape, with_targets=True))
    return StepBundle(
        fn=train_step,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, None),
        specs=specs,
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def make_prefill_step(
    model: Model,
    mesh: Mesh,
    shape: ShapeConfig,
    policy: ShardingPolicy = ShardingPolicy(),
) -> StepBundle:
    cfg = model.cfg

    def prefill_step(params, batch):
        with sharding_policy(policy):
            logits, caches = model.prefill(params, batch)
            return logits, caches

    with mesh, sharding_policy(policy):
        pshape = inputs_lib.params_shape(model, max_seq=shape.seq_len)
        pshard = param_shardings(pshape, mesh, policy)
        batch = inputs_lib.batch_specs(cfg, shape, with_targets=False)
        bs = _batch_sharding(mesh, shape.global_batch)
        bshard = jax.tree.map(lambda _: bs, batch)

    return StepBundle(
        fn=prefill_step,
        in_shardings=(pshard, bshard),
        out_shardings=None,
        specs=(pshape, batch),
    )


# ---------------------------------------------------------------------------
# Decode / serve
# ---------------------------------------------------------------------------


def make_serve_step(
    model: Model,
    mesh: Mesh,
    shape: ShapeConfig,
    policy: Optional[ShardingPolicy] = None,
) -> StepBundle:
    cfg = model.cfg
    if policy is None:
        # context-parallel KV for the single-sequence long-context cell
        policy = ShardingPolicy(context_parallel=(shape.global_batch < mesh.devices.size // (mesh.shape.get("model", 1))))

    def serve_step(params, cache, token, pos):
        with sharding_policy(policy):
            logits, new_cache = model.decode_step(params, cache, token, pos)
            return logits, new_cache

    with mesh, sharding_policy(policy):
        pshape = inputs_lib.params_shape(model, max_seq=shape.seq_len)
        pshard = param_shardings(pshape, mesh, policy)
        specs = inputs_lib.input_specs(cfg, shape, model)
        cshard = cache_shardings(specs["cache"], mesh, policy)
        tshard = _batch_sharding(mesh, shape.global_batch)
        posshard = _replicated(mesh)

    return StepBundle(
        fn=serve_step,
        in_shardings=(pshard, cshard, tshard, posshard),
        out_shardings=(None, cshard),
        specs=(pshape, specs["cache"], specs["token"], specs["pos"]),
        donate_argnums=(1,),
    )


def policy_for(shape: ShapeConfig, mesh: Mesh, opt_level: int = 0) -> ShardingPolicy:
    """§Perf hillclimb ladder.  Level 0 reproduces the recorded baseline.

    decode:  L1 = serving param layout (no FSDP; expert-ffn-dim over data)
                  + cache sequence axis sharded over the model axis
    train:   L1 = MoE light combine (no f32 combine tensor)
             L2 = + sequence parallelism on residuals
    prefill: L1 = MoE light combine;  L2 = + sequence parallelism
    """
    cp = shape.kind == "decode" and shape.global_batch < int(mesh.devices.size) // int(
        mesh.shape.get("model", 1)
    )
    if shape.kind == "decode":
        return ShardingPolicy(
            context_parallel=cp,
            serve_params=opt_level >= 1,
            cache_seq_tp=opt_level >= 1,
            moe_light_combine=opt_level >= 1,
        )
    return ShardingPolicy(
        moe_light_combine=opt_level >= 1,
        remat="collectives" if opt_level >= 2 else "full",
        seq_shard=opt_level >= 3,
    )


def make_step(
    model: Model,
    mesh: Mesh,
    shape: ShapeConfig,
    policy: Optional[ShardingPolicy] = None,
    *,
    opt_level: int = 0,
) -> StepBundle:
    if policy is None:
        policy = policy_for(shape, mesh, opt_level)
    if shape.kind == "train":
        return make_train_step(model, AdamW(), mesh, shape, policy)
    if shape.kind == "prefill":
        return make_prefill_step(model, mesh, shape, policy)
    if shape.kind == "decode":
        return make_serve_step(model, mesh, shape, policy)
    raise ValueError(shape.kind)
