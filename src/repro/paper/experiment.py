"""The paper's §VII experiments, reproduced end-to-end.

Pipeline per net (A/B/C/D):
  1. train the float net (ReLU or bsign+STE) on the synthetic classify task
     (offline container: MNIST/CIFAR10 stand-ins from repro.data.synthetic);
  2. PVQ-encode each weight layer with the paper's exact per-layer N/K
     ratios (weights flattened + bias concatenated, ONE rho per layer);
  3. evaluate before/after -> the paper's headline "few % drop";
  4. verify the §V folding claims (integer-only forward + single output
     scale == dequantized forward; argmax invariance);
  5. collect Tables 5-8 pulse statistics + §VI bits/weight estimates.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_nets import PAPER_NETS
from repro.core.codes import compression_report, golomb_length, pulse_histogram
from repro.data.synthetic import ClassifyTask
from repro.nn.sequential import SequentialNet, accuracy, xent_loss
from repro.optim import AdamW


@dataclasses.dataclass
class RepoResult:
    net: str
    acc_before: float
    acc_after: float
    acc_after_ls: float  # beyond-paper least-squares rho
    acc_refined: Optional[float]  # paper §IV hybrid recipe (PVQ-constrained fine-tune)
    drop_pct: float
    layer_stats: Dict[str, Dict[str, Any]]
    weight_tables: Dict[str, Dict[str, float]]
    fold_check: Optional[Dict[str, float]]
    train_steps: int
    wall_s: float


def train_net(
    net: SequentialNet,
    task: ClassifyTask,
    *,
    steps: int = 300,
    batch: int = 128,
    lr: float = 1e-3,
    weight_decay: float = 0.05,  # paper: L2 helps sparsify for PVQ
    seed: int = 0,
    init_params=None,
    pvq_project: bool = False,
):
    """Train (or fine-tune) the net.  ``pvq_project=True`` runs the paper's
    §IV mixed optimization: forward on PVQ-projected weights, STE backward."""
    params = init_params if init_params is not None else net.init(jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, weight_decay=weight_decay, clip_norm=1.0)
    state = opt.init(params)

    def project(p):
        if not pvq_project:
            return p
        from repro.core.qat import pvq_ste
        from repro.core import k_for

        out = dict(p)
        for i, spec in enumerate(net.cfg.layers):
            pname = f"layer{i}"
            if pname in p and spec.n_over_k is not None:
                kern = p[pname]["kernel"]
                n = kern.size + p[pname]["bias"].size
                k = k_for(n, spec.n_over_k)
                flat = jnp.concatenate([kern.reshape(-1), p[pname]["bias"]])
                q = pvq_ste(flat, k, None)
                out[pname] = {
                    "kernel": q[: kern.size].reshape(kern.shape),
                    "bias": q[kern.size :],
                }
        return out

    @jax.jit
    def step(params, state, batch_, key):
        loss, grads = jax.value_and_grad(
            lambda p: xent_loss(net, project(p), batch_, dropout_key=key)
        )(params)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        b = task.sample(rng, batch)
        b = {"x": jnp.asarray(b["x"]).reshape(batch, *net.cfg.input_shape), "y": jnp.asarray(b["y"])}
        key, sub = jax.random.split(key)
        params, state, loss = step(params, state, b, sub)
    return params


def run_net(
    net_id: str,
    *,
    steps: int = 600,
    batch: int = 128,
    noise: float = 6.0,
    seed: int = 0,
    check_fold: bool = True,
    refine_steps: int = 0,
) -> RepoResult:
    t0 = time.time()
    cfg = PAPER_NETS[net_id]
    net = SequentialNet(cfg)
    task = ClassifyTask(cfg.input_shape, n_classes=cfg.n_classes, noise=noise, seed=seed)
    params = train_net(net, task, steps=steps, batch=batch, seed=seed)

    test = task.test_set(2048)
    xt = jnp.asarray(test["x"]).reshape(-1, *cfg.input_shape)
    yt = jnp.asarray(test["y"])
    acc_before = accuracy(net, params, xt, yt)

    qparams, codes, stats = net.pvq_encode_layers(params, scale_mode="paper")
    acc_after = accuracy(net, qparams, xt, yt)
    qparams_ls, _, _ = net.pvq_encode_layers(params, scale_mode="ls")
    acc_after_ls = accuracy(net, qparams_ls, xt, yt)

    acc_refined = None
    if refine_steps:
        # paper §IV hybrid recipe: continue training as a mixed optimization
        refined = train_net(
            net, task, steps=refine_steps, batch=batch, lr=2e-4, seed=seed + 99,
            init_params=params, pvq_project=True,
        )
        rq, _, _ = net.pvq_encode_layers(refined, scale_mode="paper")
        acc_refined = accuracy(net, rq, xt, yt)

    # Tables 5-8: pulse histograms + bits/weight
    weight_tables = {}
    for lname, code in codes.items():
        pulses = np.asarray(code.pulses).ravel()
        rep = pulse_histogram(pulses)
        rep.update(compression_report(pulses))
        weight_tables[lname] = rep

    fold_check = None
    if check_fold:
        # §V: integer pulse forward * single scale == dequantized forward
        logits_deq = net.apply(qparams, xt[:64])
        logits_int, scale = net.integer_forward(params, codes, xt[:64])
        err = float(
            jnp.max(jnp.abs(scale * logits_int - logits_deq))
            / jnp.maximum(jnp.max(jnp.abs(logits_deq)), 1e-9)
        )
        same_argmax = float(
            jnp.mean(
                (jnp.argmax(logits_int, -1) == jnp.argmax(logits_deq, -1)).astype(jnp.float32)
            )
        )
        fold_check = {"rel_err": err, "argmax_agreement": same_argmax, "output_scale": scale}

    return RepoResult(
        net=net_id,
        acc_before=acc_before,
        acc_after=acc_after,
        acc_after_ls=acc_after_ls,
        acc_refined=acc_refined,
        drop_pct=100.0 * (acc_before - acc_after),
        layer_stats=stats,
        weight_tables=weight_tables,
        fold_check=fold_check,
        train_steps=steps,
        wall_s=time.time() - t0,
    )


def format_result(r: RepoResult) -> str:
    lines = [
        f"== net {r.net} ==",
        f"accuracy before PVQ: {100*r.acc_before:.2f}%   after: {100*r.acc_after:.2f}%"
        f"   (drop {r.drop_pct:.2f} pts; paper reports a few % drop)",
        f"beyond-paper LS-scale after: {100*r.acc_after_ls:.2f}%",
    ]
    if r.acc_refined is not None:
        lines.append(f"hybrid refine (paper §IV): {100*r.acc_refined:.2f}%")
    if r.fold_check:
        lines.append(
            f"rho-folding: integer-path rel err {r.fold_check['rel_err']:.2e}, "
            f"argmax agreement {100*r.fold_check['argmax_agreement']:.1f}%, "
            f"output scale {r.fold_check['output_scale']:.4g}"
        )
    for lname, st in r.layer_stats.items():
        tab = r.weight_tables[lname]
        lines.append(
            f"  {lname}: N={st['N']} K={st['K']} N/K={st['n_over_k']:.2g} | "
            f"zeros {tab['0_pct']:.1f}% ±1 {tab['+-1_pct']:.1f}% ±2..3 {tab['+-2..3_pct']:.1f}% | "
            f"golomb {tab['golomb_bits_per_weight']:.2f} b/w"
        )
    return "\n".join(lines)
