from .adamw import AdamW, AdamWState, cosine_schedule, global_norm

__all__ = ["AdamW", "AdamWState", "cosine_schedule", "global_norm"]
