"""AdamW with bf16 params + f32 moments (ZeRO-style: moments inherit the
params' FSDP sharding), global-norm clipping, and cosine/linear schedules.

Self-contained (no optax dependency in this environment)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # i32 scalar
    mu: Any  # f32 tree
    nu: Any  # f32 tree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return jnp.asarray(self.lr(step), jnp.float32)
        return jnp.asarray(self.lr, jnp.float32)

    def update(
        self, grads: Any, state: AdamWState, params: Any
    ) -> Tuple[Any, AdamWState, jax.Array]:
        """Returns (new_params, new_state, grad_norm)."""
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(g32)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
            g32 = jax.tree.map(lambda g: g * scale, g32)
        step = state.step + 1
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            m = self.b1 * m + (1.0 - self.b1) * g
            v = self.b2 * v + (1.0 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, params, g32, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr
