"""PVQ gradient compression for cross-pod data parallelism (beyond-paper,
directly built from the paper's machinery).

Motivation: on a multi-pod mesh the gradient all-reduce over the ``pod`` axis
crosses the slow inter-pod links (DCN/ICI-lite).  Gradients are near-Laplacian
— exactly PVQ's sweet spot — so each pod PVQ-encodes its local gradient in
groups of 256 (int8 pulses + one f32 rho per group ≈ 1.12 bytes/value vs 4),
all-gathers the *codes* across pods, decodes and averages.  Error feedback
(Seide et al.; Karimireddy et al. EF-SGD) keeps the quantization residual in
a local accumulator so compression error does not bias convergence.

Two entry points:
  * ``compress_decompress(g, cfg)``      — the quantization channel (pure);
  * ``make_ef_compressor(cfg)``          — stateful error-feedback transform
        (grads, ef_state) -> (decoded grads, new ef_state)
  * ``cross_pod_mean(grads, axis='pod')`` — shard_map-ready compressed
        all-reduce: encode local, all_gather codes over the pod axis, decode
        + mean (falls back to identity when the axis is absent).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.packed import is_packed
from repro.core.pvq import pvq_encode_grouped
from repro.kernels import ops as kernel_ops


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    group: int = 256
    n_over_k: float = 2.0  # K = group/2 pulses per group
    scale_mode: str = "ls"
    min_size: int = 1024  # leaves smaller than this pass through uncompressed

    @property
    def k(self) -> int:
        return max(int(round(self.group / self.n_over_k)), 1)

    def bytes_per_value(self) -> float:
        # int8 pulse + f32 scale amortized over the group
        return 1.0 + 4.0 / self.group


def _encode_grouped(flat: jax.Array, cfg: CompressionConfig):
    """(pulses i32 (G, group), rho f32 (G,)) via the kernel dispatch layer.

    The ``ls`` scale mode rides the sorted O(N log N + ΔK) encoder behind
    ``kernels.ops`` (Pallas on TPU, jnp fast path elsewhere); other scale
    modes fall back to the exact core encoder.
    """
    if cfg.scale_mode == "ls":
        return kernel_ops.pvq_encode_grouped_fast(flat, cfg.group, cfg.k)
    code = pvq_encode_grouped(flat, cfg.group, cfg.k, cfg.scale_mode)
    return code.pulses, code.scale


def compress_decompress(g: jax.Array, cfg: CompressionConfig) -> jax.Array:
    """Quantization channel Q(g): PVQ encode+decode (per-leaf, grouped).

    ``PackedPVQ`` leaves pass through unchanged: they are *already* the
    quantization channel's output (frozen packed params carry no gradient;
    apply explicit updates with ``repro.core.packed.packed_update``).
    """
    if is_packed(g):
        return g
    flat = g.reshape(-1).astype(jnp.float32)
    if flat.size < cfg.min_size:
        return g
    pulses, scale = _encode_grouped(flat, cfg)
    deq = (scale[:, None] * pulses.astype(jnp.float32)).reshape(-1)[: flat.size]
    return deq.reshape(g.shape).astype(g.dtype)


def make_ef_compressor(cfg: CompressionConfig):
    """Error-feedback wrapper:  decoded = Q(g + e);  e' = g + e - decoded.

    ``PackedPVQ`` leaves in the grad tree (frozen packed params under a
    mixed fine-tune) carry a zero-size EF state and pass through untouched.
    """

    def init(grads: Any) -> Any:
        return jax.tree.map(
            lambda g: g if is_packed(g) else jnp.zeros(g.shape, jnp.float32),
            grads,
            is_leaf=is_packed,
        )

    def apply(grads: Any, ef: Any) -> Tuple[Any, Any]:
        def one(g, e):
            if is_packed(g):
                return g, e  # frozen: no update, EF state untouched
            corrected = g.astype(jnp.float32) + e
            q = compress_decompress(corrected, cfg)
            return q.astype(g.dtype), corrected - q.astype(jnp.float32)

        out = jax.tree.map(one, grads, ef, is_leaf=is_packed)
        is_pair = lambda t: isinstance(t, tuple)
        decoded = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
        new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
        return decoded, new_ef

    return init, apply


def cross_pod_mean(grads: Any, cfg: CompressionConfig, axis: str = "pod") -> Any:
    """Compressed mean over a named mesh axis (call inside shard_map).

    Each participant encodes its local gradient; int8 pulses + f32 scales are
    all-gathered (≈1.12 B/value on the wire instead of 4); everyone decodes
    and averages.  Exact-mean property for K -> inf is covered by tests.
    """

    def one(g):
        if is_packed(g):
            return g  # frozen packed artifact: replicated, nothing to reduce
        flat = g.reshape(-1).astype(jnp.float32)
        if flat.size < cfg.min_size:
            return jax.lax.pmean(g, axis)
        pulses_i32, scales = _encode_grouped(flat, cfg)
        pulses = kernel_ops.pulses_to_int8(pulses_i32)  # (G, group) wire format
        scales = scales.astype(jnp.float32)  # (G,)
        all_pulses = jax.lax.all_gather(pulses, axis)  # (P, G, group)
        all_scales = jax.lax.all_gather(scales, axis)  # (P, G)
        deq = all_pulses.astype(jnp.float32) * all_scales[..., None]
        mean = jnp.mean(deq, axis=0).reshape(-1)[: flat.size]
        return mean.reshape(g.shape).astype(g.dtype)

    return jax.tree.map(one, grads, is_leaf=is_packed)


def wire_bytes(grads: Any, cfg: CompressionConfig) -> Tuple[int, int]:
    """(compressed, uncompressed f32) bytes per all-reduce participant."""
    comp = 0
    raw = 0
    for g in jax.tree.leaves(grads, is_leaf=is_packed):
        if is_packed(g):  # frozen packed leaves never cross the wire
            continue
        n = int(g.size)
        raw += 4 * n
        if n < cfg.min_size:
            comp += 4 * n
        else:
            import math

            groups = math.ceil(n / cfg.group)
            comp += groups * cfg.group + 4 * groups
    return comp, raw
