"""Fault tolerance + elasticity for the training loop.

Production posture (1000+ nodes):
  * periodic async checkpointing with committed-step semantics
    (repro.checkpoint) — a failed host can never corrupt restore state;
  * failure handling: any step exception -> restore latest committed step,
    rebuild the loader at that step (deterministic stream), continue;
  * straggler mitigation: per-step wall-time EWMA; a step slower than
    ``straggler_factor``x the median flags the host for eviction — on a real
    cluster the controller drains it; here the policy object records the
    decision (tested via injected delays);
  * elastic re-mesh: on shrink (lost pod / data rank), choose the largest
    surviving mesh that divides the global batch and re-shard from the last
    checkpoint (divisibility checked up front for every fallback size).

The runner is deliberately framework-level (works for any StepBundle); the
failure injector in tests exercises the restore path end-to-end on CPU.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import Checkpointer


@dataclasses.dataclass
class StragglerPolicy:
    window: int = 32
    factor: float = 3.0  # flag if step_time > factor * rolling median

    def __post_init__(self):
        self.times: deque = deque(maxlen=self.window)
        self.flagged: List[Tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        med = float(np.median(self.times)) if len(self.times) >= 8 else None
        self.times.append(dt)
        if med is not None and dt > self.factor * med:
            self.flagged.append((step, dt, med))
            return True
        return False


@dataclasses.dataclass
class ElasticPlan:
    """Valid fallback meshes, largest first; all must divide the batch."""

    global_batch: int
    candidates: Tuple[Tuple[int, int], ...] = ((16, 16), (8, 16), (4, 16), (2, 16), (1, 16))

    def pick(self, surviving_chips: int) -> Optional[Tuple[int, int]]:
        for d, m in self.candidates:
            if d * m <= surviving_chips and self.global_batch % d == 0:
                return (d, m)
        return None


class TrainingRunner:
    """Wraps a jitted step with checkpoint/restore + failure recovery."""

    def __init__(
        self,
        step_fn: Callable,  # (state, batch) -> (state, metrics)
        state: Any,
        loader,  # TokenLoader-like: host/device batch per step (deterministic)
        checkpointer: Checkpointer,
        *,
        ckpt_every: int = 50,
        max_restores: int = 8,
        straggler: Optional[StragglerPolicy] = None,
    ):
        self.step_fn = step_fn
        self.state = state
        self.loader = loader
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.max_restores = max_restores
        self.straggler = straggler or StragglerPolicy()
        self.restores = 0
        self.history: List[Dict[str, float]] = []

    def resume_step(self) -> int:
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0
        self.state, step = self.ckpt.restore(self.state)
        return step + 1

    def run(self, n_steps: int, *, failure_injector: Optional[Callable[[int], None]] = None) -> int:
        step = self.resume_step()
        end = step + n_steps
        while step < end:
            try:
                t0 = time.time()
                if failure_injector is not None:
                    failure_injector(step)
                batch = self.loader.device_batch(step)
                self.state, metrics = self.step_fn(self.state, batch)
                dt = time.time() - t0
                slow = self.straggler.observe(step, dt)
                rec = {"step": step, "dt": dt, "straggler": slow}
                rec.update({k: float(v) for k, v in metrics.items()})
                self.history.append(rec)
                if self.ckpt_every and (step + 1) % self.ckpt_every == 0:
                    self.ckpt.save(step, self.state, block=False)
                step += 1
            except Exception:
                self.restores += 1
                if self.restores > self.max_restores:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    # nothing committed yet: restart the run from step 0 state
                    step = 0
                    continue
                self.state, restored = self.ckpt.restore(self.state)
                step = restored + 1
        self.ckpt.wait()
        self.ckpt.save(end - 1, self.state, block=True)
        return end
