"""Thin facade over :mod:`repro.runtime.telemetry`.

Call sites import this module and stay one attribute away from the
process registry::

    from repro.runtime import obs

    if obs.enabled():                      # hot paths guard first
        obs.counter("engine.decode_steps").inc()
        with obs.span("engine/decode_step", args={"active": n}):
            ...

Every accessor delegates to the module registry; when it is disabled
(the default) ``counter``/``gauge``/``histogram``/``span`` return the
shared :data:`~repro.runtime.telemetry.NOOP` singleton, so unguarded
cold-path calls still cost nothing but an attribute lookup.
"""

from __future__ import annotations

from typing import Dict, Optional

from . import telemetry

NOOP = telemetry.NOOP


def registry() -> telemetry.MetricsRegistry:
    return telemetry.get_registry()


def enabled() -> bool:
    return telemetry.get_registry().enabled


def set_enabled(on: bool) -> bool:
    """Enable/disable the process registry; returns the previous state."""
    return telemetry.set_enabled(on)


def counter(name: str, labels: Optional[Dict[str, str]] = None):
    return telemetry.get_registry().counter(name, labels)


def gauge(name: str, labels: Optional[Dict[str, str]] = None):
    return telemetry.get_registry().gauge(name, labels)


def histogram(name: str, labels: Optional[Dict[str, str]] = None):
    return telemetry.get_registry().histogram(name, labels)


def span(name: str, args: Optional[dict] = None):
    return telemetry.get_registry().span(name, args)


def trace_counter(name: str, value: float) -> None:
    telemetry.get_registry().trace_counter(name, value)


def event(name: str, args: Optional[dict] = None) -> None:
    telemetry.get_registry().event(name, args)


def write(outdir: str) -> Dict[str, str]:
    """Export ``metrics.jsonl`` + ``trace.json`` into ``outdir``."""
    return telemetry.get_registry().write(outdir)
