"""Process-wide observability: metrics registry + span tracing.

One registry serves the whole serve stack — the continuous-batching
engine, the fixed-batch ``serve.py`` legs, the kernel autotuner, the
``.pvqz`` artifact codecs, and the quantization-quality probes — so a
regression in speed *or* numerics shows up as data in one place instead
of ad-hoc ``perf_counter`` prints scattered per layer.

Instruments
-----------
* :class:`Counter` — monotonically increasing value (``inc``/``add``).
* :class:`Gauge` — last-value instrument with min/max/n tracking.
* :class:`Histogram` — value distribution with **exact** percentiles:
  every recorded value is kept verbatim up to ``max_samples`` and
  ``percentile(q)`` is ``np.percentile`` over the stored values; beyond
  the cap a deterministic reservoir keeps a uniform sample and the
  snapshot flags ``exact: false``.  This is THE percentile type — the
  engine report and the benchmark latency helpers all route through it
  (no more inline ``pct`` copies).

All three are keyed by ``(name, labels)`` in the registry; labels are an
optional flat ``{str: str}`` dict (e.g. ``{"codec": "golomb"}``).

Tracing
-------
``registry.span(name, args=...)`` is a context manager recording a
Chrome trace-event *complete* event (``ph: "X"``) with microsecond
timestamps; ``trace_counter(name, value)`` records a counter-track event
(``ph: "C"``) that perfetto renders as a time series (the engine emits
queue-depth and page-pool-free this way every decode step).
``export_chrome_trace`` writes a ``trace.json`` loadable in
https://ui.perfetto.dev (open the file directly) or ``chrome://tracing``.

Hot-path contract
-----------------
A **disabled** registry is a true no-op: ``counter()``/``gauge()``/
``histogram()``/``span()`` all return the shared :data:`NOOP` singleton
and allocate nothing.  Call sites on hot loops additionally guard with
``obs.enabled()`` so not even argument tuples are built.  Nothing in
this module is ever traced into a jit body — instrumentation lives in
host-side driver loops, and the eager-only quantization probes bail out
when handed a tracer.

Export
------
* ``export_metrics_jsonl(path)`` — one JSON object per line, schema
  ``repro-metrics-v1`` (see :data:`METRICS_SCHEMA`); round-trips through
  :func:`read_metrics_jsonl` / :func:`validate_metrics_jsonl`.
* ``export_chrome_trace(path)`` — ``{"traceEvents": [...]}`` JSON;
  validated by :func:`validate_chrome_trace`.
* ``write(outdir)`` — both files into a directory (the
  ``serve --metrics-out DIR`` exit hook).

``python -m repro.runtime.telemetry --validate DIR`` runs both
validators (the CI schema gate); ``--require-engine`` additionally
asserts the engine spans/gauges/autotune counters/quant probes the
serve smoke must emit.
"""

from __future__ import annotations

import json
import os
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

METRICS_SCHEMA = "repro-metrics-v1"

#: snapshot keys every histogram line carries (the JSONL schema contract)
HISTOGRAM_FIELDS = ("count", "sum", "min", "max", "p50", "p90", "p99", "exact")


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value = 0.0

    def inc(self, n: float = 1) -> None:
        self.value += n

    add = inc

    def snapshot(self) -> Dict[str, Any]:
        v = self.value
        return {
            "kind": "counter", "name": self.name, "labels": self.labels,
            "value": int(v) if float(v).is_integer() else v,
        }


class Gauge:
    """Last-value instrument (plus min/max/n over the run)."""

    __slots__ = ("name", "labels", "value", "min", "max", "n")

    def __init__(self, name: str, labels: Optional[Dict[str, str]] = None):
        self.name = name
        self.labels = dict(labels or {})
        self.value: Optional[float] = None
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.n = 0

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        self.n += 1

    def snapshot(self) -> Dict[str, Any]:
        return {
            "kind": "gauge", "name": self.name, "labels": self.labels,
            "value": self.value, "min": self.min, "max": self.max, "n": self.n,
        }


class Histogram:
    """Distribution with exact reservoir percentiles.

    Values are stored verbatim up to ``max_samples``; past the cap a
    deterministic reservoir (seeded RNG, so runs reproduce) keeps a
    uniform sample and ``exact`` flips to False.  ``count``/``sum``/
    ``min``/``max`` stay exact regardless.
    """

    __slots__ = ("name", "labels", "max_samples", "count", "total",
                 "min", "max", "_values", "_rng")

    def __init__(
        self, name: str = "", labels: Optional[Dict[str, str]] = None,
        *, max_samples: int = 65536,
    ):
        self.name = name
        self.labels = dict(labels or {})
        self.max_samples = int(max_samples)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._values: List[float] = []
        self._rng = random.Random(0)

    @classmethod
    def from_values(cls, values, name: str = "") -> "Histogram":
        h = cls(name)
        for v in values:
            h.record(v)
        return h

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if len(self._values) < self.max_samples:
            self._values.append(v)
        else:  # reservoir sampling: uniform over everything seen so far
            j = self._rng.randrange(self.count)
            if j < self.max_samples:
                self._values[j] = v

    def record_many(self, values) -> None:
        for v in values:
            self.record(v)

    @property
    def exact(self) -> bool:
        return self.count == len(self._values)

    def percentile(self, q: float) -> float:
        """Exact percentile over the stored values (0.0 when empty)."""
        if not self._values:
            return 0.0
        return float(np.percentile(np.asarray(self._values), q))

    def snapshot(self) -> Dict[str, Any]:
        return {
            "kind": "histogram", "name": self.name, "labels": self.labels,
            "count": self.count, "sum": self.total,
            "min": self.min, "max": self.max,
            "p50": self.percentile(50), "p90": self.percentile(90),
            "p99": self.percentile(99), "exact": self.exact,
        }


class _Noop:
    """Shared do-nothing instrument AND context manager returned by a
    disabled registry — one singleton, so the disabled path never
    allocates."""

    __slots__ = ()

    def inc(self, n: float = 1) -> None:
        pass

    add = inc

    def set(self, v: float) -> None:
        pass

    def record(self, v: float) -> None:
        pass

    def record_many(self, values) -> None:
        pass

    def __enter__(self) -> "_Noop":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NOOP = _Noop()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


class _Span:
    """Context manager recording one Chrome complete event (``ph: X``)."""

    __slots__ = ("_reg", "name", "args", "_t0")

    def __init__(self, reg: "MetricsRegistry", name: str, args: Optional[dict]):
        self._reg = reg
        self.name = name
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._reg._record_span(self.name, self._t0, t1, self.args)
        return False


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def _key(name: str, labels: Optional[Dict[str, str]]) -> Tuple:
    if not labels:
        return (name,)
    return (name,) + tuple(sorted(labels.items()))


class MetricsRegistry:
    """Process-wide metric + trace store.

    ``enabled=False`` (the default for the module registry) turns every
    accessor into a :data:`NOOP` return — zero instrument allocation,
    zero recording, nothing on the decode hot path.
    """

    def __init__(self, *, enabled: bool = False):
        self.enabled = bool(enabled)
        self._lock = threading.Lock()
        self._counters: Dict[Tuple, Counter] = {}
        self._gauges: Dict[Tuple, Gauge] = {}
        self._histograms: Dict[Tuple, Histogram] = {}
        self._events: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()
        self._pid = os.getpid()

    # ------------------------------------------------------------ lifecycle

    def clear(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._events.clear()
            self._t0 = time.perf_counter()

    # ----------------------------------------------------------- instruments

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None):
        if not self.enabled:
            return NOOP
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(k, Counter(name, labels))
        return c

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None):
        if not self.enabled:
            return NOOP
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(k, Gauge(name, labels))
        return g

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None):
        if not self.enabled:
            return NOOP
        k = _key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(k, Histogram(name, labels))
        return h

    # --------------------------------------------------------------- tracing

    def span(self, name: str, args: Optional[dict] = None):
        if not self.enabled:
            return NOOP
        return _Span(self, name, args)

    def _record_span(self, name: str, t0: float, t1: float, args) -> None:
        ev = {
            "name": name, "ph": "X", "pid": self._pid,
            "tid": threading.get_ident() & 0xFFFF,
            "ts": round(1e6 * (t0 - self._t0), 1),
            "dur": round(1e6 * (t1 - t0), 1),
        }
        if args:
            ev["args"] = dict(args)
        with self._lock:
            self._events.append(ev)

    def trace_counter(self, name: str, value: float) -> None:
        """Counter-track event (``ph: C``): a per-step time series that
        perfetto renders as its own track (queue depth, free pages, ...)."""
        if not self.enabled:
            return
        ev = {
            "name": name, "ph": "C", "pid": self._pid,
            "ts": round(1e6 * (time.perf_counter() - self._t0), 1),
            "args": {"value": float(value)},
        }
        with self._lock:
            self._events.append(ev)

    def event(self, name: str, args: Optional[dict] = None) -> None:
        """Instant event (``ph: i``) — admissions, evictions, retires."""
        if not self.enabled:
            return
        ev = {
            "name": name, "ph": "i", "s": "p", "pid": self._pid,
            "tid": threading.get_ident() & 0xFFFF,
            "ts": round(1e6 * (time.perf_counter() - self._t0), 1),
        }
        if args:
            ev["args"] = dict(args)
        with self._lock:
            self._events.append(ev)

    # ---------------------------------------------------------------- export

    def snapshot(self) -> List[Dict[str, Any]]:
        """All instruments as schema-stamped dicts (one JSONL line each)."""
        with self._lock:
            instruments = (
                list(self._counters.values())
                + list(self._gauges.values())
                + list(self._histograms.values())
            )
        out = []
        for inst in instruments:
            rec = {"schema": METRICS_SCHEMA}
            rec.update(inst.snapshot())
            out.append(rec)
        return out

    def chrome_trace(self) -> Dict[str, Any]:
        with self._lock:
            events = list(self._events)
        return {"displayTimeUnit": "ms", "traceEvents": events}

    def export_metrics_jsonl(self, path: str) -> str:
        with open(path, "w") as f:
            for rec in self.snapshot():
                f.write(json.dumps(rec) + "\n")
        return str(path)

    def export_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return str(path)

    def write(self, outdir: str) -> Dict[str, str]:
        """Write ``metrics.jsonl`` + ``trace.json`` into ``outdir``."""
        os.makedirs(outdir, exist_ok=True)
        return {
            "metrics": self.export_metrics_jsonl(
                os.path.join(outdir, "metrics.jsonl")
            ),
            "trace": self.export_chrome_trace(
                os.path.join(outdir, "trace.json")
            ),
        }


# ---------------------------------------------------------------------------
# module registry (the `obs` facade delegates here)
# ---------------------------------------------------------------------------

_REGISTRY = MetricsRegistry(enabled=False)


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_enabled(on: bool) -> bool:
    """Flip the module registry; returns the previous state."""
    prev = _REGISTRY.enabled
    _REGISTRY.enabled = bool(on)
    return prev


# ---------------------------------------------------------------------------
# shared helpers (benchmarks, probes)
# ---------------------------------------------------------------------------


def time_call_us(fn: Callable[[], Any], reps: int = 5) -> float:
    """us/call of a jax-producing thunk: one warmup call (trace + compile
    outside the timed region), then ``reps`` timed calls with a final
    ``block_until_ready``.  The shared timing helper the benchmark files
    use instead of hand-rolled copies."""
    import jax

    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return 1e6 * (time.perf_counter() - t0) / reps


def snr_db(ref: np.ndarray, approx: np.ndarray, cap: float = 99.0) -> float:
    """Reconstruction signal-to-noise ratio in dB (capped for exact hits)."""
    ref = np.asarray(ref, np.float64).ravel()
    err = np.asarray(approx, np.float64).ravel() - ref
    sig = float(np.sum(ref * ref))
    noise = float(np.sum(err * err))
    if noise <= 0.0:
        return cap
    if sig <= 0.0:
        return 0.0
    return min(10.0 * np.log10(sig / noise), cap)


def bench_payload(schema: str, rows: List[dict], *, backend: Optional[str] = None) -> dict:
    """The one BENCH_*.json wrapper every benchmark file shares."""
    if backend is None:
        try:
            import jax

            backend = jax.default_backend()
        except Exception:
            backend = "unknown"
    return {"schema": schema, "backend": backend, "rows": rows}


# ---------------------------------------------------------------------------
# validation (tests + the CI schema gate)
# ---------------------------------------------------------------------------


def read_metrics_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def validate_metrics_jsonl(path: str) -> List[Dict[str, Any]]:
    """Schema-check a metrics JSONL file; returns the records or raises."""
    recs = read_metrics_jsonl(path)
    for i, rec in enumerate(recs):
        where = f"{path}:{i + 1}"
        if rec.get("schema") != METRICS_SCHEMA:
            raise ValueError(f"{where}: bad schema {rec.get('schema')!r}")
        kind = rec.get("kind")
        if kind not in ("counter", "gauge", "histogram"):
            raise ValueError(f"{where}: bad kind {kind!r}")
        if not isinstance(rec.get("name"), str) or not rec["name"]:
            raise ValueError(f"{where}: missing metric name")
        if not isinstance(rec.get("labels"), dict):
            raise ValueError(f"{where}: labels must be a dict")
        if kind == "counter" and not isinstance(rec.get("value"), (int, float)):
            raise ValueError(f"{where}: counter needs a numeric value")
        if kind == "histogram":
            for field in HISTOGRAM_FIELDS:
                if field not in rec:
                    raise ValueError(f"{where}: histogram missing {field!r}")
    return recs


def validate_chrome_trace(path: str) -> List[Dict[str, Any]]:
    """Check a trace file is perfetto-loadable trace-event JSON."""
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents must be a list")
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev.get("name"), str):
            raise ValueError(f"{where}: missing event name")
        if ev.get("ph") not in ("X", "C", "i", "B", "E", "M"):
            raise ValueError(f"{where}: bad phase {ev.get('ph')!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"{where}: missing ts")
        if ev["ph"] == "X" and not isinstance(ev.get("dur"), (int, float)):
            raise ValueError(f"{where}: complete event missing dur")
    return events


#: names the engine serve smoke must cover (ISSUE-8 acceptance: engine
#: spans, page-pool/queue gauges, autotune counters, quant-quality probes)
ENGINE_REQUIRED_SPANS = ("engine/prefill", "engine/graft", "engine/decode_step")
ENGINE_REQUIRED_METRICS = (
    "engine.page_pool_free", "engine.queue_depth",
    "autotune.lookups", "quant.weight_snr_db", "quant.kv_snr_db",
)


def validate_dir(outdir: str, *, require_engine: bool = False) -> Dict[str, int]:
    """Validate ``metrics.jsonl`` + ``trace.json`` in ``outdir``."""
    recs = validate_metrics_jsonl(os.path.join(outdir, "metrics.jsonl"))
    events = validate_chrome_trace(os.path.join(outdir, "trace.json"))
    if require_engine:
        names = {r["name"] for r in recs}
        missing = [m for m in ENGINE_REQUIRED_METRICS if m not in names]
        span_names = {e["name"] for e in events}
        missing += [s for s in ENGINE_REQUIRED_SPANS if s not in span_names]
        if missing:
            raise ValueError(
                f"{outdir}: engine telemetry incomplete, missing {missing}"
            )
    return {"metrics": len(recs), "trace_events": len(events)}


def _main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description="validate telemetry exports")
    ap.add_argument("--validate", metavar="DIR", required=True,
                    help="directory holding metrics.jsonl + trace.json")
    ap.add_argument("--require-engine", action="store_true",
                    help="additionally require the engine serve-smoke "
                    "span/metric coverage")
    args = ap.parse_args()
    counts = validate_dir(args.validate, require_engine=args.require_engine)
    print(json.dumps({"ok": True, "dir": args.validate, **counts}))
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
