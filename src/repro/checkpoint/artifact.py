"""The ``.pvqz`` single-file compressed artifact (paper §VI, end to end).

``PackedPVQ`` (PR 2) made the PVQ code the in-memory deployment format —
int8 pulses + f32 group scales, 4–8 bits/weight.  This module is the at-rest
and over-the-wire half of the story: the pulse streams are entropy-coded
(``repro.core.bitstream``) down to the paper's ~1.4–2 bits/weight, packed
into one seekable container, and decoded leaf-by-leaf straight back into
``PackedPVQ`` — bit-exact pulses and scales, no re-encode, peak memory
bounded by the largest single leaf.

File layout (all integers little-endian)::

    [magic b"PVQZ" | u8 version | 3 reserved bytes]
    [leaf blob 0][leaf blob 1]...          # written sequentially
    [TOC: json, utf-8]
    [footer: u64 toc_offset | u64 toc_len | magic b"ZPVQ"]

The TOC carries one record per leaf: path, kind (``packed`` | ``raw``),
blob offset/size, CRC32, and for packed leaves the full ``PackedPVQ``
static metadata plus the pulse-codec info and a separate scales section
(raw ``<f4``, CRC'd).  Readers parse the footer, then seek per leaf.

Pulse streams cover only the *logical* weight region — the structural
group-padding rows of the matmul layout (and the tail padding of the flat
layout) are dropped on encode and reconstructed as zeros on decode, so
padding never costs wire bits.  The fixed-length enumeration codec is the
exception: it codes whole (G, group) rows, padded groups included.

Codec selection (``codec="auto"``) follows the paper's §VI practicality
order, but *measured*: price every candidate with the exact size models
(``bitstream.measured_bits``) and take the cheapest in bits — enumeration
runs on the vectorized limb ladder, so it is default-eligible on every
leaf whose count tables fit memory (no bigint work budget).
"""

from __future__ import annotations

import json
import os
import struct
import time
import zlib
from pathlib import Path
from typing import Any, Dict, Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import bitstream
from repro.core.bitstream import (  # noqa: F401  (re-exported API)
    PULSE_CODECS,
    choose_codec,
)
from repro.core.packed import PackedPVQ, is_packed, pulse_groups, pulse_stream

MAGIC = b"PVQZ"
END_MAGIC = b"ZPVQ"
VERSION = 1
_FOOTER = struct.Struct("<QQ4s")


def _note_codec(op: str, codec: str, n_symbols: int, seconds: float) -> None:
    """Per-codec entropy-coding throughput metrics (``op`` is ``encode`` or
    ``decode``; ``n_symbols`` = int8 pulse symbols moved).  No-op unless the
    telemetry registry is enabled."""
    from repro.runtime import obs

    if not obs.enabled():
        return
    labels = {"codec": codec}
    obs.counter(f"artifact.{op}_leaves", labels).inc()
    obs.counter(f"artifact.{op}_symbols", labels).add(n_symbols)
    obs.counter(f"artifact.{op}_s", labels).add(seconds)
    if seconds > 0:
        obs.histogram(f"artifact.{op}_mb_s", labels).record(
            n_symbols / seconds / 1e6
        )


# ---------------------------------------------------------------------------
# pulse layout <-> stream transforms
# ---------------------------------------------------------------------------


def _logical_numel(pk: PackedPVQ) -> int:
    lead = pk.pulses.shape[: pk.pulses.ndim - 2]
    return int(np.prod(lead, initial=1)) * int(np.prod(pk.shape))


def _unstream(
    flat: np.ndarray, layout: str, pulse_shape: Tuple[int, ...], shape: Tuple[int, ...]
) -> np.ndarray:
    """Inverse of :func:`_stream_view`: rebuild the physical int8 tensor,
    structural padding re-materialized as zeros."""
    if layout == "matmul":
        *lead, k_pad, n = pulse_shape
        d_in = int(shape[-2])
        arr = np.asarray(flat, np.int64).reshape(*lead, n, d_in)
        out = np.zeros((*lead, n, k_pad), np.int64)
        out[..., :d_in] = arr
        return np.swapaxes(out, -1, -2).astype(np.int8)
    *lead, g, group = pulse_shape
    numel = int(np.prod(shape))
    out = np.zeros((*lead, g * group), np.int64)
    out[..., :numel] = np.asarray(flat, np.int64).reshape(*lead, numel)
    return out.reshape(*pulse_shape).astype(np.int8)


def _groups_to_physical(
    groups: np.ndarray, layout: str, pulse_shape: Tuple[int, ...]
) -> np.ndarray:
    if layout == "matmul":
        *lead, k_pad, n = pulse_shape
        return np.swapaxes(
            groups.reshape(*lead, n, k_pad), -1, -2
        ).astype(np.int8)
    return groups.reshape(*pulse_shape).astype(np.int8)


# ---------------------------------------------------------------------------
# writer
# ---------------------------------------------------------------------------


def _flatten(tree: Any) -> Dict[str, Any]:
    from .checkpointer import _flatten as ck_flatten

    return ck_flatten(tree)


def write_pvqz(
    path: str | Path,
    params: Any,
    *,
    codec: str = "auto",
    chunk: Optional[int] = None,
    meta: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Encode a (mixed) parameter pytree into a ``.pvqz`` file.

    ``PackedPVQ`` leaves get entropy-coded pulse streams + raw f32 scales;
    every other leaf is stored raw (bf16 upcast to f32, like the
    checkpointer).  ``codec`` is one of :data:`PULSE_CODECS` or ``"auto"``
    (per-leaf cheapest by measured bits).  Returns the compression report:
    per-leaf codec + bits/weight and artifact-level totals.

    Writes go through a tmp file + atomic rename: a mid-write crash (or an
    encode error) can never truncate or corrupt an existing good artifact,
    and a failed write leaves no tmp behind.
    """
    path = Path(path)
    tmp_path = path.with_name(f".{path.name}.tmp{os.getpid()}")
    try:
        report = _write_pvqz_file(
            tmp_path, params, codec=codec, chunk=chunk, meta=meta,
        )
    except BaseException:
        try:
            os.unlink(tmp_path)
        except OSError:
            pass
        raise
    os.replace(tmp_path, path)
    report["path"] = str(path)
    return report


def _write_pvqz_file(
    tmp_path: Path,
    params: Any,
    *,
    codec: str,
    chunk: Optional[int],
    meta: Optional[Dict[str, Any]],
) -> Dict[str, Any]:
    flat = _flatten(params)
    report_leaves: Dict[str, Dict[str, Any]] = {}
    toc: Dict[str, Any] = {"version": VERSION, "meta": meta or {}, "leaves": []}
    packed_payload_bits = 0.0
    packed_scale_bits = 0.0
    packed_numel = 0
    replaced_dense_bytes = 0
    with open(tmp_path, "wb") as f:
        f.write(MAGIC + bytes([VERSION]) + b"\0\0\0")
        for key, leaf in flat.items():
            rec: Dict[str, Any] = {"path": key}
            if is_packed(leaf):
                pulses = np.asarray(leaf.pulses, np.int8)
                stream = pulse_stream(leaf)
                groups = pulse_groups(leaf)
                if codec == "auto":
                    leaf_codec, sizes = choose_codec(stream, groups, leaf.k)
                else:
                    leaf_codec = codec
                    _, sizes = choose_codec(stream, groups, leaf.k)
                symbols = groups if leaf_codec == "enum" else stream
                t_enc = time.perf_counter()
                blob, info = bitstream.encode_pulses(
                    symbols, leaf_codec, k_max=leaf.k, chunk=chunk
                )
                enc_s = time.perf_counter() - t_enc
                _note_codec("encode", leaf_codec, int(np.asarray(symbols).size), enc_s)
                scales = np.ascontiguousarray(
                    np.asarray(leaf.scales, np.float32), dtype="<f4"
                )
                sblob = scales.tobytes()
                rec.update(
                    kind="packed",
                    offset=f.tell(),
                    nbytes=len(blob),
                    crc32=zlib.crc32(blob),
                    pulse_info=info,
                    group=int(leaf.group),
                    k=int(leaf.k),
                    shape=list(leaf.shape),
                    dtype=leaf.dtype,
                    layout=leaf.layout,
                    scale_mode=leaf.scale_mode,
                    pulse_shape=list(pulses.shape),
                    scales_shape=list(scales.shape),
                    # leading stack axes (scan repeats, MoE expert axis):
                    # per-stack-entry group geometry is (shape[-2] rows ->
                    # pulse_shape[-2] group-padded rows) x shape[-1] columns
                    stack=list(pulses.shape[: pulses.ndim - 2]),
                )
                f.write(blob)
                rec["scales_offset"] = f.tell()
                rec["scales_nbytes"] = len(sblob)
                rec["scales_crc32"] = zlib.crc32(sblob)
                f.write(sblob)
                numel = _logical_numel(leaf)
                payload_bits = info["nbits"]
                scale_bits = 32 * scales.size
                packed_payload_bits += payload_bits
                packed_scale_bits += scale_bits
                packed_numel += numel
                replaced_dense_bytes += leaf.nbytes_dense
                report_leaves[key] = {
                    "codec": leaf_codec,
                    "numel": numel,
                    "pulse_bits": int(payload_bits),
                    "bits_per_weight": round(
                        (payload_bits + scale_bits) / max(numel, 1), 4
                    ),
                    "candidate_bits_per_weight": {
                        c: round(b / max(numel, 1), 4) for c, b in sizes.items()
                    },
                    "encode_s": round(enc_s, 4),
                    "encode_mb_s": round(
                        int(np.asarray(symbols).size) / max(enc_s, 1e-9) / 1e6, 3
                    ),
                }
            else:
                arr = np.asarray(leaf)
                orig_dtype = str(arr.dtype)
                stored_dtype = orig_dtype
                if stored_dtype == "bfloat16":
                    arr = arr.astype(np.float32)
                    stored_dtype = "float32"
                blob = np.ascontiguousarray(arr).tobytes()
                rec.update(
                    kind="raw",
                    offset=f.tell(),
                    nbytes=len(blob),
                    crc32=zlib.crc32(blob),
                    shape=list(arr.shape),
                    dtype=orig_dtype,
                    stored_dtype=stored_dtype,
                )
                f.write(blob)
                report_leaves[key] = {"codec": "raw", "nbytes": len(blob)}
            toc["leaves"].append(rec)
        toc_offset = f.tell()
        toc_blob = json.dumps(toc).encode()
        f.write(toc_blob)
        f.write(_FOOTER.pack(toc_offset, len(toc_blob), END_MAGIC))
        file_bytes = f.tell()
    return {
        "file_bytes": file_bytes,
        "packed_numel": packed_numel,
        "packed_payload_bits": int(packed_payload_bits),
        "packed_scale_bits": int(packed_scale_bits),
        "bits_per_weight": round(
            (packed_payload_bits + packed_scale_bits) / max(packed_numel, 1), 4
        ),
        "replaced_dense_bytes": replaced_dense_bytes,
        "compression_vs_dense": round(
            8.0
            * replaced_dense_bytes
            / max(packed_payload_bits + packed_scale_bits, 1.0),
            2,
        ),
        "leaves": report_leaves,
    }


# ---------------------------------------------------------------------------
# reader
# ---------------------------------------------------------------------------


def read_toc(path: str | Path) -> Dict[str, Any]:
    with open(path, "rb") as f:
        head = f.read(8)
        if head[:4] != MAGIC:
            raise ValueError(f"{path}: not a .pvqz file (bad magic {head[:4]!r})")
        if head[4] != VERSION:
            raise ValueError(f"{path}: unsupported .pvqz version {head[4]}")
        f.seek(-_FOOTER.size, 2)
        toc_offset, toc_len, end = _FOOTER.unpack(f.read(_FOOTER.size))
        if end != END_MAGIC:
            raise ValueError(f"{path}: truncated .pvqz (bad end magic)")
        f.seek(toc_offset)
        return json.loads(f.read(toc_len).decode())


def _read_checked(f, offset: int, nbytes: int, crc: int, what: str) -> bytes:
    f.seek(offset)
    blob = f.read(nbytes)
    if len(blob) != nbytes or zlib.crc32(blob) != crc:
        raise ValueError(f"CRC mismatch in {what} (corrupt .pvqz)")
    return blob


def _read_packed_blobs(f, rec: Dict[str, Any]) -> Tuple[bytes, bytes]:
    """File half of the packed-leaf decode: seeks + CRC checks, main thread."""
    blob = _read_checked(
        f, rec["offset"], rec["nbytes"], rec["crc32"], f"pulses of {rec['path']}"
    )
    sblob = _read_checked(
        f,
        rec["scales_offset"],
        rec["scales_nbytes"],
        rec["scales_crc32"],
        f"scales of {rec['path']}",
    )
    return blob, sblob


def _decode_packed_np(
    blob: bytes, sblob: bytes, rec: Dict[str, Any]
) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy half of the packed-leaf decode (no jax, no file handle) —
    safe to run on the prefetch worker thread."""
    info = rec["pulse_info"]
    pulse_shape = tuple(rec["pulse_shape"])
    t_dec = time.perf_counter()
    if info["codec"] == "enum":
        groups = bitstream.decode_pulses(blob, info, rec["group"])
        pulses = _groups_to_physical(groups, rec["layout"], pulse_shape)
    else:
        flat = bitstream.decode_pulses(blob, info)
        pulses = _unstream(flat, rec["layout"], pulse_shape, tuple(rec["shape"]))
    _note_codec(
        "decode", info["codec"], int(pulses.size), time.perf_counter() - t_dec
    )
    scales = (
        np.frombuffer(sblob, "<f4").reshape(rec["scales_shape"]).astype(np.float32)
    )
    return pulses, scales


def _place_packed(rec: Dict[str, Any], pulses: np.ndarray, scales: np.ndarray) -> PackedPVQ:
    """Device-placement half: jnp conversion stays on the main thread."""
    return PackedPVQ(
        pulses=jnp.asarray(pulses),
        scales=jnp.asarray(scales),
        group=int(rec["group"]),
        k=int(rec["k"]),
        shape=tuple(rec["shape"]),
        dtype=rec["dtype"],
        layout=rec["layout"],
        scale_mode=rec["scale_mode"],
    )


def _decode_packed(f, rec: Dict[str, Any]) -> PackedPVQ:
    blob, sblob = _read_packed_blobs(f, rec)
    return _place_packed(rec, *_decode_packed_np(blob, sblob, rec))


def _decode_raw(f, rec: Dict[str, Any]) -> np.ndarray:
    blob = _read_checked(f, rec["offset"], rec["nbytes"], rec["crc32"], rec["path"])
    arr = np.frombuffer(blob, dtype=np.dtype(rec["stored_dtype"])).reshape(
        rec["shape"]
    )
    if rec["dtype"] != rec["stored_dtype"]:
        arr = np.asarray(jnp.asarray(arr).astype(rec["dtype"]))
    return arr


def iter_pvqz(path: str | Path, *, prefetch: bool = True) -> Iterator[Tuple[str, Any]]:
    """Stream (path_key, leaf) pairs, decoding ONE leaf at a time.

    Packed leaves come back as bit-exact ``PackedPVQ`` (identical pulses and
    scales to what was exported — no re-encode anywhere); raw leaves as
    numpy arrays.  Peak decode memory is bounded by the largest single leaf,
    never the whole artifact (the prefetch keeps at most one extra decoded
    leaf in flight).

    With ``prefetch`` (the default) the numpy entropy decode of the next
    leaf overlaps the device placement of the current one: a single worker
    thread runs :func:`_decode_packed_np` while the main thread does the
    file reads, CRC checks, and ``jnp.asarray`` placement.  Exceptions from
    the worker surface at the corresponding yield.
    """
    toc = read_toc(path)
    if not prefetch:
        with open(path, "rb") as f:
            for rec in toc["leaves"]:
                if rec["kind"] == "packed":
                    yield rec["path"], _decode_packed(f, rec)
                else:
                    yield rec["path"], _decode_raw(f, rec)
        return
    from concurrent.futures import Future, ThreadPoolExecutor

    with open(path, "rb") as f, ThreadPoolExecutor(
        max_workers=1, thread_name_prefix="pvqz-decode"
    ) as pool:
        pending: list[Tuple[Dict[str, Any], Any]] = []

        def emit(rec: Dict[str, Any], ready: Any) -> Tuple[str, Any]:
            if isinstance(ready, Future):
                return rec["path"], _place_packed(rec, *ready.result())
            return rec["path"], ready

        for rec in toc["leaves"]:
            if rec["kind"] == "packed":
                blob, sblob = _read_packed_blobs(f, rec)
                pending.append(
                    (rec, pool.submit(_decode_packed_np, blob, sblob, rec))
                )
            else:
                pending.append((rec, _decode_raw(f, rec)))
            while len(pending) > 1:  # keep exactly one decode in flight
                yield emit(*pending.pop(0))
        while pending:
            yield emit(*pending.pop(0))


def load_pvqz(path: str | Path, target: Optional[Any] = None) -> Any:
    """Load a ``.pvqz`` into a parameter pytree.

    With ``target`` (e.g. ``model.init(...)`` params), leaves are restored
    into its structure/dtypes — the serving entry point.  Without it, returns
    a nested dict keyed by the stored slash paths.
    """
    flat = dict(iter_pvqz(path))
    if target is not None:
        from .checkpointer import _unflatten_into

        return _unflatten_into(target, flat)
    nested: Dict[str, Any] = {}
    for key, leaf in flat.items():
        node = nested
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return nested
