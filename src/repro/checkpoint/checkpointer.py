"""Checkpointing: atomic, async, restart-safe; optional PVQ-compressed
weight storage (paper §VI applied to the checkpoint/network path).

Layout:  <dir>/step_<N>/  with one .npy per leaf (flat-keyed), a manifest
json, and a COMMIT marker written last — restore only trusts committed
steps, so a mid-write crash can never be restored from (fault tolerance).

Two PVQ paths:

* ``PackedPVQ`` leaves (the unified packed artifact, any compress mode) are
  stored *as the code*, never the dequantized weights, under one of two
  codecs selected by ``packed_codec``:

  - ``'packed'`` (default): int8 pulses (nibble-packed when |pulse| <= 7)
    + f32 scales, manifest codec ``pvq-packed`` — 4–8 bits/weight.
  - ``'golomb'``: the pulse tensor as a chunked signed exp-Golomb bitstream
    (``repro.core.bitstream``), manifest codec ``pvq-golomb`` — the paper's
    §VI entropy coding, ~1.4–2 bits/weight at rest for N/K >= 5 layers.

  Either way restore reconstructs the identical ``PackedPVQ`` — bit-exact
  pulses, **no re-encode** — so a serving job restarts on exactly the
  artifact it checkpointed.  (For a shippable single-file artifact with
  per-leaf codec selection, see ``repro.checkpoint.artifact`` / ``.pvqz``.)
* ``compress='pvq'`` additionally re-encodes *dense float* matrix leaves as
  PVQ codes on save and dequantizes on restore.  This is *lossy* for those
  weights (exactly the paper's trade) and bit-exact for everything else
  (moments, step counters).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitstream, pvq_encode_grouped, pvq_decode_grouped
from repro.core.codes import golomb_encode
from repro.core.packed import PackedPVQ, is_packed
from repro.core.packing import pack_nibbles, unpack_nibbles


def _flatten(tree: Any) -> Dict[str, Any]:
    """{path: np.ndarray | PackedPVQ} — packed leaves stay whole."""
    flat = {}

    def visit(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf if is_packed(leaf) else np.asarray(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(visit, tree, is_leaf=is_packed)
    return flat


def _unflatten_into(tree: Any, flat: Dict[str, Any]) -> Any:
    def visit(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        if is_packed(arr):
            return arr
        return jnp.asarray(arr).astype(leaf.dtype).reshape(leaf.shape)

    return jax.tree_util.tree_map_with_path(visit, tree, is_leaf=is_packed)


class Checkpointer:
    def __init__(
        self,
        directory: str | Path,
        *,
        keep: int = 3,
        compress: Optional[str] = None,  # None | 'pvq'
        packed_codec: str = "packed",  # 'packed' | 'golomb'
        pvq_n_over_k: float = 1.0,
        pvq_group: int = 256,
        min_compress_size: int = 4096,
    ):
        if packed_codec not in ("packed", "golomb"):
            raise ValueError(f"packed_codec must be 'packed' or 'golomb', got {packed_codec!r}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.compress = compress
        self.packed_codec = packed_codec
        self.pvq_n_over_k = pvq_n_over_k
        self.pvq_group = pvq_group
        self.min_compress_size = min_compress_size
        self._async_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Any, *, block: bool = True) -> Path:
        """Write checkpoint for ``step``. With block=False, runs in a thread
        (async checkpointing: the step loop keeps running)."""
        host_state = jax.tree.map(np.asarray, state)  # snapshot off-device now
        if block:
            return self._write(step, host_state)
        self.wait()
        self._async_thread = threading.Thread(target=self._write, args=(step, host_state), daemon=True)
        self._async_thread.start()
        return self.dir / f"step_{step:09d}"

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _write(self, step: int, state: Any) -> Path:
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f".tmp_step_{step:09d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(state)
        manifest: Dict[str, Any] = {"step": step, "leaves": {}, "compress": self.compress}
        for key, arr in flat.items():
            fname = key.replace("/", "__")
            if is_packed(arr):
                # the unified packed artifact: store the CODE, never the
                # dequantized weights — restore is bit-exact, no re-encode
                pulses = np.asarray(arr.pulses, np.int8)
                entry = {
                    "pulse_shape": list(pulses.shape),
                    "scales_shape": list(np.asarray(arr.scales).shape),
                    "group": int(arr.group),
                    "k": int(arr.k),
                    "shape": list(arr.shape),
                    "dtype": arr.dtype,
                    "layout": arr.layout,
                    "scale_mode": arr.scale_mode,
                }
                if self.packed_codec == "golomb":
                    # §VI entropy coding at rest: chunked signed exp-Golomb
                    # over the physical pulse tensor (~1.4-2 bits/weight)
                    blob, info = bitstream.encode_pulses(pulses, "golomb")
                    (tmp / f"{fname}.pulses.bin").write_bytes(blob)
                    entry["codec"] = "pvq-golomb"
                    entry["pulse_info"] = info
                elif np.abs(pulses).max(initial=0) <= 7:
                    packed_bits, pshape = pack_nibbles(pulses)
                    np.save(tmp / f"{fname}.pulses.npy", packed_bits)
                    entry["codec"] = "pvq-packed"
                    entry["pulse_format"] = "nibble"
                else:
                    np.save(tmp / f"{fname}.pulses.npy", pulses)
                    entry["codec"] = "pvq-packed"
                    entry["pulse_format"] = "int8"
                np.save(tmp / f"{fname}.scales.npy", np.asarray(arr.scales, np.float32))
                manifest["leaves"][key] = entry
                continue
            entry: Dict[str, Any] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
            is_float = str(arr.dtype) in ("float32", "float16", "bfloat16")
            if (
                self.compress == "pvq"
                and arr.ndim >= 2
                and arr.size >= self.min_compress_size
                and is_float
            ):
                code = pvq_encode_grouped(
                    jnp.asarray(arr, jnp.float32).reshape(-1),
                    group=self.pvq_group,
                    k=max(int(round(self.pvq_group / self.pvq_n_over_k)), 1),
                    scale_mode="ls",
                )
                pulses = np.asarray(code.pulses)
                if np.abs(pulses).max(initial=0) <= 7:
                    packed, pshape = pack_nibbles(pulses)
                    np.save(tmp / f"{fname}.pulses.npy", packed)
                    entry["pulse_format"] = "nibble"
                    entry["pulse_shape"] = list(pshape)
                else:
                    np.save(tmp / f"{fname}.pulses.npy", pulses.astype(np.int8))
                    entry["pulse_format"] = "int8"
                    entry["pulse_shape"] = list(pulses.shape)
                np.save(tmp / f"{fname}.scales.npy", np.asarray(code.scale, np.float32))
                entry["codec"] = "pvq"
                entry["k"] = int(code.k)
                entry["group"] = self.pvq_group
                # report-only entropy estimate (bits/weight under Golomb)
                _, nbits = golomb_encode(pulses.ravel()[: min(pulses.size, 65536)])
                entry["golomb_bits_per_weight_est"] = nbits / min(pulses.size, 65536)
            else:
                save_arr = arr
                if str(arr.dtype) == "bfloat16":
                    save_arr = arr.astype(np.float32)
                    entry["stored_dtype"] = "float32"
                np.save(tmp / f"{fname}.npy", save_arr)
                entry["codec"] = "raw"
            manifest["leaves"][key] = entry
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        (tmp / "COMMIT").write_text(str(time.time()))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None) -> Tuple[Any, int]:
        """Restore into the structure/dtypes of ``target``; returns (state, step)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no committed checkpoints under {self.dir}")
        d = self.dir / f"step_{step:09d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat: Dict[str, Any] = {}
        for key, entry in manifest["leaves"].items():
            fname = key.replace("/", "__")
            if entry["codec"] in ("pvq-packed", "pvq-golomb"):
                if entry["codec"] == "pvq-golomb":
                    blob = (d / f"{fname}.pulses.bin").read_bytes()
                    pulses = bitstream.decode_pulses(blob, entry["pulse_info"]).reshape(
                        entry["pulse_shape"]
                    ).astype(np.int8)
                elif entry["pulse_format"] == "nibble":
                    raw = np.load(d / f"{fname}.pulses.npy")
                    pulses = unpack_nibbles(raw, tuple(entry["pulse_shape"])).astype(np.int8)
                else:
                    raw = np.load(d / f"{fname}.pulses.npy")
                    pulses = raw.astype(np.int8)
                scales = np.load(d / f"{fname}.scales.npy").astype(np.float32)
                flat[key] = PackedPVQ(
                    pulses=jnp.asarray(pulses),
                    scales=jnp.asarray(scales.reshape(entry["scales_shape"])),
                    group=int(entry["group"]),
                    k=int(entry["k"]),
                    shape=tuple(entry["shape"]),
                    dtype=entry["dtype"],
                    layout=entry["layout"],
                    scale_mode=entry["scale_mode"],
                )
            elif entry["codec"] == "pvq":
                raw = np.load(d / f"{fname}.pulses.npy")
                if entry["pulse_format"] == "nibble":
                    pulses = unpack_nibbles(raw, tuple(entry["pulse_shape"]))
                else:
                    pulses = raw.astype(np.int64)
                scales = np.load(d / f"{fname}.scales.npy")
                w = (pulses.astype(np.float32) * scales[..., None]).reshape(-1)
                n = int(np.prod(entry["shape"]))
                flat[key] = w[:n].reshape(entry["shape"])
            else:
                flat[key] = np.load(d / f"{fname}.npy")
        return _unflatten_into(target, flat), step
