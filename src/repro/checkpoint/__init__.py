from .artifact import iter_pvqz, load_pvqz, read_toc, write_pvqz
from .checkpointer import Checkpointer

__all__ = ["Checkpointer", "write_pvqz", "load_pvqz", "iter_pvqz", "read_toc"]
