"""PVQ-aware training: STE projection, mixed optimization, K-annealing (paper §IV).

The paper sketches three recipes beyond post-training quantization:
  (a) mixed optimization with w constrained to rho * P(N,K)  — we implement the
      standard projected/straight-through relaxation: forward uses the
      quantized weights, backward passes gradients straight through to the
      latent float weights (Hinton STE, the same device the paper uses for
      bsign nets, eq. 18);
  (b) hybrid: train float -> PVQ -> continue training with (a) as refinement;
  (c) K-annealing: start from a large K (low quantization noise) and anneal
      down to the target.

Also provides the bsign activation with STE (paper eqs. 17-18) used by the
binary PVQ nets C and D.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .pvq import pvq_encode


# ---------------------------------------------------------------------------
# Straight-through PVQ projection
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def pvq_ste(w: jax.Array, k: int, group: Optional[int] = None, scale_mode: str = "paper") -> jax.Array:
    """Quantize-dequantize with identity gradient (straight-through)."""
    return _pvq_qdq(w, k, group, scale_mode)


def _pvq_qdq(w, k, group, scale_mode):
    flat = w.reshape(-1)
    if group is None:
        # paper-faithful whole-tensor projection (exact greedy / LR switch)
        code = pvq_encode(flat, k, scale_mode)
        deq = code.dequantize()
    else:
        # grouped QAT hot path: sorted O(N log N + ΔK) projection, dispatched
        # through the kernel layer (Pallas on TPU, jnp twin elsewhere).
        # Imported lazily: repro.core must not depend on repro.kernels at
        # import time.
        from repro.kernels import ops as kernel_ops

        n = flat.shape[0]
        pulses, scale = kernel_ops.pvq_encode_grouped_fast(
            flat, group, k, scale_mode=scale_mode
        )
        deq = (scale[:, None] * pulses.astype(jnp.float32)).reshape(-1)[:n]
    return deq.reshape(w.shape).astype(w.dtype)


def _pvq_ste_fwd(w, k, group, scale_mode):
    return _pvq_qdq(w, k, group, scale_mode), None


def _pvq_ste_bwd(k, group, scale_mode, res, g):
    return (g,)


pvq_ste.defvjp(_pvq_ste_fwd, _pvq_ste_bwd)


# ---------------------------------------------------------------------------
# bsign with STE (paper eqs. 17-18)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def bsign(x: jax.Array) -> jax.Array:
    """+1 if x >= 0 else -1, with d/dx := 1 (straight-through estimator)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _bsign_fwd(x):
    return bsign(x), None


def _bsign_bwd(res, g):
    return (g,)


bsign.defvjp(_bsign_fwd, _bsign_bwd)


def bsign_clipped_ste(x: jax.Array) -> jax.Array:
    """bsign with the hardtanh-window STE (gradient zero for |x|>1) — the
    refinement used by BinaryNet/QNN; beyond-paper option."""

    @jax.custom_vjp
    def f(x):
        return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)

    def fwd(x):
        return f(x), x

    def bwd(x, g):
        return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)

    f.defvjp(fwd, bwd)
    return f(x)


# ---------------------------------------------------------------------------
# K-annealing schedule (paper §IV)
# ---------------------------------------------------------------------------


def k_annealing_schedule(k_start: int, k_target: int, n_steps: int):
    """Geometric anneal from k_start down to k_target over n_steps.

    Returns step -> K (python int; K is a static quantization parameter, so
    the training loop re-jits on each distinct K — use few distinct stages).
    """
    if k_start < k_target:
        raise ValueError("k_start must be >= k_target")
    stages = max(n_steps, 1)

    def k_at(step: int) -> int:
        t = min(max(step, 0), stages) / stages
        k = k_start * (k_target / k_start) ** t
        return max(int(round(k)), k_target)

    return k_at


def k_annealing_stages(k_start: int, k_target: int, n_stages: int):
    """Discrete stage list [(K, fraction_of_steps)] — bounded re-jit count."""
    ks = []
    for i in range(n_stages):
        t = i / max(n_stages - 1, 1)
        k = int(round(k_start * (k_target / k_start) ** t))
        ks.append(max(k, k_target))
    # dedupe while preserving order
    seen, out = set(), []
    for k in ks:
        if k not in seen:
            seen.add(k)
            out.append(k)
    frac = 1.0 / len(out)
    return [(k, frac) for k in out]
