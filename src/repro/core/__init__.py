"""PVQ core: the paper's contribution as composable JAX modules."""

from .pvq import (
    PVQCode,
    pvq_encode,
    pvq_decode,
    pvq_encode_grouped,
    pvq_decode_grouped,
    pvq_quantize_direction,
    pvq_quantize_direction_fast,
    pvq_dot,
    pvq_encode_np,
    dot_op_counts,
)
from .enumeration import num_points, index_bits, vector_to_index, index_to_vector
from .quantize import QuantPolicy, quantize_tree, quantize_array, tree_compression_report, total_bits, k_for
from .qat import pvq_ste, bsign, k_annealing_stages
from .fold import fold_codes, check_homogeneity
from .packed import (
    PackedPVQ,
    is_packed,
    materialize,
    pack_matmul,
    pack_flat,
    quantize_params,
    dequantize_params,
    packed_leaves,
    packed_stats,
    packed_update,
)

__all__ = [
    "PVQCode",
    "pvq_encode",
    "pvq_decode",
    "pvq_encode_grouped",
    "pvq_decode_grouped",
    "pvq_quantize_direction",
    "pvq_quantize_direction_fast",
    "pvq_dot",
    "pvq_encode_np",
    "dot_op_counts",
    "num_points",
    "index_bits",
    "vector_to_index",
    "index_to_vector",
    "QuantPolicy",
    "quantize_tree",
    "quantize_array",
    "tree_compression_report",
    "total_bits",
    "k_for",
    "pvq_ste",
    "bsign",
    "k_annealing_stages",
    "fold_codes",
    "check_homogeneity",
    "PackedPVQ",
    "is_packed",
    "materialize",
    "pack_matmul",
    "pack_flat",
    "quantize_params",
    "dequantize_params",
    "packed_leaves",
    "packed_stats",
    "packed_update",
]
