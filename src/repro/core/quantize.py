"""Pytree-level PVQ quantization API (paper §IV procedure + §VII recipe).

The paper's per-layer procedure:
  1. extract weights+bias of a layer, flatten+concat into one N-vector
  2. PVQ-encode with budget K (reported as the ratio N/K)
  3. split/reshape back, replace the originals

``quantize_tree`` generalizes this to arbitrary pytrees with a policy mapping
parameter paths to (n_over_k, group) choices.  ``group=None`` reproduces the
paper exactly (whole tensor = one PVQ vector, one rho); integer groups give
the per-group-rho variant our TPU kernel consumes.
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import codes as codes_lib
from .pvq import PVQCode, pvq_decode_grouped, pvq_encode, pvq_encode_grouped


# ---------------------------------------------------------------------------
# ActQuant: the activation-quantization contract (kernel v3, int8 x int8)
# ---------------------------------------------------------------------------

ACT_QUANT_MODES = ("per_row", "per_tile", "per_tensor")

#: ``ActQuant(granularity=...)`` convenience spellings -> canonical mode
ACT_QUANT_GRANULARITIES = {
    "row": "per_row",
    "tile": "per_tile",
    "tensor": "per_tensor",
}

#: int8 symmetric range; the activation scale maps max|x| onto this bound
ACT_QMAX = 127


@dataclasses.dataclass(frozen=True)
class ActQuant:
    """Symmetric int8 activation quantization contract.

    One shared config object flows from the serving entry point through the
    nn layers into ``kernels.ops`` — every matmul that sees it quantizes its
    activation operand to int8 and dispatches the int8 x int8 kernel v3
    (int32 MXU accumulation, ``act_scale * rho`` on the accumulator).

    mode:
      * ``'per_row'``   — one scale per activation row (= per token/slot);
        the finest granularity the kernel consumes without a per-element
        multiply.  This is the serving default: decode batches mix prompt
        magnitudes, so a shared scale would let one hot row crush the rest.
      * ``'per_tile'``  — one scale per (row x k-group) tile, where the tile
        width is the weight's PVQ group (``ops.pvq_matmul`` passes it in).
        Long prefill rows whose dynamic range defeats one per-row scale
        (e.g. a single outlier channel) keep full int8 resolution in every
        other group.  The kernel applies ``act_scale[row, g]`` on the same
        per-group int32 partial it already multiplies by rho — still one
        scalar multiply per group, no per-element work.
      * ``'per_tensor'`` — one scale for the whole activation tile; cheapest,
        coarsest (ablation / per-tensor-calibrated deployments).

    ``granularity`` is a convenience spelling (``'row'``/``'tile'``/
    ``'tensor'``) that overrides ``mode`` when given:
    ``ActQuant(granularity="tile") == ActQuant(mode="per_tile")``.

    The transform is exact-roundtrip-bounded: ``x = q * scale + e`` with
    ``|e| <= scale / 2`` elementwise (see :func:`quantize_activations`),
    which gives the closed-form matmul error model
    :func:`act_matmul_error_bound` that the property tests assert against.
    """

    mode: str = "per_row"
    granularity: Optional[str] = None

    def __post_init__(self) -> None:
        if self.granularity is not None:
            if self.granularity not in ACT_QUANT_GRANULARITIES:
                raise ValueError(
                    f"ActQuant granularity {self.granularity!r} not in "
                    f"{tuple(ACT_QUANT_GRANULARITIES)}"
                )
            object.__setattr__(
                self, "mode", ACT_QUANT_GRANULARITIES[self.granularity]
            )
        if self.mode not in ACT_QUANT_MODES:
            raise ValueError(
                f"ActQuant mode {self.mode!r} not in {ACT_QUANT_MODES}"
            )


#: process default consumed by the nn layers when no explicit config is
#: passed (``launch/serve.py --act-int8`` sets it once; everything below —
#: dense, unembed, sequential.kernel_apply, the MoE dispatch buffer — picks
#: it up without threading a flag through every model signature).
_DEFAULT_ACT_QUANT: Optional[ActQuant] = None


def set_default_act_quant(aq: Optional[ActQuant]) -> Optional[ActQuant]:
    """Set the process-wide default ActQuant; returns the previous value."""
    global _DEFAULT_ACT_QUANT
    prev = _DEFAULT_ACT_QUANT
    _DEFAULT_ACT_QUANT = aq
    return prev


def default_act_quant() -> Optional[ActQuant]:
    return _DEFAULT_ACT_QUANT


@contextlib.contextmanager
def act_quant_scope(aq: Optional[ActQuant]):
    """Scoped override of the process default (A/B comparisons, tests)."""
    prev = set_default_act_quant(aq)
    try:
        yield aq
    finally:
        set_default_act_quant(prev)


def quantize_activations(
    x: jax.Array, aq: ActQuant = ActQuant(), *, tile: Optional[int] = None
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization of an activation tensor ``(..., k)``.

    Returns ``(q int8 (..., k), scale f32)`` where the scale shape is
    ``(..., 1)`` for per_row/per_tensor (``scale = max|row| / 127`` or the
    tensor-wide equivalent broadcast to every row) and ``(..., k // tile)``
    for per_tile (one scale per contiguous ``tile``-wide slice of the last
    axis; ``tile`` must divide ``k`` and is normally the weight's PVQ
    group, supplied by the kernel dispatch).  Properties (asserted in
    tests):

    * exact bound: ``|x - q * s| <= s / 2`` elementwise, ``s`` being the
      scale covering that element (round-to-nearest of ``x / s``; no
      clipping error — ``|x| <= 127 * s`` by construction, so
      ``|round(x/s)| <= 127``);
    * all-zero rows/tiles (e.g. MoE capacity padding) get ``scale = 0``
      and ``q = 0`` — they dequantize to exact zeros instead of NaNs.
    """
    xf = x.astype(jnp.float32)
    if aq.mode == "per_row":
        amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    elif aq.mode == "per_tile":
        if tile is None:
            raise ValueError("per_tile quantization needs the tile width")
        k = xf.shape[-1]
        if k % tile:
            raise ValueError(f"tile {tile} does not divide k={k}")
        xt = xf.reshape(xf.shape[:-1] + (k // tile, tile))
        amax_t = jnp.max(jnp.abs(xt), axis=-1)  # (..., k//tile)
        scale = amax_t / ACT_QMAX
        inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
        qt = jnp.clip(jnp.round(xt * inv[..., None]), -ACT_QMAX, ACT_QMAX)
        q = qt.reshape(xf.shape).astype(jnp.int8)
        scale = scale.astype(jnp.float32)
        _probe_act_quant(x, q, scale)
        return q, scale
    else:  # per_tensor
        amax = jnp.broadcast_to(
            jnp.max(jnp.abs(xf)), xf.shape[:-1] + (1,)
        )
    scale = amax / ACT_QMAX
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(xf * inv), -ACT_QMAX, ACT_QMAX).astype(jnp.int8)
    scale = scale.astype(jnp.float32)
    _probe_act_quant(x, q, scale)
    return q, scale


def _probe_act_quant(x: jax.Array, q: jax.Array, scale: jax.Array) -> None:
    """Scale-saturation / clamp-rate probe for the int8 activation path.

    Eager calls only: at trace time ``x`` is a tracer and the probe
    returns before touching the registry, so nothing lands inside jit
    bodies (jitted serving still quantizes, it just isn't probed — the
    serve entry points probe representative rows host-side instead).
    """
    if isinstance(x, jax.core.Tracer):
        return
    from repro.runtime import obs

    if not obs.enabled():
        return
    qn = np.asarray(q)
    sn = np.asarray(scale)
    obs.counter("quant.act_quant_calls").inc()
    if qn.size:
        obs.histogram("quant.act_clamp_frac").record(
            float(np.count_nonzero(np.abs(qn) == ACT_QMAX)) / qn.size
        )
    if sn.size:
        obs.histogram("quant.act_zero_scale_frac").record(
            float(np.count_nonzero(sn == 0)) / sn.size
        )


def act_matmul_error_bound(
    act_scale: jax.Array,  # (m, 1) per-row | (m, k//group) per-tile f32 scales
    w_pulses: jax.Array,  # (k, n) int8 PVQ pulses
    w_scales: jax.Array,  # (k // group, n) f32 per-group rho
    group: int,
) -> jax.Array:
    """Exact worst-case |int8-act output - f32-act output| per logit, (m, n).

    The quantization error is elementwise bounded by its covering scale / 2,
    so for output column n:

        |sum_i e_i * W_in|  <=  0.5 * sum_g a_mg * |rho_gn| * L1(pulses_gn)

    where ``a_mg`` is the activation scale covering group g of row m — the
    shared per-row scale in per_row mode, or column g of the per-tile scale
    matrix when the activation was quantized with ``tile == group``.
    ``L1(pulses_gn) = K`` for unclamped codes and <= K after the K > 127
    int8 clamp — the bound is computed from the pulses actually stored, so
    it is valid in the clamped regime too.  Zero ``act_scale`` entries
    (all-pad rows/tiles) contribute a zero bound: their outputs are exactly
    0 on both paths.
    """
    k, n = w_pulses.shape
    l1 = jnp.sum(
        jnp.abs(w_pulses.astype(jnp.float32)).reshape(k // group, group, n),
        axis=1,
    )  # (k//group, n)
    weighted = jnp.abs(w_scales.astype(jnp.float32)) * l1  # (k//group, n)
    a = act_scale.astype(jnp.float32)
    if a.shape[-1] == 1:  # per_row / per_tensor: one scale covers every group
        return 0.5 * a * jnp.sum(weighted, axis=0)[None, :]
    if a.shape[-1] != k // group:
        raise ValueError(
            f"per-tile act_scale has {a.shape[-1]} groups, weight has {k // group}"
        )
    return 0.5 * (a @ weighted)


# ---------------------------------------------------------------------------
# KVQuant: the PVQ-compressed KV-cache contract (kernel v4, attention decode)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVQuant:
    """PVQ compression contract for the attention KV cache.

    One static config flows from ``launch/serve.py --kv-pvq`` through
    ``nn.attention.init_kv_cache`` into ``core.packed.PackedKV`` and the
    kernel-v4 attention dispatch.  K and V rows are encoded per
    (token, kv-head, sub-head group): ``head_dim`` is split into
    ``head_dim // group`` PVQ groups, each stored as int8 pulses on
    P(group, k) plus one f32 rho — ``head_dim + 4 * head_dim // group``
    bytes per head per token instead of ``4 * head_dim`` (f32) or
    ``2 * head_dim`` (bf16).

    block: tokens per encoded cache block.  ``attention_decode`` appends
      into a small f32 tail ring of this length and encodes a block the
      moment it fills; decode reads packed pulses for the completed blocks
      and exact f32 for the in-flight partial block.
    group: sub-head PVQ group width (fitted down with the power-of-two
      chain when it does not divide ``head_dim``).
    k: pulse budget per group.  The default 127 saturates the int8 pulse
      plane (pulses cost 1 byte/element regardless of K, so there is no
      storage reason to go lower); smaller K trades fidelity for entropy-
      coded artifact size only.
    """

    block: int = 32
    group: int = 32
    k: int = 127

    def __post_init__(self) -> None:
        if self.block < 1:
            raise ValueError(f"KVQuant block must be >= 1, got {self.block}")
        if self.group < 1:
            raise ValueError(f"KVQuant group must be >= 1, got {self.group}")
        if not (1 <= self.k <= 127):
            raise ValueError(
                f"KVQuant k must be in [1, 127] (int8 pulse plane), got {self.k}"
            )


#: process default consumed by ``nn.attention.init_kv_cache`` /
#: ``attention_prefill_cache`` when no explicit config is passed
#: (``launch/serve.py --kv-pvq`` sets it once; every layer's cache comes
#: out packed without threading a flag through the model signatures).
_DEFAULT_KV_QUANT: Optional[KVQuant] = None


def set_default_kv_quant(kvq: Optional[KVQuant]) -> Optional[KVQuant]:
    """Set the process-wide default KVQuant; returns the previous value."""
    global _DEFAULT_KV_QUANT
    prev = _DEFAULT_KV_QUANT
    _DEFAULT_KV_QUANT = kvq
    return prev


def default_kv_quant() -> Optional[KVQuant]:
    return _DEFAULT_KV_QUANT


@contextlib.contextmanager
def kv_quant_scope(kvq: Optional[KVQuant]):
    """Scoped override of the process default (A/B comparisons, tests)."""
    prev = set_default_kv_quant(kvq)
    try:
        yield kvq
    finally:
        set_default_kv_quant(prev)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which tensors to quantize and how.

    rules: list of (path_regex, n_over_k, group). First match wins.
      n_over_k: the paper's N/K ratio (K = max(round(N / n_over_k), 1)).
      group:    None -> whole-tensor single rho (paper-faithful);
                int  -> per-group rho (kernel format).
    scale_mode: 'paper' (rho = ||w||/||y||) or 'ls' (least squares).
    skip_regex: tensors never quantized (norm scales, ssm decay params, ...).
    """

    rules: Tuple[Tuple[str, float, Optional[int]], ...] = (("", 1.0, None),)
    scale_mode: str = "paper"
    skip_regex: str = (
        r"(norm|scale|bias_only|rope|decay|a_log|dt_bias|time_|ln_)"
    )

    def match(self, path: str) -> Optional[Tuple[float, Optional[int]]]:
        if re.search(self.skip_regex, path):
            return None
        for pat, n_over_k, group in self.rules:
            if re.search(pat, path):
                return (n_over_k, group)
        return None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def k_for(n: int, n_over_k: float) -> int:
    return max(int(round(n / n_over_k)), 1)


def quantize_array(
    w: jax.Array, n_over_k: float, group: Optional[int], scale_mode: str = "paper"
) -> Tuple[jax.Array, PVQCode, Dict[str, Any]]:
    """Quantize one tensor. Returns (dequantized float array, code, stats)."""
    flat = w.reshape(-1)
    n = flat.shape[0]
    if group is None:
        k = k_for(n, n_over_k)
        code = pvq_encode(flat, k, scale_mode)
        deq = code.dequantize().reshape(w.shape).astype(w.dtype)
        eff_n = n
    else:
        k = k_for(group, n_over_k)
        code = pvq_encode_grouped(flat, group, k, scale_mode)
        deq = pvq_decode_grouped(code, n).reshape(w.shape).astype(w.dtype)
        eff_n = group
    err = jnp.linalg.norm(deq.astype(jnp.float32) - w.astype(jnp.float32))
    ref = jnp.linalg.norm(w.astype(jnp.float32))
    stats = {
        "N": eff_n,
        "K": k,
        "n_over_k": n_over_k,
        "rel_err": float(err / jnp.maximum(ref, 1e-30)),
        "numel": int(n),
    }
    return deq, code, stats


def quantize_tree(
    params: Any, policy: QuantPolicy
) -> Tuple[Any, Dict[str, PVQCode], Dict[str, Dict[str, Any]]]:
    """PVQ-quantize every matching leaf. Returns (dequantized tree, codes, stats)."""
    codes: Dict[str, PVQCode] = {}
    stats: Dict[str, Dict[str, Any]] = {}

    def visit(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)) or leaf.ndim == 0:
            return leaf
        pstr = _path_str(path)
        m = policy.match(pstr)
        if m is None or leaf.size < 8:
            return leaf
        n_over_k, group = m
        deq, code, st = quantize_array(jnp.asarray(leaf), n_over_k, group, policy.scale_mode)
        codes[pstr] = code
        stats[pstr] = st
        return deq

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, codes, stats


def tree_compression_report(codes: Dict[str, PVQCode]) -> Dict[str, Dict[str, float]]:
    """Paper §VI/§VII: per-tensor pulse histograms + bits/weight estimates."""
    out = {}
    for path, code in codes.items():
        pulses = np.asarray(code.pulses).ravel()
        rep = codes_lib.pulse_histogram(pulses)
        rep.update(codes_lib.compression_report(pulses))
        out[path] = rep
    return out


def total_bits(codes: Dict[str, PVQCode], scheme: str = "golomb") -> Dict[str, float]:
    """Aggregate compressed size across a model (weights only, + scales at f32)."""
    total_w_bits = 0.0
    total_scale_bits = 0.0
    numel = 0
    for code in codes.values():
        pulses = np.asarray(code.pulses).ravel()
        numel += pulses.size
        if scheme == "golomb":
            total_w_bits += float(codes_lib.golomb_length(pulses).sum())
        elif scheme == "rle":
            _, nbits, _ = codes_lib.rle_encode(pulses)
            total_w_bits += nbits
        else:
            raise ValueError(scheme)
        total_scale_bits += 32.0 * np.prod(np.asarray(code.scale).shape)
    return {
        "numel": numel,
        "weight_bits": total_w_bits,
        "scale_bits": total_scale_bits,
        "bits_per_weight": (total_w_bits + total_scale_bits) / max(numel, 1),
        "vs_fp32_ratio": 32.0 * numel / max(total_w_bits + total_scale_bits, 1),
        "vs_bf16_ratio": 16.0 * numel / max(total_w_bits + total_scale_bits, 1),
    }
