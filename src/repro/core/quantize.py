"""Pytree-level PVQ quantization API (paper §IV procedure + §VII recipe).

The paper's per-layer procedure:
  1. extract weights+bias of a layer, flatten+concat into one N-vector
  2. PVQ-encode with budget K (reported as the ratio N/K)
  3. split/reshape back, replace the originals

``quantize_tree`` generalizes this to arbitrary pytrees with a policy mapping
parameter paths to (n_over_k, group) choices.  ``group=None`` reproduces the
paper exactly (whole tensor = one PVQ vector, one rho); integer groups give
the per-group-rho variant our TPU kernel consumes.
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import codes as codes_lib
from .pvq import PVQCode, pvq_decode_grouped, pvq_encode, pvq_encode_grouped


# ---------------------------------------------------------------------------
# ActQuant: the activation-quantization contract (kernel v3, int8 x int8)
# ---------------------------------------------------------------------------

ACT_QUANT_MODES = ("per_row", "per_tensor")

#: int8 symmetric range; the activation scale maps max|x| onto this bound
ACT_QMAX = 127


@dataclasses.dataclass(frozen=True)
class ActQuant:
    """Symmetric int8 activation quantization contract.

    One shared config object flows from the serving entry point through the
    nn layers into ``kernels.ops`` — every matmul that sees it quantizes its
    activation operand to int8 and dispatches the int8 x int8 kernel v3
    (int32 MXU accumulation, ``act_scale * rho`` on the accumulator).

    mode:
      * ``'per_row'``   — one scale per activation row (= per token/slot);
        the finest granularity the kernel consumes without a per-element
        multiply.  This is the serving default: decode batches mix prompt
        magnitudes, so a shared scale would let one hot row crush the rest.
      * ``'per_tensor'`` — one scale for the whole activation tile; cheapest,
        coarsest (ablation / per-tensor-calibrated deployments).

    The transform is exact-roundtrip-bounded: ``x = q * scale + e`` with
    ``|e| <= scale / 2`` elementwise (see :func:`quantize_activations`),
    which gives the closed-form matmul error model
    :func:`act_matmul_error_bound` that the property tests assert against.
    """

    mode: str = "per_row"

    def __post_init__(self) -> None:
        if self.mode not in ACT_QUANT_MODES:
            raise ValueError(
                f"ActQuant mode {self.mode!r} not in {ACT_QUANT_MODES}"
            )


#: process default consumed by the nn layers when no explicit config is
#: passed (``launch/serve.py --act-int8`` sets it once; everything below —
#: dense, unembed, sequential.kernel_apply, the MoE dispatch buffer — picks
#: it up without threading a flag through every model signature).
_DEFAULT_ACT_QUANT: Optional[ActQuant] = None


def set_default_act_quant(aq: Optional[ActQuant]) -> Optional[ActQuant]:
    """Set the process-wide default ActQuant; returns the previous value."""
    global _DEFAULT_ACT_QUANT
    prev = _DEFAULT_ACT_QUANT
    _DEFAULT_ACT_QUANT = aq
    return prev


def default_act_quant() -> Optional[ActQuant]:
    return _DEFAULT_ACT_QUANT


@contextlib.contextmanager
def act_quant_scope(aq: Optional[ActQuant]):
    """Scoped override of the process default (A/B comparisons, tests)."""
    prev = set_default_act_quant(aq)
    try:
        yield aq
    finally:
        set_default_act_quant(prev)


def quantize_activations(
    x: jax.Array, aq: ActQuant = ActQuant()
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization of an activation tensor ``(..., k)``.

    Returns ``(q int8 (..., k), scale f32 (..., 1))`` with
    ``scale = max|row| / 127`` (per_row) or the tensor-wide equivalent
    broadcast to every row.  Properties (asserted in tests):

    * exact bound: ``|x - q * scale| <= scale / 2`` elementwise
      (round-to-nearest of ``x / scale``; no clipping error — ``|x| <=
      127 * scale`` by construction, so ``|round(x/scale)| <= 127``);
    * all-zero rows (e.g. MoE capacity padding) get ``scale = 0`` and
      ``q = 0`` — they dequantize to exact zeros instead of NaNs.
    """
    xf = x.astype(jnp.float32)
    if aq.mode == "per_row":
        amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    else:  # per_tensor
        amax = jnp.broadcast_to(
            jnp.max(jnp.abs(xf)), xf.shape[:-1] + (1,)
        )
    scale = amax / ACT_QMAX
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(xf * inv), -ACT_QMAX, ACT_QMAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def act_matmul_error_bound(
    act_scale: jax.Array,  # (m, 1) f32 per-row activation scales
    w_pulses: jax.Array,  # (k, n) int8 PVQ pulses
    w_scales: jax.Array,  # (k // group, n) f32 per-group rho
    group: int,
) -> jax.Array:
    """Exact worst-case |int8-act output - f32-act output| per logit, (m, n).

    The quantization error is elementwise bounded by ``act_scale / 2``, so
    for output column n:

        |sum_i e_i * W_in|  <=  (act_scale/2) * sum_g |rho_gn| * L1(pulses_gn)

    where ``L1(pulses_gn) = K`` for unclamped codes and <= K after the
    K > 127 int8 clamp — the bound is computed from the pulses actually
    stored, so it is valid in the clamped regime too.  Zero ``act_scale``
    rows (all-pad) contribute a zero bound: their outputs are exactly 0 on
    both paths.
    """
    k, n = w_pulses.shape
    l1 = jnp.sum(
        jnp.abs(w_pulses.astype(jnp.float32)).reshape(k // group, group, n),
        axis=1,
    )  # (k//group, n)
    per_col = jnp.sum(jnp.abs(w_scales.astype(jnp.float32)) * l1, axis=0)  # (n,)
    return 0.5 * act_scale.astype(jnp.float32) * per_col[None, :]


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which tensors to quantize and how.

    rules: list of (path_regex, n_over_k, group). First match wins.
      n_over_k: the paper's N/K ratio (K = max(round(N / n_over_k), 1)).
      group:    None -> whole-tensor single rho (paper-faithful);
                int  -> per-group rho (kernel format).
    scale_mode: 'paper' (rho = ||w||/||y||) or 'ls' (least squares).
    skip_regex: tensors never quantized (norm scales, ssm decay params, ...).
    """

    rules: Tuple[Tuple[str, float, Optional[int]], ...] = (("", 1.0, None),)
    scale_mode: str = "paper"
    skip_regex: str = (
        r"(norm|scale|bias_only|rope|decay|a_log|dt_bias|time_|ln_)"
    )

    def match(self, path: str) -> Optional[Tuple[float, Optional[int]]]:
        if re.search(self.skip_regex, path):
            return None
        for pat, n_over_k, group in self.rules:
            if re.search(pat, path):
                return (n_over_k, group)
        return None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def k_for(n: int, n_over_k: float) -> int:
    return max(int(round(n / n_over_k)), 1)


def quantize_array(
    w: jax.Array, n_over_k: float, group: Optional[int], scale_mode: str = "paper"
) -> Tuple[jax.Array, PVQCode, Dict[str, Any]]:
    """Quantize one tensor. Returns (dequantized float array, code, stats)."""
    flat = w.reshape(-1)
    n = flat.shape[0]
    if group is None:
        k = k_for(n, n_over_k)
        code = pvq_encode(flat, k, scale_mode)
        deq = code.dequantize().reshape(w.shape).astype(w.dtype)
        eff_n = n
    else:
        k = k_for(group, n_over_k)
        code = pvq_encode_grouped(flat, group, k, scale_mode)
        deq = pvq_decode_grouped(code, n).reshape(w.shape).astype(w.dtype)
        eff_n = group
    err = jnp.linalg.norm(deq.astype(jnp.float32) - w.astype(jnp.float32))
    ref = jnp.linalg.norm(w.astype(jnp.float32))
    stats = {
        "N": eff_n,
        "K": k,
        "n_over_k": n_over_k,
        "rel_err": float(err / jnp.maximum(ref, 1e-30)),
        "numel": int(n),
    }
    return deq, code, stats


def quantize_tree(
    params: Any, policy: QuantPolicy
) -> Tuple[Any, Dict[str, PVQCode], Dict[str, Dict[str, Any]]]:
    """PVQ-quantize every matching leaf. Returns (dequantized tree, codes, stats)."""
    codes: Dict[str, PVQCode] = {}
    stats: Dict[str, Dict[str, Any]] = {}

    def visit(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)) or leaf.ndim == 0:
            return leaf
        pstr = _path_str(path)
        m = policy.match(pstr)
        if m is None or leaf.size < 8:
            return leaf
        n_over_k, group = m
        deq, code, st = quantize_array(jnp.asarray(leaf), n_over_k, group, policy.scale_mode)
        codes[pstr] = code
        stats[pstr] = st
        return deq

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, codes, stats


def tree_compression_report(codes: Dict[str, PVQCode]) -> Dict[str, Dict[str, float]]:
    """Paper §VI/§VII: per-tensor pulse histograms + bits/weight estimates."""
    out = {}
    for path, code in codes.items():
        pulses = np.asarray(code.pulses).ravel()
        rep = codes_lib.pulse_histogram(pulses)
        rep.update(codes_lib.compression_report(pulses))
        out[path] = rep
    return out


def total_bits(codes: Dict[str, PVQCode], scheme: str = "golomb") -> Dict[str, float]:
    """Aggregate compressed size across a model (weights only, + scales at f32)."""
    total_w_bits = 0.0
    total_scale_bits = 0.0
    numel = 0
    for code in codes.values():
        pulses = np.asarray(code.pulses).ravel()
        numel += pulses.size
        if scheme == "golomb":
            total_w_bits += float(codes_lib.golomb_length(pulses).sum())
        elif scheme == "rle":
            _, nbits, _ = codes_lib.rle_encode(pulses)
            total_w_bits += nbits
        else:
            raise ValueError(scheme)
        total_scale_bits += 32.0 * np.prod(np.asarray(code.scale).shape)
    return {
        "numel": numel,
        "weight_bits": total_w_bits,
        "scale_bits": total_scale_bits,
        "bits_per_weight": (total_w_bits + total_scale_bits) / max(numel, 1),
        "vs_fp32_ratio": 32.0 * numel / max(total_w_bits + total_scale_bits, 1),
        "vs_bf16_ratio": 16.0 * numel / max(total_w_bits + total_scale_bits, 1),
    }
