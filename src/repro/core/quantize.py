"""Pytree-level PVQ quantization API (paper §IV procedure + §VII recipe).

The paper's per-layer procedure:
  1. extract weights+bias of a layer, flatten+concat into one N-vector
  2. PVQ-encode with budget K (reported as the ratio N/K)
  3. split/reshape back, replace the originals

``quantize_tree`` generalizes this to arbitrary pytrees with a policy mapping
parameter paths to (n_over_k, group) choices.  ``group=None`` reproduces the
paper exactly (whole tensor = one PVQ vector, one rho); integer groups give
the per-group-rho variant our TPU kernel consumes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import codes as codes_lib
from .pvq import PVQCode, pvq_decode_grouped, pvq_encode, pvq_encode_grouped


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Which tensors to quantize and how.

    rules: list of (path_regex, n_over_k, group). First match wins.
      n_over_k: the paper's N/K ratio (K = max(round(N / n_over_k), 1)).
      group:    None -> whole-tensor single rho (paper-faithful);
                int  -> per-group rho (kernel format).
    scale_mode: 'paper' (rho = ||w||/||y||) or 'ls' (least squares).
    skip_regex: tensors never quantized (norm scales, ssm decay params, ...).
    """

    rules: Tuple[Tuple[str, float, Optional[int]], ...] = (("", 1.0, None),)
    scale_mode: str = "paper"
    skip_regex: str = (
        r"(norm|scale|bias_only|rope|decay|a_log|dt_bias|time_|ln_)"
    )

    def match(self, path: str) -> Optional[Tuple[float, Optional[int]]]:
        if re.search(self.skip_regex, path):
            return None
        for pat, n_over_k, group in self.rules:
            if re.search(pat, path):
                return (n_over_k, group)
        return None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def k_for(n: int, n_over_k: float) -> int:
    return max(int(round(n / n_over_k)), 1)


def quantize_array(
    w: jax.Array, n_over_k: float, group: Optional[int], scale_mode: str = "paper"
) -> Tuple[jax.Array, PVQCode, Dict[str, Any]]:
    """Quantize one tensor. Returns (dequantized float array, code, stats)."""
    flat = w.reshape(-1)
    n = flat.shape[0]
    if group is None:
        k = k_for(n, n_over_k)
        code = pvq_encode(flat, k, scale_mode)
        deq = code.dequantize().reshape(w.shape).astype(w.dtype)
        eff_n = n
    else:
        k = k_for(group, n_over_k)
        code = pvq_encode_grouped(flat, group, k, scale_mode)
        deq = pvq_decode_grouped(code, n).reshape(w.shape).astype(w.dtype)
        eff_n = group
    err = jnp.linalg.norm(deq.astype(jnp.float32) - w.astype(jnp.float32))
    ref = jnp.linalg.norm(w.astype(jnp.float32))
    stats = {
        "N": eff_n,
        "K": k,
        "n_over_k": n_over_k,
        "rel_err": float(err / jnp.maximum(ref, 1e-30)),
        "numel": int(n),
    }
    return deq, code, stats


def quantize_tree(
    params: Any, policy: QuantPolicy
) -> Tuple[Any, Dict[str, PVQCode], Dict[str, Dict[str, Any]]]:
    """PVQ-quantize every matching leaf. Returns (dequantized tree, codes, stats)."""
    codes: Dict[str, PVQCode] = {}
    stats: Dict[str, Dict[str, Any]] = {}

    def visit(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)) or leaf.ndim == 0:
            return leaf
        pstr = _path_str(path)
        m = policy.match(pstr)
        if m is None or leaf.size < 8:
            return leaf
        n_over_k, group = m
        deq, code, st = quantize_array(jnp.asarray(leaf), n_over_k, group, policy.scale_mode)
        codes[pstr] = code
        stats[pstr] = st
        return deq

    qtree = jax.tree_util.tree_map_with_path(visit, params)
    return qtree, codes, stats


def tree_compression_report(codes: Dict[str, PVQCode]) -> Dict[str, Dict[str, float]]:
    """Paper §VI/§VII: per-tensor pulse histograms + bits/weight estimates."""
    out = {}
    for path, code in codes.items():
        pulses = np.asarray(code.pulses).ravel()
        rep = codes_lib.pulse_histogram(pulses)
        rep.update(codes_lib.compression_report(pulses))
        out[path] = rep
    return out


def total_bits(codes: Dict[str, PVQCode], scheme: str = "golomb") -> Dict[str, float]:
    """Aggregate compressed size across a model (weights only, + scales at f32)."""
    total_w_bits = 0.0
    total_scale_bits = 0.0
    numel = 0
    for code in codes.values():
        pulses = np.asarray(code.pulses).ravel()
        numel += pulses.size
        if scheme == "golomb":
            total_w_bits += float(codes_lib.golomb_length(pulses).sum())
        elif scheme == "rle":
            _, nbits, _ = codes_lib.rle_encode(pulses)
            total_w_bits += nbits
        else:
            raise ValueError(scheme)
        total_scale_bits += 32.0 * np.prod(np.asarray(code.scale).shape)
    return {
        "numel": numel,
        "weight_bits": total_w_bits,
        "scale_bits": total_scale_bits,
        "bits_per_weight": (total_w_bits + total_scale_bits) / max(numel, 1),
        "vs_fp32_ratio": 32.0 * numel / max(total_w_bits + total_scale_bits, 1),
        "vs_bf16_ratio": 16.0 * numel / max(total_w_bits + total_scale_bits, 1),
    }
