"""Pulse-tensor packing for storage and for the Pallas dequant-matmul kernel.

Two formats:
  * ``int8``  — pulses clipped-checked into int8 (experiments: |pulse| <= 7 in
    practice for N/K <= 1, far below 127), plus per-group f32 scales. This is
    the in-HBM format the `pvq_matmul` kernel streams.
  * ``nibble`` — 4-bit two's-complement packing (two pulses/byte) for
    checkpoint storage of layers with |pulse| <= 7; falls back to int8.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .pvq import PVQCode


def pulses_to_int8(code: PVQCode, *, debug: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(int8 pulses, f32 scales).

    The int8-range check is *static*: a P(N, K) coordinate is bounded by K
    (the whole L1 budget on one axis), so ``code.k <= 127`` guarantees the
    cast is lossless without ever inspecting trace-time values — this
    function is safe under ``jit`` (the old ``int(jnp.max(...))`` forced a
    host sync and raised ``TracerConversionError`` when traced).
    ``debug=True`` adds a host-callback runtime check of the actual range.
    """
    if code.k > 127:
        raise ValueError(
            f"pulse budget K={code.k} exceeds the int8 coordinate bound 127; "
            "use kernels.ops.pulses_to_int8 for an explicit clamp"
        )
    p = code.pulses
    if debug:

        def _check(maxabs):
            if int(maxabs) > 127:
                raise ValueError(f"pulse magnitude {int(maxabs)} exceeds int8 range")

        jax.debug.callback(_check, jnp.max(jnp.abs(p)))
    return p.astype(jnp.int8), code.scale.astype(jnp.float32)


def pack_nibbles(pulses: np.ndarray) -> Tuple[np.ndarray, Tuple[int, ...]]:
    """Pack int pulses with |v| <= 7 into uint8 nibbles (lo nibble = even idx)."""
    p = np.asarray(pulses, dtype=np.int64)
    if np.abs(p).max(initial=0) > 7:
        raise ValueError("nibble packing requires |pulse| <= 7")
    shape = p.shape
    flat = p.ravel()
    if flat.size % 2:
        flat = np.concatenate([flat, np.zeros(1, np.int64)])
    u = (flat & 0xF).astype(np.uint8)  # two's complement in 4 bits
    packed = (u[0::2] | (u[1::2] << 4)).astype(np.uint8)
    return packed, shape


def unpack_nibbles(packed: np.ndarray, shape: Tuple[int, ...]) -> np.ndarray:
    total = int(np.prod(shape))
    lo = (packed & 0xF).astype(np.int8)
    hi = ((packed >> 4) & 0xF).astype(np.int8)
    # sign-extend 4-bit two's complement
    lo = np.where(lo > 7, lo - 16, lo)
    hi = np.where(hi > 7, hi - 16, hi)
    flat = np.empty(packed.size * 2, dtype=np.int8)
    flat[0::2] = lo
    flat[1::2] = hi
    return flat[:total].reshape(shape).astype(np.int64)


def packed_nbytes(code: PVQCode, fmt: str = "nibble") -> int:
    """Storage bytes for the code (pulses + scales), for compression reports."""
    n = int(np.prod(code.pulses.shape))
    g = int(np.prod(code.scale.shape))
    if fmt == "nibble":
        return (n + 1) // 2 + 4 * g
    if fmt == "int8":
        return n + 4 * g
    raise ValueError(fmt)
