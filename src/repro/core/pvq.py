"""Pyramid Vector Quantization core (Liguori 2017; Fischer 1986).

The pyramid surface P(N, K) is the set of integer vectors with L1 norm K:

    P(N, K) = { y in Z^N : sum_i |y_i| = K }                        (paper eq. 1)

Product PVQ approximates a real vector ``w`` by a scale ("radius") and a
quantized direction:

    w  ~=  rho * y_hat,   y_hat in P(N, K)                          (paper eq. 2)

The paper's scale choice is rho = ||w||_2 / ||y_hat||_2 (preserving the L2
norm of the original vector).  We additionally provide the least-squares scale
rho* = <w, y_hat> / ||y_hat||^2, which minimizes ||w - rho*y_hat||_2 for a
given y_hat — this is a strict (beyond-paper) improvement and is recorded
separately in experiments.

Encoding (finding the nearest y_hat) uses the standard exact greedy pulse
search ("the most accurate PVQ encoding algorithm known to the author has
O(NK) complexity", paper §VII): pre-allocate floor(K * |w|/||w||_1) pulses,
then place the remaining pulses one at a time on the coordinate that maximizes
the cosine similarity of the running integer vector with |w|.  Per-pulse
placement is O(N); at most min(K, N)+ a few pulses remain after
pre-allocation, so the total is O(N + N*K_rem) <= O(NK), and in the common
K ~ N regime the pre-allocation leaves only O(sqrt(K)) corrections.

All functions are pure JAX (jit/vmap/pjit friendly).  A Pallas TPU kernel for
the batched encoder lives in ``repro.kernels.pvq_encode``; its oracle is
``pvq_encode_ref`` below via ``repro.kernels.ref``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Encoding: projection of a real vector onto P(N, K)
# ---------------------------------------------------------------------------


def _presearch(absw: Array, k: int) -> Array:
    """Initial integer pulse allocation: floor of the L1-scaled magnitudes.

    Guarantees sum(y) <= K with equality rarely; the greedy loop tops up.
    """
    l1 = jnp.sum(absw, axis=-1, keepdims=True)
    # Avoid div-by-zero for null vectors; those encode to y=0 (paper: r=0).
    safe = jnp.where(l1 > 0, l1, 1.0)
    y = jnp.floor(absw * (k / safe))
    return jnp.where(l1 > 0, y, 0.0)


def _greedy_topup(absw: Array, y: Array, k: int, n_iter: Optional[int] = None) -> Array:
    """Place remaining pulses one at a time, maximizing cosine similarity.

    After adding a pulse at coordinate j, the unnormalized correlation becomes
    C + |w_j| and the squared norm becomes E + 2*y_j + 1.  The standard exact
    greedy step (Fischer; also Opus/Daala PVQ search) picks
        argmax_j   (C + |w_j|)^2 / (E + 2*y_j + 1).
    We run a fixed ``n_iter``-iteration fori_loop (shape-static for jit,
    default K); iterations after the budget is exhausted are masked to no-ops.
    """
    n = absw.shape[-1]

    def body(_, state):
        y, corr, energy, remaining = state
        num = (corr[..., None] + absw) ** 2
        den = energy[..., None] + 2.0 * y + 1.0
        score = num / den
        j = jnp.argmax(score, axis=-1)
        onehot = jax.nn.one_hot(j, n, dtype=y.dtype)
        do = (remaining > 0).astype(y.dtype)[..., None]
        y = y + onehot * do
        corr = corr + jnp.take_along_axis(absw, j[..., None], axis=-1)[..., 0] * do[..., 0]
        energy = energy + (2.0 * jnp.take_along_axis(y, j[..., None], axis=-1)[..., 0] - 1.0) * do[..., 0]
        remaining = remaining - (remaining > 0).astype(remaining.dtype)
        return (y, corr, energy, remaining)

    corr = jnp.sum(absw * y, axis=-1)
    energy = jnp.sum(y * y, axis=-1)
    remaining = (k - jnp.sum(y, axis=-1)).astype(jnp.int32)
    if n_iter is not None:
        remaining = jnp.minimum(remaining, n_iter)
    # Pre-allocation leaves at most N fractional remainders but never more
    # than K pulses; K iterations is always enough and shape-static.
    y, _, _, _ = jax.lax.fori_loop(
        0, k if n_iter is None else min(n_iter, k), body, (y, corr, energy, remaining)
    )
    return y


def _select_top_r(frac: Array, r: Array) -> Array:
    """0/1 mask of the ``r`` largest entries of ``frac`` (>= 0) per row, ties
    broken toward lower index — identical to the stable-descending-sort
    selection, but computed as a branchless binary search over IEEE bit
    patterns: ~32 O(N) compare+count passes instead of an O(N log N) sort.
    On the 2.1M-dim layer this is ~10x faster than jnp.argsort on CPU and
    lowers to Mosaic (elementwise + reductions only).  ``r``: int32 (..., 1).
    """
    fb = jax.lax.bitcast_convert_type(frac.astype(jnp.float32), jnp.int32)
    # frac >= 0, so bit patterns order like the floats; find the smallest
    # threshold t with count(fb > t) <= r  (invariant: lo fails, hi holds)
    lo = jnp.full(frac.shape[:-1] + (1,), -1, jnp.int32)
    hi = jnp.full(frac.shape[:-1] + (1,), jnp.int32(0x7F7FFFFF))

    def body(_, state):
        lo, hi = state
        mid = lo + (hi - lo) // 2
        cnt = jnp.sum((fb > mid).astype(jnp.int32), axis=-1, keepdims=True)
        ok = cnt <= r
        return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

    lo, hi = jax.lax.fori_loop(0, 32, body, (lo, hi))
    gt = fb > hi
    extra = r - jnp.sum(gt.astype(jnp.int32), axis=-1, keepdims=True)
    eq = fb == hi
    eq_rank = jnp.cumsum(eq.astype(jnp.int32), axis=-1)
    return (gt | (eq & (eq_rank <= extra))).astype(frac.dtype)


def _largest_remainder_topup(absw: Array, y: Array, k: int) -> Array:
    """Distribute the remaining pulses to the largest fractional parts
    (Hamilton apportionment) in one O(N log N) selection pass.

    For K beyond the greedy budget this is the standard fast PVQ completion
    (Opus/Daala pre-search); the cosine loss vs the exact greedy is
    negligible at large K, and the L1=K constraint is exact.
    """
    l1 = jnp.sum(absw, axis=-1, keepdims=True)
    safe = jnp.where(l1 > 0, l1, 1.0)
    frac = absw * (k / safe) - y
    remaining = (k - jnp.sum(y, axis=-1, keepdims=True)).astype(jnp.int32)
    bump = _select_top_r(frac, remaining).astype(y.dtype)
    return y + jnp.where(l1 > 0, bump, 0.0)


def _sorted_topup(absw: Array, y: Array, k: int, delta_max: int) -> Array:
    """Sort-based completion: largest-remainder bulk allocation for all but the
    last ``delta_max`` missing pulses, then the exact greedy argmax for those.

    One O(N log N) sort replaces the O(N*K) pulse loop (the follow-up "PVQ for
    LLMs" fast projection); the bounded greedy tail keeps the result within
    ~1e-4 cosine of the exact search, and bit-exact whenever the floor
    pre-allocation leaves <= delta_max pulses (always true for K <= delta_max).
    """
    l1 = jnp.sum(absw, axis=-1, keepdims=True)
    safe = jnp.where(l1 > 0, l1, 1.0)
    target = absw * (k / safe)
    frac = target - y
    remaining = (k - jnp.sum(y, axis=-1, keepdims=True)).astype(jnp.int32)
    bulk = jnp.maximum(remaining - delta_max, 0)
    bump = _select_top_r(frac, bulk).astype(y.dtype)
    y = y + jnp.where(l1 > 0, bump, 0.0)
    return _greedy_topup(absw, y, k, n_iter=delta_max)


@partial(jax.jit, static_argnames=("k", "delta_max"))
def pvq_quantize_direction_fast(w: Array, k: int, delta_max: int = 32) -> Array:
    """O(N log N + N*delta_max) projection of the last axis onto P(N, K).

    The fast-path twin of :func:`pvq_quantize_direction` used by the kernel
    dispatch layer (QAT projection, gradient compression): floor init +
    largest-remainder sort + bounded greedy correction.  Exact L1 = K by
    construction; matches the exact greedy search bit-for-bit when
    K <= delta_max.
    """
    absw = jnp.abs(w.astype(jnp.float32))
    y = _presearch(absw, k)
    y = _sorted_topup(absw, y, k, delta_max)
    return (jnp.sign(w) * y).astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "greedy_max"))
def pvq_quantize_direction(w: Array, k: int, greedy_max: int = 1024) -> Array:
    """Project the last axis of ``w`` onto P(N, K). Returns integer pulses with sign.

    Works on arbitrary leading batch dims.  K <= greedy_max uses the exact
    greedy O(NK) search (paper §VII); larger K switches to floor allocation +
    largest-remainder completion, O(N log N) — the practical algorithm for the
    paper's million-dimensional layers (the paper resorted to CUDA; one sort
    suffices on TPU/CPU).
    """
    absw = jnp.abs(w.astype(jnp.float32))
    y = _presearch(absw, k)
    if k <= greedy_max:
        y = _greedy_topup(absw, y, k)
    else:
        y = _largest_remainder_topup(absw, y, k)
    return (jnp.sign(w) * y).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class PVQCode:
    """A product-PVQ code: integer pulses on P(N,K) plus a scalar scale per group."""

    pulses: Array  # int32, shape (..., N), sum(|pulses|, -1) == K (or 0 for null)
    scale: Array   # f32, shape (...,), the rho factor
    k: int         # pulse budget (static)

    def dequantize(self, dtype=jnp.float32) -> Array:
        return (self.scale[..., None] * self.pulses.astype(jnp.float32)).astype(dtype)


def _scales(w: Array, pulses: Array, mode: str) -> Array:
    y = pulses.astype(jnp.float32)
    ynorm2 = jnp.sum(y * y, axis=-1)
    safe = jnp.where(ynorm2 > 0, ynorm2, 1.0)
    if mode == "paper":
        # rho = ||w||_2 / ||y||_2                      (paper eq. 2/3)
        r = jnp.linalg.norm(w.astype(jnp.float32), axis=-1)
        rho = r / jnp.sqrt(safe)
    elif mode == "ls":
        # least-squares optimal scale for the chosen y_hat (beyond-paper)
        rho = jnp.sum(w.astype(jnp.float32) * y, axis=-1) / safe
        rho = jnp.maximum(rho, 0.0)  # greedy search keeps <w,y> >= 0
    else:
        raise ValueError(f"unknown scale mode {mode!r}")
    return jnp.where(ynorm2 > 0, rho, 0.0)


@partial(jax.jit, static_argnames=("k", "scale_mode"))
def pvq_encode(w: Array, k: int, scale_mode: str = "paper") -> PVQCode:
    """Product-PVQ encode the last axis of ``w`` with pulse budget K."""
    pulses = pvq_quantize_direction(w, k)
    scale = _scales(w, pulses, scale_mode)
    return PVQCode(pulses=pulses, scale=scale, k=k)


def pvq_decode(code: PVQCode, dtype=jnp.float32) -> Array:
    return code.dequantize(dtype)


jax.tree_util.register_pytree_node(
    PVQCode,
    lambda c: ((c.pulses, c.scale), c.k),
    lambda k, xs: PVQCode(pulses=xs[0], scale=xs[1], k=k),
)


# ---------------------------------------------------------------------------
# Grouped encoding: quantize a big vector as G groups of size N
# ---------------------------------------------------------------------------


def pvq_encode_grouped(w: Array, group: int, k: int, scale_mode: str = "paper") -> PVQCode:
    """Encode a flat vector (or batch of vectors) in groups of ``group`` dims.

    The paper encodes whole layers as one huge vector (single rho).  Grouped
    encoding (rho per group) is the practical variant used by our TPU matmul
    kernel; group=whole-layer reproduces the paper exactly.
    Pads with zeros to a multiple of ``group`` (zeros never receive pulses).
    """
    n = w.shape[-1]
    pad = (-n) % group
    if pad:
        w = jnp.concatenate([w, jnp.zeros(w.shape[:-1] + (pad,), w.dtype)], axis=-1)
    gshape = w.shape[:-1] + (w.shape[-1] // group, group)
    return pvq_encode(w.reshape(gshape), k, scale_mode)


def pvq_decode_grouped(code: PVQCode, n: int, dtype=jnp.float32) -> Array:
    flat = code.dequantize(dtype)
    flat = flat.reshape(flat.shape[:-2] + (-1,))
    return flat[..., :n]


# ---------------------------------------------------------------------------
# Dot products with PVQ codes + op-count accounting (paper §III)
# ---------------------------------------------------------------------------


def pvq_dot(code: PVQCode, x: Array) -> Array:
    """rho * (y_hat . x) — numerically identical to dot(dequantize, x)."""
    acc = jnp.sum(code.pulses.astype(jnp.float32) * x.astype(jnp.float32), axis=-1)
    return code.scale * acc


def dot_op_counts(code: PVQCode) -> dict:
    """Paper §III claim: y_hat . x costs exactly K-1 adds/subs (unit-pulse
    evaluation) and the scale is ONE multiplication.  Returns the claimed
    counts and the naive counts for comparison.  (Host-side accounting.)
    """
    pulses = np.asarray(code.pulses)
    n = pulses.shape[-1]
    k_actual = int(np.abs(pulses).sum(axis=-1).max()) if pulses.size else 0
    return {
        "N": int(n),
        "K": int(code.k),
        "pvq_adds": max(k_actual - 1, 0),
        "pvq_muls": 1,
        "naive_adds": n - 1,
        "naive_muls": n,
        "nonzero": int((pulses != 0).sum(axis=-1).max()) if pulses.size else 0,
    }


# ---------------------------------------------------------------------------
# Host-side exact encoder (numpy, heap-free reference for tests/tools)
# ---------------------------------------------------------------------------


def pvq_encode_np(
    w: np.ndarray, k: int, scale_mode: str = "paper", greedy_max: int = 1024
) -> Tuple[np.ndarray, float]:
    """Reference single-vector encoder in numpy (used by enumeration tools and
    brute-force tests). Same algorithm (and K switch) as the JAX path."""
    w = np.asarray(w, dtype=np.float64)
    absw = np.abs(w)
    l1 = absw.sum()
    if l1 == 0:
        return np.zeros(w.shape, np.int64), 0.0
    y = np.floor(absw * (k / l1))
    if k <= greedy_max:
        corr = float((absw * y).sum())
        energy = float((y * y).sum())
        remaining = int(k - y.sum())
        for _ in range(remaining):
            score = (corr + absw) ** 2 / (energy + 2.0 * y + 1.0)
            j = int(np.argmax(score))
            y[j] += 1
            corr += absw[j]
            energy += 2.0 * y[j] - 1.0
    else:
        frac = absw * (k / l1) - y
        remaining = int(k - y.sum())
        order = np.argsort(-frac, kind="stable")
        rank_of = np.argsort(order, kind="stable")
        y = y + (rank_of < remaining)
    y = (np.sign(w) * y).astype(np.int64)
    ynorm = float(np.sqrt((y.astype(np.float64) ** 2).sum()))
    if scale_mode == "paper":
        rho = float(np.linalg.norm(w) / ynorm)
    else:
        rho = float((w * y).sum() / (ynorm**2))
    return y, rho
