"""Entropy-coding size models and codecs for PVQ pulse vectors (paper §VI).

The paper proposes, in order of practicality:
  * fixed-length enumeration codes  -> ``repro.core.enumeration``
  * signed exponential-Golomb codes  (1 bit for 0, 3 for +/-1, 5 for +/-2..3,
    7 for +/-4..7, ... — exactly the ladder used in the paper's Table-5
    arithmetic: FC0 of net A averages ~1.4 bits/weight)
  * run-length coding of zero runs (N/K ~ 5 -> >= 4/5 zeros guaranteed)
  * Huffman with an escape code for |v| > V

This module implements bit-exact encoders/decoders for Golomb and RLE (used by
the PVQ-compressed checkpoint format) and size estimators for all schemes.
"""

from __future__ import annotations

import heapq
from collections import Counter
from typing import Dict, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# signed exp-Golomb (order 0), zigzag mapping  v -> u:  0,+1,-1,+2,-2 -> 0,1,2,3,4
# ---------------------------------------------------------------------------


def zigzag(v: np.ndarray) -> np.ndarray:
    v = np.asarray(v, dtype=np.int64)
    return np.where(v > 0, 2 * v - 1, -2 * v)


def unzigzag(u: np.ndarray) -> np.ndarray:
    u = np.asarray(u, dtype=np.int64)
    t = (u + 1) >> 1  # == |v| for both parities (u >= 0 by construction)
    return np.where(u & 1, t, -t)


def golomb_length(v: np.ndarray) -> np.ndarray:
    """Code length in bits of signed exp-Golomb order 0 for each value."""
    u = zigzag(v)
    return 2 * np.floor(np.log2(u + 1)).astype(np.int64) + 1


def golomb_encode(values: np.ndarray) -> Tuple[bytes, int]:
    """Bit-exact encoder. Returns (blob, nbits)."""
    u = zigzag(np.asarray(values).ravel())
    bits = []
    for x in u.tolist():
        x1 = x + 1
        nb = x1.bit_length()
        bits.append("0" * (nb - 1) + format(x1, "b"))
    stream = "".join(bits)
    nbits = len(stream)
    if nbits == 0:
        return b"", 0
    stream_padded = stream + "0" * ((8 - nbits % 8) % 8)
    blob = int(stream_padded, 2).to_bytes(len(stream_padded) // 8, "big")
    return blob, nbits


def golomb_decode(blob: bytes, nbits: int, count: int) -> np.ndarray:
    stream = bin(int.from_bytes(blob, "big"))[2:].zfill(len(blob) * 8)[:nbits] if blob else ""
    out = []
    i = 0
    for _ in range(count):
        z = 0
        while stream[i] == "0":
            z += 1
            i += 1
        x1 = int(stream[i : i + z + 1], 2)
        i += z + 1
        out.append(x1 - 1)
    return unzigzag(np.asarray(out, dtype=np.int64))


# ---------------------------------------------------------------------------
# zero run-length + Golomb values (good fit for N/K >= 5 FC layers)
# ---------------------------------------------------------------------------


def rle_flat_pairs(values: np.ndarray) -> np.ndarray:
    """Interleaved (zero-run, nonzero-value) pair stream of ``values``.

    Vectorized: one pair per nonzero (zeros preceding it, then the value),
    plus — when the vector ends in zeros — a terminator pair with value 0
    (invalid as a nonzero).  Returns the flat int64 symbol stream
    ``[run0, v0, run1, v1, ...]`` of length ``2 * n_pairs``.
    """
    v = np.asarray(values, dtype=np.int64).ravel()
    nz = np.flatnonzero(v)
    runs = np.diff(np.concatenate([np.asarray([-1]), nz])) - 1
    vals = v[nz]
    trailing = v.size - (int(nz[-1]) + 1 if nz.size else 0)
    if trailing:
        runs = np.concatenate([runs, np.asarray([trailing])])
        vals = np.concatenate([vals, np.asarray([0])])
    flat = np.empty(2 * runs.size, dtype=np.int64)
    flat[0::2] = runs
    flat[1::2] = vals
    return flat


def rle_bits(values: np.ndarray) -> int:
    """Exact bit count of :func:`rle_encode` without building the stream —
    the size model the artifact codec chooser and ``packed_stats`` use."""
    flat = rle_flat_pairs(values)
    return int(golomb_length(flat).sum()) if flat.size else 0


def rle_encode(values: np.ndarray) -> Tuple[bytes, int, int]:
    """(zero-run, nonzero-value) pair stream; both exp-Golomb coded.

    Returns (blob, nbits, n_pairs). A final run with no trailing value is
    encoded as a pair with value 0 (invalid as a nonzero, acts as terminator).
    """
    flat = rle_flat_pairs(values)
    blob, nbits = golomb_encode(flat)
    return blob, nbits, flat.size // 2


def rle_decode(blob: bytes, nbits: int, n_pairs: int, total: int) -> np.ndarray:
    flat = golomb_decode(blob, nbits, 2 * n_pairs)
    out = []
    for i in range(n_pairs):
        run, val = int(flat[2 * i]), int(flat[2 * i + 1])
        out.extend([0] * run)
        if val != 0:
            out.append(val)
    out.extend([0] * (total - len(out)))
    return np.asarray(out, dtype=np.int64)


# ---------------------------------------------------------------------------
# Huffman-with-escape size model (paper's practical table scheme)
# ---------------------------------------------------------------------------


def huffman_escape_bits(values: np.ndarray, v_max: int = 7, escape_payload_bits: int = 16) -> float:
    """Average bits/value of a Huffman code over {-v_max..v_max} + ESC."""
    v = np.asarray(values).ravel()
    inlier = np.abs(v) <= v_max
    counts = Counter(v[inlier].tolist())
    n_esc = int((~inlier).sum())
    if n_esc:
        counts["ESC"] = n_esc
    if len(counts) == 1:
        return 1.0
    heap = [(c, i, sym) for i, (sym, c) in enumerate(counts.items())]
    heapq.heapify(heap)
    depth: Dict = {sym: 0 for sym in counts}
    groups = {i: [sym] for i, (sym, _) in enumerate(counts.items())}
    next_id = len(groups)
    heap = [(c, i) for i, (sym, c) in enumerate(counts.items())]
    heapq.heapify(heap)
    while len(heap) > 1:
        c1, g1 = heapq.heappop(heap)
        c2, g2 = heapq.heappop(heap)
        for sym in groups[g1] + groups[g2]:
            depth[sym] += 1
        groups[next_id] = groups.pop(g1) + groups.pop(g2)
        heapq.heappush(heap, (c1 + c2, next_id))
        next_id += 1
    total_bits = sum(counts[sym] * depth[sym] for sym in counts)
    total_bits += n_esc * escape_payload_bits
    return total_bits / max(len(v), 1)


# ---------------------------------------------------------------------------
# Reports
# ---------------------------------------------------------------------------


def pulse_histogram(values: np.ndarray) -> Dict[str, float]:
    """Bucketized stats exactly as in the paper's Tables 5-8."""
    v = np.abs(np.asarray(values).ravel())
    n = max(v.size, 1)
    buckets = {
        "0": int((v == 0).sum()),
        "+-1": int((v == 1).sum()),
        "+-2..3": int(((v >= 2) & (v <= 3)).sum()),
        "+-4..7": int(((v >= 4) & (v <= 7)).sum()),
        "others": int((v > 7).sum()),
    }
    out = {}
    for k_, c in buckets.items():
        out[k_] = c
        out[k_ + "_pct"] = 100.0 * c / n
    return out


def compression_report(values: np.ndarray, n: int | None = None, k: int | None = None) -> Dict[str, float]:
    """Bits/weight under each §VI scheme (+ fixed enumeration bound if n,k given)."""
    v = np.asarray(values).ravel()
    count = max(v.size, 1)
    golomb_bits = float(golomb_length(v).sum()) / count
    _, rle_nbits, _ = rle_encode(v)
    report = {
        "golomb_bits_per_weight": golomb_bits,
        "rle_bits_per_weight": rle_nbits / count,
        "huffman_esc_bits_per_weight": huffman_escape_bits(v),
        "raw_int8_bits_per_weight": 8.0,
    }
    if n is not None and k is not None and n <= 4096:
        from .enumeration import index_bits

        report["enumeration_bits_per_weight"] = index_bits(n, k) / n
    return report
