"""The unified packed PVQ parameter representation.

``PackedPVQ`` is the *single* quantized-weight artifact of this repo: the
int8 pulse tensor plus per-group f32 scales, carried together with the
static metadata (group size, pulse budget K, original shape/dtype, layout)
needed to consume it anywhere — the Pallas int8-native matmul, the serving
layers, the checkpointer, the sharding rules, and the gradient pipeline all
speak this one type.  The paper's value proposition is exactly this: the
PVQ code is both the storage format (≈1 byte/weight before entropy coding)
and the compute format (adds/subs + ONE multiply per group), so a weight is
encoded once and never expanded back to a full f32 matrix on the hot path.

Two physical layouts:

* ``'matmul'`` — pulses ``(k_pad, n)`` int8 / scales ``(k_pad//group, n)``
  f32, the exact HBM layout ``repro.kernels.ops.pvq_matmul`` streams.  Used
  for 2-D dense kernels (and their scan-stacked ``(repeats, k_pad, n)``
  variants: the leading axes ride along as batch dims, so ``lax.scan``
  slices a packed layer per step with zero repacking).
* ``'flat'`` — pulses ``(G, group)`` int8 / scales ``(G,)`` f32, row-major
  groups of the flattened original tensor.  Used for embeddings (group is
  chosen to divide ``d`` so a token row maps to whole groups — lookups
  gather + dequantize only the touched rows) and any other non-matmul leaf.

``PackedPVQ`` is registered as a pytree node with named children
(``pulses``/``scales``); the metadata is static aux data.  That makes packed
params transparently compatible with ``jit``, ``lax.scan`` over stacked
layers, ``jax.device_put`` with shardings, and the checkpointer's
path-keyed flattening.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .quantize import KVQuant, QuantPolicy, _path_str, k_for

Array = jax.Array

#: the MoE expert banks `_pack_leaf` packs into the expert-stacked matmul
#: layout — THE predicate every expert-bank report (serve, export,
#: moe_bench) filters with, so they can never drift from what is packed.
EXPERT_LEAF_REGEX = r"(wi_up|wi_gate|wo)_experts$"

#: leaves the packed policy must never touch even when a rule matches:
#: conv kernels and learned positions are consumed raw (einsum / dynamic
#: slice), and the MLA absorbed-decode b-projections are reshaped per head
#: at decode time — packing them would force a per-step dequant.
PACK_SKIP_REGEX = r"(conv_kernel|pos_embedding|wk_b|wv_b|time_|router)"


def _fit_group(group: int, dim: int) -> int:
    """Largest power-of-two divisor chain of ``group`` that divides ``dim``."""
    g = max(int(group), 1)
    while g > 1 and dim % g:
        g //= 2
    return max(g, 1)


def matmul_plan(group: int, d_in: int) -> Tuple[int, int]:
    """(effective group, group-padded contraction dim) for a matmul-layout
    pack of a ``(d_in, n)`` kernel.  This is THE shape derivation the packed
    artifact dispatches with — anything pre-tuning kernel tiles (e.g.
    ``launch/serve.py --tune``) must key on exactly these values."""
    g = _fit_group(group, d_in) if d_in < group else int(group)
    k_pad = -(-d_in // g) * g
    return g, k_pad


def _resolve_k(g: int, n_over_k: Optional[float], k: Optional[int]) -> int:
    if (n_over_k is None) == (k is None):
        raise ValueError("pass exactly one of n_over_k / k")
    return int(k) if k is not None else k_for(g, n_over_k)


@dataclasses.dataclass(frozen=True, eq=False)
class PackedPVQ:
    """One PVQ-coded tensor: int8 pulses + per-group f32 scales + metadata.

    ``shape``/``dtype`` describe the logical dense tensor (unstacked — extra
    leading axes on ``pulses``/``scales`` are treated as batch/stack dims).
    """

    pulses: Array  # int8; 'matmul': (..., k_pad, n)  'flat': (..., G, group)
    scales: Array  # f32;  'matmul': (..., k_pad//group, n)  'flat': (..., G)
    group: int  # group size (static)
    k: int  # pulse budget per group (static)
    shape: Tuple[int, ...]  # logical dense shape (unstacked)
    dtype: str  # logical dense dtype name
    layout: str = "matmul"  # 'matmul' | 'flat'
    scale_mode: str = "ls"

    # ------------------------------------------------------------- properties

    @property
    def k_pad(self) -> int:
        """Group-padded contraction extent (matmul layout)."""
        return int(self.pulses.shape[-2]) if self.layout == "matmul" else 0

    @property
    def nbytes_packed(self) -> int:
        """HBM bytes of the packed artifact (int8 pulses + f32 scales)."""
        return int(np.prod(self.pulses.shape)) + 4 * int(np.prod(self.scales.shape))

    @property
    def nbytes_dense(self) -> int:
        """Bytes of the dense tensor this replaces (at its logical dtype)."""
        lead = self.pulses.shape[: self.pulses.ndim - 2]
        itemsize = jnp.dtype(self.dtype).itemsize
        return int(np.prod(lead, initial=1)) * int(np.prod(self.shape)) * itemsize

    # ------------------------------------------------------------ dequantize

    def dequantize(self, dtype=None) -> Array:
        """Expand back to the logical dense tensor (leading stack dims kept).

        This is the *cold* path — tests, tooling, and the few consumers with
        no packed compute path.  Hot paths stream ``pulses``/``scales``.
        """
        out_dtype = jnp.dtype(dtype if dtype is not None else self.dtype)
        p = self.pulses.astype(jnp.float32)
        if self.layout == "matmul":
            w = p * jnp.repeat(self.scales, self.group, axis=-2)
            lead = w.shape[:-2]
            w = w[..., : self.shape[-2], :]
            return w.reshape(*lead, *self.shape).astype(out_dtype)
        deq = p * self.scales[..., None]
        lead = deq.shape[:-2]
        flat = deq.reshape(*lead, -1)[..., : int(np.prod(self.shape))]
        return flat.reshape(*lead, *self.shape).astype(out_dtype)

    def __repr__(self) -> str:  # keep pytree dumps readable
        return (
            f"PackedPVQ(shape={self.shape}, dtype={self.dtype}, layout={self.layout!r}, "
            f"group={self.group}, k={self.k}, pulses={tuple(self.pulses.shape)})"
        )


def _packed_flatten_with_keys(p: PackedPVQ):
    children = (
        (jax.tree_util.DictKey("pulses"), p.pulses),
        (jax.tree_util.DictKey("scales"), p.scales),
    )
    aux = (p.group, p.k, p.shape, p.dtype, p.layout, p.scale_mode)
    return children, aux


def _packed_unflatten(aux, children):
    group, k, shape, dtype, layout, scale_mode = aux
    return PackedPVQ(
        pulses=children[0], scales=children[1], group=group, k=k,
        shape=shape, dtype=dtype, layout=layout, scale_mode=scale_mode,
    )


jax.tree_util.register_pytree_with_keys(
    PackedPVQ,
    _packed_flatten_with_keys,
    lambda aux, xs: _packed_unflatten(aux, xs),
)


def is_packed(leaf: Any) -> bool:
    return isinstance(leaf, PackedPVQ)


def materialize(leaf: Any, dtype=None) -> Array:
    """Dense view of a (possibly packed) leaf — the sanctioned escape hatch
    for consumers without a packed compute path."""
    if is_packed(leaf):
        return leaf.dequantize(dtype)
    return leaf if dtype is None else leaf.astype(dtype)


# ---------------------------------------------------------------------------
# PackedKV: the PVQ-compressed attention KV cache (kernel v4 consumer)
# ---------------------------------------------------------------------------


def _kv_encode_planes(x: Array, group: int, k: int) -> Tuple[Array, Array]:
    """PVQ-encode the head dim of ``x (..., hd)`` in ``hd // group`` groups.

    Returns ``(pulses int8 (..., hd), scales f32 (..., hd // group))`` with
    the least-squares rho fitted against the int8 pulses actually stored.
    Jit-safe (static ``group``/``k``) — this runs *inside* the traced decode
    step every time a cache block fills.
    """
    from .pvq import _scales, pvq_quantize_direction_fast

    shp = x.shape
    ng = shp[-1] // group
    xg = x.astype(jnp.float32).reshape(shp[:-1] + (ng, group))
    pulses = pvq_quantize_direction_fast(xg, k)
    p8 = jnp.clip(pulses, -127, 127).astype(jnp.int8)
    scales = _scales(xg, p8, "ls").astype(jnp.float32)
    if not isinstance(x, jax.core.Tracer):
        # eager calls only — inside the jitted decode step x is a tracer
        # and the probe never runs (host-side hooks only)
        _probe_kv_encode(xg, p8, scales)
    return p8.reshape(shp), scales


def _probe_kv_encode(xg, p8, scales) -> None:
    """KV-block reconstruction SNR + scale-saturation probe (eager only)."""
    from repro.runtime import obs, telemetry

    if not obs.enabled():
        return
    ref = np.asarray(xg)
    pn = np.asarray(p8)
    sn = np.asarray(scales)
    approx = pn.astype(np.float32) * sn[..., None]
    obs.counter("quant.kv_blocks_probed").inc()
    obs.histogram("quant.kv_snr_db").record(telemetry.snr_db(ref, approx))
    if pn.size:
        obs.histogram("quant.kv_clamp_frac").record(
            float(np.count_nonzero(np.abs(pn) == 127)) / pn.size
        )
    if sn.size:
        obs.histogram("quant.kv_zero_scale_frac").record(
            float(np.count_nonzero(sn == 0)) / sn.size
        )


@dataclasses.dataclass(frozen=True, eq=False)
class PackedKV:
    """Block-aligned PVQ-compressed KV cache for one attention layer.

    Layout (``S`` = block-padded cache length, ``ng = head_dim // group``):

    * ``k_pulses``/``v_pulses`` — ``(b, S, n_kv, head_dim)`` int8 pulse
      planes, one PVQ code of P(group, k) per (token, kv-head, sub-group);
    * ``k_scales``/``v_scales`` — ``(b, S, n_kv, ng)`` f32 per-group rho;
    * ``tail_k``/``tail_v`` — ``(b, block, n_kv, head_dim)`` ring in the
      logical cache dtype holding the in-flight partial block.  Slot
      ``pos % block`` holds position ``pos``; the moment a block completes
      (``(pos+1) % block == 0``) it is encoded and stored at
      ``pos + 1 - block`` in the pulse planes, and the ring is reused.

    The split between packed and tail is *physical*: positions below
    ``packed_end(filled) = (filled // block) * block`` are served from the
    pulse planes, positions in ``[packed_end, filled)`` from the exact
    tail.  Per-batch ragged ``length`` masks only — it never moves the
    split, because every batch row shares the same global write position.

    Registered as a pytree with named children, so the cache shards with
    path-keyed rules (``kv/k_pulses`` ...), rides ``lax.scan`` over stacked
    layers, and pads along the sequence axis like the dense cache.
    """

    k_pulses: Array  # int8 (b, S, n_kv, hd)
    k_scales: Array  # f32  (b, S, n_kv, ng)
    v_pulses: Array  # int8 (b, S, n_kv, hd)
    v_scales: Array  # f32  (b, S, n_kv, ng)
    tail_k: Array  # cache dtype (b, block, n_kv, hd)
    tail_v: Array  # cache dtype (b, block, n_kv, hd)
    block: int  # tokens per encoded block (static)
    group: int  # effective sub-head PVQ group (static, divides hd)
    k: int  # pulse budget per group (static, <= 127)
    dtype: str  # logical cache dtype name (tail dtype, dequantize target)

    # ------------------------------------------------------------- properties

    @property
    def head_dim(self) -> int:
        return int(self.k_pulses.shape[-1])

    @property
    def n_groups(self) -> int:
        return int(self.k_scales.shape[-1])

    @property
    def max_len(self) -> int:
        """Block-padded cache length (>= the requested max_len)."""
        return int(self.k_pulses.shape[-3])

    @property
    def packed_bytes_per_token(self) -> int:
        """HBM bytes per token per kv-head pair (K+V pulses + scales)."""
        return 2 * (self.head_dim + 4 * self.n_groups)

    @property
    def dense_bytes_per_token(self) -> int:
        """Bytes per token per kv-head pair of the dense cache it replaces."""
        return 2 * self.head_dim * jnp.dtype(self.dtype).itemsize

    def packed_end(self, filled) -> Array:
        """First position served from the tail (= completed-block extent)."""
        return (filled // self.block) * self.block

    # -------------------------------------------------------------- creation

    @classmethod
    def init(
        cls, batch: int, max_len: int, n_kv: int, head_dim: int,
        *, kvq: KVQuant, dtype=jnp.bfloat16,
    ) -> "PackedKV":
        g = _fit_group(kvq.group, head_dim)
        blk = int(kvq.block)
        s_pad = -(-int(max_len) // blk) * blk
        ng = head_dim // g
        dt = jnp.dtype(dtype)
        return cls(
            k_pulses=jnp.zeros((batch, s_pad, n_kv, head_dim), jnp.int8),
            k_scales=jnp.zeros((batch, s_pad, n_kv, ng), jnp.float32),
            v_pulses=jnp.zeros((batch, s_pad, n_kv, head_dim), jnp.int8),
            v_scales=jnp.zeros((batch, s_pad, n_kv, ng), jnp.float32),
            tail_k=jnp.zeros((batch, blk, n_kv, head_dim), dt),
            tail_v=jnp.zeros((batch, blk, n_kv, head_dim), dt),
            block=blk, group=g, k=int(kvq.k), dtype=dt.name,
        )

    @classmethod
    def from_dense(cls, k: Array, v: Array, *, kvq: KVQuant, dtype=None) -> "PackedKV":
        """Encode a dense prefill cache ``(b, s, n_kv, hd)`` pair.

        The ``s // block`` complete blocks are encoded into the pulse
        planes; the remainder lands in the tail at slots ``0 .. s%block-1``
        (= ``pos % block`` for those positions, matching ``append``).
        """
        b, s, n_kv, hd = k.shape
        dt = jnp.dtype(dtype if dtype is not None else k.dtype)
        pkv = cls.init(b, s, n_kv, hd, kvq=kvq, dtype=dt)
        blk = pkv.block
        n_full = s // blk
        rem = s - n_full * blk
        new = {}
        if n_full:
            full_k = k[:, : n_full * blk].astype(jnp.float32)
            full_v = v[:, : n_full * blk].astype(jnp.float32)
            kp, ks = _kv_encode_planes(full_k, pkv.group, pkv.k)
            vp, vs = _kv_encode_planes(full_v, pkv.group, pkv.k)
            new.update(
                k_pulses=pkv.k_pulses.at[:, : n_full * blk].set(kp),
                k_scales=pkv.k_scales.at[:, : n_full * blk].set(ks),
                v_pulses=pkv.v_pulses.at[:, : n_full * blk].set(vp),
                v_scales=pkv.v_scales.at[:, : n_full * blk].set(vs),
            )
        if rem:
            new.update(
                tail_k=pkv.tail_k.at[:, :rem].set(k[:, n_full * blk :].astype(dt)),
                tail_v=pkv.tail_v.at[:, :rem].set(v[:, n_full * blk :].astype(dt)),
            )
        return dataclasses.replace(pkv, **new) if new else pkv

    # --------------------------------------------------------------- updates

    def append(self, k_new: Array, v_new: Array, pos) -> "PackedKV":
        """Write one decode step ``(b, 1, n_kv, hd)`` at position ``pos``.

        The write always lands in the tail ring (cast to the *cache* dtype,
        never the projection dtype); when it completes a block, the whole
        block is PVQ-encoded and stored into the pulse planes.
        """
        blk = self.block
        tdt = self.tail_k.dtype
        slot = jnp.mod(pos, blk)
        tail_k = jax.lax.dynamic_update_slice_in_dim(
            self.tail_k, k_new.astype(tdt), slot, axis=1
        )
        tail_v = jax.lax.dynamic_update_slice_in_dim(
            self.tail_v, v_new.astype(tdt), slot, axis=1
        )

        def encode(planes):
            kp, ks, vp, vs = planes
            start = pos + 1 - blk
            pk, sk = _kv_encode_planes(tail_k, self.group, self.k)
            pv, sv = _kv_encode_planes(tail_v, self.group, self.k)
            upd = jax.lax.dynamic_update_slice_in_dim
            return (
                upd(kp, pk, start, axis=1),
                upd(ks, sk, start, axis=1),
                upd(vp, pv, start, axis=1),
                upd(vs, sv, start, axis=1),
            )

        planes = (self.k_pulses, self.k_scales, self.v_pulses, self.v_scales)
        kp, ks, vp, vs = jax.lax.cond(
            jnp.mod(pos + 1, blk) == 0, encode, lambda p: p, planes
        )
        return dataclasses.replace(
            self, k_pulses=kp, k_scales=ks, v_pulses=vp, v_scales=vs,
            tail_k=tail_k, tail_v=tail_v,
        )

    # ------------------------------------------------------------ dequantize

    def dense_kv(self, filled, dtype=jnp.float32) -> Tuple[Array, Array]:
        """Exact dense view ``(k, v)`` of shape ``(b, S, n_kv, hd)``.

        Positions below ``packed_end(filled)`` are dequantized from the
        pulse planes; positions at/above it come from the tail ring via a
        gather + where overlay (no dynamic_update_slice — its index
        clamping would corrupt rows when the tail window runs past ``S``).
        Rows beyond ``filled`` carry garbage and must stay length-masked.
        """
        blk = self.block
        s = self.max_len
        # filled may be scalar or per-batch (b,); broadcast against positions
        pe = jnp.atleast_1d(self.packed_end(filled))[:, None]  # (b|1, 1)
        posn = jnp.arange(s)[None, :]  # (1, S)

        def expand(pulses, scales):
            return pulses.astype(jnp.float32) * jnp.repeat(
                scales, self.group, axis=-1
            )

        tidx = jnp.mod(posn - pe, blk)  # (b|1, S)
        mask = (posn >= pe)[:, :, None, None]

        def overlay(deq, tail):
            t_full = jnp.take_along_axis(
                tail.astype(jnp.float32), tidx[:, :, None, None], axis=1
            )
            return jnp.where(mask, t_full, deq)

        k = overlay(expand(self.k_pulses, self.k_scales), self.tail_k)
        v = overlay(expand(self.v_pulses, self.v_scales), self.tail_v)
        return k.astype(dtype), v.astype(dtype)

    def __repr__(self) -> str:  # keep pytree dumps readable
        return (
            f"PackedKV(shape={tuple(self.k_pulses.shape)}, dtype={self.dtype}, "
            f"block={self.block}, group={self.group}, k={self.k})"
        )


def _packed_kv_flatten_with_keys(p: PackedKV):
    names = ("k_pulses", "k_scales", "v_pulses", "v_scales", "tail_k", "tail_v")
    children = tuple(
        (jax.tree_util.DictKey(n), getattr(p, n)) for n in names
    )
    aux = (p.block, p.group, p.k, p.dtype)
    return children, aux


def _packed_kv_unflatten(aux, children):
    block, group, k, dtype = aux
    return PackedKV(
        k_pulses=children[0], k_scales=children[1],
        v_pulses=children[2], v_scales=children[3],
        tail_k=children[4], tail_v=children[5],
        block=block, group=group, k=k, dtype=dtype,
    )


jax.tree_util.register_pytree_with_keys(
    PackedKV,
    _packed_kv_flatten_with_keys,
    lambda aux, xs: _packed_kv_unflatten(aux, xs),
)


def is_packed_kv(leaf: Any) -> bool:
    return isinstance(leaf, PackedKV)


# ---------------------------------------------------------------------------
# Paged PVQ KV pool (continuous-batching serve engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class PagedKV:
    """Physical-page pool view of :class:`PackedKV` for a slot-pool engine.

    The continuous-batching engine (``launch.engine``) serves a fixed pool
    of ``n_slots`` decode slots whose sequences join and leave mid-flight.
    Instead of one contiguous plane per slot, the PVQ-encoded KV blocks
    live in a shared pool of physical *pages* — **page size = kv block
    size**, so a page is exactly one PVQ encode unit and pages stay packed
    at rest (int8 pulse planes + per-group rho, never re-encoded on
    allocator moves; moving a page is moving int8 bytes).

    Children (unstacked; a leading layer-stack axis rides along like every
    other cache leaf):

    * ``k_pages``/``v_pages`` — ``(P + 1, page, n_kv, hd)`` int8 pulse
      pool.  Physical page ``P`` (the last one) is the *trash page*:
      masked scatter destinations land there, and page-table entries of
      unallocated logical blocks point at it.  Its content is garbage and
      is never visible through a length mask.
    * ``k_page_scales``/``v_page_scales`` — ``(P + 1, page, n_kv, ng)``
      f32 per-group rho pool.
    * ``tail_k``/``tail_v`` — ``(n_slots, page, n_kv, hd)`` exact ring in
      the cache dtype: the per-slot in-flight partial block (ring slot of
      position ``p`` is ``p % page``, same as :class:`PackedKV`).
    * ``page_table`` — ``(n_slots, max_pages)`` int32: physical page of
      each slot's logical block, trash-page id where unallocated.  The
      engine's host-side allocator owns these values and refreshes them
      every step.
    * ``write_page`` — ``(n_slots,)`` int32: physical destination of the
      block a slot completes THIS step (trash-page id when the step does
      not complete a block).  Pre-assigned by the allocator, so ``append``
      never needs host round-trips.

    ``gather()`` materializes a :class:`PackedKV` view through the page
    table — the kernel-v4 decode contract is unchanged, only indirected.
    """

    k_pages: Array  # int8 (P+1, page, n_kv, hd)
    k_page_scales: Array  # f32 (P+1, page, n_kv, ng)
    v_pages: Array  # int8 (P+1, page, n_kv, hd)
    v_page_scales: Array  # f32 (P+1, page, n_kv, ng)
    tail_k: Array  # cache dtype (n_slots, page, n_kv, hd)
    tail_v: Array  # cache dtype (n_slots, page, n_kv, hd)
    page_table: Array  # int32 (n_slots, max_pages)
    write_page: Array  # int32 (n_slots,)
    page: int  # tokens per page == PVQ block (static)
    group: int  # sub-head PVQ group (static, divides hd)
    k: int  # pulse budget per group (static, <= 127)
    dtype: str  # logical cache dtype name (tail dtype)

    # ------------------------------------------------------------- properties

    @property
    def _stacked(self) -> bool:
        return self.k_pages.ndim == 5

    @property
    def n_pages(self) -> int:
        """Usable physical pages (the +1 trash page excluded)."""
        return int(self.k_pages.shape[-4]) - 1

    @property
    def trash_page(self) -> int:
        return self.n_pages

    @property
    def n_slots(self) -> int:
        return int(self.tail_k.shape[-4])

    @property
    def max_pages(self) -> int:
        """Logical pages per slot (page-table width)."""
        return int(self.page_table.shape[-1])

    @property
    def head_dim(self) -> int:
        return int(self.k_pages.shape[-1])

    @property
    def n_groups(self) -> int:
        return int(self.k_page_scales.shape[-1])

    @property
    def block(self) -> int:
        """PackedKV-compatible alias: the PVQ encode granularity."""
        return self.page

    def packed_end(self, filled) -> Array:
        return (filled // self.page) * self.page

    # -------------------------------------------------------------- creation

    @classmethod
    def init(
        cls, n_slots: int, n_pages: int, max_pages: int, n_kv: int,
        head_dim: int, *, kvq: KVQuant, dtype=jnp.bfloat16,
    ) -> "PagedKV":
        g = _fit_group(kvq.group, head_dim)
        page = int(kvq.block)
        ng = head_dim // g
        dt = jnp.dtype(dtype)
        trash = int(n_pages)
        return cls(
            k_pages=jnp.zeros((n_pages + 1, page, n_kv, head_dim), jnp.int8),
            k_page_scales=jnp.zeros((n_pages + 1, page, n_kv, ng), jnp.float32),
            v_pages=jnp.zeros((n_pages + 1, page, n_kv, head_dim), jnp.int8),
            v_page_scales=jnp.zeros((n_pages + 1, page, n_kv, ng), jnp.float32),
            tail_k=jnp.zeros((n_slots, page, n_kv, head_dim), dt),
            tail_v=jnp.zeros((n_slots, page, n_kv, head_dim), dt),
            page_table=jnp.full((n_slots, max_pages), trash, jnp.int32),
            write_page=jnp.full((n_slots,), trash, jnp.int32),
            page=page, group=g, k=int(kvq.k), dtype=dt.name,
        )

    def with_tables(self, page_table: Array, write_page: Array) -> "PagedKV":
        """Refresh the allocator-owned children (broadcasts over a leading
        layer-stack axis when the container is stacked)."""
        if self._stacked:
            reps = self.k_pages.shape[0]
            page_table = jnp.broadcast_to(page_table[None], (reps,) + page_table.shape)
            write_page = jnp.broadcast_to(write_page[None], (reps,) + write_page.shape)
        return dataclasses.replace(
            self, page_table=page_table.astype(jnp.int32),
            write_page=write_page.astype(jnp.int32),
        )

    # ---------------------------------------------------------------- views

    def gather(self) -> PackedKV:
        """Slot-major :class:`PackedKV` view through the page table.

        ``k_pulses[slot, b * page + t] = k_pages[page_table[slot, b], t]``
        — unallocated logical blocks read the trash page, whose garbage
        stays behind the per-slot length mask.  This is the gather a fused
        paged kernel would do through its page-table operand; expressing it
        as a jnp gather keeps kernel v4 bit-compatible.
        """
        pt = self.page_table  # (n_slots, mp)
        ns, mp = pt.shape
        s = mp * self.page

        def pick(pool):  # (P+1, page, n_kv, X) -> (n_slots, S, n_kv, X)
            g = pool[pt]  # (n_slots, mp, page, n_kv, X)
            return g.reshape(ns, s, g.shape[-2], g.shape[-1])

        return PackedKV(
            k_pulses=pick(self.k_pages), k_scales=pick(self.k_page_scales),
            v_pulses=pick(self.v_pages), v_scales=pick(self.v_page_scales),
            tail_k=self.tail_k, tail_v=self.tail_v,
            block=self.page, group=self.group, k=self.k, dtype=self.dtype,
        )

    def gather_slot(self, slot) -> PackedKV:
        """Single-slot :class:`PackedKV` view through one page-table row
        (batch 1).  The chunked-prefill read leg attends only to the slot
        it extends, so gathering the full slot pool per chunk would be
        ``n_slots`` times the bytes for no extra information."""
        pt = jax.lax.dynamic_slice_in_dim(
            self.page_table, jnp.asarray(slot, jnp.int32), 1, axis=0
        )  # (1, mp)
        s = int(pt.shape[-1]) * self.page

        def pick(pool):  # (P+1, page, n_kv, X) -> (1, S, n_kv, X)
            g = pool[pt]
            return g.reshape(1, s, g.shape[-2], g.shape[-1])

        def row(tail):
            return jax.lax.dynamic_slice_in_dim(
                tail, jnp.asarray(slot, jnp.int32), 1, axis=0
            )

        return PackedKV(
            k_pulses=pick(self.k_pages), k_scales=pick(self.k_page_scales),
            v_pulses=pick(self.v_pages), v_scales=pick(self.v_page_scales),
            tail_k=row(self.tail_k), tail_v=row(self.tail_v),
            block=self.page, group=self.group, k=self.k, dtype=self.dtype,
        )

    def dense_kv(self, filled, dtype=jnp.float32) -> Tuple[Array, Array]:
        """Exact dense oracle view (via the gathered :class:`PackedKV`)."""
        return self.gather().dense_kv(filled, dtype=dtype)

    # --------------------------------------------------------------- updates

    def append(self, k_new: Array, v_new: Array, pos) -> "PagedKV":
        """Write one decode step ``(n_slots, 1, n_kv, hd)`` at per-slot
        positions ``pos (n_slots,)``.

        Every slot's row lands in its tail ring at ``pos % page``; slots
        whose write completes a block (``(pos + 1) % page == 0``) get the
        whole ring PVQ-encoded and scattered to their pre-assigned
        ``write_page`` — all other slots scatter to the trash page, so the
        encode is one masked vector op with no per-slot control flow.
        """
        page = self.page
        tdt = self.tail_k.dtype
        pos = jnp.asarray(pos, jnp.int32)
        slot_in_ring = jnp.mod(pos, page)

        upd_row = jax.vmap(
            lambda ring, row, p: jax.lax.dynamic_update_slice_in_dim(
                ring, row, p, axis=0
            )
        )
        tail_k = upd_row(self.tail_k, k_new.astype(tdt), slot_in_ring)
        tail_v = upd_row(self.tail_v, v_new.astype(tdt), slot_in_ring)

        completes = jnp.mod(pos + 1, page) == 0  # (n_slots,)
        dest = jnp.where(completes, self.write_page, self.trash_page)

        def encode(pools):
            kpg, ksg, vpg, vsg = pools
            pk, sk = _kv_encode_planes(tail_k.astype(jnp.float32), self.group, self.k)
            pv, sv = _kv_encode_planes(tail_v.astype(jnp.float32), self.group, self.k)
            # duplicate trash indices are fine: the trash page is never read
            return (
                kpg.at[dest].set(pk), ksg.at[dest].set(sk),
                vpg.at[dest].set(pv), vsg.at[dest].set(sv),
            )

        pools = (self.k_pages, self.k_page_scales, self.v_pages, self.v_page_scales)
        kpg, ksg, vpg, vsg = jax.lax.cond(
            jnp.any(completes), encode, lambda p: p, pools
        )
        return dataclasses.replace(
            self, k_pages=kpg, k_page_scales=ksg, v_pages=vpg, v_page_scales=vsg,
            tail_k=tail_k, tail_v=tail_v,
        )

    def graft(
        self, k_dense: Array, v_dense: Array, slot, page_ids: Array, real_len
    ) -> "PagedKV":
        """Graft one prefilled request into decode slot ``slot``.

        ``k_dense``/``v_dense``: the request's EXACT dense prefill cache
        ``(1, L_b, n_kv, hd)`` at a page-aligned bucket length ``L_b``
        (prompt padded up; padded rows are garbage and stay behind the
        length mask).  ``page_ids (L_b // page,)`` are the allocator's
        physical destinations — trash-page id for block indices at/after
        ``real_len // page``, so the partially-filled last block never
        pollutes the pool.  The exact rows of that partial block land in
        the slot's tail ring (f32-exact, same as a fresh ``append``
        stream would have left them).

        PVQ encoding happens HERE, not in the prefill step: the prefill
        runs with a dense cache and the graft encodes only complete
        blocks, which keeps the encode bit-identical to the fixed-batch
        ``PackedKV.from_dense`` path.  Implemented as the ``start=0``
        case of :meth:`graft_chunk`, so the monolithic and chunked
        prefill paths share one encode and cannot drift apart.
        """
        return self.graft_chunk(k_dense, v_dense, slot, page_ids, 0, real_len)

    def graft_chunk(
        self, k_dense: Array, v_dense: Array, slot, page_ids: Array,
        start, real_len,
    ) -> "PagedKV":
        """Graft one page-aligned prefill *chunk* into decode slot ``slot``.

        ``k_dense``/``v_dense`` hold the chunk's EXACT dense KV
        ``(1, C, n_kv, hd)`` for absolute positions
        ``[start, start + C)`` of the slot's context, with ``C`` a page
        multiple and ``start`` page-aligned (the chunked-prefill
        scheduler only ever cuts at page boundaries, so a chunk never
        straddles a partially-filled page).  ``page_ids (C // page,)``
        are the physical destinations of the chunk's logical blocks
        ``start // page ..`` — trash-page id for block indices at/after
        ``real_len // page``.  Blocks are PVQ-encoded with the same
        ``_kv_encode_planes`` every other write path uses, so running a
        context through any sequence of chunks leaves the pool (and the
        tail ring) bit-identical to one whole-prompt ``graft`` /
        ``PackedKV.from_dense``.

        The tail window write targets ``packed_end(real_len) - start``:
        only the FINAL chunk (the one containing ``packed_end``) writes
        meaningful tail rows; earlier chunks write a clamped garbage
        window that the final chunk overwrites (harmless — tail rings
        are slot-private and masked by length until then).
        """
        if self._stacked:
            return jax.vmap(
                lambda s, kd, vd: s.graft_chunk(
                    kd, vd, slot, page_ids, start, real_len
                )
            )(self, k_dense, v_dense)
        page = self.page
        kf = k_dense[0].astype(jnp.float32)  # (C, n_kv, hd)
        vf = v_dense[0].astype(jnp.float32)
        nb = kf.shape[0] // page
        kb = kf.reshape(nb, page, kf.shape[-2], kf.shape[-1])
        vb = vf.reshape(nb, page, vf.shape[-2], vf.shape[-1])
        pk, sk = _kv_encode_planes(kb, self.group, self.k)
        pv, sv = _kv_encode_planes(vb, self.group, self.k)
        ids = jnp.asarray(page_ids, jnp.int32)

        # exact tail: the block window starting at packed_end(real_len),
        # chunk-relative.  dynamic_slice clamps both ends: a mid chunk
        # (packed_end beyond the chunk) or a fully-packed final chunk
        # copies garbage that the tail-valid count masks until the real
        # writer (final chunk / appends) lands.
        pe = self.packed_end(jnp.asarray(real_len, jnp.int32))
        off = pe - jnp.asarray(start, jnp.int32)
        tdt = self.tail_k.dtype
        tk = jax.lax.dynamic_slice_in_dim(kf, off, page, axis=0).astype(tdt)
        tv = jax.lax.dynamic_slice_in_dim(vf, off, page, axis=0).astype(tdt)
        upd = jax.lax.dynamic_update_slice_in_dim
        return dataclasses.replace(
            self,
            k_pages=self.k_pages.at[ids].set(pk),
            k_page_scales=self.k_page_scales.at[ids].set(sk),
            v_pages=self.v_pages.at[ids].set(pv),
            v_page_scales=self.v_page_scales.at[ids].set(sv),
            tail_k=upd(self.tail_k, tk[None], slot, axis=0),
            tail_v=upd(self.tail_v, tv[None], slot, axis=0),
        )

    def __repr__(self) -> str:
        return (
            f"PagedKV(pages={self.n_pages}, page={self.page}, "
            f"slots={tuple(self.tail_k.shape)}, dtype={self.dtype}, "
            f"group={self.group}, k={self.k})"
        )


_PAGED_KV_CHILDREN = (
    "k_pages", "k_page_scales", "v_pages", "v_page_scales",
    "tail_k", "tail_v", "page_table", "write_page",
)


def _paged_kv_flatten_with_keys(p: PagedKV):
    children = tuple(
        (jax.tree_util.DictKey(n), getattr(p, n)) for n in _PAGED_KV_CHILDREN
    )
    aux = (p.page, p.group, p.k, p.dtype)
    return children, aux


def _paged_kv_unflatten(aux, children):
    page, group, k, dtype = aux
    kwargs = dict(zip(_PAGED_KV_CHILDREN, children))
    return PagedKV(page=page, group=group, k=k, dtype=dtype, **kwargs)


jax.tree_util.register_pytree_with_keys(
    PagedKV,
    _paged_kv_flatten_with_keys,
    lambda aux, xs: _paged_kv_unflatten(aux, xs),
)


def is_paged_kv(leaf: Any) -> bool:
    return isinstance(leaf, PagedKV)


# ---------------------------------------------------------------------------
# Pulse geometry: layout -> canonical symbol orders (entropy coding + stats)
# ---------------------------------------------------------------------------


def pulse_stream(pk: PackedPVQ) -> np.ndarray:
    """1-D int64 stream of the *logical* pulse symbols (no structural padding).

    The canonical symbol order the ``.pvqz`` entropy streams encode:
    matmul layout walks column-major over the contraction dim (groups stay
    contiguous) and drops the group-padding rows; flat layout walks row-major
    and drops the tail padding.  Padding therefore never costs wire bits.
    """
    pulses = np.asarray(pk.pulses, np.int64)
    if pk.layout == "matmul":
        d_in = int(pk.shape[-2])
        return np.swapaxes(pulses, -1, -2)[..., :d_in].ravel()
    numel = int(np.prod(pk.shape))
    lead = pulses.shape[:-2]
    return pulses.reshape(*lead, -1)[..., :numel].ravel()


def pulse_groups(pk: PackedPVQ) -> np.ndarray:
    """(G_total, group) group-major int64 view, padded groups included —
    the geometry the fixed-length enumeration codec and per-group size
    models price."""
    pulses = np.asarray(pk.pulses, np.int64)
    if pk.layout == "matmul":
        return np.swapaxes(pulses, -1, -2).reshape(-1, pk.group)
    return pulses.reshape(-1, pk.group)


# ---------------------------------------------------------------------------
# Encoding single arrays
# ---------------------------------------------------------------------------


def pack_matmul(
    w: Array, *, group: int, n_over_k: Optional[float] = None,
    k: Optional[int] = None, scale_mode: str = "ls",
    interpret: Optional[bool] = None,
) -> PackedPVQ:
    """Encode a dense weight matrix (contraction dim first) into the
    kernel-native matmul layout.  An N-D input (N >= 3) is treated as a
    stack over its leading axes — ``(repeats, d_in, d_out)`` scan stacks,
    ``(E, d_in, d_out)`` expert banks, and ``(repeats, E, d_in, d_out)``
    scan-stacked expert banks are all encoded per trailing matrix with the
    stack axes riding along on ``pulses``/``scales``.  Pass either the
    paper's ``n_over_k`` ratio (K derived from the *effective* group) or an
    explicit per-group ``k`` (used verbatim, even if the group is fitted
    down to divide ``d_in``)."""
    from repro.kernels import ops  # deferred: core must stay importable alone

    if w.ndim > 2:
        lead = w.shape[:-2]
        flat = w.reshape((-1,) + w.shape[-2:])
        packed = [
            pack_matmul(flat[i], group=group, n_over_k=n_over_k, k=k,
                        scale_mode=scale_mode, interpret=interpret)
            for i in range(flat.shape[0])
        ]
        pulses = jnp.stack([p.pulses for p in packed])
        scales = jnp.stack([p.scales for p in packed])
        return PackedPVQ(
            pulses=pulses.reshape(lead + pulses.shape[1:]),
            scales=scales.reshape(lead + scales.shape[1:]),
            group=packed[0].group, k=packed[0].k, shape=packed[0].shape,
            dtype=str(w.dtype), layout="matmul", scale_mode=scale_mode,
        )
    if w.ndim != 2:
        raise ValueError(f"matmul layout needs a tensor of rank >= 2, got {w.shape}")
    d_in, _ = w.shape
    g, _ = matmul_plan(group, d_in)
    k = _resolve_k(g, n_over_k, k)
    pulses, scales, _ = ops.encode_weight_matrix(
        w.astype(jnp.float32), group=g, k_pulses=k, interpret=interpret
    )
    # encode_weight_matrix emits the 'ls' scale natively — but it fits rho
    # against the *unclamped* int32 pulses.  When K > 127 a coordinate may
    # legally exceed the int8 range and get clamped, so refit the scale from
    # the pulses actually stored (the artifact must be self-consistent);
    # non-'ls' scale modes always recompute.
    if scale_mode != "ls" or k > 127:
        from .pvq import _scales

        k_pad = pulses.shape[0]
        pad = k_pad - d_in
        wp = jnp.pad(w.astype(jnp.float32), ((0, pad), (0, 0))) if pad else w.astype(jnp.float32)
        wg = wp.T.reshape(wp.shape[1], k_pad // g, g)
        pg = pulses.T.reshape(pulses.shape[1], k_pad // g, g)
        scales = _scales(wg, pg, scale_mode).T.astype(jnp.float32)
    return PackedPVQ(
        pulses=pulses, scales=scales, group=g, k=k, shape=tuple(w.shape),
        dtype=str(w.dtype), layout="matmul", scale_mode=scale_mode,
    )


def pack_flat(
    w: Array, *, group: int, n_over_k: Optional[float] = None,
    k: Optional[int] = None, scale_mode: str = "ls",
    row_align: Optional[int] = None,
) -> PackedPVQ:
    """Encode any tensor as row-major groups of its flattening.

    ``row_align`` (e.g. the embedding dim) shrinks the group so it divides
    the row length — then every original row covers whole groups and row
    gathers touch only that row's codes.  K comes from ``n_over_k`` (scaled
    with the effective group) or is passed explicitly via ``k``.
    """
    from repro.kernels import ops

    g = _fit_group(group, row_align) if row_align else int(group)
    k = _resolve_k(g, n_over_k, k)
    flat = w.reshape(-1).astype(jnp.float32)
    pulses_i32, scales = ops.pvq_encode_grouped_fast(flat, g, k, scale_mode=scale_mode)
    pulses = ops.pulses_to_int8(pulses_i32)
    if k > 127:
        # K > 127 permits clamped coordinates: refit rho from stored pulses
        from .pvq import _scales

        pad = (-flat.shape[0]) % g
        wg = (jnp.pad(flat, (0, pad)) if pad else flat).reshape(-1, g)
        scales = _scales(wg, pulses, scale_mode)
    scales = scales.astype(jnp.float32)
    return PackedPVQ(
        pulses=pulses, scales=scales, group=g, k=k, shape=tuple(w.shape),
        dtype=str(w.dtype), layout="flat", scale_mode=scale_mode,
    )


# ---------------------------------------------------------------------------
# Tree transforms
# ---------------------------------------------------------------------------


def _pack_leaf(
    pstr: str, leaf: Array, n_over_k: float, group: Optional[int],
    scale_mode: str, interpret: Optional[bool],
) -> Optional[PackedPVQ]:
    """Pack one leaf if a packed consumer exists for it; else None."""
    g = group or 256
    if re.search(PACK_SKIP_REGEX, pstr):
        return None
    if re.search(r"(^|/)embedding$", pstr) and leaf.ndim == 2:
        return pack_flat(
            leaf, group=g, n_over_k=n_over_k, scale_mode=scale_mode,
            row_align=leaf.shape[-1],
        )
    if re.search(r"kernel$", pstr) and leaf.ndim in (2, 3):
        return pack_matmul(
            leaf, group=g, n_over_k=n_over_k, scale_mode=scale_mode,
            interpret=interpret,
        )
    # stacked MoE expert banks: (E, d_in, d_out) or scan-stacked
    # (repeats, E, d_in, d_out).  Encoded per expert matrix into the
    # expert-stacked matmul layout; moe_forward contracts the dispatch
    # buffers against them through ops.packed_matmul_stacked.
    if re.search(EXPERT_LEAF_REGEX, pstr) and leaf.ndim in (3, 4):
        return pack_matmul(
            leaf, group=g, n_over_k=n_over_k, scale_mode=scale_mode,
            interpret=interpret,
        )
    return None


def quantize_params(
    params: Any,
    policy: QuantPolicy,
    *,
    min_size: int = 64,
    interpret: Optional[bool] = None,
) -> Any:
    """Encode a model pytree once into a mixed pytree of ``PackedPVQ`` leaves
    (dense kernels, embeddings) and untouched leaves (norms, biases, and
    anything without a packed consumer).

    The result is the deployment artifact: serve it, checkpoint it, shard
    it — the pulses are never re-encoded and never expanded to a full f32
    matrix on the decode path.
    """

    def visit(path, leaf):
        if is_packed(leaf):
            return leaf  # idempotent: already the artifact
        if not isinstance(leaf, (jax.Array, np.ndarray)) or leaf.ndim < 2:
            return leaf
        if leaf.size < min_size or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        pstr = _path_str(path)
        m = policy.match(pstr)
        if m is None:
            return leaf
        n_over_k, group = m
        packed = _pack_leaf(
            pstr, jnp.asarray(leaf), n_over_k, group, policy.scale_mode, interpret
        )
        if packed is None:
            return leaf
        _probe_weight_pack(pstr, leaf, packed)
        return packed

    return jax.tree_util.tree_map_with_path(visit, params, is_leaf=is_packed)


def _probe_weight_pack(pstr: str, leaf, packed: PackedPVQ) -> None:
    """Per-leaf pack-time reconstruction SNR (pack is a host-side, eager
    transform, so dequantizing once per leaf here never touches a hot
    loop; no-op unless the registry is enabled)."""
    from repro.runtime import obs, telemetry

    if not obs.enabled() or isinstance(leaf, jax.core.Tracer):
        return
    ref = np.asarray(jnp.asarray(leaf), np.float32)
    approx = np.asarray(packed.dequantize(jnp.float32))
    obs.counter("quant.weight_leaves_packed").inc()
    obs.counter("quant.weight_bytes_packed").add(packed.nbytes_packed)
    obs.counter("quant.weight_bytes_dense").add(packed.nbytes_dense)
    obs.histogram("quant.weight_snr_db").record(telemetry.snr_db(ref, approx))


def dequantize_params(params: Any) -> Any:
    """Inverse transform: expand every ``PackedPVQ`` leaf back to dense."""
    return jax.tree.map(materialize, params, is_leaf=is_packed)


def packed_leaves(params: Any) -> Dict[str, PackedPVQ]:
    """{path: PackedPVQ} for every packed leaf (reporting/tests)."""
    out: Dict[str, PackedPVQ] = {}

    def visit(path, leaf):
        if is_packed(leaf):
            out[_path_str(path)] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(visit, params, is_leaf=is_packed)
    return out


def expert_leaves(params: Any) -> Dict[str, PackedPVQ]:
    """{path: PackedPVQ} for the packed MoE expert banks only."""
    return {
        k: v for k, v in packed_leaves(params).items()
        if re.search(EXPERT_LEAF_REGEX, k)
    }


def packed_stats(params: Any, *, entropy: bool = True) -> Dict[str, float]:
    """Aggregate artifact-size report for a mixed pytree.

    Beyond the raw int8+f32 HBM byte counts, ``entropy=True`` (default)
    prices the pulse streams under the paper's §VI codecs with the *exact*
    ``core.codes`` size models.  ``entropy_bits_per_weight`` applies the
    ``.pvqz`` per-leaf selection rule itself (``bitstream.choose_codec``),
    so it reports what ``write_pvqz`` would actually produce; the per-codec
    ``*_bits_per_weight`` keys are whole-tree totals under that single
    codec (``enum`` is the exact sub-ladder stream size wherever its count
    tables fit memory).
    """
    packed_bytes = 0
    replaced_dense_bytes = 0
    untouched_bytes = 0
    n_packed = 0
    numel = 0
    scale_bits = 0
    best_bits = 0.0
    codec_bits = {"golomb": 0.0, "rle": 0.0, "enum": 0.0}
    enum_priceable = True
    for leaf in jax.tree.leaves(params, is_leaf=is_packed):
        if is_packed(leaf):
            packed_bytes += leaf.nbytes_packed
            replaced_dense_bytes += leaf.nbytes_dense
            n_packed += 1
            if entropy:
                from . import bitstream

                stream = pulse_stream(leaf)
                numel += stream.size
                scale_bits += 32 * int(np.prod(leaf.scales.shape))
                chosen, sizes = bitstream.choose_codec(
                    stream, pulse_groups(leaf), leaf.k
                )
                best_bits += sizes[chosen]
                codec_bits["golomb"] += sizes["golomb"]
                codec_bits["rle"] += sizes["rle"]
                if "enum" in sizes:
                    codec_bits["enum"] += sizes["enum"]
                else:
                    enum_priceable = False
        elif isinstance(leaf, (jax.Array, np.ndarray)):
            untouched_bytes += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    out = {
        "packed_tensors": n_packed,
        "packed_bytes": packed_bytes,
        "replaced_dense_bytes": replaced_dense_bytes,
        "untouched_bytes": untouched_bytes,
        "weight_compression_ratio": replaced_dense_bytes / max(packed_bytes, 1),
        "total_bytes": packed_bytes + untouched_bytes,
    }
    if entropy and n_packed:
        if not enum_priceable:
            del codec_bits["enum"]
        for codec, bits in codec_bits.items():
            out[f"{codec}_bits_per_weight"] = bits / max(numel, 1)
        out["entropy_bits_per_weight"] = (best_bits + scale_bits) / max(numel, 1)
        out["entropy_coded_bytes_est"] = int((best_bits + scale_bits) // 8)
        out["entropy_compression_ratio"] = 8.0 * replaced_dense_bytes / max(
            best_bits + scale_bits, 1.0
        )
    return out


# ---------------------------------------------------------------------------
# Update semantics
# ---------------------------------------------------------------------------


def packed_update(packed: PackedPVQ, delta: Array) -> PackedPVQ:
    """Apply a dense additive update to a packed leaf: dequantize, add,
    re-encode onto the same pyramid (same layout/group/K).

    This is the *explicit* re-encode point for fine-tuning or EMA on a
    packed artifact; the gradient pipeline (``optim.grad_compress``) treats
    packed leaves as frozen unless the caller opts in via this helper.
    """
    dense = packed.dequantize(jnp.float32)
    lead = packed.pulses.shape[: packed.pulses.ndim - 2]
    updated = dense + delta.astype(jnp.float32).reshape(*lead, *packed.shape)
    if packed.layout == "matmul":
        return pack_matmul(
            updated.astype(packed.dtype), group=packed.group, k=packed.k,
            scale_mode=packed.scale_mode,
        )
    return pack_flat(
        updated.astype(packed.dtype), group=packed.group, k=packed.k,
        scale_mode=packed.scale_mode,
        row_align=packed.shape[-1] if len(packed.shape) >= 2 else None,
    )
