"""Vectorized bit-level I/O for PVQ pulse streams (paper §VI, at rest).

``repro.core.codes`` carries the bit-exact *size models* and slow per-symbol
reference codecs; this module is the production path: numpy-vectorized
bit packing and **chunked** streams that decode with bounded Python overhead
regardless of leaf size (all chunks advance one symbol per vectorized round,
so a million-weight leaf costs ~``chunk`` numpy rounds, not a million).

Three stream families, all bit-exact round-trips:

* ``golomb``  — signed exp-Golomb order 0 (zigzag mapped), the paper's
  Table-5 ladder: 1 bit for 0, 3 for +/-1, 5 for +/-2..3, ...
* ``rle``     — (zero-run, nonzero-value) pairs, both Golomb coded; the
  natural fit for N/K >= 5 layers (>= 4/5 zeros guaranteed).
* ``enum``    — Fischer enumeration over sub-ladders: each group row is
  split into ``enum_sub_width(N)``-wide sub-rows; the stream is all L1
  headers (fixed width) then each sub-row's lexicographic rank within
  P(sub, k_s) in ``index_bits(sub, k_s)`` bits.  Encoded and decoded by the
  vectorized limb ladder (``repro.core.enumeration``) — near-optimal length
  at bulk-numpy speed, the default-eligible codec on every leaf whose count
  tables fit memory.

Chunked streams embed their per-chunk bit-offset table in the blob header
(``[u32 n_chunks][u64 * n_chunks bit offsets][stream bytes]``) so a blob +
its info dict is self-contained; :func:`encode_pulses` / :func:`decode_pulses`
are the single entry points the ``.pvqz`` container and the checkpointer use.
"""

from __future__ import annotations

import struct
from typing import Dict, Optional, Tuple

import numpy as np

from .codes import golomb_length, rle_bits, rle_flat_pairs, zigzag
from .enumeration import (
    enum_supported,
    index_bits,
    index_to_vector_batch,
    limb_count,
    vector_to_index_batch,
)

DEFAULT_CHUNK = 1024

#: ladder width of the enumeration stream — group rows are split into
#: contiguous sub-rows of (at most) this many coordinates, each carrying its
#: own L1 header.  Narrower ladders decode faster (fewer sequential coordinate
#: rounds, fewer rank limbs) and the per-sub headers act as a crude adaptive
#: bit allocation, so the split *reduces* total payload bits on real leaves.
ENUM_SUB = 64

#: deterministic tie-break order for codec selection (paper §VI practicality)
PULSE_CODECS = ("golomb", "rle", "enum", "nibble", "int8")

# ---------------------------------------------------------------------------
# bit-packing primitives
# ---------------------------------------------------------------------------


def pack_bits(codes: np.ndarray, lengths: np.ndarray) -> Tuple[np.ndarray, int]:
    """Concatenate variable-length big-endian codewords into a byte array.

    ``codes[i]`` carries the low ``lengths[i]`` bits of symbol i (MSB first on
    the wire; leading-zero bits of the codeword are part of the length).
    Vectorized over symbols: one numpy pass per bit *position* (bounded by the
    longest codeword, ~65 for int64 symbols), not per symbol.
    Returns (uint8 array from ``np.packbits``, total_bits).
    """
    codes = np.asarray(codes, dtype=np.uint64)
    lengths = np.asarray(lengths, dtype=np.int64)
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, np.uint8), 0
    starts = np.cumsum(lengths) - lengths
    bits = np.zeros(total, np.uint8)
    for j in range(int(lengths.max())):
        m = lengths > j
        shift = (lengths[m] - 1 - j).astype(np.uint64)
        bits[starts[m] + j] = ((codes[m] >> shift) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits), total


def _bit_length(x: np.ndarray) -> np.ndarray:
    """Per-element bit length of positive int64 values (vectorized)."""
    # float64 log2 is exact-enough below 2^52: the gap to the next power of
    # two is >= 1 ulp at these magnitudes, so floor() cannot round across it.
    return (np.floor(np.log2(x.astype(np.float64))).astype(np.int64)) + 1


# ---------------------------------------------------------------------------
# chunked signed exp-Golomb
# ---------------------------------------------------------------------------


def golomb_lengths_codes(values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(codes, lengths) of the signed exp-Golomb codewords for ``values``."""
    x1 = zigzag(np.asarray(values, np.int64).ravel()) + 1
    nb = _bit_length(x1)
    return x1.astype(np.uint64), 2 * nb - 1


def auto_chunk(count: int) -> int:
    """Chunk size targeting ~1.5k parallel chunks (power of two in
    [64, 4096]): decode wall time scales with the chunk length while numpy
    per-op overhead amortizes across chunks, so small streams want small
    chunks.  The choice is baked into the stream's offset table at encode
    time and travels in its info dict."""
    c = max(count // 1536, 64)
    return 1 << min(c.bit_length() - 1, 12)


def golomb_encode_chunked(
    values: np.ndarray, chunk: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """Encode to one contiguous bitstream + per-chunk bit offsets.

    Returns (packed uint8 array, chunk_offsets uint64 (ceil(count/chunk),),
    total_bits, chunk).  Offsets point at the first bit of symbols 0, chunk,
    2*chunk, ... — the decoder processes all chunks in parallel.  ``chunk``
    defaults to :func:`auto_chunk` of the symbol count.
    """
    codes, lengths = golomb_lengths_codes(values)
    if chunk is None:
        chunk = auto_chunk(codes.size)
    if codes.size == 0:
        return np.zeros(0, np.uint8), np.zeros(0, np.uint64), 0, chunk
    ends = np.cumsum(lengths)
    n_chunks = -(-codes.size // chunk)
    offsets = np.concatenate([[0], ends[chunk - 1 :: chunk]])[:n_chunks]
    blob, total = pack_bits(codes, lengths)
    return blob, offsets.astype(np.uint64), total, chunk


def golomb_decode_chunked(
    blob: bytes | np.ndarray,
    chunk_offsets: np.ndarray,
    count: int,
    chunk: int = DEFAULT_CHUNK,
) -> np.ndarray:
    """Inverse of :func:`golomb_encode_chunked` (vectorized across chunks).

    Every chunk advances one symbol per round; a round is ~a dozen numpy ops
    on (n_chunks,)-sized arrays, so wall time scales with ``chunk``, not with
    ``count``.  Each round reads one big-endian 64-bit byte window per chunk
    and takes the prefix-zero count, the payload, and the unzigzagged value
    from it — no per-bit inner loop and no unpacked bit array.  The zero
    count comes from the float32 exponent of the window's top 24 bits (< 2^24
    so the conversion is exact); the rare codeword longer than 24 bits falls
    back to an exact float64 log2 on the top 32.  Chunks that run out of
    symbols keep walking a 0xFF guard tail (one bit per round, masked off by
    the final trim), which keeps the rounds branch- and mask-free.  Handles
    codewords up to 57 bits, with decoded values accumulated in int32
    (|symbol| <= 2^29 after zigzag — far beyond any pulse value or zero-run
    the RLE pair stream can produce).
    """
    if count == 0:
        return np.zeros(0, np.int64)
    u64, u32, i64 = np.uint64, np.uint32, np.int64
    if isinstance(blob, np.ndarray):
        data = np.asarray(blob, np.uint8)
    else:
        data = np.frombuffer(blob, np.uint8)
    # guard tail: exhausted chunks park here (z = 0, one bit per round) and
    # the +8 tail keeps every 8-byte window gather in bounds
    guard = -(-chunk // 8) + 8
    p = np.concatenate([data, np.full(guard, 0xFF, np.uint8)])
    # big-endian 64-bit window starting at every byte, built by doubling:
    # byte pairs -> 16-bit, pairs of those -> 32-bit, -> 64-bit (3 passes)
    m = p.size - 7
    w2 = (p[:-1].astype(np.uint16) << np.uint16(8)) | p[1:]
    w4 = (w2[: m + 4].astype(u32) << u32(16)) | w2[2 : m + 6]
    win = (w4[:m].astype(u64) << u64(32)) | w4[4 : m + 4]
    pos = np.asarray(chunk_offsets, u64).copy()
    out = np.empty((chunk, pos.size), np.int32)
    c3, c7, c23, c40, c63, c150 = u64(3), u64(7), u32(23), u64(40), u64(63), u64(150)
    for s in range(chunk):
        w = win[pos >> c3] << (pos & c7)  # stream bits from pos
        # prefix-zero count: exact float32 exponent of the top 24 bits
        f = (w >> c40).astype(u32).astype(np.float32)
        z = c150 - (f.view(u32) >> c23).astype(u64)
        bad = np.flatnonzero(z > u64(23))
        if bad.size:  # codeword longer than the 24-bit fast window
            hb = ((w[bad] >> u64(32)) | u64(1)).astype(np.float64)
            z[bad] = (31 - np.floor(np.log2(hb)).astype(i64)).astype(u64)
        # payload: drop the z prefix zeros, keep the z+1 code bits; unzigzag
        # in-round (x1 = u+1; u odd <=> x1 even <=> positive value)
        x1 = ((w << z) >> (c63 - z)).view(i64)
        out[s] = (x1 >> 1) * (1 - ((x1 & 1) << 1))
        pos += (z << u64(1)) + u64(1)
    return out.T.ravel()[:count].astype(i64)


# ---------------------------------------------------------------------------
# zero-run RLE (pairs stream, Golomb coded)
# ---------------------------------------------------------------------------


def rle_encode_chunked(
    values: np.ndarray, chunk: Optional[int] = None
) -> Tuple[np.ndarray, np.ndarray, int, int, int]:
    """(blob, chunk_offsets, total_bits, n_pairs, chunk) — same pair stream
    as ``codes.rle_encode`` (and therefore the same exact size),
    chunk-decodable; ``chunk`` defaults to :func:`auto_chunk` of the *pair
    stream* length (the unit the decoder rounds over).
    """
    flat = rle_flat_pairs(values)
    blob, offsets, nbits, chunk = golomb_encode_chunked(flat, chunk)
    return blob, offsets, nbits, flat.size // 2, chunk


def rle_decode_chunked(
    blob: bytes | np.ndarray,
    chunk_offsets: np.ndarray,
    n_pairs: int,
    total: int,
    chunk: int = DEFAULT_CHUNK,
) -> np.ndarray:
    """Inverse of :func:`rle_encode_chunked`: one chunked-golomb decode of
    the pair stream (which has ~2 symbols per *nonzero*, so it is usually
    faster than a golomb stream of the same leaf), then a vectorized
    scatter of the nonzero values."""
    flat = golomb_decode_chunked(blob, chunk_offsets, 2 * n_pairs, chunk)
    runs, vals = flat[0::2], flat[1::2]
    out = np.zeros(total, np.int64)
    if n_pairs:
        pos = np.cumsum(runs) + np.arange(n_pairs)  # index of each pair's value
        has_val = vals != 0
        out[pos[has_val]] = vals[has_val]
    return out


# ---------------------------------------------------------------------------
# fixed-length Fischer enumeration stream
# ---------------------------------------------------------------------------


def enum_sub_width(n: int) -> int:
    """Ladder width the enumeration stream uses for N-wide groups.

    Groups are split into equal contiguous sub-rows of at most
    :data:`ENUM_SUB` coordinates when N divides evenly; otherwise the ladder
    runs at the full group width."""
    if n <= ENUM_SUB:
        return max(n, 1)
    s = -(-n // ENUM_SUB)
    return n // s if n % s == 0 else n


def _enum_ibits_table(sub: int, k_max: int) -> np.ndarray:
    """index_bits(sub, k) for k = 0..k_max (rank field width per L1 header)."""
    return np.asarray([index_bits(sub, t) for t in range(k_max + 1)], np.int64)


def enum_stream_bits(groups: np.ndarray, k_max: int) -> int:
    """Exact payload bits of :func:`enum_encode_groups` without encoding."""
    groups = np.asarray(groups, np.int64)
    sub = enum_sub_width(groups.shape[-1])
    k_sub = np.abs(groups.reshape(-1, sub)).sum(axis=1)
    kbits = max(int(k_max).bit_length(), 1)
    return int(k_sub.size * kbits + _enum_ibits_table(sub, k_max)[k_sub].sum())


def _extract_fields(data: np.ndarray, start: np.ndarray, width: np.ndarray):
    """Big-endian bit fields (width <= 32) out of a byte array, vectorized.

    Gathers the 5 bytes covering each field and shifts the field out; rows
    with ``width == 0`` return 0 regardless of ``start`` (which may then be
    out of range — the gather wraps harmlessly into the guard tail)."""
    d = np.concatenate([data, np.zeros(5, np.uint8)])
    start = np.maximum(start, 0)  # width-0 rows may sit before bit 0
    byte0 = start >> 3
    acc = np.zeros(start.shape, np.int64)
    for t in range(5):
        acc = (acc << 8) | d[byte0 + t]
    return (acc >> (40 - (start & 7) - width)) & ((np.int64(1) << width) - 1)


def enum_encode_groups(groups: np.ndarray, k_max: int) -> Tuple[bytes, int]:
    """Enumeration stream of a (G, N) group matrix, all groups at once.

    Each group row is split into :func:`enum_sub_width` sub-rows; every
    sub-row may sit on any pyramid P(sub, k_s) with k_s <= k_max (zero
    sub-rows and K>127-clamped groups included).  The wire format is all L1
    headers first (fixed ``max(bit_length(k_max), 1)`` bits each), then each
    sub-row's rank within P(sub, k_s) in ``index_bits(sub, k_s)`` bits,
    concatenated MSB-first and padded to a byte.  Ranks come from the
    vectorized limb ladder — no per-group Python work.  Returns
    (blob, total_bits).
    """
    groups = np.asarray(groups, np.int64)
    g, n = groups.shape
    sub = enum_sub_width(n)
    rows = groups.reshape(-1, sub)
    k_sub = np.abs(rows).sum(axis=1)
    if int(k_sub.max(initial=0)) > k_max:
        raise ValueError(
            f"group L1 {int(k_sub.max(initial=0))} exceeds k_max {k_max}"
        )
    kbits = max(int(k_max).bit_length(), 1)
    b = _enum_ibits_table(sub, k_max)[k_sub]  # per-sub rank width
    limbs = vector_to_index_batch(rows, k_max).astype(np.uint64)
    L = limbs.shape[1]
    hi = np.arange(L - 1, -1, -1)  # wire order: most significant limb first
    widths = np.clip(b[:, None] - 32 * hi[None, :], 0, 32)
    codes = np.concatenate([k_sub.astype(np.uint64), limbs[:, hi].ravel()])
    lens = np.concatenate(
        [np.full(k_sub.size, kbits, np.int64), widths.ravel()]
    )
    packed, total = pack_bits(codes, lens)
    return packed.tobytes(), total


def enum_decode_groups(
    blob: bytes, g: int, n: int, k_max: int, sub: Optional[int] = None
) -> np.ndarray:
    """Inverse of :func:`enum_encode_groups` — one vectorized pass.

    Header fields are fixed-width (one gather round), the variable-width
    rank fields are located from the header cumsum and pulled out limb by
    limb (L <= a handful of 32-bit windows per sub-row), then the whole
    (G*s, sub) rank matrix goes through the limb-ladder decode at once.
    ``sub`` pins the ladder width the blob was written with (streams carry
    it in their info dict); it defaults to the current policy."""
    sub = enum_sub_width(n) if sub is None else int(sub)
    gs = g * (n // sub)
    out = np.zeros((g, n), np.int64)
    if gs == 0:
        return out
    data = np.frombuffer(blob, np.uint8)
    kbits = max(int(k_max).bit_length(), 1)
    k_sub = _extract_fields(
        data, np.arange(gs, dtype=np.int64) * kbits, np.full(gs, kbits, np.int64)
    )
    if int(k_sub.max(initial=0)) > k_max:
        raise ValueError(f"corrupt enum stream: L1 header exceeds k_max {k_max}")
    b = _enum_ibits_table(sub, k_max)[k_sub]
    starts = gs * kbits + np.cumsum(b) - b
    L = limb_count(sub, k_max)
    j = np.arange(L)
    # all-zero sub-rows (structural group padding, fully-cancelled groups)
    # carry no rank bits and need no ladder pass: decode the live rows only
    # and scatter them back
    live = np.flatnonzero(k_sub)
    if live.size == 0:
        return out
    b, starts = b[live], starts[live]
    widths = np.clip(b[:, None] - 32 * j[None, :], 0, 32)
    ends = starts[:, None] + b[:, None] - 32 * j[None, :]
    limbs = _extract_fields(data, ends - widths, widths).astype(np.uint32)
    rows = out.reshape(gs, sub)
    rows[live] = index_to_vector_batch(limbs, k_sub[live], sub, k_max)
    return rows.reshape(g, n)


# ---------------------------------------------------------------------------
# unified pulse-stream entry points (used by .pvqz and the checkpointer)
# ---------------------------------------------------------------------------

#: chunked-stream blob header: [u32 n_chunks][u64 * n_chunks bit offsets]
_HDR_COUNT = struct.Struct("<I")


def _wrap_chunked(stream: np.ndarray, offsets: np.ndarray) -> bytes:
    return (
        _HDR_COUNT.pack(offsets.size)
        + offsets.astype("<u8").tobytes()
        + stream.tobytes()
    )


def _unwrap_chunked(blob: bytes) -> Tuple[np.ndarray, bytes]:
    (n_chunks,) = _HDR_COUNT.unpack_from(blob, 0)
    off_end = 4 + 8 * n_chunks
    offsets = np.frombuffer(blob[4:off_end], "<u8")
    return offsets, blob[off_end:]


def encode_pulses(
    values: np.ndarray,
    codec: str,
    *,
    k_max: Optional[int] = None,
    chunk: Optional[int] = None,
) -> Tuple[bytes, Dict]:
    """Encode a pulse stream (any shape; ``enum`` needs (G, N) groups).

    Returns (blob, info); ``info`` holds everything :func:`decode_pulses`
    needs besides the blob itself: codec, count, payload bits, and
    codec-specific fields.  Codecs: ``golomb`` / ``rle`` (chunked, embedded
    offset table), ``enum`` (fixed length, needs ``k_max`` and a 2-D group
    matrix), ``nibble`` / ``int8`` (raw fallbacks).
    """
    groups = np.asarray(values, np.int64)
    flat = groups.ravel()
    info: Dict = {"codec": codec, "count": int(flat.size)}
    if codec == "golomb":
        stream, offsets, nbits, chunk = golomb_encode_chunked(flat, chunk)
        info.update(nbits=int(nbits), chunk=int(chunk))
        return _wrap_chunked(stream, offsets), info
    if codec == "rle":
        stream, offsets, nbits, n_pairs, chunk = rle_encode_chunked(flat, chunk)
        info.update(nbits=int(nbits), chunk=int(chunk), n_pairs=int(n_pairs))
        return _wrap_chunked(stream, offsets), info
    if codec == "enum":
        if k_max is None:
            raise ValueError("enum codec needs k_max")
        if groups.ndim != 2:
            raise ValueError("enum codec needs a (G, N) group matrix")
        blob, total = enum_encode_groups(groups, k_max)
        info.update(
            nbits=int(total),
            k_max=int(k_max),
            n_groups=int(groups.shape[0]),
            group=int(groups.shape[1]),
            sub=enum_sub_width(int(groups.shape[1])),
        )
        return blob, info
    if codec == "nibble":
        from .packing import pack_nibbles  # one 4-bit layout, shared with the checkpointer

        if np.abs(flat).max(initial=0) > 7:
            raise ValueError("nibble codec requires |pulse| <= 7")
        packed, _ = pack_nibbles(flat)
        info["nbits"] = 4 * int(flat.size)
        return packed.tobytes(), info
    if codec == "int8":
        info["nbits"] = 8 * int(flat.size)
        return flat.astype(np.int8).tobytes(), info
    raise ValueError(f"unknown pulse codec {codec!r}")


def decode_pulses(blob: bytes, info: Dict, group: Optional[int] = None) -> np.ndarray:
    """Inverse of :func:`encode_pulses`.

    Returns the flat int64 symbol stream, reshaped to (G, group) when
    ``group`` is given (``enum`` blobs are always grouped).
    """
    codec, count = info["codec"], info["count"]
    if codec == "golomb":
        offsets, stream = _unwrap_chunked(blob)
        flat = golomb_decode_chunked(stream, offsets, count, info["chunk"])
    elif codec == "rle":
        offsets, stream = _unwrap_chunked(blob)
        flat = rle_decode_chunked(
            stream, offsets, info["n_pairs"], count, info["chunk"]
        )
    elif codec == "enum":
        return enum_decode_groups(
            blob, info["n_groups"], info["group"], info["k_max"],
            sub=info.get("sub"),
        )
    elif codec == "nibble":
        from .packing import unpack_nibbles

        flat = unpack_nibbles(np.frombuffer(blob, np.uint8), (count,))
    elif codec == "int8":
        flat = np.frombuffer(blob, np.int8).astype(np.int64)[:count]
    else:
        raise ValueError(f"unknown pulse codec {codec!r}")
    return flat.reshape(-1, group) if group is not None else flat


def measured_bits(
    stream: np.ndarray,
    *,
    group_matrix: Optional[np.ndarray] = None,
    k_max: Optional[int] = None,
) -> Dict[str, float]:
    """Exact payload bits under each codec (the .pvqz selection rule input).

    ``stream`` is the symbol stream the variable-length codecs would encode
    (golomb/rle/nibble/int8); ``group_matrix``/``k_max`` additionally price
    the enumeration stream over the (G, N) group view.  All entries are
    *exact*: the ``golomb_length`` sum, the RLE pair model, and the
    enumeration header + per-sub-row rank widths are identical to the
    produced streams.
    """
    flat = np.asarray(stream, np.int64).ravel()
    out = {
        "golomb": float(golomb_length(flat).sum()) if flat.size else 0.0,
        "rle": float(rle_bits(flat)),
        "int8": 8.0 * flat.size,
    }
    if np.abs(flat).max(initial=0) <= 7:
        out["nibble"] = 4.0 * flat.size
    if group_matrix is not None and k_max is not None:
        sub = enum_sub_width(int(group_matrix.shape[-1]))
        if enum_supported(sub, int(k_max)) and int(
            np.abs(group_matrix).reshape(-1, sub).sum(axis=1).max(initial=0)
        ) <= int(k_max):
            out["enum"] = float(enum_stream_bits(group_matrix, int(k_max)))
    return out


def choose_codec(
    stream: np.ndarray,
    groups: np.ndarray,
    k: int,
) -> Tuple[str, Dict[str, float]]:
    """Pick the cheapest codec by measured payload bits — THE ``.pvqz``
    per-leaf selection rule (also applied by ``packed_stats`` so its report
    matches what the artifact actually produces).

    Returns (codec, {codec: bits}).  Every priced codec is eligible:
    enumeration runs on the vectorized limb ladder, so there is no bigint
    work budget anymore — it is only absent when its precomputed count
    tables would not fit :data:`repro.core.enumeration.ENUM_TABLE_MAX_BYTES`
    (or the limb ladder's float-proxy width cap) at the leaf's sub-ladder
    geometry, which :func:`measured_bits` already accounts for.
    """
    sizes = measured_bits(stream, group_matrix=groups, k_max=k)
    codec = min(sizes, key=lambda c: (sizes[c], PULSE_CODECS.index(c)))
    return codec, sizes
