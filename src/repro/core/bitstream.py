"""Vectorized bit-level I/O for PVQ pulse streams (paper §VI, at rest).

``repro.core.codes`` carries the bit-exact *size models* and slow per-symbol
reference codecs; this module is the production path: numpy-vectorized
bit packing and **chunked** streams that decode with bounded Python overhead
regardless of leaf size (all chunks advance one symbol per vectorized round,
so a million-weight leaf costs ~``chunk`` numpy rounds, not a million).

Three stream families, all bit-exact round-trips:

* ``golomb``  — signed exp-Golomb order 0 (zigzag mapped), the paper's
  Table-5 ladder: 1 bit for 0, 3 for +/-1, 5 for +/-2..3, ...
* ``rle``     — (zero-run, nonzero-value) pairs, both Golomb coded; the
  natural fit for N/K >= 5 layers (>= 4/5 zeros guaranteed).
* ``enum``    — fixed-length Fischer enumeration: per group, the L1 norm
  k_g in ``ceil(log2(K+1))`` bits then the lexicographic rank within
  P(N, k_g) in ``index_bits(N, K)`` bits (``repro.core.enumeration``).
  Optimal-length but O(N*K) bigint work per group — offline/small leaves.

Chunked streams embed their per-chunk bit-offset table in the blob header
(``[u32 n_chunks][u64 * n_chunks bit offsets][stream bytes]``) so a blob +
its info dict is self-contained; :func:`encode_pulses` / :func:`decode_pulses`
are the single entry points the ``.pvqz`` container and the checkpointer use.
"""

from __future__ import annotations

import struct
from typing import Dict, Optional, Tuple

import numpy as np

from .codes import golomb_length, rle_bits, rle_flat_pairs, unzigzag, zigzag
from .enumeration import index_bits, index_to_vector, vector_to_index

DEFAULT_CHUNK = 1024

#: max G * group * K bigint ops admitted for the enumeration codec — its
#: encode is O(N*K) Python bigints per group, so it is only *eligible* on
#: small leaves even though it is the measured-bits winner almost everywhere
DEFAULT_ENUM_BUDGET = 500_000

#: deterministic tie-break order for codec selection (paper §VI practicality)
PULSE_CODECS = ("golomb", "rle", "enum", "nibble", "int8")

# ---------------------------------------------------------------------------
# bit-packing primitives
# ---------------------------------------------------------------------------


def pack_bits(codes: np.ndarray, lengths: np.ndarray) -> Tuple[np.ndarray, int]:
    """Concatenate variable-length big-endian codewords into a byte array.

    ``codes[i]`` carries the low ``lengths[i]`` bits of symbol i (MSB first on
    the wire; leading-zero bits of the codeword are part of the length).
    Vectorized over symbols: one numpy pass per bit *position* (bounded by the
    longest codeword, ~65 for int64 symbols), not per symbol.
    Returns (uint8 array from ``np.packbits``, total_bits).
    """
    codes = np.asarray(codes, dtype=np.uint64)
    lengths = np.asarray(lengths, dtype=np.int64)
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, np.uint8), 0
    starts = np.cumsum(lengths) - lengths
    bits = np.zeros(total, np.uint8)
    for j in range(int(lengths.max())):
        m = lengths > j
        shift = (lengths[m] - 1 - j).astype(np.uint64)
        bits[starts[m] + j] = ((codes[m] >> shift) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits), total


def unpack_to_bits(blob: bytes | np.ndarray) -> np.ndarray:
    """Byte blob -> 0/1 uint8 array (length a multiple of 8)."""
    return np.unpackbits(np.frombuffer(bytes(blob), np.uint8))


def _bit_length(x: np.ndarray) -> np.ndarray:
    """Per-element bit length of positive int64 values (vectorized)."""
    # float64 log2 is exact-enough below 2^52: the gap to the next power of
    # two is >= 1 ulp at these magnitudes, so floor() cannot round across it.
    return (np.floor(np.log2(x.astype(np.float64))).astype(np.int64)) + 1


# ---------------------------------------------------------------------------
# chunked signed exp-Golomb
# ---------------------------------------------------------------------------


def golomb_lengths_codes(values: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(codes, lengths) of the signed exp-Golomb codewords for ``values``."""
    x1 = zigzag(np.asarray(values, np.int64).ravel()) + 1
    nb = _bit_length(x1)
    return x1.astype(np.uint64), 2 * nb - 1


def golomb_encode_chunked(
    values: np.ndarray, chunk: int = DEFAULT_CHUNK
) -> Tuple[np.ndarray, np.ndarray, int]:
    """Encode to one contiguous bitstream + per-chunk bit offsets.

    Returns (packed uint8 array, chunk_offsets uint64 (ceil(count/chunk),),
    total_bits).  Offsets point at the first bit of symbols 0, chunk,
    2*chunk, ... — the decoder processes all chunks in parallel.
    """
    codes, lengths = golomb_lengths_codes(values)
    if codes.size == 0:
        return np.zeros(0, np.uint8), np.zeros(0, np.uint64), 0
    ends = np.cumsum(lengths)
    n_chunks = -(-codes.size // chunk)
    offsets = np.concatenate([[0], ends[chunk - 1 :: chunk]])[:n_chunks]
    blob, total = pack_bits(codes, lengths)
    return blob, offsets.astype(np.uint64), total


def golomb_decode_chunked(
    blob: bytes | np.ndarray,
    chunk_offsets: np.ndarray,
    count: int,
    chunk: int = DEFAULT_CHUNK,
) -> np.ndarray:
    """Inverse of :func:`golomb_encode_chunked` (vectorized across chunks).

    Every chunk advances one symbol per round; a round is ~a dozen numpy ops
    on (n_chunks,)-sized arrays, so wall time scales with ``chunk``, not with
    ``count``.  Working set: the unpacked bit array (1 B/bit) plus one
    next-one index table (4 B/bit for streams under 2^31 bits) — built in
    place so decode memory stays a small multiple of the compressed blob,
    not of the dense leaf.
    """
    if count == 0:
        return np.zeros(0, np.int64)
    bits = unpack_to_bits(blob)
    # next-one table: smallest index >= i holding a 1 bit (suffix-min in place)
    idx_dtype = np.int64 if bits.size > np.iinfo(np.int32).max else np.int32
    nxt = np.where(bits == 1, np.arange(bits.size, dtype=idx_dtype), bits.size)
    rev = nxt[::-1]
    np.minimum.accumulate(rev, out=rev)
    offsets = np.asarray(chunk_offsets, np.int64)
    n_chunks = offsets.size
    counts = np.full(n_chunks, chunk, np.int64)
    counts[-1] = count - chunk * (n_chunks - 1)
    pos = offsets.copy()
    out = np.empty(count, np.int64)
    out_base = np.arange(n_chunks) * chunk
    for s in range(int(counts.max())):
        active = counts > s
        p = pos[active]
        f = nxt[p]  # leading 1 of the codeword; z = f - p prefix zeros
        z = f - p
        val = np.zeros(p.size, np.int64)
        for j in range(int(z.max()) + 1):
            take = j <= z
            bitj = bits[np.minimum(f + j, bits.size - 1)]
            val = np.where(take, (val << 1) | bitj, val)
        out[out_base[active] + s] = val - 1
        pos[active] = f + z + 1
    return unzigzag(out)


# ---------------------------------------------------------------------------
# zero-run RLE (pairs stream, Golomb coded)
# ---------------------------------------------------------------------------


def rle_encode_chunked(
    values: np.ndarray, chunk: int = DEFAULT_CHUNK
) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """(blob, chunk_offsets, total_bits, n_pairs) — same pair stream as
    ``codes.rle_encode`` (and therefore the same exact size), chunk-decodable.
    """
    flat = rle_flat_pairs(values)
    blob, offsets, nbits = golomb_encode_chunked(flat, chunk)
    return blob, offsets, nbits, flat.size // 2


def rle_decode_chunked(
    blob: bytes | np.ndarray,
    chunk_offsets: np.ndarray,
    n_pairs: int,
    total: int,
    chunk: int = DEFAULT_CHUNK,
) -> np.ndarray:
    flat = golomb_decode_chunked(blob, chunk_offsets, 2 * n_pairs, chunk)
    runs, vals = flat[0::2], flat[1::2]
    out = np.zeros(total, np.int64)
    if n_pairs:
        pos = np.cumsum(runs) + np.arange(n_pairs)  # index of each pair's value
        has_val = vals != 0
        out[pos[has_val]] = vals[has_val]
    return out


# ---------------------------------------------------------------------------
# fixed-length Fischer enumeration stream
# ---------------------------------------------------------------------------


def enum_bits_per_group(n: int, k_max: int) -> int:
    """Fixed bits per group: the L1 header plus the P(N, K) rank."""
    return max(int(k_max).bit_length(), 1) + index_bits(n, k_max)


def enum_encode_groups(groups: np.ndarray, k_max: int) -> Tuple[bytes, int]:
    """Fixed-length enumeration stream of a (G, N) group matrix.

    Each group may sit on any pyramid P(N, k_g) with k_g <= k_max (zero
    groups and K>127-clamped groups included): the per-group record is
    ``k_g`` then the rank of the vector within P(N, k_g).  Returns
    (blob, bits_per_group); total bits = G * bits_per_group.  O(N*K) bigint
    work per group — gate by leaf size (see ``.pvqz`` codec selection).
    """
    groups = np.asarray(groups, np.int64)
    g, n = groups.shape
    kbits = max(int(k_max).bit_length(), 1)
    ibits = index_bits(n, k_max)
    per = kbits + ibits
    acc = 0
    for row in groups:
        k_g = int(np.abs(row).sum())
        if k_g > k_max:
            raise ValueError(f"group L1 {k_g} exceeds k_max {k_max}")
        acc = (acc << per) | (k_g << ibits) | vector_to_index(row.tolist())
    nbytes = (per * g + 7) // 8
    acc <<= nbytes * 8 - per * g  # left-align: stream starts at bit 0
    return acc.to_bytes(nbytes, "big") if nbytes else b"", per


def enum_decode_groups(blob: bytes, g: int, n: int, k_max: int) -> np.ndarray:
    kbits = max(int(k_max).bit_length(), 1)
    ibits = index_bits(n, k_max)
    per = kbits + ibits
    acc = int.from_bytes(blob, "big")
    total_bits = len(blob) * 8
    out = np.zeros((g, n), np.int64)
    for i in range(g):
        shift = total_bits - per * (i + 1)
        rec = (acc >> shift) & ((1 << per) - 1)
        k_g = rec >> ibits
        idx = rec & ((1 << ibits) - 1)
        out[i] = index_to_vector(idx, n, k_g)
    return out


# ---------------------------------------------------------------------------
# unified pulse-stream entry points (used by .pvqz and the checkpointer)
# ---------------------------------------------------------------------------

#: chunked-stream blob header: [u32 n_chunks][u64 * n_chunks bit offsets]
_HDR_COUNT = struct.Struct("<I")


def _wrap_chunked(stream: np.ndarray, offsets: np.ndarray) -> bytes:
    return (
        _HDR_COUNT.pack(offsets.size)
        + offsets.astype("<u8").tobytes()
        + stream.tobytes()
    )


def _unwrap_chunked(blob: bytes) -> Tuple[np.ndarray, bytes]:
    (n_chunks,) = _HDR_COUNT.unpack_from(blob, 0)
    off_end = 4 + 8 * n_chunks
    offsets = np.frombuffer(blob[4:off_end], "<u8")
    return offsets, blob[off_end:]


def encode_pulses(
    values: np.ndarray,
    codec: str,
    *,
    k_max: Optional[int] = None,
    chunk: int = DEFAULT_CHUNK,
) -> Tuple[bytes, Dict]:
    """Encode a pulse stream (any shape; ``enum`` needs (G, N) groups).

    Returns (blob, info); ``info`` holds everything :func:`decode_pulses`
    needs besides the blob itself: codec, count, payload bits, and
    codec-specific fields.  Codecs: ``golomb`` / ``rle`` (chunked, embedded
    offset table), ``enum`` (fixed length, needs ``k_max`` and a 2-D group
    matrix), ``nibble`` / ``int8`` (raw fallbacks).
    """
    groups = np.asarray(values, np.int64)
    flat = groups.ravel()
    info: Dict = {"codec": codec, "count": int(flat.size)}
    if codec == "golomb":
        stream, offsets, nbits = golomb_encode_chunked(flat, chunk)
        info.update(nbits=int(nbits), chunk=chunk)
        return _wrap_chunked(stream, offsets), info
    if codec == "rle":
        stream, offsets, nbits, n_pairs = rle_encode_chunked(flat, chunk)
        info.update(nbits=int(nbits), chunk=chunk, n_pairs=int(n_pairs))
        return _wrap_chunked(stream, offsets), info
    if codec == "enum":
        if k_max is None:
            raise ValueError("enum codec needs k_max")
        if groups.ndim != 2:
            raise ValueError("enum codec needs a (G, N) group matrix")
        blob, per = enum_encode_groups(groups, k_max)
        info.update(
            nbits=int(per * groups.shape[0]),
            k_max=int(k_max),
            n_groups=int(groups.shape[0]),
            group=int(groups.shape[1]),
        )
        return blob, info
    if codec == "nibble":
        from .packing import pack_nibbles  # one 4-bit layout, shared with the checkpointer

        if np.abs(flat).max(initial=0) > 7:
            raise ValueError("nibble codec requires |pulse| <= 7")
        packed, _ = pack_nibbles(flat)
        info["nbits"] = 4 * int(flat.size)
        return packed.tobytes(), info
    if codec == "int8":
        info["nbits"] = 8 * int(flat.size)
        return flat.astype(np.int8).tobytes(), info
    raise ValueError(f"unknown pulse codec {codec!r}")


def decode_pulses(blob: bytes, info: Dict, group: Optional[int] = None) -> np.ndarray:
    """Inverse of :func:`encode_pulses`.

    Returns the flat int64 symbol stream, reshaped to (G, group) when
    ``group`` is given (``enum`` blobs are always grouped).
    """
    codec, count = info["codec"], info["count"]
    if codec == "golomb":
        offsets, stream = _unwrap_chunked(blob)
        flat = golomb_decode_chunked(stream, offsets, count, info["chunk"])
    elif codec == "rle":
        offsets, stream = _unwrap_chunked(blob)
        flat = rle_decode_chunked(
            stream, offsets, info["n_pairs"], count, info["chunk"]
        )
    elif codec == "enum":
        return enum_decode_groups(
            blob, info["n_groups"], info["group"], info["k_max"]
        )
    elif codec == "nibble":
        from .packing import unpack_nibbles

        flat = unpack_nibbles(np.frombuffer(blob, np.uint8), (count,))
    elif codec == "int8":
        flat = np.frombuffer(blob, np.int8).astype(np.int64)[:count]
    else:
        raise ValueError(f"unknown pulse codec {codec!r}")
    return flat.reshape(-1, group) if group is not None else flat


def measured_bits(
    stream: np.ndarray,
    *,
    group_matrix: Optional[np.ndarray] = None,
    k_max: Optional[int] = None,
) -> Dict[str, float]:
    """Exact payload bits under each codec (the .pvqz selection rule input).

    ``stream`` is the symbol stream the variable-length codecs would encode
    (golomb/rle/nibble/int8); ``group_matrix``/``k_max`` additionally price
    the fixed-length enumeration stream over the (G, N) group view.  Uses the
    ``core.codes`` size models — the ``golomb_length`` sum and the RLE pair
    model are *exact* (identical to the produced streams); the enumeration
    entry is the fixed-length formula.
    """
    flat = np.asarray(stream, np.int64).ravel()
    out = {
        "golomb": float(golomb_length(flat).sum()) if flat.size else 0.0,
        "rle": float(rle_bits(flat)),
        "int8": 8.0 * flat.size,
    }
    if np.abs(flat).max(initial=0) <= 7:
        out["nibble"] = 4.0 * flat.size
    if group_matrix is not None and k_max is not None:
        n = int(group_matrix.shape[-1])
        if n <= 4096:
            out["enum"] = float(
                enum_bits_per_group(n, k_max) * group_matrix.shape[0]
            )
    return out


def choose_codec(
    stream: np.ndarray,
    groups: np.ndarray,
    k: int,
    *,
    enum_budget: int = DEFAULT_ENUM_BUDGET,
) -> Tuple[str, Dict[str, float]]:
    """Pick the cheapest codec by measured payload bits — THE ``.pvqz``
    per-leaf selection rule (also applied by ``packed_stats`` so its report
    matches what the artifact actually produces).

    Returns (codec, {codec: bits}).  Enumeration is priced always (it goes
    in the report) but only *eligible* when the bigint encode work
    ``G * group * K`` fits the budget.
    """
    sizes = measured_bits(stream, group_matrix=groups, k_max=k)
    eligible = dict(sizes)
    enum_cost = groups.shape[0] * groups.shape[1] * max(k, 1)
    if "enum" in eligible and enum_cost > enum_budget:
        del eligible["enum"]
    codec = min(eligible, key=lambda c: (eligible[c], PULSE_CODECS.index(c)))
    return codec, sizes
