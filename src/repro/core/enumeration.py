"""Fischer enumeration of the pyramid surface P(N, K)  (paper §II, §VI).

Provides:
  * ``num_points(N, K)``  — the exact number of lattice points N_p(N, K)
    (Python bigints; the paper notes these get thousands of bits long).
  * ``index_bits(N, K)``  — ceil(log2(N_p)), the fixed-size code length.
  * ``vector_to_index`` / ``index_to_vector`` — the bijection between points
    of P(N, K) and integers [0, N_p), via lexicographic ranking with the
    per-coordinate value order 0, +1, -1, +2, -2, ...  O(N*K) bigint ops —
    kept as the exact reference implementation.
  * ``vector_to_index_batch`` / ``index_to_vector_batch`` — the same
    bijection as vectorized limb arithmetic: ranks are little-endian
    uint32 limb arrays and all groups of a leaf advance one coordinate per
    numpy round, so enumeration coding is fast enough to be the default
    ``.pvqz`` codec (no bigint in the per-group path).

Recurrence (Fischer 1986):
    N_p(L, K) = N_p(L-1, K) + N_p(L-1, K-1) + N_p(L, K-1)
    N_p(L, 0) = 1,   N_p(0, K) = 0 for K > 0
Closed form: N_p(N, K) = sum_d 2^d C(N, d) C(K-1, d-1).
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import Iterable, List, Sequence, Tuple

import numpy as np

_LIMB_BITS = 32
_LIMB_MASK = (1 << _LIMB_BITS) - 1

#: Per-(n, k_max) cumulative count tables are materialized once and cached;
#: this caps their footprint so a pathological leaf shape cannot OOM the
#: encoder.  It is a table-memory bound, not an encode-cost gate: every
#: realistic group size (<= 1024 dims) fits with orders of magnitude to spare.
ENUM_TABLE_MAX_BYTES = 256 * 2**20


@lru_cache(maxsize=None)
def num_points(n: int, k: int) -> int:
    """N_p(n, k): number of integer vectors of dim n with L1 norm exactly k."""
    if k == 0:
        return 1
    if n == 0:
        return 0
    # Closed form with bigints — O(min(n,k)) terms, no deep recursion.
    total = 0
    for d in range(1, min(n, k) + 1):
        total += (1 << d) * math.comb(n, d) * math.comb(k - 1, d - 1)
    return total


def index_bits(n: int, k: int) -> int:
    """Bits for a fixed-length enumeration code of P(n, k) (paper: N_p(8,4)=2816 -> <12 bits)."""
    points = num_points(n, k)
    return max((points - 1).bit_length(), 1)


def _value_order(k: int) -> List[int]:
    """Per-coordinate value order: 0, +1, -1, +2, -2, ... +k, -k."""
    order = [0]
    for m in range(1, k + 1):
        order.extend((m, -m))
    return order


def vector_to_index(y: Sequence[int]) -> int:
    """Rank a point of P(N, K) lexicographically (value order above)."""
    y = [int(v) for v in y]
    k = sum(abs(v) for v in y)
    n = len(y)
    idx = 0
    for pos, v in enumerate(y):
        rem_dims = n - pos - 1
        for u in _value_order(k):
            if u == v:
                break
            idx += num_points(rem_dims, k - abs(u))
        k -= abs(v)
    return idx


def index_to_vector(idx: int, n: int, k: int) -> List[int]:
    """Inverse of :func:`vector_to_index`."""
    if not (0 <= idx < num_points(n, k)):
        raise ValueError(f"index {idx} out of range for P({n},{k})")
    out: List[int] = []
    for pos in range(n):
        rem_dims = n - pos - 1
        for u in _value_order(k):
            cnt = num_points(rem_dims, k - abs(u))
            if idx < cnt:
                out.append(u)
                k -= abs(u)
                break
            idx -= cnt
        else:  # pragma: no cover - unreachable for valid idx
            raise AssertionError("enumeration overflow")
    assert k == 0
    return out


def enumerate_all(n: int, k: int) -> Iterable[List[int]]:
    """Yield every point of P(n, k) in rank order (test utility; small n,k only)."""
    for i in range(num_points(n, k)):
        yield index_to_vector(i, n, k)


def pack_indices(vectors: np.ndarray) -> bytes:
    """Fixed-length bit-packing of a batch of P(N,K) points via enumeration.

    vectors: int array (G, N), each row on P(N, K_row) with a shared K
    (rows may use fewer pulses only if they are exact zeros => K=0 rows get
    index 0 of P(N,0)={0}).  Returns the concatenated bitstream.
    """
    vectors = np.asarray(vectors)
    g, n = vectors.shape
    k = int(np.abs(vectors).sum(axis=-1).max()) if vectors.size else 0
    nbits = index_bits(n, k)
    acc = 0
    for row in vectors:
        acc = (acc << nbits) | vector_to_index(row.tolist())
    total_bits = nbits * g
    nbytes = (total_bits + 7) // 8
    return acc.to_bytes(nbytes, "big") if nbytes else b""


def unpack_indices(blob: bytes, g: int, n: int, k: int) -> np.ndarray:
    nbits = index_bits(n, k)
    acc = int.from_bytes(blob, "big")
    rows = []
    for i in range(g):
        shift = nbits * (g - 1 - i)
        idx = (acc >> shift) & ((1 << nbits) - 1)
        rows.append(index_to_vector(idx, n, k))
    return np.asarray(rows, dtype=np.int64)


# ---------------------------------------------------------------------------
# Vectorized limb-bignum enumeration (the fast path behind the `enum` codec).
#
# A rank of P(n, k) needs up to index_bits(n, k) bits — far beyond int64 for
# real group sizes — so ranks are fixed-width little-endian uint32 limb
# arrays of shape (G, L).  The per-coordinate ladder of the reference
# implementation becomes gathers into two precomputed tables:
#
#   NP[rem, t] = N_p(rem, t)                       (rem = dims after this one)
#   DP[rem, t] = sum_{j < t} N_p(rem, j)           (exclusive prefix over t)
#
# both stored as limb arrays, so one encode round sums, over all groups at
# once, the lexicographic skip-count of the chosen value v (|v| = m > 0):
#
#   inc = NP[rem, k] + 2*(DP[rem, k] - DP[rem, k-m+1]) + (v < 0)*NP[rem, k-m]
#
# (the v=0 subtree, both signs of every smaller magnitude, and +m if v is
# negative).  Decode inverts this with a v==0 test over all groups followed
# by a magnitude scan over the shrinking nonzero subset.  Limb intermediates
# use int64: |term| < 4*2^32 and n <= 4096 keeps accumulated sums < 2^46,
# and comparisons only ever subtract two carry-normalized operands, so the
# sign of the most significant nonzero limb difference is the sign of the
# difference.
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def limb_count(n: int, k_max: int) -> int:
    """uint32 limbs needed to hold any rank (or cumulative count) of P(n, k<=k_max)."""
    return max(1, (num_points(n, k_max).bit_length() + _LIMB_BITS - 1) // _LIMB_BITS)


def enum_table_bytes(n: int, k_max: int) -> int:
    """Footprint of the cached NP/DP limb tables for (n, k_max)."""
    if n <= 0:
        return 0
    return 8 * limb_count(n, k_max) * (n + 1) * (2 * k_max + 3)


def enum_supported(n: int, k_max: int) -> bool:
    """Whether the limb tables for (n, k_max) fit under ENUM_TABLE_MAX_BYTES.

    Also bounds the rank width at 29 limbs (928 bits) so every decode-side
    float64 proxy — value 1 at the widest per-position scale up to the top
    limb's weight — stays inside the normal float range.
    """
    return (
        n > 0
        and k_max >= 0
        and enum_table_bytes(n, k_max) <= ENUM_TABLE_MAX_BYTES
        and limb_count(n, k_max) <= 29
    )


@lru_cache(maxsize=8)
def enum_tables(n: int, k_max: int) -> Tuple[np.ndarray, np.ndarray]:
    """(NP, DP) limb tables as int64 limbs in [0, 2^32).

    NP has shape (n+1, k_max+1, L): NP[rem, t] = N_p(rem, t) for rem in
    [0, n] (the extra row n exists because NP[rem, k] + 2*DP[rem, k] ==
    N_p(rem+1, k), which the encoder exploits as a single gather).
    DP has shape (n, k_max+2, L): DP[rem, t] = sum_{j < t} N_p(rem, j).
    """
    if n <= 0 or k_max < 0:
        raise ValueError(f"invalid enumeration table shape ({n}, {k_max})")
    if not enum_supported(n, k_max):
        raise ValueError(
            f"enum tables for (n={n}, k_max={k_max}) would need "
            f"{enum_table_bytes(n, k_max)} bytes > ENUM_TABLE_MAX_BYTES"
        )
    L = limb_count(n, k_max)
    # Bigint rows via the Fischer recurrence (O(n*k) adds — far cheaper than
    # the closed form per entry), then one bulk little-endian conversion.
    rows: List[List[int]] = [[1] + [0] * k_max]
    for _ in range(n):
        prev = rows[-1]
        new = [1] + [0] * k_max
        for t in range(1, k_max + 1):
            new[t] = prev[t] + prev[t - 1] + new[t - 1]
        rows.append(new)
    width = 4 * L
    np_buf = b"".join(v.to_bytes(width, "little") for row in rows for v in row)
    NP = (
        np.frombuffer(np_buf, dtype=np.uint32)
        .reshape(n + 1, k_max + 1, L)
        .astype(np.int64)
    )
    dp_chunks: List[bytes] = []
    for row in rows[:n]:
        acc = 0
        parts = [b"\0" * width]
        for v in row:
            acc += v
            parts.append(acc.to_bytes(width, "little"))
        dp_chunks.append(b"".join(parts))
    DP = (
        np.frombuffer(b"".join(dp_chunks), dtype=np.uint32)
        .reshape(n, k_max + 2, L)
        .astype(np.int64)
    )
    return NP, DP


def _carry_norm(acc: np.ndarray) -> np.ndarray:
    """Normalize int64 limbs (possibly mixed-sign) to [0, 2^32); value must fit."""
    for _ in range(4 * acc.shape[-1] + 8):
        carry = acc >> _LIMB_BITS  # arithmetic shift == floor division
        if not carry.any():
            return acc
        acc &= _LIMB_MASK
        acc[..., 1:] += carry[..., :-1]
    if (acc >> _LIMB_BITS).any():  # pragma: no cover - guarded by callers
        raise AssertionError("limb accumulator failed to normalize")
    return acc


def vector_to_index_batch(groups: np.ndarray, k_max: int) -> np.ndarray:
    """Rank every row of ``groups`` on P(n, k_row); returns (G, L) uint32 limbs.

    Bit-identical to ``vector_to_index`` per row (property-tested); rows may
    carry any L1 norm k_row <= k_max, including 0.  Only nonzero coordinates
    contribute skip counts, so the gathers run over the nonzero set and the
    per-group rank is a ``reduceat`` segment sum.
    """
    groups = np.ascontiguousarray(np.asarray(groups, dtype=np.int64))
    if groups.ndim != 2:
        raise ValueError(f"expected (G, n) groups, got shape {groups.shape}")
    g, n = groups.shape
    k_max = int(k_max)
    NP, DP = enum_tables(n, k_max)
    L = NP.shape[-1]
    out = np.zeros((g, L), dtype=np.uint32)
    if g == 0:
        return out
    m_all = np.abs(groups)
    k_g = m_all.sum(axis=-1)
    if int(k_g.max(initial=0)) > k_max:
        raise ValueError(f"group L1 {int(k_g.max())} exceeds k_max {k_max}")
    gi, pi = np.nonzero(m_all)  # row-major: coordinates stay grouped by row
    if gi.size == 0:
        return out
    m = m_all[gi, pi]
    k_rem = k_g[gi] - np.cumsum(m_all, axis=1)[gi, pi] + m  # L1 left to spend
    rem = n - 1 - pi
    NPf = NP.reshape(-1, L)
    DPf = DP.reshape(-1, L)
    base = rem * (k_max + 1)
    # skip(v) = N_p(rem, k) + 2*(DP[rem, k] - DP[rem, k-m+1]) + (v<0)*N_p(rem, k-m)
    # and N_p(rem, k) + 2*DP[rem, k] == N_p(rem+1, k): one gather for two terms.
    term = NPf[base + (k_max + 1) + k_rem].copy()
    term -= 2 * DPf[rem * (k_max + 2) + k_rem - m + 1]
    neg = np.flatnonzero(groups[gi, pi] < 0)
    if neg.size:
        term[neg] += NPf[base[neg] + k_rem[neg] - m[neg]]
    cnt = (m_all > 0).sum(axis=1)
    nz_rows = np.flatnonzero(cnt)
    starts = np.cumsum(cnt[nz_rows]) - cnt[nz_rows]
    # |term limb| < 2*2^32 and n <= 4096 coords keep segment sums < 2^46.
    out[nz_rows] = _carry_norm(np.add.reduceat(term, starts, axis=0)).astype(np.uint32)
    return out


@lru_cache(maxsize=16)  # decode sizes tables by each batch's own L1 ceiling
def _decode_tables(n: int, k_max: int):
    """Decode-side companions of the NP table.

    ``dp2[r] = 2*DP[r]`` pre-doubled and carry-normalized, so the fire-block
    residual ``idx - NP[r+1, k] + dp2[r, k-m+1]`` starts with limbs already
    in (-2^32, 2*2^32) and normalizes in ~2 carry passes.  The hot-path
    comparisons run on scalar float64 proxies: ``fnp[r][t]`` is N_p(r, t)
    scaled by 2^(-32*(las[r]-2)), a per-position common factor that keeps
    proxies inside float64 range (tables under the byte cap can exceed
    2^1024); comparisons at one position all share the factor.  ``wsc[la]``
    is the matching full-L limb weight vector — limbs above ``las[r]`` are
    exactly zero for every in-range value, so no trimming is needed.
    """
    NP, DP = enum_tables(n, k_max)
    L = NP.shape[-1]
    sig = NP[1:, k_max] != 0  # row r: N_p(r+1, k_max)
    las = np.maximum(L - np.argmax(sig[:, ::-1], axis=1), 1)
    las[~sig.any(axis=1)] = 1
    # 2*DP[r, j] is only ever gathered at j <= k_max (j = k-m+1 with m >= 1),
    # where it fits L limbs; the j = k_max+1 column may wrap — it is unused.
    dp2 = _carry_norm(DP << 1)
    wsc = {
        la: np.ldexp(np.ones(L), _LIMB_BITS * (np.arange(L) - la + 2))
        for la in set(int(x) for x in las)
    }
    fnp = [NP[r] @ wsc[int(las[r])] for r in range(n)]
    # Fire-block companions, trimmed to the las[r] limbs that are live at
    # position r (every in-range value's upper limbs are exactly zero, so
    # the residual arithmetic and carry passes only touch la columns):
    # ntab[r] = N_p(r+1, .), dtab[r] = 2*DP[r, .], ztab[r] = N_p(r, .).
    ntab = [np.ascontiguousarray(NP[r + 1, :, : las[r]]) for r in range(n)]
    dtab = [np.ascontiguousarray(dp2[r, :, : las[r]]) for r in range(n)]
    ztab = [np.ascontiguousarray(NP[r, :, : las[r]]) for r in range(n)]
    wtr = {la: np.ascontiguousarray(w[:la]) for la, w in wsc.items()}
    # cumulative magnitude thresholds, same proxy scale as fnp[r]:
    # tcz[r][k, m] = 2 * sum_{j=1..m} N_p(r, k-j) (column 0 is the zero
    # floor), so the decoded magnitude of a live row is 1 + (#thresholds
    # <= u) — one broadcasted compare instead of a level-by-level scan —
    # and tcz[r][k, m-1] is the float floor of level m for the sign test
    tcz = []
    for r in range(n):
        if k_max == 0:
            tcz.append(np.zeros((1, 1)))
            continue
        pad = np.concatenate([np.zeros(k_max), fnp[r]])
        wv = np.lib.stride_tricks.sliding_window_view(pad, k_max)
        cum = 2.0 * np.cumsum(wv[: k_max + 1, ::-1], axis=1)
        tcz.append(np.ascontiguousarray(np.pad(cum, ((0, 0), (1, 0)))))
    # fused fire-block residual table, two's-complement mod 2^(32*la):
    # cfl[r][kf, kn+1, s] = 2*DP[r, kn+1] - N_p(r+1, kf) - s*N_p(r, kn),
    # so a fired row commits with one gather + one add + one carry pass
    # (the sign s comes from the float proxies; a boundary mistake lands
    # the residual outside [0, N_p(r, kn)) and is redone exactly).  The
    # table is quadratic in k, so it is built only under a memory cap —
    # None falls back to the two-gather + ztab path.
    cfl = None
    cbytes = 16 * n * (k_max + 1) * (k_max + 2) * int(las.max())
    if cbytes <= 48 * 2**20:
        jz = np.arange(k_max + 2) - 1  # kn for each column j = kn+1
        cfl = []
        for r in range(n):
            d = dtab[r][None, :, :] - ntab[r][:, None, :]
            zj = np.take(ztab[r], jz, axis=0, mode="wrap")
            both = np.stack([d, d - zj[None, :, :]], axis=2)
            la = int(las[r])
            cfl.append(_carry_norm(both).reshape(-1, la))
    return dp2, las, wsc, fnp, ntab, dtab, ztab, wtr, tcz, cfl


def _int_of_limbs(row) -> int:
    """Exact Python-int value of a little-endian int64 limb row (any sign mix)."""
    v = 0
    for x in row[::-1].tolist():
        v = (v << _LIMB_BITS) + x
    return v


def _exact_step(idx, fidx, k_rem, out, j, u, k, r, pos, scale_exp):
    """Exact bigint decode of one ladder position for one suspect row.

    The vectorized scan flags a row as suspect whenever a float-proxy
    comparison fell inside its rounding band (or its reconstructed residual
    failed the [0, N_p(r, k_new)) range check); this redoes the position
    from the row's pre-fire rank ``u`` and L1 budget ``k`` with Python ints
    and writes all of the row's state (limbs, proxy, k_rem, out) back,
    overwriting whatever the vector path committed.
    """
    val = 0
    c = num_points(r, k)
    if u >= c:
        u -= c
        m = 1
        while m <= k:
            c = num_points(r, k - m)
            if u < c:
                val = m
                break
            u -= c
            if u < c:
                val = -m
                break
            u -= c
            m += 1
        else:
            raise ValueError("rank out of range for P(n, k)")
    out[j, pos] = val
    k_rem[j] = k - abs(val)
    L = idx.shape[-1]
    limbs = np.frombuffer(u.to_bytes(4 * L, "little"), dtype=np.uint32)
    idx[j] = limbs.astype(np.int64)
    sh = max(0, u.bit_length() - 53)  # keep full float64 precision in the proxy
    fidx[j] = np.ldexp(float(u >> sh), sh + scale_exp)


def index_to_vector_batch(
    ranks: np.ndarray, k_g: np.ndarray, n: int, k_max: int
) -> np.ndarray:
    """Inverse of :func:`vector_to_index_batch`.

    ranks: (G, L) uint32 limb array; k_g: per-group L1 norms. Returns (G, n)
    int64 pulse rows.

    The hot loop is one pass per coordinate over all groups at once.  Live
    rows read their magnitude off precomputed cumulative thresholds in one
    broadcasted compare against scalar float64 proxies (no limb arithmetic,
    no per-level scan); the exact residual of a fired row
    is then reconstructed in one shot from the encode identity
    ``skip(+/-m) = N_p(r+1, k) - 2*DP[r, k-m+1] (+ N_p(r, k-m) if negative)``
    and verified against the range invariant ``0 <= res < N_p(r, k-m)``.
    Any float rounding mistake lands the residual outside that range (wrong
    magnitude, sign, or liveness are all equivalent to an out-of-band
    ``u``), so mis-scanned rows are provably flagged and redone exactly via
    :func:`_exact_step`; clean rows commit without ever comparing limbs.
    """
    ranks = np.asarray(ranks, dtype=np.uint32)
    k_g = np.asarray(k_g, dtype=np.int64)
    n, k_max = int(n), int(k_max)
    NP, _ = enum_tables(n, k_max)
    L = NP.shape[-1]
    if ranks.ndim != 2 or ranks.shape[-1] != L:
        raise ValueError(f"expected (G, {L}) rank limbs, got shape {ranks.shape}")
    g = ranks.shape[0]
    if k_g.shape != (g,):
        raise ValueError(f"k_g shape {k_g.shape} does not match {g} groups")
    if g == 0:
        return np.zeros((0, n), dtype=np.int64)
    k_batch = int(k_g.max())
    if k_batch > k_max or int(k_g.min()) < 0:
        raise ValueError(f"group L1 out of range for k_max {k_max}")
    if k_batch == 0:
        return np.zeros((g, n), dtype=np.int64)
    # heavy outlier rows shouldn't force wide limbs on everyone: when the
    # 90th-percentile L1 needs strictly fewer limbs than the batch max,
    # decode the bulk narrow and the heavy tail at full width separately
    # (the cap widens to the last k that still fits the narrow limb count)
    if g > 512:
        L_hi = limb_count(n, k_batch)
        p90 = (9 * g) // 10
        k90 = max(int(np.partition(k_g, p90)[p90]), 1)
        if limb_count(n, k90) < L_hi:
            cap = k90
            while cap + 1 < k_batch and limb_count(n, cap + 1) == limb_count(n, k90):
                cap += 1
            ni = np.flatnonzero(k_g <= cap)
            wi = np.flatnonzero(k_g > cap)
            out = np.empty((g, n), dtype=np.int64)
            out[ni] = index_to_vector_batch(ranks[ni], k_g[ni], n, k_max)
            out[wi] = index_to_vector_batch(ranks[wi], k_g[wi], n, k_max)
            return out
    # size the ladder by the batch's real L1 ceiling, not the wire-format
    # k_max: every gather below only ever touches table rows <= k_batch,
    # and valid ranks fit the (usually much narrower) k_batch limb count —
    # fewer limbs shrink the fire/carry/commit arithmetic and the fused
    # table quadratically.  Limbs above that width are zero for any
    # in-range rank; a nonzero one (corrupt stream) keeps the full width
    # so the range checks see the whole value.
    k_eff = k_batch
    L2 = limb_count(n, k_eff)
    if L2 < L and ranks[:, L2:].any():
        k_eff, L2 = k_max, L
    dp2, las, wsc, fnp, ntab, dtab, ztab, wtr, tcz, cfl = _decode_tables(n, k_eff)
    idx = ranks[:, :L2].astype(np.int64)
    k_rem = k_g.copy()
    out = np.zeros((g, n), dtype=np.int64)
    rel = np.ldexp(1.0, -45)  # proxy operands carry <= ~2^-49 relative error
    ones = np.ones(max(k_eff, 1))
    la_cur = int(las[n - 1])
    fidx = idx @ wsc[la_cur]
    for pos in range(n):
        r = n - 1 - pos
        la = int(las[r])
        if la != la_cur:  # re-scale the rank proxies to this position's factor
            fidx = fidx * np.ldexp(1.0, _LIMB_BITS * (la_cur - la))
            la_cur = la
        ft, w = fnp[r], wsc[la]
        ft0 = ft[k_rem]
        fu = fidx - ft0  # rank minus the v=0 subtree count, in proxy scale
        # rows whose v=0 test fell inside the rounding band may really fire:
        # redo them exactly (fired rows are instead vetted by the range check)
        sus = (fu < 0.0) & (fu >= (fidx + ft0) * -rel)
        # live rows (v != 0 here, ~K/n of the batch) read their magnitude
        # straight off the cumulative thresholds: m = 1 + #(t_m <= u).  A
        # proxy error near a boundary picks the wrong side exactly like the
        # level scan would — the fire-block range check flags either way
        # (m > k_row overshoots to kn < 0, also flagged).
        fi = np.flatnonzero(fu >= 0.0)
        if fi.size:
            fuc = fu[fi]
            kf = k_rem[fi]
            mm = max(int(kf.max()), 1)
            cmp = fuc[:, None] >= tcz[r][:, 1 : mm + 1][kf]
            mf = (cmp @ ones[:mm]).astype(np.int64) + 1
            kn = kf - mf
            wl = wtr[la]
            fhi = ft[kn]
            pre = idx[fi, :la]
            if cfl is not None:
                # sign from the float proxies: the in-level offset past
                # N_p(r, kn) means v = -m; then commit with a single fused
                # gather (see _decode_tables) — a mis-signed boundary row
                # wraps mod 2^(32*la) and fails the range check below
                negm = fuc - tcz[r][kf, mf - 1] >= fhi
                res = pre + cfl[r][((kf * (k_eff + 2) + kn + 1) << 1) + negm]
                res = _carry_norm(res)  # nonneg limbs; top carry-out drops
                fres = res @ wl
                bnd = (fres + fhi) * rel
            else:
                res = pre - ntab[r][kf]
                res += dtab[r][kn + 1]
                fres = res @ wl
                bnd = (np.abs(fres) + fhi) * rel
                negm = fres >= fhi  # residual past the +m band means v = -m
                ngi = np.flatnonzero(negm)
                if ngi.size:
                    res[ngi] -= ztab[r][kn[ngi]]
                res = _carry_norm(res)  # negatives wrap high, fail the check
                fres = res @ wl
            # range invariant: certainly-inside via the float band, or res
            # exactly 0 (every group's final pulse lands there; post-carry
            # limbs are nonnegative so fres == 0.0 iff all limbs are zero);
            # kn < 0 means the magnitude overshot the row's own L1 budget,
            # never a valid fire
            clean = (((fres > bnd) & (fres < fhi - bnd)) | (fres == 0.0)) & (kn >= 0)
            idx[fi, :la] = res
            fidx[fi] = fres
            k_rem[fi] = kn
            out[fi, pos] = np.where(negm, -mf, mf)
            if not clean.all():
                bi = np.flatnonzero(~clean)
                scale_exp = _LIMB_BITS * (2 - la)
                for t in bi.tolist():
                    j = int(fi[t])
                    _exact_step(
                        idx, fidx, k_rem, out, j,
                        _int_of_limbs(pre[t]), int(kf[t]), r, pos, scale_exp,
                    )
        if sus.any():
            scale_exp = _LIMB_BITS * (2 - la)
            for j in np.flatnonzero(sus).tolist():
                _exact_step(
                    idx, fidx, k_rem, out, j,
                    _int_of_limbs(idx[j]), int(k_rem[j]), r, pos, scale_exp,
                )
    return out
