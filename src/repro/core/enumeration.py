"""Fischer enumeration of the pyramid surface P(N, K)  (paper §II, §VI).

Provides:
  * ``num_points(N, K)``  — the exact number of lattice points N_p(N, K)
    (Python bigints; the paper notes these get thousands of bits long).
  * ``index_bits(N, K)``  — ceil(log2(N_p)), the fixed-size code length.
  * ``vector_to_index`` / ``index_to_vector`` — the bijection between points
    of P(N, K) and integers [0, N_p), via lexicographic ranking with the
    per-coordinate value order 0, +1, -1, +2, -2, ...  O(N*K) bigint ops —
    exact but (as the paper observes) only practical offline for moderate N;
    the entropy coders in ``repro.core.codes`` are the practical path.

Recurrence (Fischer 1986):
    N_p(L, K) = N_p(L-1, K) + N_p(L-1, K-1) + N_p(L, K-1)
    N_p(L, 0) = 1,   N_p(0, K) = 0 for K > 0
Closed form: N_p(N, K) = sum_d 2^d C(N, d) C(K-1, d-1).
"""

from __future__ import annotations

import math
from functools import lru_cache
from typing import Iterable, List, Sequence

import numpy as np


@lru_cache(maxsize=None)
def num_points(n: int, k: int) -> int:
    """N_p(n, k): number of integer vectors of dim n with L1 norm exactly k."""
    if k == 0:
        return 1
    if n == 0:
        return 0
    # Closed form with bigints — O(min(n,k)) terms, no deep recursion.
    total = 0
    for d in range(1, min(n, k) + 1):
        total += (1 << d) * math.comb(n, d) * math.comb(k - 1, d - 1)
    return total


def index_bits(n: int, k: int) -> int:
    """Bits for a fixed-length enumeration code of P(n, k) (paper: N_p(8,4)=2816 -> <12 bits)."""
    points = num_points(n, k)
    return max((points - 1).bit_length(), 1)


def _value_order(k: int) -> List[int]:
    """Per-coordinate value order: 0, +1, -1, +2, -2, ... +k, -k."""
    order = [0]
    for m in range(1, k + 1):
        order.extend((m, -m))
    return order


def vector_to_index(y: Sequence[int]) -> int:
    """Rank a point of P(N, K) lexicographically (value order above)."""
    y = [int(v) for v in y]
    k = sum(abs(v) for v in y)
    n = len(y)
    idx = 0
    for pos, v in enumerate(y):
        rem_dims = n - pos - 1
        for u in _value_order(k):
            if u == v:
                break
            idx += num_points(rem_dims, k - abs(u))
        k -= abs(v)
    return idx


def index_to_vector(idx: int, n: int, k: int) -> List[int]:
    """Inverse of :func:`vector_to_index`."""
    if not (0 <= idx < num_points(n, k)):
        raise ValueError(f"index {idx} out of range for P({n},{k})")
    out: List[int] = []
    for pos in range(n):
        rem_dims = n - pos - 1
        for u in _value_order(k):
            cnt = num_points(rem_dims, k - abs(u))
            if idx < cnt:
                out.append(u)
                k -= abs(u)
                break
            idx -= cnt
        else:  # pragma: no cover - unreachable for valid idx
            raise AssertionError("enumeration overflow")
    assert k == 0
    return out


def enumerate_all(n: int, k: int) -> Iterable[List[int]]:
    """Yield every point of P(n, k) in rank order (test utility; small n,k only)."""
    for i in range(num_points(n, k)):
        yield index_to_vector(i, n, k)


def pack_indices(vectors: np.ndarray) -> bytes:
    """Fixed-length bit-packing of a batch of P(N,K) points via enumeration.

    vectors: int array (G, N), each row on P(N, K_row) with a shared K
    (rows may use fewer pulses only if they are exact zeros => K=0 rows get
    index 0 of P(N,0)={0}).  Returns the concatenated bitstream.
    """
    vectors = np.asarray(vectors)
    g, n = vectors.shape
    k = int(np.abs(vectors).sum(axis=-1).max()) if vectors.size else 0
    nbits = index_bits(n, k)
    acc = 0
    for row in vectors:
        acc = (acc << nbits) | vector_to_index(row.tolist())
    total_bits = nbits * g
    nbytes = (total_bits + 7) // 8
    return acc.to_bytes(nbytes, "big") if nbytes else b""


def unpack_indices(blob: bytes, g: int, n: int, k: int) -> np.ndarray:
    nbits = index_bits(n, k)
    acc = int.from_bytes(blob, "big")
    rows = []
    for i in range(g):
        shift = nbits * (g - 1 - i)
        idx = (acc >> shift) & ((1 << nbits) - 1)
        rows.append(index_to_vector(idx, n, k))
    return np.asarray(rows, dtype=np.int64)
