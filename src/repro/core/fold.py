"""Scale folding / rho propagation through homogeneous networks (paper §V).

For positively-homogeneous nonlinearities (f(rho*x) = rho*f(x): ReLU, MaxPool,
identity, avg-pool) the per-layer PVQ scale rho_l passes through the
activation, so an L-layer net evaluates as

    out = (prod_l rho_l) * f_L(What_L . f_{L-1}(... f_1(What_1 . x)))    (eq. 14)

i.e. every layer runs on INTEGER pulse weights and a single scalar is applied
at the output (or dropped entirely under argmax — "integer PVQ nets").  For
bsign nets (f(rho*x) = f(x), eq. 16-17) the scales are absorbed layer-by-layer
("binary PVQ nets").

This module implements the folding transform on our Sequential MLP/CNN
representation (repro.nn.sequential), verifying the paper's equality claims.
Transformers use per-group epilogue folding instead (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import List, Literal, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .pvq import PVQCode

Activation = Literal["relu", "bsign", "none"]

HOMOGENEOUS: Tuple[str, ...] = ("relu", "none", "maxpool", "avgpool")
ABSORBING: Tuple[str, ...] = ("bsign",)


@dataclasses.dataclass
class FoldedLayer:
    """One folded layer: integer pulse weights (+ integer-pulse bias) only."""

    w_pulses: jax.Array  # int32 (in, out) or conv kernel
    b_pulses: jax.Array  # int32 (out,)
    activation: str
    kind: str  # 'dense' | 'conv' | 'maxpool' | 'flatten'
    # bias pre-scale: bias pulses enter at the layer's own rho, but the input
    # arrives scaled by prod(previous rho); to keep pure-integer arithmetic
    # exact we carry the ratio bias_gain = 1/prod(prev rho) applied to bias
    # pulses... see fold_sequential for the exact bookkeeping.
    bias_gain: float = 1.0


@dataclasses.dataclass
class FoldedNet:
    layers: List[FoldedLayer]
    output_scale: float  # prod of rho_l for homogeneous nets; 1.0 for bsign


def fold_codes(
    layer_codes: List[PVQCode],
    activations: List[str],
) -> Tuple[List[np.ndarray], float]:
    """Given per-layer whole-layer PVQ codes (single rho each) and the layer
    activation kinds, return integer pulse tensors and the single output scale.

    Homogeneous activations propagate rho; absorbing activations (bsign) reset
    the running product to 1 after their layer.  Mixed nets fold up to the
    last absorbing layer, then continue the product.
    """
    if len(layer_codes) != len(activations):
        raise ValueError("one activation kind per coded layer")
    out_scale = 1.0
    pulse_tensors: List[np.ndarray] = []
    for code, act in zip(layer_codes, activations):
        rho = float(np.asarray(code.scale))
        pulse_tensors.append(np.asarray(code.pulses))
        if act in ABSORBING:
            out_scale = 1.0  # f(rho x) = f(x): scale absorbed
        elif act in HOMOGENEOUS:
            out_scale *= rho  # f(rho x) = rho f(x): scale passes through
        else:
            raise ValueError(f"activation {act!r} is neither homogeneous nor absorbing")
    return pulse_tensors, out_scale


def check_homogeneity(act_name: str, fn, rho: float = 2.5, n: int = 128, seed: int = 0) -> bool:
    """Empirical check of f(rho x) = rho f(x) (or = f(x) for absorbing)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    if act_name in ABSORBING:
        return bool(jnp.allclose(fn(rho * x), fn(x)))
    return bool(jnp.allclose(fn(rho * x), rho * fn(x), rtol=1e-5, atol=1e-6))
