"""PVQ gradient compression: channel properties, error feedback, wire bytes,
and convergence parity on a toy problem."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import AdamW
from repro.optim.grad_compress import (
    CompressionConfig,
    compress_decompress,
    cross_pod_mean,
    make_ef_compressor,
    wire_bytes,
)


def test_channel_preserves_direction_energy():
    cfg = CompressionConfig(group=256, n_over_k=2.0)
    g = jax.random.laplace(jax.random.PRNGKey(0), (4096,))
    q = compress_decompress(g, cfg)
    cos = jnp.sum(g * q) / (jnp.linalg.norm(g) * jnp.linalg.norm(q))
    assert float(cos) > 0.85


def test_channel_exact_as_k_grows():
    g = jax.random.laplace(jax.random.PRNGKey(1), (2048,))
    errs = []
    for n_over_k in (8.0, 2.0, 0.25):
        cfg = CompressionConfig(group=256, n_over_k=n_over_k)
        q = compress_decompress(g, cfg)
        errs.append(float(jnp.linalg.norm(q - g) / jnp.linalg.norm(g)))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 0.08  # K = 4*group -> a few % error


def test_small_leaves_pass_through():
    cfg = CompressionConfig(min_size=1024)
    g = jnp.ones(10)
    np.testing.assert_array_equal(np.asarray(compress_decompress(g, cfg)), np.ones(10))


def test_error_feedback_unbiased_mean():
    """With EF, the time-average of the decoded gradients approaches the true
    gradient (compression error does not accumulate)."""
    cfg = CompressionConfig(group=128, n_over_k=8.0)  # aggressive compression
    init, apply = make_ef_compressor(cfg)
    g_true = {"w": jax.random.laplace(jax.random.PRNGKey(2), (1024,))}
    ef = init(g_true)
    acc = jnp.zeros(1024)
    n = 120
    for _ in range(n):
        dec, ef = apply(g_true, ef)
        acc = acc + dec["w"]
    mean_dec = acc / n
    rel = float(jnp.linalg.norm(mean_dec - g_true["w"]) / jnp.linalg.norm(g_true["w"]))
    assert rel < 0.05  # O(1/n): error feedback does not accumulate bias


def test_wire_bytes_ratio():
    cfg = CompressionConfig(group=256, n_over_k=2.0)
    grads = {"a": jnp.zeros((1024, 64)), "b": jnp.zeros(128)}
    comp, raw = wire_bytes(grads, cfg)
    assert raw == 4 * (1024 * 64 + 128)
    # large leaf ~1.016 B/val, small leaf uncompressed
    assert comp < 0.3 * raw


def test_cross_pod_mean_matches_pmean_at_high_k():
    """shard_map over a 1-axis mesh: compressed mean ~= exact mean."""
    from jax.sharding import Mesh, PartitionSpec as P

    try:
        from jax import shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map

    devs = np.array(jax.devices()[:1]).reshape(1)
    mesh = Mesh(devs, ("pod",))
    cfg = CompressionConfig(group=128, n_over_k=0.25, min_size=128)  # K=4N: near-exact
    g = jax.random.laplace(jax.random.PRNGKey(3), (1, 2048))

    f = shard_map(
        lambda x: cross_pod_mean({"g": x[0]}, cfg, axis="pod")["g"][None],
        mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
    )
    out = f(g)
    rel = float(jnp.linalg.norm(out[0] - g[0]) / jnp.linalg.norm(g[0]))
    assert rel < 0.08  # K=4N channel error, no extra loss from the gather path


def test_compressed_training_converges():
    """AdamW + EF-compressed grads reaches (near) the uncompressed loss."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (256, 32))
    w_true = jax.random.laplace(jax.random.PRNGKey(5), (32,))
    y = x @ w_true

    def loss_fn(w):
        return jnp.mean((x @ w - y) ** 2)

    def train(compressed: bool, steps=150):
        opt = AdamW(lr=3e-2, weight_decay=0.0)
        w = {"w": jnp.zeros(32)}
        st = opt.init(w)
        cfg = CompressionConfig(group=32, n_over_k=2.0, min_size=16)
        init, apply = make_ef_compressor(cfg)
        ef = init(w)
        for _ in range(steps):
            g = jax.grad(lambda p: loss_fn(p["w"]))(w)
            if compressed:
                g, ef = apply(g, ef)
            w, st, _ = opt.update(g, st, w)
        return float(loss_fn(w["w"]))

    l_plain = train(False)
    l_comp = train(True)
    assert l_comp < 10 * max(l_plain, 1e-6) + 1e-3
