"""Autotuner tests: candidate generation, persistent JSON cache semantics,
cache-hit dispatch, and tuned-kernel correctness vs the oracle."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.kernels.ref import pvq_matmul_ref


@pytest.fixture
def tune_cache(tmp_path, monkeypatch):
    """Point the persistent cache at a fresh file, reset the memory mirror."""
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_PVQ_TUNE_CACHE", str(path))
    autotune.clear_memory_cache()
    yield path
    autotune.clear_memory_cache()


def test_candidates_aligned_and_bounded():
    cands = autotune.candidate_tiles(8, 512, 512, group=128, max_candidates=24)
    assert cands, "no candidates"
    assert cands[0] == autotune.heuristic_tiles(8, 512, 512, 128)
    for bm, bn, bk in cands:
        assert bk % 128 == 0  # group multiple
        assert bm <= 8 and bn <= 512 and bk <= 512  # clamped to the problem
    assert len(set(cands)) == len(cands)  # deduped


def test_autotune_persists_cache_file(tune_cache):
    entry = autotune.autotune(8, 128, 128, group=128, reps=1, interpret=True)
    assert {"bm", "bn", "bk", "us", "candidates"} <= set(entry)
    assert tune_cache.exists()
    on_disk = json.loads(tune_cache.read_text())
    key = autotune.cache_key(8, 128, 128, 128, jnp.float32, jax.default_backend())
    assert on_disk[key] == entry  # JSON round-trip preserves the entry


def test_second_call_skips_search(tune_cache, monkeypatch):
    entry1 = autotune.autotune(8, 128, 128, group=128, reps=1, interpret=True)

    def boom(*a, **k):  # any timing attempt after the first call is a bug
        raise AssertionError("search ran despite cache hit")

    monkeypatch.setattr(autotune, "_time_candidate", boom)
    entry2 = autotune.autotune(8, 128, 128, group=128, reps=1, interpret=True)
    assert entry2 == entry1
    # dispatch side: get_tiles must serve the tuned tiles without timing
    tiles = autotune.get_tiles(8, 128, 128, group=128, search=True, interpret=True)
    assert tiles == (entry1["bm"], entry1["bn"], entry1["bk"])


def test_cache_survives_memory_reset(tune_cache, monkeypatch):
    """A fresh process (simulated by clearing the mirror) reads the JSON."""
    entry = autotune.autotune(8, 128, 128, group=128, reps=1, interpret=True)
    autotune.clear_memory_cache()
    monkeypatch.setattr(
        autotune, "_time_candidate", lambda *a, **k: pytest.fail("re-searched")
    )
    tiles = autotune.get_tiles(8, 128, 128, group=128, search=True, interpret=True)
    assert tiles == (entry["bm"], entry["bn"], entry["bk"])


def test_get_tiles_heuristic_without_search(tune_cache):
    tiles = autotune.get_tiles(16, 256, 256, group=128, search=False, interpret=True)
    assert tiles == autotune.heuristic_tiles(16, 256, 256, 128)
    assert not tune_cache.exists()  # no search -> no I/O


@pytest.mark.parametrize(
    "m,k,n,group,dtype",
    [
        (8, 128, 128, 128, jnp.float32),
        (16, 256, 128, 64, jnp.float32),
        (8, 128, 128, 128, jnp.bfloat16),
        (32, 512, 256, 128, jnp.float32),
    ],
)
def test_tuned_dispatch_matches_ref(tune_cache, m, k, n, group, dtype):
    """ops.pvq_matmul with autotuned tiles stays correct across a grid."""
    kx, kw, ks = jax.random.split(jax.random.PRNGKey(m + n), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(dtype)
    pulses = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
    scales = jnp.abs(jax.random.normal(ks, (k // group, n))) * 0.05
    got = ops.pvq_matmul(x, pulses, scales, group=group, tune=True, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=group)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=1e-1 if dtype == jnp.bfloat16 else 1e-4,
    )


# ---------------------------------------------------------------------------
# kernel-version invalidation (ISSUE 5 satellite: the kv{N} tag had no test)
# ---------------------------------------------------------------------------


def test_kernel_version_bump_changes_every_cache_key(monkeypatch):
    """Bumping KERNEL_VERSION must change EVERY matmul cache key — no shape,
    group, dtype, or backend combination may survive a kernel-body change."""
    combos = [
        (8, 128, 128, 128, jnp.float32, "cpu"),
        (16, 256, 512, 64, jnp.bfloat16, "tpu"),
        (8, 128, 128, 128, jnp.int8, "cpu"),
        (128, 4096, 11008, 128, jnp.float32, "tpu"),
    ]
    before = {autotune.cache_key(*c) for c in combos}
    monkeypatch.setattr(autotune, "KERNEL_VERSION", autotune.KERNEL_VERSION + 1)
    after = {autotune.cache_key(*c) for c in combos}
    assert len(before) == len(after) == len(combos)
    assert before.isdisjoint(after)


def test_v2_tagged_entries_never_served_for_v3_dispatch(tune_cache):
    """A cache file carrying kv2/v2-era entries (older kernel body AND older
    schema) must never satisfy current dispatch: get_tiles falls through to
    the heuristic instead of serving the stale tiles."""
    import json as json_lib

    from repro.kernels.pvq_matmul import KERNEL_VERSION

    assert KERNEL_VERSION >= 3  # the premise of the regression
    m, k, n, group = 8, 256, 256, 128
    poison = {"bm": 1, "bn": 1, "bk": 1, "us": 0.0, "candidates": 1}
    key_now = autotune.cache_key(m, k, n, group, jnp.float32, jax.default_backend())
    stale_keys = {
        # same shape, previous kernel body tag
        key_now.replace(f"kv{KERNEL_VERSION}", f"kv{KERNEL_VERSION - 1}"),
        # same shape, previous schema tag (hand-edited / pre-bump cache file)
        key_now.replace(":v3", ":v2"),
        key_now.replace(f"kv{KERNEL_VERSION}", "kv2").replace(":v3", ":v2"),
    }
    assert key_now not in stale_keys
    tune_cache.write_text(json_lib.dumps({kk: poison for kk in stale_keys}))
    autotune.clear_memory_cache()
    tiles = autotune.get_tiles(m, k, n, group=group, search=False, interpret=True)
    assert tiles == autotune.heuristic_tiles(m, k, n, group)
    assert tiles != (1, 1, 1)


def test_int8_act_dtype_gets_its_own_cache_entry(tune_cache):
    """The activation dtype is part of the key: int8 entries are timed
    against the v3 quantized-activation body and never collide with the
    f32-activation tiles for the same GEMM shape."""
    k_f32 = autotune.cache_key(8, 128, 128, 128, jnp.float32, "cpu")
    k_int8 = autotune.cache_key(8, 128, 128, 128, jnp.int8, "cpu")
    assert k_f32 != k_int8 and "int8" in k_int8
    entry = autotune.autotune(
        8, 128, 128, group=128, dtype=jnp.int8, reps=1, interpret=True
    )
    assert {"bm", "bn", "bk", "us"} <= set(entry)
    tiles = autotune.get_tiles(
        8, 128, 128, group=128, dtype=jnp.int8, search=False, interpret=True
    )
    assert tiles == (entry["bm"], entry["bn"], entry["bk"])
    # the f32 key is still a miss — the int8 search didn't pollute it
    assert autotune._load().get(
        autotune.cache_key(8, 128, 128, 128, jnp.float32, jax.default_backend())
    ) is None


# ---------------------------------------------------------------------------
# encoder autotune: pvq_encode's (bg, delta_max) knobs (ROADMAP satellite)
# ---------------------------------------------------------------------------


def test_encode_cache_key_carries_encoder_kernel_version():
    from repro.kernels.pvq_encode import ENCODE_KERNEL_VERSION

    key = autotune.encode_cache_key(16, 128, 32, jnp.float32, "cpu")
    assert f":ekv{ENCODE_KERNEL_VERSION}:" in key
    assert key.endswith(":v3")  # same schema/store as the matmul tiles
    # encoder and matmul keys can never collide
    assert key != autotune.cache_key(16, 128, 32, 128, jnp.float32, "cpu")


def test_encode_candidates_never_lower_delta_max():
    """Tuning may only make the encoder *more* exact: every candidate keeps
    delta_max at or above the heuristic default."""
    cands = autotune.encode_candidates(64, 256, max_candidates=16)
    assert cands[0] == autotune.ENCODE_DEFAULTS
    assert all(delta >= autotune.ENCODE_DEFAULTS[1] for _, delta in cands)
    assert all(bg <= 64 for bg, _ in cands)
    assert len(set(cands)) == len(cands)
    # bg clamps to tiny group counts
    assert all(bg <= 2 for bg, _ in autotune.encode_candidates(2, 64, 8))


def test_autotune_encode_persists_and_hits(tune_cache, monkeypatch):
    entry = autotune.autotune_encode(8, 64, 16, reps=1, interpret=True)
    assert {"bg", "delta_max", "us", "candidates"} <= set(entry)
    on_disk = json.loads(tune_cache.read_text())
    key = autotune.encode_cache_key(8, 64, 16, jnp.float32, jax.default_backend())
    assert on_disk[key] == entry
    monkeypatch.setattr(
        autotune,
        "_time_encode_candidate",
        lambda *a, **k: pytest.fail("re-searched despite cache hit"),
    )
    assert autotune.autotune_encode(8, 64, 16, reps=1, interpret=True) == entry
    # dispatch resolves to the tuned knobs without timing
    assert autotune.get_encode_params(8, 64, 16) == (entry["bg"], entry["delta_max"])


def test_get_encode_params_heuristic_without_search(tune_cache):
    assert autotune.get_encode_params(512, 256, 64, search=False) == autotune.ENCODE_DEFAULTS
    assert not tune_cache.exists()  # no search -> no I/O


def test_ops_encode_uses_tuned_knobs(tune_cache):
    """ops.pvq_encode with defaulted knobs resolves through the cache and
    stays correct (L1 = K exactly)."""
    autotune.autotune_encode(8, 128, 32, reps=1, interpret=True)
    w = jax.random.laplace(jax.random.PRNGKey(2), (8, 128))
    pulses, _ = ops.pvq_encode(w, k_pulses=32, interpret=True)
    np.testing.assert_array_equal(np.abs(np.asarray(pulses)).sum(-1), 32)
