"""Checkpoint save/restore: atomicity, async, PVQ-compressed format."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {
        "params": {
            "w": jax.random.laplace(k1, (64, 128)),
            "scale": jnp.ones(128),
        },
        "opt": {"mu": jax.random.normal(k2, (64, 128)), "step": jnp.int32(7)},
    }


def test_roundtrip_exact(tmp_path):
    ck = Checkpointer(tmp_path)
    state = _state()
    ck.save(10, state)
    restored, step = ck.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 10
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(tmp_path)
    state = _state(1)
    ck.save(5, state, block=False)
    ck.wait()
    _, step = ck.restore(state)
    assert step == 5


def test_keep_last_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    state = _state(2)
    for s in (1, 2, 3, 4):
        ck.save(s, state)
    assert ck.all_steps() == [3, 4]


def test_uncommitted_checkpoint_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    state = _state(3)
    ck.save(1, state)
    # simulate a crash mid-write: a step dir without COMMIT
    broken = tmp_path / "step_000000099"
    broken.mkdir()
    (broken / "manifest.json").write_text(json.dumps({"step": 99, "leaves": {}}))
    assert ck.latest_step() == 1


def test_pvq_compressed_checkpoint(tmp_path):
    ck = Checkpointer(tmp_path, compress="pvq", pvq_n_over_k=1.0, pvq_group=256, min_compress_size=1024)
    state = {"params": {"w": jax.random.laplace(jax.random.PRNGKey(4), (128, 64))}}
    ck.save(1, state)
    restored, _ = ck.restore(state)
    w0 = np.asarray(state["params"]["w"])
    w1 = np.asarray(restored["params"]["w"])
    # lossy but close (N/K=1 keeps relative error modest on Laplacian weights)
    rel = np.linalg.norm(w1 - w0) / np.linalg.norm(w0)
    assert rel < 0.35
    # and the on-disk pulses must actually be compressed (nibble-packed)
    man = json.loads((tmp_path / "step_000000001" / "manifest.json").read_text())
    entry = man["leaves"]["params/w"]
    assert entry["codec"] == "pvq"
    pulses_file = tmp_path / "step_000000001" / "params__w.pulses.npy"
    assert pulses_file.stat().st_size < 128 * 64 * 4 / 2  # < fp32/2


def test_packed_leaf_roundtrip_bit_exact(tmp_path):
    """A PackedPVQ leaf restores to IDENTICAL int8 pulses + f32 scales —
    no re-encode, no dequantize — under any compress mode."""
    from repro.core.packed import is_packed, pack_flat, pack_matmul

    w = jax.random.laplace(jax.random.PRNGKey(6), (100, 72)) * 0.1
    pk = pack_matmul(w, group=64, n_over_k=4.0)  # small K: nibble-packable
    e = jax.random.normal(jax.random.PRNGKey(7), (64, 32)) * 0.02
    pe = pack_flat(e, group=32, n_over_k=0.5, row_align=32)
    state = {"params": {"w": {"kernel": pk}, "emb": {"embedding": pe}},
             "step": jnp.int32(3)}
    for compress in (None, "pvq"):
        ck = Checkpointer(tmp_path / str(compress), compress=compress)
        ck.save(1, state)
        restored, _ = ck.restore(state)
        for got, want in (
            (restored["params"]["w"]["kernel"], pk),
            (restored["params"]["emb"]["embedding"], pe),
        ):
            assert is_packed(got)
            assert got.pulses.dtype == jnp.int8
            np.testing.assert_array_equal(np.asarray(got.pulses), np.asarray(want.pulses))
            np.testing.assert_array_equal(np.asarray(got.scales), np.asarray(want.scales))
            assert (got.group, got.k, got.shape, got.dtype, got.layout, got.scale_mode) == (
                want.group, want.k, want.shape, want.dtype, want.layout, want.scale_mode
            )
        # the artifact is stored as the code, not expanded weights
        man = json.loads((tmp_path / str(compress) / "step_000000001" / "manifest.json").read_text())
        assert man["leaves"]["params/w/kernel"]["codec"] == "pvq-packed"
        assert man["leaves"]["params/w/kernel"]["pulse_format"] == "nibble"


def test_pvq_checkpoint_skips_small_and_nonmatrix(tmp_path):
    ck = Checkpointer(tmp_path, compress="pvq", min_compress_size=10**6)
    state = _state(5)
    ck.save(2, state)
    man = json.loads((tmp_path / "step_000000002" / "manifest.json").read_text())
    assert all(e["codec"] == "raw" for e in man["leaves"].values())
    restored, _ = ck.restore(state)
    np.testing.assert_array_equal(
        np.asarray(state["params"]["w"]), np.asarray(restored["params"]["w"])
    )
