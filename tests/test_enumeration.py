"""Tests for Fischer enumeration + entropy codes (paper §II, §VI)."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import index_bits, index_to_vector, num_points, vector_to_index
from repro.core.codes import (
    compression_report,
    golomb_decode,
    golomb_encode,
    golomb_length,
    huffman_escape_bits,
    pulse_histogram,
    rle_decode,
    rle_encode,
)
from repro.core.enumeration import enumerate_all, pack_indices, unpack_indices
from repro.core.packing import pack_nibbles, packed_nbytes, unpack_nibbles
from repro.core.pvq import pvq_encode_np


def test_paper_np_8_4_is_2816():
    """Paper §II: N_p(8,4) = 2816, under 12 bits."""
    assert num_points(8, 4) == 2816
    assert index_bits(8, 4) == 12  # 2^11 = 2048 < 2816 <= 4096 = 2^12


def test_num_points_recurrence():
    for n in range(1, 10):
        for k in range(1, 10):
            assert num_points(n, k) == (
                num_points(n - 1, k) + num_points(n - 1, k - 1) + num_points(n, k - 1)
            )


def test_num_points_base_cases():
    assert num_points(0, 0) == 1
    assert num_points(0, 3) == 0
    assert num_points(5, 0) == 1
    assert num_points(1, 7) == 2  # +7 and -7
    assert num_points(2, 1) == 4


@pytest.mark.parametrize("n,k", [(3, 2), (4, 3), (2, 5), (5, 2)])
def test_enumeration_bijection(n, k):
    seen = set()
    for i, v in enumerate(enumerate_all(n, k)):
        assert sum(abs(x) for x in v) == k
        assert vector_to_index(v) == i
        seen.add(tuple(v))
    assert len(seen) == num_points(n, k)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=12),
    k=st.integers(min_value=1, max_value=12),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_prop_roundtrip_random_points(n, k, seed):
    rng = np.random.default_rng(seed)
    w = rng.laplace(size=n)
    if np.abs(w).sum() == 0:
        return
    y, _ = pvq_encode_np(w, k)
    idx = vector_to_index(y.tolist())
    assert 0 <= idx < num_points(n, k)
    assert index_to_vector(idx, n, k) == y.tolist()


def test_pack_unpack_indices():
    rng = np.random.default_rng(0)
    rows = []
    for s in range(6):
        y, _ = pvq_encode_np(rng.laplace(size=16), 8)
        rows.append(y)
    rows = np.stack(rows)
    blob = pack_indices(rows)
    back = unpack_indices(blob, g=6, n=16, k=8)
    np.testing.assert_array_equal(rows, back)
    assert len(blob) * 8 <= 6 * index_bits(16, 8) + 8


# ---------------------------------------------------------------------------
# Golomb / RLE bit-exact codecs
# ---------------------------------------------------------------------------


def test_golomb_lengths_match_paper_ladder():
    """Paper §VII: 1 bit for 0, 3 bits for +-1, 5 bits for +-2..3, 7 for +-4..7."""
    assert golomb_length(np.array([0])).tolist() == [1]
    assert golomb_length(np.array([1, -1])).tolist() == [3, 3]
    assert golomb_length(np.array([2, -2, 3, -3])).tolist() == [5, 5, 5, 5]
    assert golomb_length(np.array([4, -4, 7, -7])).tolist() == [7, 7, 7, 7]


def test_paper_fc0_bits_per_weight_arithmetic():
    """Reproduce the paper's ~1.4 bits/weight arithmetic for net A FC0."""
    fracs = {0: 0.8119, 1: 0.1771, 2: 0.011, 4: 0.000052}
    avg = sum(f * golomb_length(np.array([v]))[0] for v, f in fracs.items())
    assert abs(avg - 1.4) < 0.05


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1), n=st.integers(min_value=1, max_value=200))
def test_prop_golomb_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    vals = rng.integers(-50, 50, size=n)
    blob, nbits = golomb_encode(vals)
    back = golomb_decode(blob, nbits, n)
    np.testing.assert_array_equal(vals, back)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1), n=st.integers(min_value=1, max_value=300))
def test_prop_rle_roundtrip(seed, n):
    rng = np.random.default_rng(seed)
    # sparse vector typical of N/K=5 PVQ output
    vals = rng.integers(-3, 4, size=n) * (rng.random(n) < 0.2)
    blob, nbits, n_pairs = rle_encode(vals)
    back = rle_decode(blob, nbits, n_pairs, n)
    np.testing.assert_array_equal(vals, back)


def test_golomb_bits_at_paper_ratio():
    rng = np.random.default_rng(1)
    y, _ = pvq_encode_np(rng.laplace(size=4000), 800)  # N/K = 5
    rep = compression_report(y)
    assert rep["golomb_bits_per_weight"] < 2.0  # paper: ~1.4 at N/K=5
    assert (y == 0).mean() >= 0.8  # paper: >= 4/5 zeros guaranteed at N/K=5


def test_rle_beats_golomb_on_very_sparse():
    """RLE wins once zero runs get long (paper: 'long runs of zeros')."""
    rng = np.random.default_rng(1)
    y, _ = pvq_encode_np(rng.laplace(size=8000), 400)  # N/K = 20, ~95% zeros
    rep = compression_report(y)
    assert rep["rle_bits_per_weight"] <= rep["golomb_bits_per_weight"]
    assert rep["rle_bits_per_weight"] < 1.0  # sub-bit per weight


def test_pulse_histogram_buckets():
    h = pulse_histogram(np.array([0, 0, 1, -1, 2, -3, 4, -7, 8]))
    assert h["0"] == 2 and h["+-1"] == 2 and h["+-2..3"] == 2
    assert h["+-4..7"] == 2 and h["others"] == 1


def test_huffman_escape_reasonable():
    rng = np.random.default_rng(2)
    y, _ = pvq_encode_np(rng.laplace(size=2000), 400)
    bits = huffman_escape_bits(y)
    assert 0.5 < bits < 3.0


# ---------------------------------------------------------------------------
# nibble packing
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_nibble_roundtrip(seed):
    rng = np.random.default_rng(seed)
    p = rng.integers(-7, 8, size=(3, 17))
    packed, shape = pack_nibbles(p)
    np.testing.assert_array_equal(unpack_nibbles(packed, shape), p)


def test_packed_nbytes():
    import jax.numpy as jnp

    from repro.core import pvq_encode_grouped

    w = jnp.asarray(np.random.default_rng(3).laplace(size=1024).astype(np.float32))
    code = pvq_encode_grouped(w, group=256, k=64)
    assert packed_nbytes(code, "nibble") == 512 + 16
    assert packed_nbytes(code, "int8") == 1024 + 16


# ---------------------------------------------------------------------------
# vectorized limb-ladder codec vs the bigint reference (PR 9)
# ---------------------------------------------------------------------------


def _rand_rows(rng, g, n, k, clamp_hi=1):
    """Random pyramid rows with L1 <= k: mixes all-zero rows, k_g < k rows,
    and (when clamp_hi > 1) clamped-magnitude pulses beyond int8."""
    rows = np.zeros((g, n), np.int64)
    for i in range(g):
        budget = int(rng.integers(0, k + 1))  # k_g < k headers + all-zero rows
        while budget > 0:
            m = int(rng.integers(1, min(budget, clamp_hi) + 1))
            rows[i, rng.integers(0, n)] += m * int(rng.choice([-1, 1]))
            budget -= m
    return rows


def _limbs(value, L):
    """Python bigint -> little-endian uint32 limb row."""
    return np.asarray(
        [(value >> (32 * j)) & 0xFFFFFFFF for j in range(L)], np.uint32
    )


@pytest.mark.parametrize(
    "n,k",
    [(2, 1), (8, 4), (16, 9), (31, 7), (64, 51), (64, 130), (96, 30)],
)
def test_batch_rank_matches_bigint_reference(n, k):
    """The limb ladder is the bigint Fischer rank, limb for limb — including
    K > 127 clamped groups (k=130) and groups whose own L1 is below K."""
    from repro.core.enumeration import limb_count, vector_to_index_batch

    rng = np.random.default_rng(n * 1000 + k)
    rows = _rand_rows(rng, 40, n, k, clamp_hi=min(k, 130))
    rows[0] = 0  # force an all-zero group
    L = limb_count(n, k)
    got = vector_to_index_batch(rows, k)
    want = np.stack([_limbs(vector_to_index(r.tolist()), L) for r in rows])
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n,k", [(8, 4), (16, 9), (64, 51), (64, 130)])
def test_batch_unrank_matches_bigint_reference(n, k):
    from repro.core.enumeration import (
        index_to_vector_batch,
        limb_count,
        vector_to_index_batch,
    )

    rng = np.random.default_rng(n * 7 + k)
    rows = _rand_rows(rng, 40, n, k, clamp_hi=min(k, 130))
    k_g = np.abs(rows).sum(axis=1)
    ranks = vector_to_index_batch(rows, k)
    got = index_to_vector_batch(ranks, k_g, n, k)
    np.testing.assert_array_equal(got, rows)
    # and each row against the scalar bigint decoder
    L = limb_count(n, k)
    for i in range(rows.shape[0]):
        big = sum(int(ranks[i, j]) << (32 * j) for j in range(L))
        assert index_to_vector(big, n, int(k_g[i])) == rows[i].tolist()


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 48),
    k=st.integers(1, 40),
    g=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_batch_roundtrip(n, k, g, seed):
    from repro.core.enumeration import index_to_vector_batch, vector_to_index_batch

    rng = np.random.default_rng(seed)
    rows = _rand_rows(rng, g, n, k, clamp_hi=min(k, 5))
    ranks = vector_to_index_batch(rows, k)
    got = index_to_vector_batch(ranks, np.abs(rows).sum(axis=1), n, k)
    np.testing.assert_array_equal(got, rows)


def test_enum_supported_bounds():
    """Support = cumulative tables fit the cache budget AND the float64
    rank proxy keeps every limb scale normal (limb_count <= 29)."""
    from repro.core.enumeration import enum_supported, limb_count

    assert enum_supported(64, 130)  # every sub-ladder the codec emits
    assert enum_supported(64, 64)
    assert not enum_supported(4096, 4096)  # table blow-up
    assert limb_count(64, 130) <= 29
