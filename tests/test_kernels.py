"""Per-kernel interpret=True validation against the pure-jnp oracles,
with explicit shape/dtype grids + hypothesis sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pvq import pvq_encode_grouped
from repro.kernels import ops
from repro.kernels.ref import pvq_encode_ref, pvq_matmul_ref


def _mk_pvq_weight(key, k_dim, n_dim, group, k_pulses):
    """A real PVQ-coded weight matrix: (pulses int8 (k,n), scales (k/group, n))."""
    w = jax.random.laplace(key, (k_dim, n_dim))
    # encode each (group, col) slice: transpose to (n, k) rows then group
    cols = []
    scs = []
    for j in range(0, 1):  # vectorized below instead
        pass
    wt = w.T.reshape(n_dim, k_dim // group, group)
    code = None
    from repro.core.pvq import pvq_encode

    code = pvq_encode(wt, k_pulses, "ls")  # (n, k/group, group)
    pulses = jnp.transpose(code.pulses, (1, 2, 0)).reshape(k_dim, n_dim).astype(jnp.int8)
    scales = jnp.transpose(code.scale, (1, 0)).astype(jnp.float32)  # (k/group, n)
    return pulses, scales


# ---------------------------------------------------------------------------
# pvq_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n,group,bm,bn,bk",
    [
        (8, 128, 128, 128, 8, 128, 128),      # decode GEMV-ish tile
        (128, 256, 128, 128, 128, 128, 128),  # two k-tiles (accumulation)
        (16, 256, 512, 64, 16, 256, 128),     # group < bk, wide n
        (32, 512, 64, 128, 32, 64, 256),      # bk > group multiple tiles
    ],
)
def test_pvq_matmul_matches_ref(m, k, n, group, bm, bn, bk):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses, scales = _mk_pvq_weight(kw, k, n, group, k_pulses=group // 2)
    got = ops.pvq_matmul(x, pulses, scales, group=group, bm=bm, bn=bn, bk=bk, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pvq_matmul_dtypes(dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (16, 128), jnp.float32).astype(dtype)
    pulses, scales = _mk_pvq_weight(kw, 128, 128, 128, k_pulses=64)
    got = ops.pvq_matmul(x, pulses, scales, group=128, bm=16, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=128)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=1e-1
    )


@settings(max_examples=10, deadline=None)
@given(
    mt=st.integers(1, 3), kt=st.integers(1, 3), nt=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_pvq_matmul_tile_sweep(mt, kt, nt, seed):
    m, k, n = 8 * mt, 128 * kt, 128 * nt
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses, scales = _mk_pvq_weight(kw, k, n, 128, k_pulses=32)
    got = ops.pvq_matmul(x, pulses, scales, group=128, bm=8, bn=128, bk=128, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# pvq_encode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g,n,k_pulses,bg", [(8, 128, 32, 8), (16, 256, 64, 8), (4, 64, 16, 4)])
def test_pvq_encode_matches_ref(g, n, k_pulses, bg):
    w = jax.random.laplace(jax.random.PRNGKey(g * n), (g, n))
    got_p, got_rho = ops.pvq_encode(w, k_pulses=k_pulses, bg=bg, interpret=True)
    want_p, want_rho = pvq_encode_ref(w, k_pulses)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    np.testing.assert_allclose(np.asarray(got_rho), np.asarray(want_rho), rtol=1e-5)


def test_pvq_encode_l1_constraint():
    w = jax.random.laplace(jax.random.PRNGKey(3), (8, 128))
    pulses, _ = ops.pvq_encode(w, k_pulses=48, interpret=True)
    np.testing.assert_array_equal(np.abs(np.asarray(pulses)).sum(-1), 48)


def test_pvq_encode_zero_rows():
    w = jnp.zeros((8, 128))
    pulses, rho = ops.pvq_encode(w, k_pulses=16, interpret=True)
    assert int(jnp.abs(pulses).sum()) == 0
    np.testing.assert_array_equal(np.asarray(rho), 0.0)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k_pulses=st.integers(1, 96),
)
def test_prop_pvq_encode_sweep(seed, k_pulses):
    w = jax.random.laplace(jax.random.PRNGKey(seed), (8, 128))
    got_p, got_rho = ops.pvq_encode(w, k_pulses=k_pulses, interpret=True)
    want_p, want_rho = pvq_encode_ref(w, k_pulses)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    np.testing.assert_allclose(np.asarray(got_rho), np.asarray(want_rho), rtol=1e-4)


# ---------------------------------------------------------------------------
# end-to-end: kernel-format weights == core dequantized matmul
# ---------------------------------------------------------------------------


def test_kernel_weights_equal_core_dequant():
    """pvq_matmul on kernel-format tensors must equal x @ dequant(core code)."""
    key = jax.random.PRNGKey(11)
    kx, kw = jax.random.split(key)
    k_dim, n_dim, group = 256, 128, 128
    x = jax.random.normal(kx, (8, k_dim))
    pulses, scales = _mk_pvq_weight(kw, k_dim, n_dim, group, k_pulses=64)
    y_kernel = ops.pvq_matmul(x, pulses, scales, group=group, bm=8, interpret=True)
    w_deq = pulses.astype(jnp.float32) * jnp.repeat(scales, group, axis=0)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(x @ w_deq), rtol=1e-5, atol=1e-4
    )
