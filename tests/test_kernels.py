"""Per-kernel interpret=True validation against the pure-jnp oracles,
with explicit shape/dtype grids + hypothesis sweeps.

Encoder contract (since the sort-based rewrite): bit-exact vs the greedy
oracle whenever the floor pre-allocation leaves <= delta_max pulses (always
for K <= delta_max), else within 1e-3 cosine correlation; the L1 = K pyramid
constraint is always exact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import pvq_encode_ref, pvq_matmul_ref


def _mk_pvq_weight(key, k_dim, n_dim, group, k_pulses):
    """A real PVQ-coded weight matrix: (pulses int8 (k,n), scales (k/group, n))."""
    w = jax.random.laplace(key, (k_dim, n_dim))
    wt = w.T.reshape(n_dim, k_dim // group, group)
    from repro.core.pvq import pvq_encode

    code = pvq_encode(wt, k_pulses, "ls")  # (n, k/group, group)
    pulses = jnp.transpose(code.pulses, (1, 2, 0)).reshape(k_dim, n_dim).astype(jnp.int8)
    scales = jnp.transpose(code.scale, (1, 0)).astype(jnp.float32)  # (k/group, n)
    return pulses, scales


def _row_corr(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    den = np.sqrt((a * a).sum(-1) * (b * b).sum(-1))
    den = np.where(den > 0, den, 1.0)
    return (a * b).sum(-1) / den


# ---------------------------------------------------------------------------
# pvq_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n,group,bm,bn,bk",
    [
        (8, 128, 128, 128, 8, 128, 128),      # decode GEMV-ish tile
        (128, 256, 128, 128, 128, 128, 128),  # two k-tiles (accumulation)
        (16, 256, 512, 64, 16, 256, 128),     # group < bk, wide n
        (32, 512, 64, 128, 32, 64, 256),      # bk > group multiple tiles
    ],
)
def test_pvq_matmul_matches_ref(m, k, n, group, bm, bn, bk):
    kx, kw = jax.random.split(jax.random.PRNGKey(m + k + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses, scales = _mk_pvq_weight(kw, k, n, group, k_pulses=group // 2)
    got = ops.pvq_matmul(x, pulses, scales, group=group, bm=bm, bn=bn, bk=bk, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=group)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pvq_matmul_dtypes(dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (16, 128), jnp.float32).astype(dtype)
    pulses, scales = _mk_pvq_weight(kw, 128, 128, 128, k_pulses=64)
    got = ops.pvq_matmul(x, pulses, scales, group=128, bm=16, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=128)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=1e-1
    )


@pytest.mark.parametrize(
    "m,k,n,group",
    [
        (5, 384, 257, 128),   # every dim ragged vs 128-tiles
        (3, 128, 100, 64),    # tiny decode batch, narrow n
        (17, 640, 130, 128),  # k not a bk multiple
    ],
)
def test_pvq_matmul_ragged_shapes(m, k, n, group):
    """Non-tile-divisible shapes pad internally instead of asserting."""
    kx, kw = jax.random.split(jax.random.PRNGKey(m * n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses, scales = _mk_pvq_weight(kw, k, n, group, k_pulses=group // 2)
    got = ops.pvq_matmul(x, pulses, scales, group=group, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=group)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("activation", ["relu", "gelu", "silu", "relu2", "none"])
def test_pvq_matmul_fused_epilogue(activation):
    """bias + activation fused into the final store == unfused reference."""
    kx, kw, kb = jax.random.split(jax.random.PRNGKey(13), 3)
    m, k, n, group = 16, 256, 128, 128
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses, scales = _mk_pvq_weight(kw, k, n, group, k_pulses=64)
    bias = jax.random.normal(kb, (n,))
    got = ops.pvq_matmul(
        x, pulses, scales, group=group, bias=bias, activation=activation, interpret=True
    )
    pre = pvq_matmul_ref(x, pulses, scales, group=group) + bias
    from repro.kernels.pvq_matmul import _apply_activation

    want = _apply_activation(pre, activation)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    mt=st.integers(1, 3), kt=st.integers(1, 3), nt=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_pvq_matmul_tile_sweep(mt, kt, nt, seed):
    m, k, n = 8 * mt, 128 * kt, 128 * nt
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses, scales = _mk_pvq_weight(kw, k, n, 128, k_pulses=32)
    got = ops.pvq_matmul(x, pulses, scales, group=128, bm=8, bn=128, bk=128, interpret=True)
    want = pvq_matmul_ref(x, pulses, scales, group=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# pvq_encode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g,n,k_pulses,bg", [(8, 128, 32, 8), (4, 64, 16, 4)])
def test_pvq_encode_exact_small_k(g, n, k_pulses, bg):
    """K <= delta_max: the sorted encoder IS the exact greedy search."""
    w = jax.random.laplace(jax.random.PRNGKey(g * n), (g, n))
    got_p, got_rho = ops.pvq_encode(w, k_pulses=k_pulses, bg=bg, interpret=True)
    want_p, want_rho = pvq_encode_ref(w, k_pulses)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    np.testing.assert_allclose(np.asarray(got_rho), np.asarray(want_rho), rtol=1e-5)


@pytest.mark.parametrize("g,n,k_pulses", [(16, 256, 64), (64, 256, 128), (8, 1024, 256)])
def test_pvq_encode_matches_ref_correlation(g, n, k_pulses):
    """K > delta_max: within 1e-3 cosine of the exact greedy oracle."""
    w = jax.random.laplace(jax.random.PRNGKey(g * n), (g, n))
    got_p, got_rho = ops.pvq_encode(w, k_pulses=k_pulses, interpret=True)
    want_p, want_rho = pvq_encode_ref(w, k_pulses)
    corr = _row_corr(got_p, want_p)
    assert corr.min() > 1 - 1e-3, corr.min()
    np.testing.assert_allclose(np.asarray(got_rho), np.asarray(want_rho), rtol=2e-2)


def test_pvq_encode_exact_when_delta_max_covers_k():
    """delta_max >= K degenerates to the seed's exact greedy kernel."""
    w = jax.random.laplace(jax.random.PRNGKey(9), (8, 256))
    got_p, _ = ops.pvq_encode(w, k_pulses=96, delta_max=96, interpret=True)
    want_p, _ = pvq_encode_ref(w, 96)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))


def test_pvq_encode_l1_constraint():
    w = jax.random.laplace(jax.random.PRNGKey(3), (8, 128))
    pulses, _ = ops.pvq_encode(w, k_pulses=48, interpret=True)
    np.testing.assert_array_equal(np.abs(np.asarray(pulses)).sum(-1), 48)


def test_pvq_encode_l1_constraint_large_k():
    """The sort-based bulk allocation must land exactly on the pyramid."""
    w = jax.random.laplace(jax.random.PRNGKey(4), (16, 256))
    pulses, _ = ops.pvq_encode(w, k_pulses=192, interpret=True)
    np.testing.assert_array_equal(np.abs(np.asarray(pulses)).sum(-1), 192)


@pytest.mark.parametrize("k_pulses,delta_max", [(48, 8), (192, 16), (64, 64)])
def test_pvq_encode_bisect_fallback_bit_exact(k_pulses, delta_max):
    """Satellite (ROADMAP "Mosaic sort fallback"): forcing the no-argsort
    bulk allocation (threshold-count binary search; elementwise + reductions
    only) reproduces the argsort path bit-for-bit — including fractional-part
    ties, which quantized weights force below."""
    from repro.kernels.pvq_encode import pvq_encode_batch

    for seed in range(3):
        w = jnp.round(jax.random.laplace(jax.random.PRNGKey(seed), (16, 128)) * 4) / 4
        pa, ra = pvq_encode_batch(
            w, k_pulses=k_pulses, delta_max=delta_max, interpret=True,
            sort_impl="argsort",
        )
        pb, rb = pvq_encode_batch(
            w, k_pulses=k_pulses, delta_max=delta_max, interpret=True,
            sort_impl="bisect",
        )
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
        np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))


def test_pvq_encode_sort_impl_env_dispatch(monkeypatch):
    """REPRO_PVQ_ENCODE_SORT=bisect flips the ops-layer default."""
    w = jax.random.laplace(jax.random.PRNGKey(7), (8, 128))
    want_p, want_rho = ops.pvq_encode(w, k_pulses=32, interpret=True)
    monkeypatch.setenv("REPRO_PVQ_ENCODE_SORT", "bisect")
    got_p, got_rho = ops.pvq_encode(w, k_pulses=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
    np.testing.assert_array_equal(np.asarray(got_rho), np.asarray(want_rho))


def test_pvq_encode_rejects_unknown_sort_impl():
    from repro.kernels.pvq_encode import pvq_encode_batch

    with pytest.raises(ValueError, match="sort_impl"):
        pvq_encode_batch(
            jnp.ones((4, 64)), k_pulses=8, interpret=True, sort_impl="bogo"
        )


def test_pvq_encode_zero_rows():
    w = jnp.zeros((8, 128))
    pulses, rho = ops.pvq_encode(w, k_pulses=16, interpret=True)
    assert int(jnp.abs(pulses).sum()) == 0
    np.testing.assert_array_equal(np.asarray(rho), 0.0)


def test_pvq_encode_row_padding():
    """Group counts that don't tile by bg are padded, not asserted."""
    w = jax.random.laplace(jax.random.PRNGKey(5), (5, 128))
    pulses, rho = ops.pvq_encode(w, k_pulses=32, bg=8, interpret=True)
    assert pulses.shape == (5, 128) and rho.shape == (5,)
    np.testing.assert_array_equal(np.abs(np.asarray(pulses)).sum(-1), 32)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k_pulses=st.integers(1, 96),
)
def test_prop_pvq_encode_sweep(seed, k_pulses):
    w = jax.random.laplace(jax.random.PRNGKey(seed), (8, 128))
    got_p, got_rho = ops.pvq_encode(w, k_pulses=k_pulses, interpret=True)
    want_p, want_rho = pvq_encode_ref(w, k_pulses)
    np.testing.assert_array_equal(np.abs(np.asarray(got_p)).sum(-1), k_pulses)
    if k_pulses <= 32:  # delta_max default: bit-exact regime
        np.testing.assert_array_equal(np.asarray(got_p), np.asarray(want_p))
        np.testing.assert_allclose(np.asarray(got_rho), np.asarray(want_rho), rtol=1e-4)
    else:
        assert _row_corr(got_p, want_p).min() > 1 - 1e-3


# ---------------------------------------------------------------------------
# int8 pulse boundary + encode -> matmul round-trip
# ---------------------------------------------------------------------------


def test_pulses_to_int8_clamps():
    p = jnp.array([[-300, -128, -1, 0, 1, 127, 300]], jnp.int32)
    q = ops.pulses_to_int8(p)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q)[0], [-127, -127, -1, 0, 1, 127, 127])


@pytest.mark.parametrize("k_dim,n_dim,group", [(256, 64, 128), (200, 96, 64)])
def test_encode_matmul_roundtrip(k_dim, n_dim, group):
    """encode_weight_matrix -> pvq_matmul composes with no caller-side casts
    and equals the explicit dequantized matmul (incl. ragged k padding)."""
    w = jax.random.laplace(jax.random.PRNGKey(11), (k_dim, n_dim)) * 0.1
    pulses, scales, k_pad = ops.encode_weight_matrix(
        w, group=group, k_pulses=group // 4, interpret=True
    )
    assert pulses.dtype == jnp.int8
    assert pulses.shape == (k_pad, n_dim) and k_pad % group == 0
    x = jax.random.normal(jax.random.PRNGKey(12), (8, k_dim))
    xp = jnp.pad(x, ((0, 0), (0, k_pad - k_dim)))
    y = ops.pvq_matmul(xp, pulses, scales, group=group, interpret=True)
    w_deq = pulses.astype(jnp.float32) * jnp.repeat(scales, group, axis=0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(xp @ w_deq), rtol=1e-5, atol=1e-4)
    # padded tail rows never receive pulses
    assert int(jnp.abs(pulses[k_dim:]).sum()) == 0


# ---------------------------------------------------------------------------
# end-to-end: kernel-format weights == core dequantized matmul
# ---------------------------------------------------------------------------


def test_kernel_weights_equal_core_dequant():
    """pvq_matmul on kernel-format tensors must equal x @ dequant(core code)."""
    key = jax.random.PRNGKey(11)
    kx, kw = jax.random.split(key)
    k_dim, n_dim, group = 256, 128, 128
    x = jax.random.normal(kx, (8, k_dim))
    pulses, scales = _mk_pvq_weight(kw, k_dim, n_dim, group, k_pulses=64)
    y_kernel = ops.pvq_matmul(x, pulses, scales, group=group, bm=8, interpret=True)
    w_deq = pulses.astype(jnp.float32) * jnp.repeat(scales, group, axis=0)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(x @ w_deq), rtol=1e-5, atol=1e-4
    )


def test_sequential_kernel_apply_matches_dequant_forward():
    """SequentialNet.kernel_apply (fused Pallas fc path) == manual forward
    with the dequantized kernel-format weights."""
    from repro.nn.sequential import LayerSpec, SequentialConfig, SequentialNet

    cfg = SequentialConfig(
        name="tiny",
        input_shape=(100,),
        layers=(
            LayerSpec(kind="fc", out=72, activation="relu", n_over_k=2.0),
            LayerSpec(kind="fc", out=10, activation="none", n_over_k=1.0),
        ),
    )
    net = SequentialNet(cfg)
    params = net.init(jax.random.PRNGKey(0))
    group = 64
    kparams = net.pvq_kernel_encode(params, group=group)
    assert set(kparams) == {"layer0", "layer1"}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 100))
    got = net.kernel_apply(params, kparams, x, group=group)

    h = x
    for i, spec in enumerate(cfg.layers):
        packed = kparams[f"layer{i}"]["kernel"]  # the unified PackedPVQ artifact
        w_deq = packed.pulses.astype(jnp.float32) * jnp.repeat(
            packed.scales, packed.group, axis=0
        )
        hp = jnp.pad(h, ((0, 0), (0, w_deq.shape[0] - h.shape[-1])))
        pre = hp @ w_deq + params[f"layer{i}"]["bias"]
        h = jax.nn.relu(pre) if spec.activation == "relu" else pre
    np.testing.assert_allclose(np.asarray(got), np.asarray(h), rtol=1e-4, atol=1e-4)
