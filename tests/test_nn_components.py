"""Unit tests for NN components: attention equivalences, MoE routing
invariants, MLA absorbed-decode equivalence, mamba/rwkv decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.nn import attention as A
from repro.nn import mamba as M
from repro.nn import mla as L
from repro.nn import moe as MOE
from repro.nn import rwkv as R
from repro.nn.mamba import SSMConfig
from repro.nn.mla import MLAConfig
from repro.nn.moe import MoEConfig
from repro.nn.rwkv import RWKVConfig


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def test_chunked_equals_full_attention():
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 256, 8, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    full = A.full_causal_attention(q, k, v, scale=0.25)
    chunked = A.chunked_causal_attention(q, k, v, scale=0.25, q_chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full), rtol=2e-5, atol=2e-5)


def test_grouped_equals_expanded_attention():
    """GQA grouped einsum == reference with materialized KV expansion."""
    key = jax.random.PRNGKey(3)
    b, s, h, kv, hd = 2, 64, 8, 4, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, kv, hd))
    got = A.full_causal_attention(q, k, v, scale=0.25)
    ke, ve = A._expand_kv(k, h), A._expand_kv(v, h)
    want = A.full_causal_attention(q, ke, ve, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_prefix_lm_mask():
    """With prefix_len=s the attention must be fully bidirectional."""
    key = jax.random.PRNGKey(6)
    b, s, h, hd = 1, 32, 2, 8
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, h, hd))
    causal = A.full_causal_attention(q, k, v, scale=0.3)
    prefix = A.full_causal_attention(q, k, v, scale=0.3, prefix_len=s)
    assert not np.allclose(np.asarray(causal), np.asarray(prefix))
    # row 0 with full prefix attends everywhere; causal row 0 attends only pos 0
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * 0.3
    probs = jax.nn.softmax(scores, axis=-1)
    want0 = jnp.einsum("bhqk,bkhd->bqhd", probs, A._expand_kv(v, h))[:, 0]
    np.testing.assert_allclose(np.asarray(prefix[:, 0]), np.asarray(want0), rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_full():
    """decode at position p == row p of full causal attention."""
    key = jax.random.PRNGKey(9)
    b, s, h, kv, hd = 2, 16, 4, 2, 8
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(10), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(11), (b, s, kv, hd))
    full = A.full_causal_attention(q, k, v, scale=0.35)
    p = 7
    got = A.decode_attention(
        q[:, p : p + 1], k, v, scale=0.35, length=jnp.full((b,), p + 1)
    )
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(full[:, p]), rtol=2e-5, atol=2e-5)


def test_rope_relative_property():
    """RoPE: dot(q_m, k_n) depends only on (m - n)."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(12), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(13), (1, 1, 1, hd))
    def dot_at(m, n):
        qm = A.apply_rope(q, jnp.array([[m]]))
        kn = A.apply_rope(k, jnp.array([[n]]))
        return float(jnp.sum(qm * kn))
    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_cfg(**kw):
    d = dict(n_experts=4, top_k=2, n_shared=0, d_expert=32, capacity_factor=2.0,
             group_size=32, activation="swiglu")
    d.update(kw)
    return MoEConfig(**d)


def test_topk_argmax_matches_lax_topk():
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (3, 16, 8)), -1)
    got_v, got_i = MOE._topk_argmax(probs, 3)
    want_v, want_i = jax.lax.top_k(probs, 3)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_moe_light_combine_equals_dense_combine():
    cfg = _moe_cfg()
    p = MOE.init_moe(jax.random.PRNGKey(1), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 16))
    from repro.parallel import ShardingPolicy, sharding_policy

    out_ref, aux_ref = MOE.moe_forward(p, x, cfg)
    with sharding_policy(ShardingPolicy(moe_light_combine=True)):
        out_light, aux_light = MOE.moe_forward(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out_light), np.asarray(out_ref), rtol=2e-2, atol=1e-4)
    np.testing.assert_allclose(float(aux_light), float(aux_ref), rtol=1e-6)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 some tokens must pass through unrouted."""
    cfg = _moe_cfg(capacity_factor=0.1)
    p = MOE.init_moe(jax.random.PRNGKey(3), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 16))
    out, _ = MOE.moe_forward(p, x, cfg)
    # dropped tokens produce zero output (residual handles them upstream)
    zero_rows = np.asarray(jnp.all(jnp.abs(out[0]) < 1e-6, axis=-1))
    assert zero_rows.sum() > 0


def test_moe_router_gates_sum_to_one():
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(5), (2, 8, 6)), -1)
    v, i = MOE._topk_argmax(probs, 2)
    renorm = v / jnp.sum(v, -1, keepdims=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(renorm, -1)), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------


def test_mla_decode_matches_forward():
    """Absorbed decode logits == decompressed forward at each position."""
    cfg = MLAConfig(kv_lora_rank=16, q_lora_rank=None, nope_head_dim=8,
                    rope_head_dim=4, v_head_dim=8)
    d, h, b, s = 32, 4, 2, 12
    p = L.init_mla(jax.random.PRNGKey(0), d, h, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    full = L.mla_forward(p, x, n_heads=h, cfg=cfg)
    cache = L.MLACache(
        c_kv=jnp.zeros((b, s, cfg.kv_lora_rank)),
        k_rope=jnp.zeros((b, s, cfg.rope_head_dim)),
    )
    for t in range(s):
        y, cache = L.mla_decode(p, x[:, t : t + 1], cache, jnp.int32(t), n_heads=h, cfg=cfg)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-4
        )


# ---------------------------------------------------------------------------
# Mamba / RWKV decode parity
# ---------------------------------------------------------------------------


def test_mamba_decode_matches_forward():
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2)
    d, b, s = 16, 2, 10
    p = M.init_mamba(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    full = M.mamba_forward(p, x, cfg)
    cache = M.init_mamba_cache(b, d, cfg)
    for t in range(s):
        y, cache = M.mamba_decode(p, x[:, t : t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5
        )


def test_mamba_prefill_state_continues_decode():
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2)
    d, b, s = 16, 2, 12
    p = M.init_mamba(jax.random.PRNGKey(2), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, d)) * 0.5
    full = M.mamba_forward(p, x, cfg)
    _, cache = M.mamba_forward(p, x[:, :8], cfg, return_state=True)
    for t in range(8, s):
        y, cache = M.mamba_decode(p, x[:, t : t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5
        )


def test_rwkv_streaming_matches_forward():
    cfg = RWKVConfig(head_size=8, decay_lora=4, mix_lora=4)
    d, b, s = 16, 2, 10
    p = R.init_rwkv_time_mix(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    full = R.rwkv_time_mix(p, x, cfg)
    state = None
    x_prev = jnp.zeros((b, d))
    for t in range(s):
        y, state = R.rwkv_time_mix(
            p, x[:, t : t + 1], cfg, x_prev=x_prev, state=state, return_state=True
        )
        x_prev = x[:, t]
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=5e-3, atol=5e-4
        )


def test_rwkv_decay_in_unit_interval():
    cfg = RWKVConfig(head_size=8, decay_lora=4, mix_lora=4)
    p = R.init_rwkv_time_mix(jax.random.PRNGKey(2), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 16))
    w = R._decay(p, x)
    assert bool(jnp.all((w > 0) & (w < 1)))
