"""Unit tests for NN components: attention equivalences, MoE routing
invariants, MLA absorbed-decode equivalence, mamba/rwkv decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.nn import attention as A
from repro.nn import mamba as M
from repro.nn import mla as L
from repro.nn import moe as MOE
from repro.nn import rwkv as R
from repro.nn.mamba import SSMConfig
from repro.nn.mla import MLAConfig
from repro.nn.moe import MoEConfig
from repro.nn.rwkv import RWKVConfig


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def test_chunked_equals_full_attention():
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 256, 8, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, hd))
    full = A.full_causal_attention(q, k, v, scale=0.25)
    chunked = A.chunked_causal_attention(q, k, v, scale=0.25, q_chunk=64)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full), rtol=2e-5, atol=2e-5)


def test_grouped_equals_expanded_attention():
    """GQA grouped einsum == reference with materialized KV expansion."""
    key = jax.random.PRNGKey(3)
    b, s, h, kv, hd = 2, 64, 8, 4, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, kv, hd))
    got = A.full_causal_attention(q, k, v, scale=0.25)
    ke, ve = A._expand_kv(k, h), A._expand_kv(v, h)
    want = A.full_causal_attention(q, ke, ve, scale=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_prefix_lm_mask():
    """With prefix_len=s the attention must be fully bidirectional."""
    key = jax.random.PRNGKey(6)
    b, s, h, hd = 1, 32, 2, 8
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, h, hd))
    causal = A.full_causal_attention(q, k, v, scale=0.3)
    prefix = A.full_causal_attention(q, k, v, scale=0.3, prefix_len=s)
    assert not np.allclose(np.asarray(causal), np.asarray(prefix))
    # row 0 with full prefix attends everywhere; causal row 0 attends only pos 0
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * 0.3
    probs = jax.nn.softmax(scores, axis=-1)
    want0 = jnp.einsum("bhqk,bkhd->bqhd", probs, A._expand_kv(v, h))[:, 0]
    np.testing.assert_allclose(np.asarray(prefix[:, 0]), np.asarray(want0), rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_full():
    """decode at position p == row p of full causal attention."""
    key = jax.random.PRNGKey(9)
    b, s, h, kv, hd = 2, 16, 4, 2, 8
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.PRNGKey(10), (b, s, kv, hd))
    v = jax.random.normal(jax.random.PRNGKey(11), (b, s, kv, hd))
    full = A.full_causal_attention(q, k, v, scale=0.35)
    p = 7
    got = A.decode_attention(
        q[:, p : p + 1], k, v, scale=0.35, length=jnp.full((b,), p + 1)
    )
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(full[:, p]), rtol=2e-5, atol=2e-5)


def test_rope_relative_property():
    """RoPE: dot(q_m, k_n) depends only on (m - n)."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(12), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(13), (1, 1, 1, hd))
    def dot_at(m, n):
        qm = A.apply_rope(q, jnp.array([[m]]))
        kn = A.apply_rope(k, jnp.array([[n]]))
        return float(jnp.sum(qm * kn))
    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(5, 3) != pytest.approx(dot_at(5, 4), rel=1e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_cfg(**kw):
    d = dict(n_experts=4, top_k=2, n_shared=0, d_expert=32, capacity_factor=2.0,
             group_size=32, activation="swiglu")
    d.update(kw)
    return MoEConfig(**d)


def test_topk_argmax_matches_lax_topk():
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (3, 16, 8)), -1)
    got_v, got_i = MOE._topk_argmax(probs, 3)
    want_v, want_i = jax.lax.top_k(probs, 3)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_moe_light_combine_equals_dense_combine():
    cfg = _moe_cfg()
    p = MOE.init_moe(jax.random.PRNGKey(1), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 16))
    from repro.parallel import ShardingPolicy, sharding_policy

    out_ref, aux_ref = MOE.moe_forward(p, x, cfg)
    with sharding_policy(ShardingPolicy(moe_light_combine=True)):
        out_light, aux_light = MOE.moe_forward(p, x, cfg)
    np.testing.assert_allclose(np.asarray(out_light), np.asarray(out_ref), rtol=2e-2, atol=1e-4)
    np.testing.assert_allclose(float(aux_light), float(aux_ref), rtol=1e-6)


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 some tokens must pass through unrouted."""
    cfg = _moe_cfg(capacity_factor=0.1)
    p = MOE.init_moe(jax.random.PRNGKey(3), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 16))
    out, _ = MOE.moe_forward(p, x, cfg)
    # dropped tokens produce zero output (residual handles them upstream)
    zero_rows = np.asarray(jnp.all(jnp.abs(out[0]) < 1e-6, axis=-1))
    assert zero_rows.sum() > 0


def test_moe_router_gates_sum_to_one():
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(5), (2, 8, 6)), -1)
    v, i = MOE._topk_argmax(probs, 2)
    renorm = v / jnp.sum(v, -1, keepdims=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(renorm, -1)), 1.0, rtol=1e-6)


def test_routing_token_mask_frees_capacity_and_aux():
    """Satellite regression: padded (masked) tokens must not be dispatched,
    must not occupy capacity slots, and must not enter the aux statistics.

    Construction: 4 real tokens prefer expert 1 then expert 0; 4 zero-logit
    pads argmax to expert 0 in round 0.  Unmasked, the pads fill expert 0's
    capacity (c=4) so every real token's second choice is dropped; masked,
    all real second choices land.
    """
    cfg = _moe_cfg(n_experts=2, top_k=2, capacity_factor=0.5, group_size=8)
    real = jnp.tile(jnp.array([[1.0, 3.0]]), (4, 1))  # prefer e1, then e0
    pads = jnp.zeros((4, 2))
    logits = jnp.concatenate([real, pads])[None]  # (1, 8, 2); c = 4
    mask = (jnp.arange(8) < 4)[None]

    d_unmasked, _, _, aux_unmasked = MOE._routing(logits, cfg)
    d_masked, _, _, aux_masked = MOE._routing(logits, cfg, token_mask=mask)

    # unmasked: pads claim expert 0's 4 slots in round 0 -> real tokens'
    # second choice (expert 0) is fully starved
    assert float(jnp.sum(d_unmasked[0, :4, 0])) == 0.0
    assert float(jnp.sum(d_unmasked[0, 4:])) > 0.0  # pads were dispatched
    # masked: pads dispatch nowhere, real tokens keep both choices
    assert float(jnp.sum(d_masked[0, 4:])) == 0.0
    assert float(jnp.sum(d_masked[0, :4, 0])) == 4.0
    assert float(jnp.sum(d_masked[0, :4])) == 8.0  # 4 tokens x top-2, no drops

    # aux over real tokens only: me/ce from the first 4 rows
    probs = jax.nn.softmax(logits[0, :4].astype(jnp.float32), -1)
    me = jnp.mean(probs, 0)
    ce = jnp.array([0.0, 1.0])  # all real top-1 picks are expert 1
    want_aux = 2.0 * float(jnp.sum(me * ce))
    assert float(aux_masked) == pytest.approx(want_aux, rel=1e-6)
    assert float(aux_unmasked) != pytest.approx(want_aux, rel=1e-3)


def test_moe_forward_masks_group_padding():
    """moe_forward pads t to a group multiple; the pad tokens must not alter
    the aux statistics (old behavior: 31 zero tokens all voted expert 0)."""
    cfg = _moe_cfg(group_size=32)
    p = MOE.init_moe(jax.random.PRNGKey(6), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 33, 16))  # pad = 31
    out, aux = MOE.moe_forward(p, x, cfg)
    assert out.shape == (1, 33, 16)
    # aux must equal the mask-aware routing of the same padded logits
    tokens = jnp.concatenate([x.reshape(-1, 16), jnp.zeros((31, 16))]).reshape(2, 32, 16)
    logits = jnp.einsum("gsd,de->gse", tokens.astype(jnp.float32), p["router"]["kernel"])
    mask = (jnp.arange(64) < 33).reshape(2, 32)
    _, _, _, want_aux = MOE._routing(logits, cfg, token_mask=mask)
    assert float(aux) == pytest.approx(float(want_aux), rel=1e-6)


def test_moe_router_jitter():
    """Satellite: cfg.router_jitter is multiplicative train-time logit noise —
    active only with train=True AND an rng key, deterministic per key."""
    cfg = _moe_cfg(router_jitter=0.5, capacity_factor=1.0)
    p = MOE.init_moe(jax.random.PRNGKey(8), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 32, 16)) * 3.0
    base, base_aux = MOE.moe_forward(p, x, cfg)
    # eval (train=False) and train-without-rng are noise-free
    for kw in ({}, {"train": True}, {"rng": jax.random.PRNGKey(0)}):
        out, aux = MOE.moe_forward(p, x, cfg, **kw)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base))
    # train + rng perturbs routing; same key is deterministic
    j1, _ = MOE.moe_forward(p, x, cfg, train=True, rng=jax.random.PRNGKey(1))
    j1b, _ = MOE.moe_forward(p, x, cfg, train=True, rng=jax.random.PRNGKey(1))
    j2, _ = MOE.moe_forward(p, x, cfg, train=True, rng=jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(j1), np.asarray(j1b))
    assert np.any(np.asarray(j1) != np.asarray(base))
    assert np.any(np.asarray(j1) != np.asarray(j2))
    # jitter=0 is a no-op even under train
    out0, _ = MOE.moe_forward(p, x, cfg._replace(router_jitter=0.0),
                              train=True, rng=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(base))


def test_moe_router_jitter_reachable_from_model_loss():
    """The rng must thread Model.loss -> run_segment (scan xs) ->
    block_forward -> moe_forward, so router_jitter is live in the real
    train step, not just at the layer level."""
    import dataclasses

    from repro.configs import get_config
    from repro.nn.models import build_model

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(cfg, moe=cfg.moe._replace(router_jitter=0.5))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=8)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks}
    l0 = float(model.loss(params, batch)[0])  # no rng: deterministic
    assert l0 == float(model.loss(params, batch)[0])
    l1 = float(model.loss(params, batch, rng=jax.random.PRNGKey(2))[0])
    l1b = float(model.loss(params, batch, rng=jax.random.PRNGKey(2))[0])
    assert l1 == l1b  # deterministic per key
    assert l1 != l0  # jitter perturbed the routing/gates


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------


def test_mla_decode_matches_forward():
    """Absorbed decode logits == decompressed forward at each position."""
    cfg = MLAConfig(kv_lora_rank=16, q_lora_rank=None, nope_head_dim=8,
                    rope_head_dim=4, v_head_dim=8)
    d, h, b, s = 32, 4, 2, 12
    p = L.init_mla(jax.random.PRNGKey(0), d, h, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    full = L.mla_forward(p, x, n_heads=h, cfg=cfg)
    cache = L.MLACache(
        c_kv=jnp.zeros((b, s, cfg.kv_lora_rank)),
        k_rope=jnp.zeros((b, s, cfg.rope_head_dim)),
    )
    for t in range(s):
        y, cache = L.mla_decode(p, x[:, t : t + 1], cache, jnp.int32(t), n_heads=h, cfg=cfg)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-4
        )


# ---------------------------------------------------------------------------
# Mamba / RWKV decode parity
# ---------------------------------------------------------------------------


def test_mamba_decode_matches_forward():
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2)
    d, b, s = 16, 2, 10
    p = M.init_mamba(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    full = M.mamba_forward(p, x, cfg)
    cache = M.init_mamba_cache(b, d, cfg)
    for t in range(s):
        y, cache = M.mamba_decode(p, x[:, t : t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5
        )


def test_mamba_prefill_state_continues_decode():
    cfg = SSMConfig(d_state=4, d_conv=4, expand=2)
    d, b, s = 16, 2, 12
    p = M.init_mamba(jax.random.PRNGKey(2), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, d)) * 0.5
    full = M.mamba_forward(p, x, cfg)
    _, cache = M.mamba_forward(p, x[:, :8], cfg, return_state=True)
    for t in range(8, s):
        y, cache = M.mamba_decode(p, x[:, t : t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=2e-4, atol=2e-5
        )


def test_rwkv_streaming_matches_forward():
    cfg = RWKVConfig(head_size=8, decay_lora=4, mix_lora=4)
    d, b, s = 16, 2, 10
    p = R.init_rwkv_time_mix(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    full = R.rwkv_time_mix(p, x, cfg)
    state = None
    x_prev = jnp.zeros((b, d))
    for t in range(s):
        y, state = R.rwkv_time_mix(
            p, x[:, t : t + 1], cfg, x_prev=x_prev, state=state, return_state=True
        )
        x_prev = x[:, t]
        np.testing.assert_allclose(
            np.asarray(y[:, 0]), np.asarray(full[:, t]), rtol=5e-3, atol=5e-4
        )


def test_rwkv_decay_in_unit_interval():
    cfg = RWKVConfig(head_size=8, decay_lora=4, mix_lora=4)
    p = R.init_rwkv_time_mix(jax.random.PRNGKey(2), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 16))
    w = R._decay(p, x)
    assert bool(jnp.all((w > 0) & (w < 1)))
