"""The ActQuant contract: symmetric int8 activation quantization, the exact
dequant error model, kernel v3 (int8 x int8, int32 MXU accumulation) against
its analytic bound, the double-buffered DMA pulse-streaming variant, and the
contract threaded through layers / sequential / MoE / serve."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.packed import dequantize_params, pack_matmul, quantize_params
from repro.core.quantize import (
    ActQuant,
    QuantPolicy,
    act_matmul_error_bound,
    act_quant_scope,
    default_act_quant,
    quantize_activations,
    set_default_act_quant,
)
from repro.kernels import ops
from repro.kernels.pvq_matmul import pvq_matmul, pvq_matmul_q
from repro.kernels.ref import pvq_matmul_ref


# ---------------------------------------------------------------------------
# quantize_activations: the exact roundtrip bound
# ---------------------------------------------------------------------------


def test_actquant_mode_validation():
    with pytest.raises(ValueError):
        ActQuant(mode="per_column")


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1), st.sampled_from(["per_row", "per_tensor"]))
def test_prop_quantize_roundtrip_bound(seed, mode):
    """|x - q * scale| <= scale / 2 elementwise, q within the int8 range."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (9, 37)) * 3.0
    q, scale = quantize_activations(x, ActQuant(mode=mode))
    assert q.dtype == jnp.int8
    assert scale.shape == (9, 1)
    err = jnp.abs(x - q.astype(jnp.float32) * scale)
    assert bool(jnp.all(err <= scale / 2 + 1e-7))
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127


def test_quantize_zero_rows_are_exact():
    """All-pad rows (MoE empty capacity slots) get scale 0 / pulses 0 — no
    NaNs, exact zeros on dequant."""
    x = jnp.zeros((4, 16)).at[1].set(jax.random.normal(jax.random.PRNGKey(0), (16,)))
    q, scale = quantize_activations(x)
    assert bool(jnp.all(jnp.isfinite(scale)))
    assert float(scale[0, 0]) == 0.0 and float(scale[2, 0]) == 0.0
    assert bool(jnp.all(q[0] == 0)) and bool(jnp.all(q[3] == 0))


def test_per_tensor_shares_one_scale():
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 32))
    _, scale = quantize_activations(x, ActQuant(mode="per_tensor"))
    assert len(np.unique(np.asarray(scale))) == 1


# ---------------------------------------------------------------------------
# per-tile (row x k-group) scales: ActQuant(granularity="tile")
# ---------------------------------------------------------------------------


def test_actquant_granularity_maps_to_mode():
    assert ActQuant(granularity="tile").mode == "per_tile"
    assert ActQuant(granularity="row").mode == "per_row"
    with pytest.raises(ValueError):
        ActQuant(granularity="per_block")


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 2**31 - 1))
def test_prop_per_tile_roundtrip_bound(seed):
    """Per-tile roundtrip: |x - q * scale_tile| <= scale_tile / 2 within
    each (row, k-group) tile; scale shape is (m, k // tile)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (5, 128)) * 2.0
    # pathological dynamic range: one huge group per row defeats a per-row scale
    x = x.at[:, :32].multiply(100.0)
    q, scale = quantize_activations(x, ActQuant(granularity="tile"), tile=32)
    assert scale.shape == (5, 4)
    err = jnp.abs(x - q.astype(jnp.float32) * jnp.repeat(scale, 32, axis=-1))
    cap = jnp.repeat(scale, 32, axis=-1) / 2 + 1e-6
    assert bool(jnp.all(err <= cap))


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2**31 - 1))
def test_prop_kernel_v3_per_tile_within_error_bound(seed):
    """Satellite: per-tile int8 logits stay within the per-tile analytic
    bound (a @ weighted), which is strictly tighter than the per-row bound
    on high-dynamic-range rows."""
    m, k, n, group = 6, 256, 96, 64
    x, w, s = _problem(seed, m, k, n, group)
    x = x.at[:, :group].multiply(50.0)  # long-prefill-style outlier group
    xq, a = quantize_activations(x, ActQuant(granularity="tile"), tile=group)
    assert a.shape == (m, k // group)
    y_f = pvq_matmul_ref(x, w, s, group=group)
    y_q = pvq_matmul_q(xq, w, s, a, group=group, interpret=True)
    bound = act_matmul_error_bound(a, w, s, group)
    assert bound.shape == (m, n)
    assert bool(jnp.all(jnp.abs(y_q - y_f) <= bound + 1e-5))


def test_per_tile_beats_per_row_on_outlier_rows():
    """The motivating case: a row whose groups span 100x dynamic range loses
    most of its small-group signal to one per-row scale; per-tile scales
    recover it.  Compare actual kernel error, not just bounds."""
    m, k, n, group = 4, 256, 64, 64
    x, w, s = _problem(30, m, k, n, group)
    x = x.at[:, :group].multiply(100.0)
    y_f = pvq_matmul_ref(x, w, s, group=group)
    y_row = ops.pvq_matmul(x, w, s, group=group, act_quant=ActQuant())
    y_tile = ops.pvq_matmul(
        x, w, s, group=group, act_quant=ActQuant(granularity="tile")
    )
    e_row = float(jnp.linalg.norm(y_row - y_f))
    e_tile = float(jnp.linalg.norm(y_tile - y_f))
    assert e_tile < e_row


def test_ops_per_tile_dispatch_through_packed_matmul():
    """ops threads the weight group into the per-tile quantizer (the tile
    width IS the PVQ group) — the packed entry point works end to end, with
    padding applied before quantization so scale groups stay aligned."""
    w = jax.random.laplace(jax.random.PRNGKey(31), (96, 48)) * 0.1
    pk = pack_matmul(w, group=64, n_over_k=2.0)  # k_pad = 128 > d_in = 96
    x = jax.random.normal(jax.random.PRNGKey(32), (5, 96))
    y_f = ops.packed_matmul(x, pk)
    y_t = ops.packed_matmul(x, pk, act_quant=ActQuant(granularity="tile"))
    rel = float(jnp.linalg.norm(y_t - y_f) / jnp.linalg.norm(y_f))
    assert rel < 0.05


# ---------------------------------------------------------------------------
# kernel v3 vs the analytic error bound
# ---------------------------------------------------------------------------


def _problem(seed, m, k, n, group, pulse_lo=-3, pulse_hi=4):
    kx, kw, ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.randint(kw, (k, n), pulse_lo, pulse_hi, jnp.int8)
    s = (jnp.abs(jax.random.normal(ks, (k // group, n))) * 0.05).astype(jnp.float32)
    return x, w, s


@settings(deadline=None, max_examples=8)
@given(st.integers(0, 2**31 - 1))
def test_prop_kernel_v3_within_error_bound(seed):
    """Satellite: int8 x int8 logits stay within the analytic per-group
    bound vs the f32-activation kernel — the error model is EXACT, not a
    heuristic."""
    m, k, n, group = 9, 256, 130, 128
    x, w, s = _problem(seed, m, k, n, group)
    xq, a = quantize_activations(x)
    y_f = pvq_matmul_ref(x, w, s, group=group)
    y_q = pvq_matmul_q(xq, w, s, a, group=group, interpret=True)
    bound = act_matmul_error_bound(a, w, s, group)
    assert bool(jnp.all(jnp.abs(y_q - y_f) <= bound + 1e-5))


def test_kernel_v3_k_gt_127_clamped_pulses_within_bound():
    """Satellite: the K > 127 clamped-pulse regime — the bound is computed
    from the pulses actually stored (L1 <= K after the clamp), so it holds
    on the clamped artifact too."""
    w = jax.random.laplace(jax.random.PRNGKey(3), (64, 48)) * 0.1
    pk = pack_matmul(w, group=64, k=200)  # K > 127: coordinates may clamp
    assert pk.k > 127
    x = jax.random.normal(jax.random.PRNGKey(4), (7, 64))
    xq, a = quantize_activations(x)
    y_f = ops.packed_matmul(x, pk)
    y_q = ops.packed_matmul(x, pk, act_quant=ActQuant())
    bound = act_matmul_error_bound(a, pk.pulses, pk.scales, pk.group)
    assert bool(jnp.all(jnp.abs(y_q - y_f) <= bound + 1e-4))


def test_kernel_v3_zero_scale_rows_yield_exact_zero_logits():
    """Satellite: zero-scale (all-pad) rows — both paths produce exactly 0,
    the bound degrades to 0, nothing divides by the zero scale."""
    m, k, n, group = 6, 128, 64, 64
    x, w, s = _problem(5, m, k, n, group)
    x = x.at[2].set(0.0).at[4].set(0.0)
    xq, a = quantize_activations(x)
    y_q = pvq_matmul_q(xq, w, s, a, group=group, interpret=True)
    bound = act_matmul_error_bound(a, w, s, group)
    assert float(jnp.max(jnp.abs(y_q[2]))) == 0.0
    assert float(jnp.max(jnp.abs(y_q[4]))) == 0.0
    assert float(jnp.max(bound[2])) == 0.0
    assert bool(jnp.all(jnp.isfinite(y_q)))


def test_kernel_v3_epilogue_bias_activation():
    """bias + relu fuse into the v3 epilogue AFTER the act_scale multiply;
    relu is 1-Lipschitz so the pre-activation bound survives."""
    m, k, n, group = 8, 128, 96, 64
    x, w, s = _problem(6, m, k, n, group)
    bias = jax.random.normal(jax.random.PRNGKey(7), (n,))
    xq, a = quantize_activations(x)
    y_f = jax.nn.relu(pvq_matmul_ref(x, w, s, group=group) + bias)
    y_q = pvq_matmul_q(
        xq, w, s, a, bias, group=group, activation="relu", interpret=True
    )
    bound = act_matmul_error_bound(a, w, s, group)
    assert bool(jnp.all(jnp.abs(y_q - y_f) <= bound + 1e-5))


def test_kernel_v3_many_groups_batched_fallback():
    """Beyond _MAX_UNROLL_GROUPS per k-tile the body switches to one batched
    int8 x int8 dot_general — still integer feeds, same numbers."""
    m, k, n, group = 4, 1280, 64, 128  # 10 groups in one bk=1280 tile
    x, w, s = _problem(8, m, k, n, group)
    xq, a = quantize_activations(x)
    y_big = pvq_matmul_q(xq, w, s, a, group=group, bk=1280, interpret=True)
    y_ref = (xq.astype(jnp.float32) * a) @ (
        w.astype(jnp.float32) * jnp.repeat(s, group, axis=0)
    )
    np.testing.assert_allclose(np.asarray(y_big), np.asarray(y_ref), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# double-buffered DMA pulse streaming
# ---------------------------------------------------------------------------


def test_dma_streaming_matches_automatic_pipeline_bit_exact():
    """Satellite: the hand-rolled make_async_copy double-buffer path runs
    the same per-chunk contraction in the same order as the automatic
    k-grid pipeline — outputs are bit-identical, with and without the
    bias/activation epilogue."""
    m, k, n, group = 8, 512, 256, 128
    x, w, s = _problem(9, m, k, n, group)
    bias = jax.random.normal(jax.random.PRNGKey(10), (n,))
    xq, a = quantize_activations(x)
    for kwargs in (
        {},
        {"bias": bias, "activation": "relu"},
        {"activation": "silu"},
    ):
        b = kwargs.pop("bias", None)
        y_dma = pvq_matmul_q(
            xq, w, s, a, b, group=group, bk=128, dma_streaming=True,
            interpret=True, **kwargs,
        )
        y_pipe = pvq_matmul_q(
            xq, w, s, a, b, group=group, bk=128, dma_streaming=False,
            interpret=True, **kwargs,
        )
        assert bool(jnp.array_equal(y_dma, y_pipe))


def test_dma_streaming_auto_gate(monkeypatch):
    """Auto-selection: big bk*bn tiles with >= 2 k-chunks stream via DMA,
    small tiles keep the automatic pipeline, REPRO_PVQ_DMA=0 kills it."""
    from repro.kernels.pvq_matmul import _dma_streaming_wanted

    monkeypatch.delenv("REPRO_PVQ_DMA", raising=False)
    assert _dma_streaming_wanted(8, 4096, 512, 8, 512, 256)  # big FFN shape
    assert not _dma_streaming_wanted(8, 256, 128, 8, 128, 128)  # small tile
    assert not _dma_streaming_wanted(8, 512, 512, 8, 512, 512)  # 1 chunk
    monkeypatch.setenv("REPRO_PVQ_DMA", "0")
    assert not _dma_streaming_wanted(8, 4096, 512, 8, 512, 256)


# ---------------------------------------------------------------------------
# ops dispatch: pre-quantized contract + batched expert entry
# ---------------------------------------------------------------------------


def test_ops_prequantized_act_scale_contract():
    """act_scale marks x as already-quantized: same result as act_quant,
    and a float x with act_scale is rejected."""
    m, k, n, group = 5, 128, 64, 64
    x, w, s = _problem(11, m, k, n, group)
    xq, a = quantize_activations(x)
    y1 = ops.pvq_matmul(x, w, s, group=group, act_quant=ActQuant())
    y2 = ops.pvq_matmul(xq, w, s, group=group, act_scale=a)
    assert bool(jnp.array_equal(y1, y2))
    with pytest.raises(ValueError, match="int8"):
        ops.pvq_matmul(x, w, s, group=group, act_scale=a)


def test_packed_matmul_stacked_act_quant_matches_per_slice():
    e, m, d, f, group = 3, 6, 64, 48, 64
    w = jax.random.laplace(jax.random.PRNGKey(12), (e, d, f)) * 0.1
    bank = pack_matmul(w, group=group, n_over_k=2.0)
    x = jax.random.normal(jax.random.PRNGKey(13), (e, m, d))
    y = ops.packed_matmul_stacked(x, bank, act_quant=ActQuant())
    for i in range(e):
        sl = type(bank)(
            pulses=bank.pulses[i], scales=bank.scales[i], group=bank.group,
            k=bank.k, shape=bank.shape, dtype=bank.dtype, layout=bank.layout,
            scale_mode=bank.scale_mode,
        )
        yi = ops.packed_matmul(x[i], sl, act_quant=ActQuant())
        np.testing.assert_allclose(
            np.asarray(y[i]), np.asarray(yi), rtol=1e-5, atol=1e-6
        )


# ---------------------------------------------------------------------------
# the contract through the layers
# ---------------------------------------------------------------------------


def test_default_act_quant_scope_sets_and_restores():
    assert default_act_quant() is None
    with act_quant_scope(ActQuant(mode="per_tensor")) as aq:
        assert default_act_quant() is aq
        with act_quant_scope(None):
            assert default_act_quant() is None
        assert default_act_quant() is aq
    assert default_act_quant() is None


def test_pvq_dense_act_quant_close_to_f32_path():
    from repro.nn.layers import dense, init_dense, pvq_quantize_dense

    p = init_dense(jax.random.PRNGKey(14), 96, 64, bias=True)
    q = pvq_quantize_dense(p, group=32, k_pulses=16)
    x = jax.random.normal(jax.random.PRNGKey(15), (5, 96))
    y_f = dense(q, x)
    with act_quant_scope(ActQuant()):
        y_q = dense(q, x)
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.linalg.norm(y_f))
    assert rel < 0.05
    # explicit kwarg wins over the (unset) process default
    y_kw = dense(q, x, act_quant=ActQuant())
    assert bool(jnp.array_equal(y_kw, y_q))


def test_unembed_act_quant_integer_logits_close():
    from repro.core.packed import pack_flat
    from repro.nn.layers import unembed

    table = jax.random.normal(jax.random.PRNGKey(16), (64, 32)) * 0.02
    p = {"embedding": pack_flat(table, group=32, k=16, row_align=32)}
    x = jax.random.normal(jax.random.PRNGKey(17), (2, 3, 32))
    lo_f = unembed(p, x)
    lo_q = unembed(p, x, act_quant=ActQuant())
    rel = float(jnp.linalg.norm(lo_q - lo_f) / jnp.linalg.norm(lo_f))
    assert rel < 0.05
    assert lo_q.dtype == jnp.float32


def test_sequential_kernel_apply_act_quant():
    from repro.nn.sequential import LayerSpec, SequentialConfig, SequentialNet

    cfg = SequentialConfig(
        name="tiny",
        input_shape=(64,),
        layers=(
            LayerSpec(kind="fc", out=48, activation="relu", n_over_k=2.0),
            LayerSpec(kind="fc", out=10, activation="none", n_over_k=2.0),
        ),
    )
    net = SequentialNet(cfg)
    params = net.init(jax.random.PRNGKey(18))
    kparams = net.pvq_kernel_encode(params, group=64)
    x = jax.random.normal(jax.random.PRNGKey(19), (4, 64))
    y_f = net.kernel_apply(params, kparams, x)
    y_q = net.kernel_apply(params, kparams, x, act_quant=ActQuant())
    rel = float(jnp.linalg.norm(y_q - y_f) / jnp.maximum(jnp.linalg.norm(y_f), 1e-9))
    assert rel < 0.1


# ---------------------------------------------------------------------------
# MoE: quantize the dispatch buffer once, reuse across the expert matmuls
# ---------------------------------------------------------------------------

MOE_POLICY = QuantPolicy(rules=(("kernel|experts", 2.0, 64),), scale_mode="ls")


def _moe_cfg():
    from repro.nn.moe import MoEConfig

    return MoEConfig(
        n_experts=4, top_k=2, n_shared=0, d_expert=32, capacity_factor=2.0,
        group_size=32, activation="swiglu",
    )


def test_moe_forward_packed_act_quant_matches_dense():
    """Acceptance: packed-vs-dense MoE forward equivalence with activation
    quantization enabled on the dispatch buffer (routing is identical; the
    only deltas are PVQ weights + int8 activations, both bounded)."""
    from repro.nn.moe import init_moe, moe_forward

    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(20), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(21), (2, 32, 16))
    q = quantize_params(p, MOE_POLICY)
    out_dq, aux_dq = moe_forward(dequantize_params(q), x, cfg)
    with act_quant_scope(ActQuant()):
        out_q, aux_q = moe_forward(q, x, cfg)
    # routing consumes raw f32 logits — aux loss must be bit-comparable
    assert float(aux_q) == pytest.approx(float(aux_dq), rel=1e-6)
    np.testing.assert_allclose(
        np.asarray(out_q), np.asarray(out_dq), rtol=0.15, atol=0.05
    )
    # and the act-quant delta on top of the packed path is small
    out_pk, _ = moe_forward(q, x, cfg)
    rel = float(
        jnp.linalg.norm(out_q - out_pk) / jnp.maximum(jnp.linalg.norm(out_pk), 1e-9)
    )
    assert rel < 0.05


def test_moe_dispatch_buffer_quantized_once():
    """The quantize-once contract: up and gate reuse ONE (int8 buffer,
    scales) pair — quantize_activations runs twice per forward (dispatch
    buffer + hidden h), not three times."""
    from repro.core import quantize as qz
    from repro.nn.moe import init_moe, moe_forward

    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(22), 16, cfg)
    q = quantize_params(p, MOE_POLICY)
    x = jax.random.normal(jax.random.PRNGKey(23), (2, 32, 16))
    calls = []
    orig = qz.quantize_activations

    def counting(xx, aq=ActQuant()):
        calls.append(xx.shape)
        return orig(xx, aq)

    qz.quantize_activations = counting
    try:
        with act_quant_scope(ActQuant()):
            moe_forward(q, x, cfg)
    finally:
        qz.quantize_activations = orig
    assert len(calls) == 2, calls  # dispatch buffer once + h once


def test_moe_dense_bank_ignores_act_quant():
    """Dense (unpacked) expert banks have no integer operand to pair with —
    the contract is a no-op there, bit-identical outputs."""
    from repro.nn.moe import init_moe, moe_forward

    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(24), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(25), (2, 32, 16))
    out_f, _ = moe_forward(p, x, cfg)
    with act_quant_scope(ActQuant()):
        out_q, _ = moe_forward(p, x, cfg)
    assert bool(jnp.array_equal(out_f, out_q))


# ---------------------------------------------------------------------------
# serve-side agreement probe
# ---------------------------------------------------------------------------


def test_top1_agreement_metric():
    from repro.launch.serve import top1_agreement

    a = jnp.array([[[1.0, 0.5, 0.0], [1.0, 0.995, 0.0]]])
    # identical -> 1.0 strict
    ag = top1_agreement(a, a)
    assert ag["top1_agreement"] == 1.0 and ag["top1_agreement_strict"] == 1.0
    # second position flips a genuine near-tie (margin 0.005, within both
    # the measured noise and 5% of the logit spread) -> excused
    b = a.at[0, 1, 1].add(0.02)
    ag = top1_agreement(a, b)
    assert ag["top1_agreement_strict"] == 0.5
    assert ag["top1_agreement"] == 1.0 and ag["ties_excused"] == 1
    # a clearly-separated pick flipped by a gross perturbation is NEVER
    # excused, however large the perturbation (no laundering a broken kernel)
    c = a.at[0, 0, :].set(jnp.array([0.0, 2.0, 0.0]))
    ag = top1_agreement(a, c)
    assert ag["top1_agreement"] == 0.5 and ag["ties_excused"] == 0
