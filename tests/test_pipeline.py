"""Pipeline-parallel schedule tests (multi-device via subprocess with
forced host device count; the scheduling math unit-tested in-process)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(2, 8) == pytest.approx(1 / 9)
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)


@pytest.mark.slow
def test_pipeline_matches_sequential_subprocess():
    """4 pipeline stages on 4 forced host devices == sequential composition."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.parallel.pipeline import pipeline_forward

        n_stages, n_micro, mb, d = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3
        xs = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        mesh = jax.make_mesh((n_stages,), ("pod",))
        out = pipeline_forward(stage_fn, ws, xs, mesh=mesh, axis="pod")

        ref = xs
        for s in range(n_stages):
            ref = jnp.tanh(ref @ ws[s])
        err = float(jnp.max(jnp.abs(out - ref)))
        print("MAXERR", err)
        assert err < 1e-5, err
        """
    )
    env = dict(os.environ, PYTHONPATH="src")
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "MAXERR" in res.stdout
