"""Dry-run machinery integration: the production-mesh lower+compile path runs
under pytest via a subprocess (device count must be set before jax init)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(prog: str, timeout=560):
    env = dict(os.environ, PYTHONPATH="src")
    return subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=env, cwd=REPO, timeout=timeout,
    )


@pytest.mark.slow
def test_dryrun_cell_single_pod(tmp_path):
    res = _run(
        textwrap.dedent(
            f"""
            import sys
            sys.argv = ["dryrun", "--arch", "smollm-360m", "--shape", "train_4k",
                        "--mesh", "single", "--out", {str(tmp_path)!r}]
            from repro.launch.dryrun import main
            raise SystemExit(main())
            """
        )
    )
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads((tmp_path / "smollm-360m__train_4k__single.json").read_text())
    assert rec["status"] == "ok"
    assert rec["chips"] == 256
    roof = rec["roofline"]
    assert roof["flops"] > 0 and roof["hbm_bytes"] > 0
    assert roof["bottleneck"] in ("compute", "memory", "collective")
    assert 0 < roof["useful_ratio"] < 1.5  # physical after depth correction


@pytest.mark.slow
def test_dryrun_decode_multi_pod(tmp_path):
    res = _run(
        textwrap.dedent(
            f"""
            import sys
            sys.argv = ["dryrun", "--arch", "gemma-2b", "--shape", "decode_32k",
                        "--mesh", "multi", "--out", {str(tmp_path)!r}, "--opt-level", "1"]
            from repro.launch.dryrun import main
            raise SystemExit(main())
            """
        )
    )
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads((tmp_path / "gemma-2b__decode_32k__multi.json").read_text())
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert "analytic_decode" in rec
    assert rec["analytic_decode"]["pvq_weight_speedup"] > 1.0
