"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness; plus a prefill+decode step for
decode-capable archs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.nn import build_model

BATCH, SEQ = 2, 32


def _batch(cfg, key):
    kt, kp = jax.random.split(key)
    b = {
        "tokens": jax.random.randint(kt, (BATCH, SEQ), 0, cfg.vocab_size),
        "targets": jax.random.randint(kp, (BATCH, SEQ), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(kp, (BATCH, SEQ, cfg.d_model))
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(kp, (BATCH, cfg.prefix_len, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key, max_seq=SEQ)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux, _ = model.forward(params, batch, mode="train")
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one SGD step on the loss must produce finite grads
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", sorted(a for a in ARCHS if ARCHS[a].supports_decode))
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=SEQ + 8)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, cache = model.prefill(params, batch, cache_len=SEQ + 8)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)

    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, jnp.int32(SEQ))
    assert logits2.shape == (BATCH, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    # cache must be updated, not recreated with a new structure
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-1.6b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must agree with the parallel forward pass."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=SEQ)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    tokens = batch["tokens"]

    full_logits, _, _ = model.forward(params, batch, mode="train")

    prefix = SEQ // 2
    pre_batch = dict(batch, tokens=tokens[:, :prefix])
    logits_p, cache = model.prefill(params, pre_batch, cache_len=SEQ)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, prefix - 1]),
        rtol=2e-2, atol=2e-3,
    )
    # decode the next 3 tokens, feeding ground-truth tokens
    for t in range(prefix, prefix + 3):
        logits_d, cache = model.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-3,
        )


def test_param_counts_full_configs():
    """Full (non-reduced) configs must be buildable as shape pytrees and hit
    the expected parameter counts (rough check against the names)."""
    import numpy as np

    expected = {
        "granite-8b": (7e9, 9e9),
        "smollm-360m": (0.30e9, 0.45e9),
        "starcoder2-15b": (13e9, 17e9),
        "gemma-2b": (2.0e9, 3.0e9),
        "deepseek-v2-236b": (200e9, 250e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "jamba-1.5-large-398b": (330e9, 420e9),
        "rwkv6-1.6b": (1.3e9, 2.0e9),
        "whisper-small": (0.15e9, 0.35e9),
        "paligemma-3b": (2.0e9, 3.5e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), max_seq=4096))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]"


def test_whisper_decode_matches_forward():
    """Enc-dec: token-by-token decode (self KV + cross KV caches) must agree
    with the parallel decoder forward pass."""
    cfg = get_config("whisper-small").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=SEQ)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    full_logits, _, _ = model.forward(params, batch, mode="train")

    prefix = SEQ // 2
    pre_batch = dict(batch, tokens=batch["tokens"][:, :prefix])
    logits_p, cache = model.prefill(params, pre_batch, cache_len=SEQ)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, prefix - 1]),
        rtol=2e-2, atol=2e-3,
    )
    for t in range(prefix, prefix + 3):
        logits_d, cache = model.decode_step(
            params, cache, batch["tokens"][:, t : t + 1], jnp.int32(t)
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-3,
        )


def test_paligemma_decode_matches_forward():
    """VLM: prefix-LM prefill + decode must agree with the parallel forward."""
    cfg = get_config("paligemma-3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=SEQ + cfg.prefix_len)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    full_logits, _, _ = model.forward(params, batch, mode="train")

    prefix = SEQ // 2
    pre_batch = dict(batch, tokens=batch["tokens"][:, :prefix])
    logits_p, cache = model.prefill(params, pre_batch, cache_len=SEQ + cfg.prefix_len)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, prefix - 1]),
        rtol=2e-2, atol=2e-3,
    )
    # decode positions are offset by the patch prefix
    for t in range(prefix, prefix + 2):
        logits_d, cache = model.decode_step(
            params, cache, batch["tokens"][:, t : t + 1],
            jnp.int32(cfg.prefix_len + t),
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-3,
        )
