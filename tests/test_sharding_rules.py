"""Sharding rule unit tests — including the regression class for 'rule
silently never matches' (the NamedTuple cache-path bug found in §Perf)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import (
    ShardingPolicy,
    cache_pspec,
    param_pspec,
)


class FakeMesh:
    """Duck-typed mesh for rule tests (no devices needed)."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})
POL = ShardingPolicy()


def test_embedding_rule():
    spec = param_pspec("embed/embedding", (49152, 4096), MESH, POL)
    assert spec == P("model", ("data",))


def test_column_and_row_parallel():
    assert param_pspec("segments/seg0/b0/mixer/wq/kernel", (4096, 4096), MESH, POL) == P(("data",), "model")
    assert param_pspec("segments/seg0/b0/mixer/wo/kernel", (4096, 4096), MESH, POL) == P("model", ("data",))


def test_stacked_scan_params_get_leading_none():
    spec = param_pspec("segments/seg0/b0/mixer/wq/kernel", (36, 4096, 4096), MESH, POL)
    assert spec == P(None, ("data",), "model")


def test_expert_rules():
    assert param_pspec("ffn/wi_up_experts", (160, 5120, 1536), MESH, POL) == P("model", ("data",), None)
    assert param_pspec("ffn/wo_experts", (160, 1536, 5120), MESH, POL) == P("model", None, ("data",))


def test_serve_layout_experts():
    pol = ShardingPolicy(serve_params=True)
    assert param_pspec("ffn/wi_up_experts", (160, 5120, 1536), MESH, pol) == P("model", None, "data")
    # non-expert kernels: no FSDP at serve
    assert param_pspec("mixer/wq/kernel", (4096, 4096), MESH, pol) == P(None, "model")


def test_norm_scales_replicated():
    assert param_pspec("ln_mix/rms_scale", (4096,), MESH, POL) == P()
    assert param_pspec("final_norm/ln_bias", (768,), MESH, POL) == P()


def test_indivisible_dims_fall_back_to_replicated():
    # 15 heads * 64 = 960 not divisible by 16 -> no model sharding
    spec = param_pspec("mixer/wq/kernel", (960, 900), MESH, POL)
    assert spec == P(("data",), None)


def test_every_model_param_matches_a_rule():
    """No parameter leaf may silently fall through to the generic default
    UNLESS it is 1-D (replicated by design). Guards the rule table against
    renames (the bug class that left decode caches replicated)."""
    from repro.configs import get_config
    from repro.nn.models import build_model

    cfg = get_config("jamba-1.5-large-398b").reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), max_seq=64))

    def visit(path, leaf):
        pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = param_pspec(pstr, tuple(leaf.shape), MESH, POL)
        if leaf.ndim >= 2 and "experts" not in pstr:
            # matrices must get SOME sharding intent (even if divisibility
            # falls back); the rule must at least match (not default P())
            import re
            from repro.parallel.sharding import _PARAM_RULES

            assert any(re.search(pat, pstr) for pat, _ in _PARAM_RULES), pstr
        return leaf

    jax.tree_util.tree_map_with_path(visit, shapes)


def test_cache_rules_match_dict_paths():
    """Decode-cache rules MUST match the actual pytree paths produced by
    init_cache (regression: NamedTuple paths were positional and never hit)."""
    from repro.configs import get_config
    from repro.nn.models import build_model

    pol = ShardingPolicy(cache_seq_tp=True)
    matched = {"kv": 0, "mla": 0, "mamba": 0, "rwkv": 0}
    for arch, key in (("granite-8b", "kv"), ("deepseek-v2-lite-16b", "mla"),
                      ("jamba-1.5-large-398b", "mamba"), ("rwkv6-1.6b", "rwkv")):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        cache = jax.eval_shape(lambda m=model: m.init_cache(4, 64))

        def visit(path, leaf):
            pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            spec = cache_pspec(pstr, tuple(leaf.shape), MESH, pol)
            if f"/{key}/" in pstr or pstr.endswith(("rwkv_state", "rwkv_shift_att", "rwkv_shift_ffn")):
                assert spec != P() or leaf.ndim < 3, f"no cache rule matched {pstr}"
                matched[key] += 1
            return leaf

        jax.tree_util.tree_map_with_path(visit, cache)
    assert all(v > 0 for v in matched.values()), matched


def test_cache_seq_axis_sharded_only_with_policy():
    on = ShardingPolicy(cache_seq_tp=True)
    off = ShardingPolicy()
    shape = (2, 128, 32768, 8, 128)
    assert cache_pspec("seg0/b0/kv/k", shape, MESH, on)[2] in ("model", ("model",))
    assert cache_pspec("seg0/b0/kv/k", shape, MESH, off)[2] is None


def test_context_parallel_adds_data_axis():
    pol = ShardingPolicy(context_parallel=True, cache_seq_tp=True)
    spec = cache_pspec("seg0/b0/kv/k", (2, 1, 524288, 8, 128), MESH, pol)
    assert spec[2] == ("data", "model")
