"""Tests for pytree quantization, STE/QAT, K-annealing, and rho folding."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantPolicy,
    bsign,
    check_homogeneity,
    fold_codes,
    k_for,
    pvq_encode,
    pvq_ste,
    quantize_tree,
    total_bits,
    tree_compression_report,
)
from repro.core.qat import bsign_clipped_ste, k_annealing_stages, k_annealing_schedule


def _params(seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "dense0": {"kernel": jax.random.laplace(k1, (64, 32)), "bias": jnp.zeros(32)},
        "dense1": {"kernel": jax.random.laplace(k2, (32, 10)), "bias": jnp.zeros(10)},
        "norm": {"scale": jnp.ones(32)},
        "ssm": {"a_log": jax.random.normal(k3, (16,))},
    }


def test_quantize_tree_respects_skip():
    params = _params()
    policy = QuantPolicy(rules=(("kernel", 2.0, None),))
    q, codes, stats = quantize_tree(params, policy)
    assert set(codes) == {"dense0/kernel", "dense1/kernel"}
    np.testing.assert_array_equal(np.asarray(q["norm"]["scale"]), np.ones(32))
    np.testing.assert_array_equal(np.asarray(q["ssm"]["a_log"]), np.asarray(params["ssm"]["a_log"]))
    for path, st in stats.items():
        assert st["K"] == k_for(st["N"], 2.0)
        assert st["rel_err"] < 0.5


def test_quantize_tree_grouped_vs_whole():
    params = _params(1)
    whole = QuantPolicy(rules=(("kernel", 1.0, None),))
    grouped = QuantPolicy(rules=(("kernel", 1.0, 128),))
    qw, cw, _ = quantize_tree(params, whole)
    qg, cg, _ = quantize_tree(params, grouped)
    # per-group scales should approximate at least as well (more dof)
    w = params["dense0"]["kernel"]
    ew = float(jnp.linalg.norm(qw["dense0"]["kernel"] - w))
    eg = float(jnp.linalg.norm(qg["dense0"]["kernel"] - w))
    assert eg <= ew * 1.25  # grouped usually wins; allow slack (different rho defs)
    assert cw["dense0/kernel"].scale.ndim == 0
    assert cg["dense0/kernel"].scale.shape == (64 * 32 // 128,)


def test_compression_report_and_total_bits():
    params = _params(2)
    policy = QuantPolicy(rules=(("kernel", 5.0, None),))
    _, codes, _ = quantize_tree(params, policy)
    rep = tree_compression_report(codes)
    for path, r in rep.items():
        assert r["0_pct"] > 50.0  # N/K=5 -> most pulses zero
        assert r["golomb_bits_per_weight"] < 3.0
    agg = total_bits(codes, "golomb")
    assert agg["vs_bf16_ratio"] > 4.0  # >4x smaller than bf16


# ---------------------------------------------------------------------------
# STE
# ---------------------------------------------------------------------------


def test_pvq_ste_forward_is_quantized_backward_is_identity():
    w = jax.random.laplace(jax.random.PRNGKey(3), (256,))
    q = pvq_ste(w, 64)
    code = pvq_encode(w, 64)
    np.testing.assert_allclose(np.asarray(q), np.asarray(code.dequantize()), rtol=1e-6)
    g = jax.grad(lambda w: jnp.sum(pvq_ste(w, 64) ** 2))(w)
    # identity STE: grad == 2 * q(w)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(q), rtol=1e-5)


def test_bsign_values_and_grad():
    x = jnp.array([-2.0, -0.0, 0.0, 3.0])
    np.testing.assert_array_equal(np.asarray(bsign(x)), [-1.0, 1.0, 1.0, 1.0])
    g = jax.grad(lambda x: jnp.sum(bsign(x) * jnp.arange(4.0)))(x)
    np.testing.assert_allclose(np.asarray(g), np.arange(4.0))
    gc = jax.grad(lambda x: jnp.sum(bsign_clipped_ste(x) * jnp.ones(4)))(x)
    np.testing.assert_allclose(np.asarray(gc), [0.0, 1.0, 1.0, 0.0])


def test_qat_step_reduces_loss():
    """One projected-QAT step on a toy regression must reduce loss."""
    key = jax.random.PRNGKey(4)
    w_true = jax.random.laplace(key, (32,))
    x = jax.random.normal(jax.random.PRNGKey(5), (128, 32))
    y = x @ w_true

    def loss(w):
        return jnp.mean((x @ pvq_ste(w, 16) - y) ** 2)

    w = jnp.zeros(32)
    l0 = float(loss(w))
    for _ in range(50):
        w = w - 0.05 * jax.grad(loss)(w)
    assert float(loss(w)) < 0.5 * l0


# ---------------------------------------------------------------------------
# K-annealing
# ---------------------------------------------------------------------------


def test_k_annealing_monotone():
    k_at = k_annealing_schedule(256, 16, 100)
    ks = [k_at(s) for s in range(0, 101, 10)]
    assert ks[0] == 256 and ks[-1] == 16
    assert all(a >= b for a, b in zip(ks, ks[1:]))


def test_k_annealing_stages():
    stages = k_annealing_stages(256, 16, 5)
    ks = [k for k, _ in stages]
    assert ks[0] == 256 and ks[-1] == 16
    assert all(a > b for a, b in zip(ks, ks[1:]))
    assert abs(sum(f for _, f in stages) - 1.0) < 1e-9


# ---------------------------------------------------------------------------
# rho folding (paper §V)
# ---------------------------------------------------------------------------


def test_homogeneity_checks():
    assert check_homogeneity("relu", jax.nn.relu)
    assert check_homogeneity("none", lambda x: x)
    assert check_homogeneity("bsign", bsign)
    assert not check_homogeneity("gelu", jax.nn.gelu)


def test_fold_relu_net_exact():
    """Integer-only forward * folded scale == dequantized forward (eq. 14)."""
    key = jax.random.PRNGKey(6)
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.laplace(k1, (16, 32))
    w2 = jax.random.laplace(k2, (32, 8))
    x = jax.random.normal(k3, (4, 16))

    c1 = pvq_encode(w1.reshape(-1), 128)
    c2 = pvq_encode(w2.reshape(-1), 64)
    pulses, out_scale = fold_codes([c1, c2], ["relu", "relu"])

    # reference: dequantized weights
    d1 = c1.dequantize().reshape(16, 32)
    d2 = c2.dequantize().reshape(32, 8)
    ref = jax.nn.relu(jax.nn.relu(x @ d1) @ d2)

    # integer path: pulse weights only, one final scale
    p1 = jnp.asarray(pulses[0], jnp.float32).reshape(16, 32)
    p2 = jnp.asarray(pulses[1], jnp.float32).reshape(32, 8)
    got = out_scale * jax.nn.relu(jax.nn.relu(x @ p1) @ p2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_fold_bsign_absorbs_scale():
    key = jax.random.PRNGKey(7)
    w1 = jax.random.laplace(key, (16, 32))
    c1 = pvq_encode(w1.reshape(-1), 128)
    _, out_scale = fold_codes([c1], ["bsign"])
    assert out_scale == 1.0


def test_fold_argmax_invariance():
    """Paper: under one-hot/argmax output the final scale can be dropped."""
    key = jax.random.PRNGKey(8)
    logits = jax.random.normal(key, (4, 10))
    rho = 0.37
    np.testing.assert_array_equal(
        np.asarray(jnp.argmax(logits, -1)), np.asarray(jnp.argmax(rho * logits, -1))
    )
