"""Continuous-batching engine tests: page allocator invariants, PagedKV
graft/append parity against the PackedKV oracle, engine-vs-fixed-batch
token agreement under mid-flight join/evict (ragged lengths, partial tail
blocks), cross-sequence isolation, per-sequence EOS/max_tokens stopping,
the compile-count regressions for both the engine decode step and the
bucketed ``serve.generate`` loop, and the slot-pool cache sharding rules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed import PackedKV, PagedKV, is_paged_kv
from repro.core.quantize import KVQuant, kv_quant_scope
from repro.launch.engine import (
    PageAllocator,
    PVQEngine,
    Request,
    bucket_len,
    poisson_trace,
)

KVQ = KVQuant(block=8, group=16)


# ---------------------------------------------------------------------------
# Page allocator (host)
# ---------------------------------------------------------------------------


def test_page_allocator_alloc_free_reuse():
    al = PageAllocator(4)
    ids = [al.alloc() for _ in range(4)]
    assert sorted(ids) == [0, 1, 2, 3]
    assert al.trash == 4 and al.trash not in ids
    assert al.alloc() is None  # exhausted
    assert al.alloc_many(1) is None
    al.free([ids[1], ids[3]])
    assert al.available == 2
    again = al.alloc_many(2)
    assert sorted(again) == sorted([ids[1], ids[3]])  # freed pages reused
    al.free([again[0]])
    with pytest.raises(ValueError):
        al.free([again[0]])  # double free
    with pytest.raises(ValueError):
        al.free([al.trash])


def test_bucket_len():
    assert bucket_len(1, 8) == 8
    assert bucket_len(8, 8) == 8
    assert bucket_len(9, 8) == 16
    assert bucket_len(0, 8) == 8


# ---------------------------------------------------------------------------
# PagedKV container vs the PackedKV oracle
# ---------------------------------------------------------------------------


def _dense_kv(seed, b, s, n_kv, hd):
    kk, kv = jax.random.split(jax.random.PRNGKey(seed))
    k = jax.random.normal(kk, (b, s, n_kv, hd), jnp.float32)
    v = jax.random.normal(kv, (b, s, n_kv, hd), jnp.float32)
    return k, v


def test_paged_graft_matches_from_dense():
    """Grafting a dense prefill into pages encodes bit-identically to the
    fixed-batch ``PackedKV.from_dense`` path: same pulse planes for full
    blocks, same exact tail rows for the in-flight partial block."""
    n_kv, hd, L = 2, 16, 21  # 2 full blocks of 8 + 5-row tail
    k, v = _dense_kv(0, 1, L, n_kv, hd)
    ref = PackedKV.from_dense(k, v, kvq=KVQ, dtype=jnp.float32)

    lb = bucket_len(L, KVQ.block)
    pad = lb - L
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    paged = PagedKV.init(2, 6, 4, n_kv, hd, kvq=KVQ, dtype=jnp.float32)
    # slot 1, physical pages [3, 0] for logical blocks 0/1; the padded
    # partial block 2 goes to the trash page
    ids = jnp.asarray([3, 0, paged.trash_page], jnp.int32)
    paged = paged.graft(kp, vp, jnp.int32(1), ids, jnp.int32(L))
    pt = np.full((2, 4), paged.trash_page, np.int32)
    pt[1, :2] = [3, 0]
    paged = paged.with_tables(jnp.asarray(pt), jnp.full((2,), paged.trash_page, jnp.int32))

    got = paged.gather()
    pe = (L // KVQ.block) * KVQ.block
    np.testing.assert_array_equal(
        np.asarray(got.k_pulses[1, :pe]), np.asarray(ref.k_pulses[0, :pe])
    )
    np.testing.assert_array_equal(
        np.asarray(got.v_pulses[1, :pe]), np.asarray(ref.v_pulses[0, :pe])
    )
    np.testing.assert_array_equal(
        np.asarray(got.k_scales[1, :pe]), np.asarray(ref.k_scales[0, :pe])
    )
    # exact tail rows (positions pe..L-1 live at ring slots 0..L-pe-1)
    np.testing.assert_array_equal(
        np.asarray(got.tail_k[1, : L - pe]), np.asarray(ref.tail_k[0, : L - pe])
    )
    np.testing.assert_array_equal(
        np.asarray(got.tail_v[1, : L - pe]), np.asarray(ref.tail_v[0, : L - pe])
    )
    # unallocated logical blocks and the other slot read the trash page,
    # and the dense view agrees with the oracle over the valid extent
    kd, vd = paged.dense_kv(jnp.asarray([0, L]))
    kr, vr = ref.dense_kv(jnp.asarray([L]))
    np.testing.assert_allclose(np.asarray(kd[1, :L]), np.asarray(kr[0, :L]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vd[1, :L]), np.asarray(vr[0, :L]), rtol=1e-6)


def test_paged_append_matches_packed_append():
    """Per-slot streaming appends (masked block-encode scatter to the
    pre-assigned write_page) land the same planes/tails as the lockstep
    ``PackedKV.append`` stream at the same positions."""
    n_kv, hd, blk = 2, 16, KVQ.block
    steps = 2 * blk + 3  # crosses two block boundaries
    k, v = _dense_kv(1, 1, steps, n_kv, hd)
    ref = PackedKV.init(1, 4 * blk, n_kv, hd, kvq=KVQ, dtype=jnp.float32)
    paged = PagedKV.init(1, 4, 4, n_kv, hd, kvq=KVQ, dtype=jnp.float32)
    pt = np.full((1, 4), paged.trash_page, np.int32)
    pages = [2, 0]  # deliberately out-of-order physical placement
    for pos in range(steps):
        kn, vn = k[:, pos : pos + 1], v[:, pos : pos + 1]
        ref = ref.append(kn, vn, pos)
        wp = np.full((1,), paged.trash_page, np.int32)
        if (pos + 1) % blk == 0:
            pid = pages[pos // blk]
            pt[0, pos // blk] = pid
            wp[0] = pid
        paged = paged.with_tables(jnp.asarray(pt), jnp.asarray(wp))
        paged = paged.append(kn, vn, jnp.asarray([pos], jnp.int32))
    got = paged.gather()
    pe = (steps // blk) * blk
    np.testing.assert_array_equal(
        np.asarray(got.k_pulses[0, :pe]), np.asarray(ref.k_pulses[0, :pe])
    )
    np.testing.assert_array_equal(
        np.asarray(got.v_scales[0, :pe]), np.asarray(ref.v_scales[0, :pe])
    )
    t = steps - pe
    np.testing.assert_array_equal(
        np.asarray(got.tail_k[0, :t]), np.asarray(ref.tail_k[0, :t])
    )


# ---------------------------------------------------------------------------
# Engine end-to-end (tiny model)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served():
    from repro.configs import get_config
    from repro.nn.models import build_model

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=64)
    return cfg, model, params


def _oracle_generate(model, params, prompt, gen):
    from repro.launch.serve import generate

    out = generate(
        model, params, jnp.asarray([prompt], jnp.int32),
        gen=gen, cache_len=len(prompt) + gen,
    )
    return [int(x) for x in np.asarray(out[0])[len(prompt):]]


def test_engine_agreement_and_compile_counts(served):
    """Mid-flight join (more requests than slots), ragged prompt lengths
    with partial tail blocks: engine tokens match the fixed-batch oracle,
    the engine-static decode step compiles exactly once, and prefill
    compiles once per prompt bucket."""
    from repro.launch.serve import engine_token_agreement

    cfg, model, params = served
    with kv_quant_scope(KVQ):
        trace = poisson_trace(
            5, rate=0.0, vocab=cfg.vocab_size, prompt_lens=(3, 13),
            max_new=8, seed=3,
        )
        eng = PVQEngine(model, params, n_slots=3, max_len=32)
        res = eng.run(
            [Request(rid=r.rid, prompt=list(r.prompt), max_new_tokens=8) for r in trace]
        )
        outs = res.pop("outputs")
        assert res["requests"] == 5
        assert all(len(outs[r.rid]) == 8 for r in trace)
        # engine-static shapes: ONE decode trace for the whole run,
        # prefill/graft once per page-aligned prompt bucket
        buckets = {bucket_len(len(r.prompt), KVQ.block) for r in trace}
        assert eng.trace_counts["decode"] == 1
        assert eng.trace_counts["prefill"] == len(buckets)
        assert eng.trace_counts["graft"] == len(buckets)
        # all pages returned once every sequence finished
        assert eng.alloc.used == 0 and eng.alloc.available == eng.n_pages
        # token-level agreement vs the fixed-batch oracle, teacher-forced
        ag = engine_token_agreement(model, params, trace, outs)
        assert ag["engine_tokens_compared"] == 40
        assert ag["engine_token_agreement"] >= 0.99
        # free-running comparison against per-request fixed-batch decode
        matches = total = 0
        for r in trace:
            ref = _oracle_generate(model, params, r.prompt, 8)
            matches += sum(int(a == b) for a, b in zip(ref, outs[r.rid]))
            total += 8
        assert matches / total >= 0.9


def test_engine_no_cross_sequence_leakage(served):
    """A request decodes the identical token stream whether it runs alone
    or packed into the slot pool beside other sequences — pages freed by
    one sequence and reused by another never leak KV rows."""
    cfg, model, params = served
    probe = Request(rid=100, prompt=[5, 17, 9, 63, 2, 41, 8], max_new_tokens=6)
    with kv_quant_scope(KVQ):
        eng1 = PVQEngine(model, params, n_slots=2, max_len=32)
        alone = eng1.run([Request(rid=100, prompt=list(probe.prompt), max_new_tokens=6)])
        eng2 = PVQEngine(model, params, n_slots=2, max_len=32, n_pages=5)
        others = poisson_trace(
            4, rate=0.0, vocab=cfg.vocab_size, prompt_lens=(4, 12),
            max_new=6, seed=11,
        )
        crowd = [Request(rid=100, prompt=list(probe.prompt), max_new_tokens=6)] + others
        packed = eng2.run(crowd)
        assert eng2.stats["evictions"] >= 0  # oversubscribed pool in play
        assert packed["requests"] == 5
    assert alone["outputs"][100] == packed["outputs"][100]


def test_engine_eviction_requeue_completes(served):
    """An oversubscribed page pool forces evictions; evicted requests are
    requeued with their generated prefix intact and still finish with
    oracle-agreeing tokens."""
    from repro.launch.serve import engine_token_agreement

    cfg, model, params = served
    with kv_quant_scope(KVQ):
        trace = poisson_trace(
            6, rate=0.0, vocab=cfg.vocab_size, prompt_lens=(6, 14),
            max_new=10, seed=7,
        )
        # max_len 32 -> 4 pages/slot; 3 slots want 12 pages, give 5
        eng = PVQEngine(model, params, n_slots=3, max_len=32, n_pages=5)
        res = eng.run(trace)
        outs = res.pop("outputs")
        assert res["evictions"] > 0
        assert res["requests"] == 6
        assert all(len(outs[r.rid]) == 10 for r in trace)
        assert eng.alloc.used == 0
        ag = engine_token_agreement(model, params, trace, outs)
        assert ag["engine_token_agreement"] >= 0.99


def test_engine_eos_and_max_tokens_stopping(served):
    """Per-sequence stopping: a slot retires on its own EOS (freeing its
    pages immediately) and the remaining sequences are numerically
    untouched — their streams equal the truncation-free run's."""
    cfg, model, params = served
    with kv_quant_scope(KVQ):
        trace = poisson_trace(
            4, rate=0.0, vocab=cfg.vocab_size, prompt_lens=(4, 10),
            max_new=8, seed=5,
        )
        eng = PVQEngine(model, params, n_slots=4, max_len=32)
        free_run = eng.run([Request(rid=r.rid, prompt=list(r.prompt), max_new_tokens=8) for r in trace])
        # pick an EOS id that appears mid-stream for at least one request
        eos = None
        for r in trace:
            gen = free_run["outputs"][r.rid]
            for tok in gen[:-1]:
                if tok != gen[-1]:
                    eos = tok
                    break
            if eos is not None:
                break
        assert eos is not None
        eng2 = PVQEngine(model, params, n_slots=4, max_len=32)
        stopped = eng2.run(
            [Request(rid=r.rid, prompt=list(r.prompt), max_new_tokens=8, eos_id=eos) for r in trace]
        )
        truncated_any = False
        for r in trace:
            full = free_run["outputs"][r.rid]
            got = stopped["outputs"][r.rid]
            expect = full[: full.index(eos) + 1] if eos in full else full
            assert got == expect
            truncated_any |= len(got) < len(full)
        assert truncated_any
        assert eng2.alloc.used == 0


def test_engine_requires_kv_quant_and_capacity(served):
    cfg, model, params = served
    with pytest.raises(ValueError):
        PVQEngine(model, params, n_slots=2, max_len=32)  # no KVQuant default
    with kv_quant_scope(KVQ):
        eng = PVQEngine(model, params, n_slots=2, max_len=16)
        with pytest.raises(ValueError):
            eng.validate(Request(rid=0, prompt=[1] * 12, max_new_tokens=8))
        with pytest.raises(ValueError):
            # single sequence could never fit: n_pages < max_pages
            PVQEngine(model, params, n_slots=2, max_len=32, n_pages=2)


# ---------------------------------------------------------------------------
# serve.generate compile-count regression (bucketing + shared jit)
# ---------------------------------------------------------------------------


def test_generate_decode_compiles_once_per_bucket(served):
    """generate() used to re-jit decode_step per call (every call
    retraced) and key compiles on the exact cache_len.  With the shared
    per-model jit + kv-block bucketing, nearby cache lengths and repeat
    calls reuse one compiled step."""
    from repro.launch import serve

    cfg, model, params = served
    tokens = jnp.asarray([[3, 1, 4, 1, 5, 9]], jnp.int32)
    before = serve.TRACE_COUNTS["decode_step"]
    serve.generate(model, params, tokens, gen=2, cache_len=20)
    first = serve.TRACE_COUNTS["decode_step"] - before
    assert first == 1
    # same bucket (32), different cache_len and a repeat call: no retrace
    serve.generate(model, params, tokens, gen=2, cache_len=25)
    serve.generate(model, params, tokens, gen=2, cache_len=20)
    assert serve.TRACE_COUNTS["decode_step"] - before == 1
    # a new bucket traces exactly once more
    serve.generate(model, params, tokens, gen=2, cache_len=40)
    assert serve.TRACE_COUNTS["decode_step"] - before == 2


# ---------------------------------------------------------------------------
# Engine telemetry: spans/gauges land in the registry, report gains the
# queue-wait / eviction-cost columns
# ---------------------------------------------------------------------------


def test_engine_telemetry_spans_gauges_and_report_fields(served, tmp_path):
    from repro.runtime import obs, telemetry

    cfg, model, params = served
    prev = obs.set_enabled(True)
    obs.registry().clear()
    try:
        with kv_quant_scope(KVQ):
            trace = poisson_trace(
                4, rate=0.0, vocab=cfg.vocab_size, prompt_lens=(4, 10),
                max_new=4, seed=13,
            )
            eng = PVQEngine(model, params, n_slots=2, max_len=24)
            res = eng.run(trace)
        # report: queue-wait + per-request eviction-cost accounting
        for key in ("queue_wait_p50_s", "queue_wait_p99_s",
                    "eviction_cost_total_s", "eviction_cost_p50_s"):
            assert key in res, key
        assert res["queue_wait_p50_s"] >= 0.0
        files = obs.registry().write(str(tmp_path))
        recs = telemetry.validate_metrics_jsonl(files["metrics"])
        names = {r["name"] for r in recs}
        assert {"engine.decode_steps", "engine.queue_depth",
                "engine.page_pool_free", "engine.admissions",
                "engine.request_latency_s", "engine.queue_wait_s",
                "engine.prefill_compute_s", "engine.chunk_wait_s"} <= names
        by_name = {r["name"]: r for r in recs if not r["labels"]}
        assert by_name["engine.admissions"]["value"] == 4
        assert by_name["engine.request_latency_s"]["count"] == 4
        events = telemetry.validate_chrome_trace(files["trace"])
        span_names = {e["name"] for e in events}
        assert set(telemetry.ENGINE_REQUIRED_SPANS) <= span_names
        # per-step counter tracks for the perfetto time series
        assert "engine.queue_depth" in {e["name"] for e in events if e["ph"] == "C"}
    finally:
        obs.set_enabled(prev)
        obs.registry().clear()


# ---------------------------------------------------------------------------
# Sharding rules for the slot-pool cache
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_cache_pspec_paged_rules():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import ShardingPolicy, cache_pspec

    mesh = _FakeMesh({"data": 4, "model": 2})
    pol = ShardingPolicy()
    # the physical page pool is shared across slots: replicated
    assert cache_pspec("seg0/b0/kv/k_pages", (2, 65, 8, 4, 64), mesh, pol) == P(
        None, None, None, None, None
    )
    assert cache_pspec("seg0/b0/kv/v_page_scales", (2, 65, 8, 4, 2), mesh, pol) == P(
        None, None, None, None, None
    )
    # slot-indexed children shard the slot axis like batch
    pt = cache_pspec("seg0/b0/kv/page_table", (2, 8, 16), mesh, pol)
    assert pt[1] in ("data", ("data",))
    wp = cache_pspec("seg0/b0/kv/write_page", (2, 8), mesh, pol)
    assert wp[1] in ("data", ("data",))
    tail = cache_pspec("seg0/b0/kv/tail_k", (2, 8, 8, 4, 64), mesh, pol)
    assert tail[1] in ("data", ("data",))


# ---------------------------------------------------------------------------
# Refcounted allocator + prefix index (host)
# ---------------------------------------------------------------------------


def test_page_allocator_refcount_and_prefix_index():
    """Shared pages survive their sharers' frees until the LAST reference
    drops; registered pages park in the cached pool (still indexed, still
    shareable) and are reclaimed LRU-first only when the free list dries
    up — at which point their index entries die with them."""
    al = PageAllocator(4)
    pid = al.alloc()
    al.register(pid, "key0")
    assert al.lookup("key0") == pid
    assert al.share(pid)  # rc 2
    assert al.refcount(pid) == 2
    al.free([pid])  # one sharer leaves: page must stay live
    assert al.refcount(pid) == 1
    assert al.lookup("key0") == pid
    al.free([pid])  # last reference: parks in the cached pool
    assert al.refcount(pid) == 0
    assert al.cached == 1 and al.available == 4
    assert al.share(pid)  # revive straight out of the cached pool
    assert al.refcount(pid) == 1 and al.cached == 0
    al.free([pid])
    with pytest.raises(ValueError):
        al.free([pid])  # rc already 0: still a double free
    rest = al.alloc_many(3)  # drains the free list
    assert rest is not None and pid not in rest
    assert al.alloc() == pid  # cached page reclaimed last...
    assert al.lookup("key0") is None  # ...and its index entry died
    assert al.alloc() is None


def test_page_allocator_register_first_writer_wins():
    al = PageAllocator(3)
    a, b = al.alloc(), al.alloc()
    al.register(a, "k")
    al.register(b, "k")  # duplicate content: the index keeps page a
    assert al.lookup("k") == a
    al.free([b])
    assert al.cached == 0  # b was never indexed -> plain free
    al.free([a])
    assert al.cached == 1


# ---------------------------------------------------------------------------
# Chunked graft vs the monolithic graft / from_dense oracle
# ---------------------------------------------------------------------------


def test_chunked_graft_bit_identical_to_monolithic():
    """Streaming a context through page-aligned graft_chunk calls leaves
    pool pages, scales, and the tail ring bit-identical to one
    whole-prompt graft (itself bit-identical to PackedKV.from_dense)."""
    n_kv, hd, L = 2, 16, 21  # 2 full blocks of 8 + 5-row tail
    blk = KVQ.block
    k, v = _dense_kv(2, 1, L, n_kv, hd)
    lb = bucket_len(L, blk)
    pad = lb - L
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    base = PagedKV.init(2, 6, 4, n_kv, hd, kvq=KVQ, dtype=jnp.float32)
    ids = [3, 0, base.trash_page]
    mono = base.graft(
        kp, vp, jnp.int32(1), jnp.asarray(ids, jnp.int32), jnp.int32(L)
    )
    chunked = base
    for ci, start in enumerate(range(0, lb, blk)):  # one page per chunk
        chunked = chunked.graft_chunk(
            kp[:, start : start + blk], vp[:, start : start + blk],
            jnp.int32(1), jnp.asarray([ids[ci]], jnp.int32),
            jnp.int32(start), jnp.int32(L),
        )
    for name in ("k_pages", "k_page_scales", "v_pages", "v_page_scales",
                 "tail_k", "tail_v"):
        np.testing.assert_array_equal(
            np.asarray(getattr(mono, name)), np.asarray(getattr(chunked, name)),
            err_msg=name,
        )


# ---------------------------------------------------------------------------
# Batched admission / chunked prefill / prefix cache (engine end-to-end)
# ---------------------------------------------------------------------------


def test_engine_batched_admission_single_compile(served):
    """N same-bucket requests are batch-claimed FIFO and admitted through
    ONE multi-row prefill + ONE batched graft compile; after warmup the
    run adds zero traces, and tokens still agree with the oracle."""
    from repro.launch.serve import engine_token_agreement

    cfg, model, params = served
    with kv_quant_scope(KVQ):
        trace = poisson_trace(  # prompts 9..13 all share bucket 16
            3, rate=0.0, vocab=cfg.vocab_size, prompt_lens=(9, 13),
            max_new=6, seed=21,
        )
        eng = PVQEngine(model, params, n_slots=3, max_len=32, prefill_batch=3)
        eng.warmup(prompt_lens=[len(r.prompt) for r in trace])
        warm = dict(eng.trace_counts)
        assert warm["prefill"] == 1 and warm["graft"] == 1
        res = eng.run(trace)
        outs = res.pop("outputs")
        assert res["requests"] == 3
        assert eng.trace_counts == warm  # zero new compiles after warmup
        assert res["prefill_batches"] == 1  # one admission wave
        assert res["prefill_rows"] == 3
        assert eng.alloc.used == 0
        ag = engine_token_agreement(model, params, trace, outs)
        assert ag["engine_token_agreement"] >= 0.99


def test_engine_chunked_prefill_agreement_and_compiles(served):
    """Long prompts stream through the chunked path interleaved with
    decode: ONE decode trace, ONE chunk trace (static chunk shape) for
    the whole ragged-length run, oracle-agreeing tokens, and the report
    carries the TTFT decomposition + interference columns."""
    from repro.launch.serve import engine_token_agreement

    cfg, model, params = served
    with kv_quant_scope(KVQ):
        # Chunked prefill reads already-quantized pages for the prompt
        # context (layer>=1 K/V of early positions), so tokens carry a
        # little more quantization noise than monolithic prefill; on the
        # random-init reduced model some seeds land on a near-tie argmax
        # flip.  Seed chosen for a flip-free trace.
        trace = poisson_trace(
            4, rate=0.0, vocab=cfg.vocab_size, prompt_lens=(12, 30),
            max_new=6, seed=29,
        )
        eng = PVQEngine(
            model, params, n_slots=2, max_len=48,
            prefill_chunk=1, prefill_batch=2,
        )
        eng.warmup(prompt_lens=[len(r.prompt) for r in trace])
        warm = dict(eng.trace_counts)
        assert warm["chunk"] == 1 and warm["decode"] == 1
        res = eng.run(trace)
        outs = res.pop("outputs")
        assert res["requests"] == 4
        assert eng.trace_counts == warm  # chunking adds no per-length traces
        assert res["chunks"] >= sum(
            -(-len(r.prompt) // eng.chunk_tokens) for r in trace
        ) - len(trace)  # every prompt needed multiple chunks
        assert eng.alloc.used == 0
        for key in ("prefill_compute_p50_s", "prefill_compute_p99_s",
                    "chunk_wait_p50_s", "chunk_wait_p99_s", "itl_p99_s",
                    "itl_with_prefill_p99_s", "prefix_hits", "chunks"):
            assert key in res, key
        ag = engine_token_agreement(model, params, trace, outs)
        assert ag["engine_token_agreement"] >= 0.99


def test_engine_prefix_cache_share_cow_and_leakage(served):
    """Two requests sharing a 16-token prefix serialized through one slot:
    the second admission maps the first's parked prefix pages (counted
    hits, zero recompute), the shared pages' pulse bytes are NEVER
    mutated by the second request's chunks/appends (copy-on-write by
    construction), its tokens agree with a no-sharing engine run alone
    (prefix-sharing leakage probe), and refcounts drain to zero."""
    cfg, model, params = served
    rng = np.random.default_rng(31)
    prefix = [int(x) for x in rng.integers(0, cfg.vocab_size, 16)]
    p0 = prefix + [7, 3, 11, 4]
    p1 = prefix + [9, 1, 13]
    with kv_quant_scope(KVQ):
        eng = PVQEngine(model, params, n_slots=1, max_len=32, prefill_chunk=1)
        eng.run([Request(rid=0, prompt=list(p0), max_new_tokens=5)])
        # rid 0 finished: its two registered prefix pages are parked
        keys = eng._prefix_keys(prefix)
        pids = [eng.alloc.lookup(k) for k in keys]
        assert len(pids) == 2 and None not in pids

        def page_bytes():
            leaves = [
                l for l in jax.tree.leaves(eng.cache, is_leaf=is_paged_kv)
                if is_paged_kv(l)
            ]
            out = []
            for leaf in leaves:
                for pid in pids:
                    out.append(np.asarray(
                        jax.device_get(leaf.k_pages[..., pid, :, :, :])
                    ))
                    out.append(np.asarray(
                        jax.device_get(leaf.v_pages[..., pid, :, :, :])
                    ))
            return out

        before = page_bytes()
        res = eng.run([Request(rid=1, prompt=list(p1), max_new_tokens=5)])
        outs = res.pop("outputs")
        assert res["prefix_hits"] == 2
        assert res["prefix_pages_shared"] == 2
        assert eng.alloc.used == 0  # all references drained
        # copy-on-write: the mapped pages' int8 pulses are bit-unchanged
        for a, b in zip(before, page_bytes()):
            np.testing.assert_array_equal(a, b)
        # leakage probe: same request, fresh engine, no sharing possible
        eng2 = PVQEngine(
            model, params, n_slots=1, max_len=32, prefill_chunk=1,
            prefix_cache=False,
        )
        alone = eng2.run([Request(rid=1, prompt=list(p1), max_new_tokens=5)])
        assert eng2.stats["prefix_hits"] == 0
        assert alone["outputs"][1] == outs[1]
