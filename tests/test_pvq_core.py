"""Unit + property tests for the PVQ core (paper §II-§V claims)."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    PVQCode,
    dot_op_counts,
    pvq_decode_grouped,
    pvq_dot,
    pvq_encode,
    pvq_encode_grouped,
    pvq_encode_np,
    pvq_quantize_direction,
)
from repro.core.pvq import _scales

jax.config.update("jax_enable_x64", False)


def _rand(n, seed=0, dist="laplace"):
    rng = np.random.default_rng(seed)
    if dist == "laplace":
        return rng.laplace(size=n).astype(np.float32)
    return rng.normal(size=n).astype(np.float32)


# ---------------------------------------------------------------------------
# The L1 constraint (paper eq. 1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k", [(8, 4), (16, 16), (64, 13), (128, 256), (7, 1)])
def test_l1_constraint(n, k):
    w = _rand(n, seed=n * k)
    y = np.asarray(pvq_quantize_direction(jnp.asarray(w), k))
    assert int(np.abs(y).sum()) == k


def test_null_vector_encodes_to_zero():
    code = pvq_encode(jnp.zeros(16), 8)
    assert int(jnp.abs(code.pulses).sum()) == 0
    assert float(code.scale) == 0.0
    np.testing.assert_allclose(np.asarray(code.dequantize()), np.zeros(16))


def test_signs_match_input():
    w = _rand(64, seed=3)
    y = np.asarray(pvq_quantize_direction(jnp.asarray(w), 32))
    nz = y != 0
    assert np.all(np.sign(y[nz]) == np.sign(w[nz]))


# ---------------------------------------------------------------------------
# Optimality of the greedy search vs exhaustive enumeration (small N, K)
# ---------------------------------------------------------------------------


def _all_points(n, k):
    """All integer vectors with L1 norm == k (brute force, tiny n/k)."""
    pts = []
    for mags in itertools.product(range(k + 1), repeat=n):
        if sum(mags) != k:
            continue
        signs_axes = [(1,) if m == 0 else (1, -1) for m in mags]
        for signs in itertools.product(*signs_axes):
            pts.append(tuple(m * s for m, s in zip(mags, signs)))
    return np.asarray(sorted(set(pts)), dtype=np.float64)


@pytest.mark.parametrize("n,k,seed", [(4, 3, 0), (4, 3, 1), (5, 4, 2), (3, 6, 3), (6, 2, 4)])
def test_greedy_matches_exhaustive_cosine(n, k, seed):
    """Greedy pulse search should find a direction whose cosine similarity to w
    is within float tolerance of the best over all of P(n,k)."""
    w = _rand(n, seed=seed).astype(np.float64)
    pts = _all_points(n, k)
    cos = (pts @ w) / (np.linalg.norm(pts, axis=1) * np.linalg.norm(w))
    best = cos.max()
    y = np.asarray(pvq_quantize_direction(jnp.asarray(w.astype(np.float32)), k)).astype(np.float64)
    got = (y @ w) / (np.linalg.norm(y) * np.linalg.norm(w))
    assert got >= best - 1e-5


# ---------------------------------------------------------------------------
# Scales: paper's rho and least-squares rho
# ---------------------------------------------------------------------------


def test_paper_scale_preserves_l2_norm():
    w = jnp.asarray(_rand(256, seed=7))
    code = pvq_encode(w, 64, scale_mode="paper")
    deq = code.dequantize()
    np.testing.assert_allclose(
        float(jnp.linalg.norm(deq)), float(jnp.linalg.norm(w)), rtol=1e-5
    )


def test_ls_scale_never_worse_than_paper():
    for seed in range(5):
        w = jnp.asarray(_rand(256, seed=seed))
        cp = pvq_encode(w, 64, scale_mode="paper")
        cl = pvq_encode(w, 64, scale_mode="ls")
        ep = float(jnp.linalg.norm(cp.dequantize() - w))
        el = float(jnp.linalg.norm(cl.dequantize() - w))
        assert el <= ep + 1e-6


def test_error_decreases_with_k():
    w = jnp.asarray(_rand(128, seed=11))
    errs = []
    for k in (8, 32, 128, 512):
        code = pvq_encode(w, k, scale_mode="ls")
        errs.append(float(jnp.linalg.norm(code.dequantize() - w)))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 0.10 * float(jnp.linalg.norm(w))


# ---------------------------------------------------------------------------
# Dot product (paper §III): exactness + op-count claim
# ---------------------------------------------------------------------------


def test_pvq_dot_matches_dequantized_dot():
    w = jnp.asarray(_rand(512, seed=5))
    x = jnp.asarray(_rand(512, seed=6, dist="normal"))
    code = pvq_encode(w, 128)
    np.testing.assert_allclose(
        float(pvq_dot(code, x)), float(code.dequantize() @ x), rtol=1e-5
    )


def test_opcount_claim():
    """Paper §III: dot with y_hat in P(N,K) costs K-1 adds/subs + 1 mul."""
    n, k = 1024, 128
    code = pvq_encode(jnp.asarray(_rand(n, seed=9)), k)
    c = dot_op_counts(code)
    assert c["pvq_adds"] == k - 1
    assert c["pvq_muls"] == 1
    assert c["naive_muls"] == n
    # the unit-pulse evaluation bound: nonzero coordinates <= K
    assert c["nonzero"] <= k


# ---------------------------------------------------------------------------
# Grouped encoding
# ---------------------------------------------------------------------------


def test_grouped_roundtrip_shape_and_constraint():
    w = jnp.asarray(_rand(1000, seed=13))
    code = pvq_encode_grouped(w, group=256, k=64)
    assert code.pulses.shape == (4, 256)
    sums = np.abs(np.asarray(code.pulses)).sum(axis=-1)
    assert list(sums) == [64, 64, 64, 64]
    deq = pvq_decode_grouped(code, 1000)
    assert deq.shape == (1000,)


def test_grouped_padding_zeros_get_no_pulses():
    w = jnp.asarray(_rand(130, seed=17))
    code = pvq_encode_grouped(w, group=128, k=32)
    # last group has 126 zero pads; pulses must concentrate in first 2 slots
    tail = np.asarray(code.pulses)[1, 2:]
    assert np.all(tail == 0)


# ---------------------------------------------------------------------------
# numpy reference agrees with JAX path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,seed", [(32, 8, 0), (64, 64, 1), (16, 40, 2)])
def test_np_and_jax_encoders_agree(n, k, seed):
    w = _rand(n, seed=seed)
    y_np, rho_np = pvq_encode_np(w, k)
    code = pvq_encode(jnp.asarray(w), k)
    np.testing.assert_array_equal(y_np, np.asarray(code.pulses))
    np.testing.assert_allclose(rho_np, float(code.scale), rtol=1e-5)


# ---------------------------------------------------------------------------
# Property-based invariants
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=64),
    k=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_prop_l1_norm_and_sign(n, k, seed):
    rng = np.random.default_rng(seed)
    w = rng.laplace(size=n).astype(np.float32)
    y = np.asarray(pvq_quantize_direction(jnp.asarray(w), k))
    assert int(np.abs(y).sum()) == k
    nz = y != 0
    assert np.all(np.sign(y[nz]) == np.sign(w[nz]))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=4, max_value=48),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_prop_k_equal_monotone_error(n, seed):
    """rel err at K=4N must be <= rel err at K=N (monotone refinement)."""
    rng = np.random.default_rng(seed)
    w = rng.laplace(size=n).astype(np.float32)
    if np.abs(w).sum() < 1e-6:
        return
    wj = jnp.asarray(w)
    e1 = float(jnp.linalg.norm(pvq_encode(wj, n, "ls").dequantize() - wj))
    e2 = float(jnp.linalg.norm(pvq_encode(wj, 4 * n, "ls").dequantize() - wj))
    assert e2 <= e1 + 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_scale_invariance_of_direction(seed):
    """PVQ direction must be invariant to positive rescaling of the input."""
    rng = np.random.default_rng(seed)
    w = rng.laplace(size=32).astype(np.float32)
    y1 = np.asarray(pvq_quantize_direction(jnp.asarray(w), 16))
    y2 = np.asarray(pvq_quantize_direction(jnp.asarray(w * 37.5), 16))
    np.testing.assert_array_equal(y1, y2)


# ---------------------------------------------------------------------------
# Batched encoding
# ---------------------------------------------------------------------------


def test_batched_encode_matches_loop():
    ws = np.stack([_rand(64, seed=s) for s in range(8)])
    code = pvq_encode(jnp.asarray(ws), 32)
    for i in range(8):
        ci = pvq_encode(jnp.asarray(ws[i]), 32)
        np.testing.assert_array_equal(np.asarray(code.pulses[i]), np.asarray(ci.pulses))
