"""Optional-hypothesis shim for the test suite.

The container may not ship ``hypothesis``; property tests degrade to skips
instead of breaking collection for the whole module.  Import from here:

    from _hyp import given, settings, st, HAVE_HYPOTHESIS
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    class _Strategy:
        """Placeholder strategy: accepts any spec, never drawn from."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _St:
        def __getattr__(self, name):
            return _Strategy()

    st = _St()
