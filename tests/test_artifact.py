"""The .pvqz compressed-artifact subsystem: vectorized bitstream codecs
(property round-trips vs the core.codes size models), the single-file
container (TOC/CRC/codec selection), the pvq-golomb checkpoint codec, and
the end-to-end export -> load -> serve bit-exactness guarantee."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import Checkpointer
from repro.checkpoint.artifact import (
    choose_codec,
    iter_pvqz,
    load_pvqz,
    read_toc,
    write_pvqz,
)
from repro.core import bitstream, codes
from repro.core.packed import (
    is_packed,
    pack_flat,
    pack_matmul,
    packed_leaves,
    packed_stats,
    pulse_groups,
    pulse_stream,
    quantize_params,
)
from repro.core.quantize import QuantPolicy


def _sparse_values(rng, n, density=0.25, lo=-130, hi=130):
    """Pulse-like test vector: mostly zeros, values spanning int8 overflow."""
    v = rng.integers(lo, hi + 1, size=n)
    return (v * (rng.random(n) < density)).astype(np.int64)


# ---------------------------------------------------------------------------
# bitstream: chunked codec round-trips + size-model exactness
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=st.integers(0, 3000), chunk=st.integers(1, 600), seed=st.integers(0, 2**31 - 1))
def test_prop_golomb_chunked_roundtrip(n, chunk, seed):
    v = _sparse_values(np.random.default_rng(seed), n)
    blob, offsets, nbits, chunk = bitstream.golomb_encode_chunked(v, chunk)
    # the stream size IS the core.codes size model, bit for bit
    assert nbits == int(codes.golomb_length(v).sum()) if n else nbits == 0
    got = bitstream.golomb_decode_chunked(blob, offsets, n, chunk)
    np.testing.assert_array_equal(got, v)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(0, 3000), chunk=st.integers(1, 600), seed=st.integers(0, 2**31 - 1))
def test_prop_rle_chunked_roundtrip(n, chunk, seed):
    v = _sparse_values(np.random.default_rng(seed), n, density=0.1)
    blob, offsets, nbits, n_pairs, chunk = bitstream.rle_encode_chunked(v, chunk)
    _, ref_bits, ref_pairs = codes.rle_encode(v)
    assert (nbits, n_pairs) == (ref_bits, ref_pairs)
    got = bitstream.rle_decode_chunked(blob, offsets, n_pairs, n, chunk)
    np.testing.assert_array_equal(got, v)


def test_golomb_stream_bytes_match_reference_encoder():
    """The vectorized packer emits the exact byte stream of the per-symbol
    reference encoder in core.codes."""
    rng = np.random.default_rng(0)
    v = _sparse_values(rng, 500)
    blob, _, nbits, _ = bitstream.golomb_encode_chunked(v, chunk=64)
    ref_blob, ref_bits = codes.golomb_encode(v)
    assert nbits == ref_bits
    assert blob.tobytes() == ref_blob


def test_rle_bits_size_model_exact():
    rng = np.random.default_rng(1)
    v = _sparse_values(rng, 700, density=0.15)
    _, nbits, _ = codes.rle_encode(v)
    assert codes.rle_bits(v) == nbits


@settings(max_examples=15, deadline=None)
@given(
    g=st.integers(1, 8),
    n=st.integers(2, 24),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_enum_groups_roundtrip(g, n, k, seed):
    """Random pyramids, including k_g < K (cancellation) and all-zero groups."""
    rng = np.random.default_rng(seed)
    rows = np.zeros((g, n), np.int64)
    for i in range(g):
        for _ in range(int(rng.integers(0, k + 1))):
            rows[i, rng.integers(0, n)] += int(rng.choice([-1, 1]))
    blob, total = bitstream.enum_encode_groups(rows, k)
    assert total == bitstream.enum_stream_bits(rows, k)
    assert len(blob) == -(-total // 8)
    got = bitstream.enum_decode_groups(blob, g, n, k, sub=bitstream.enum_sub_width(n))
    np.testing.assert_array_equal(got, rows)


def test_enum_rejects_overbudget_group():
    with pytest.raises(ValueError, match="exceeds k_max"):
        bitstream.enum_encode_groups(np.asarray([[3, -3]]), 4)


@pytest.mark.parametrize("codec", ["golomb", "rle", "nibble", "int8"])
def test_encode_pulses_roundtrip(codec):
    rng = np.random.default_rng(2)
    v = _sparse_values(rng, 41 * 16, density=0.3, lo=-7, hi=7).reshape(41, 16)
    blob, info = bitstream.encode_pulses(v, codec, k_max=32, chunk=100)
    np.testing.assert_array_equal(bitstream.decode_pulses(blob, info, 16), v)
    np.testing.assert_array_equal(bitstream.decode_pulses(blob, info), v.ravel())


def test_encode_pulses_nibble_rejects_wide_values():
    with pytest.raises(ValueError, match="nibble"):
        bitstream.encode_pulses(np.asarray([9]), "nibble")


def test_measured_bits_prices_every_codec():
    rng = np.random.default_rng(3)
    groups = _sparse_values(rng, 12 * 16, density=0.2, lo=-5, hi=5).reshape(12, 16)
    sizes = bitstream.measured_bits(
        groups.ravel(), group_matrix=groups, k_max=16
    )
    assert {"golomb", "rle", "int8", "nibble", "enum"} <= set(sizes)
    for codec in ("golomb", "rle", "nibble", "int8"):
        blob, info = bitstream.encode_pulses(groups, codec, k_max=16)
        assert info["nbits"] == sizes[codec], codec  # measured == produced


# ---------------------------------------------------------------------------
# pulse geometry: stream/group views drop structural padding
# ---------------------------------------------------------------------------


def test_pulse_stream_drops_matmul_padding():
    w = jax.random.laplace(jax.random.PRNGKey(0), (100, 24)) * 0.1
    pk = pack_matmul(w, group=64, n_over_k=2.0)  # k_pad=128: 28 pad rows
    stream = pulse_stream(pk)
    assert stream.size == 100 * 24  # logical numel only
    groups = pulse_groups(pk)
    assert groups.shape == (24 * 2, 64)
    # padded group rows carry the pad zeros the stream dropped
    assert np.abs(groups).sum() == np.abs(stream).sum()


def test_pulse_stream_flat_tail_padding():
    w = jax.random.normal(jax.random.PRNGKey(1), (10, 7)) * 0.1  # 70 % 16 != 0
    pk = pack_flat(w, group=16, n_over_k=1.0)
    assert pulse_stream(pk).size == 70
    assert pulse_groups(pk).shape == (5, 16)


# ---------------------------------------------------------------------------
# .pvqz container
# ---------------------------------------------------------------------------


def _mixed_tree():
    pk = pack_matmul(
        jax.random.laplace(jax.random.PRNGKey(2), (100, 72)) * 0.1,
        group=64, n_over_k=5.0,
    )
    pe = pack_flat(
        jax.random.normal(jax.random.PRNGKey(3), (64, 48)) * 0.02,
        group=256, n_over_k=0.5, row_align=48,
    )
    pk3 = pack_matmul(
        jax.random.laplace(jax.random.PRNGKey(4), (3, 64, 64)) * 0.1,
        group=64, n_over_k=2.0,
    )  # scan-stacked
    pc = pack_flat(jnp.full((256,), 0.01).at[3].set(10.0), group=256, n_over_k=1.0)
    assert int(jnp.max(jnp.abs(pc.pulses))) == 127  # K>127 clamp engaged
    return {
        "a": {"kernel": pk},
        "emb": {"embedding": pe},
        "stack": {"kernel": pk3},
        "clamp": {"kernel": pc},
        "ln": jnp.ones(64),
        "bf": (jnp.ones((4, 4), jnp.bfloat16) * 1.5),
        "step": jnp.int32(7),
    }


def _assert_packed_equal(a, b):
    assert is_packed(b)
    assert b.pulses.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(a.pulses), np.asarray(b.pulses))
    np.testing.assert_array_equal(np.asarray(a.scales), np.asarray(b.scales))
    assert (a.group, a.k, a.shape, a.dtype, a.layout, a.scale_mode) == (
        b.group, b.k, b.shape, b.dtype, b.layout, b.scale_mode
    )


def test_pvqz_roundtrip_bit_exact(tmp_path):
    """Every leaf kind — matmul/flat/stacked/K>127-clamped packed, raw f32,
    bf16, scalar — restores bit-exact with no re-encode."""
    tree = _mixed_tree()
    path = tmp_path / "m.pvqz"
    report = write_pvqz(path, tree, meta={"arch": "unit-test"})
    assert report["bits_per_weight"] < 8.0
    got = load_pvqz(path, target=tree)
    want_packed = packed_leaves(tree)
    got_packed = packed_leaves(got)
    assert set(got_packed) == set(want_packed)
    for key, a in want_packed.items():
        _assert_packed_equal(a, got_packed[key])
    np.testing.assert_array_equal(np.asarray(got["ln"]), np.ones(64))
    assert got["bf"].dtype == tree["bf"].dtype
    np.testing.assert_array_equal(
        np.asarray(got["bf"], np.float32), np.asarray(tree["bf"], np.float32)
    )
    assert int(got["step"]) == 7
    assert read_toc(path)["meta"]["arch"] == "unit-test"


def test_pvqz_expert_bank_roundtrip_bit_exact(tmp_path):
    """MoE expert banks: (E, d, f) and scan-stacked (R, E, d, f) packed
    leaves restore bit-exact per expert, with the stack geometry in the TOC."""
    from repro.core.quantize import QuantPolicy
    from repro.nn.moe import MoEConfig, init_moe

    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=32, capacity_factor=2.0,
                    group_size=32)
    p = init_moe(jax.random.PRNGKey(40), 16, cfg)
    p = jax.tree.map(lambda a: jnp.stack([a, a * 1.1]), p)  # scan stack
    tree = quantize_params(
        p, QuantPolicy(rules=(("kernel|experts", 2.0, 64),), scale_mode="ls")
    )
    want = packed_leaves(tree)
    assert {"wi_up_experts", "wi_gate_experts", "wo_experts"} <= set(want)
    path = tmp_path / "experts.pvqz"
    report = write_pvqz(path, tree)
    got = load_pvqz(path, target=tree)
    for key, a in want.items():
        _assert_packed_equal(a, packed_leaves(got)[key])
    # the TOC records the leading stack axes (scan repeats x expert axis)
    recs = {r["path"]: r for r in read_toc(path)["leaves"] if r["kind"] == "packed"}
    assert recs["wi_up_experts"]["stack"] == [2, 4]
    assert recs["wo_experts"]["stack"] == [2, 4]
    # per-leaf report covers the expert bank
    assert report["leaves"]["wi_up_experts"]["bits_per_weight"] < 8.0


@pytest.mark.parametrize("codec", ["golomb", "rle", "nibble", "int8"])
def test_pvqz_forced_codec_roundtrip(tmp_path, codec):
    pk = pack_matmul(
        jax.random.laplace(jax.random.PRNGKey(5), (64, 32)) * 0.1,
        group=64, n_over_k=5.0,
    )
    tree = {"w": {"kernel": pk}}
    report = write_pvqz(tmp_path / f"{codec}.pvqz", tree, codec=codec)
    assert report["leaves"]["w/kernel"]["codec"] == codec
    got = load_pvqz(tmp_path / f"{codec}.pvqz", target=tree)
    _assert_packed_equal(pk, got["w"]["kernel"])


def test_pvqz_enum_codec_roundtrip(tmp_path):
    """Small groups put the fixed-length enumeration stream within budget."""
    pk = pack_flat(
        jax.random.laplace(jax.random.PRNGKey(6), (40, 8)) * 0.1,
        group=8, n_over_k=2.0,
    )
    tree = {"w": {"kernel": pk}}
    report = write_pvqz(tmp_path / "e.pvqz", tree, codec="enum")
    assert report["leaves"]["w/kernel"]["codec"] == "enum"
    _assert_packed_equal(
        pk, load_pvqz(tmp_path / "e.pvqz", target=tree)["w"]["kernel"]
    )


def test_pvqz_auto_picks_measured_minimum():
    rng = np.random.default_rng(7)
    pk = pack_matmul(
        jax.random.laplace(jax.random.PRNGKey(8), (128, 32)) * 0.1,
        group=64, n_over_k=5.0,
    )
    stream, groups = pulse_stream(pk), pulse_groups(pk)
    codec, sizes = choose_codec(stream, groups, pk.k)
    assert "enum" in sizes  # priced alongside the entropy codecs
    assert sizes[codec] == min(sizes.values())


def test_pvqz_crc_detects_corruption(tmp_path):
    tree = {"w": {"kernel": pack_matmul(
        jax.random.laplace(jax.random.PRNGKey(9), (64, 32)) * 0.1,
        group=64, n_over_k=4.0,
    )}}
    path = tmp_path / "c.pvqz"
    write_pvqz(path, tree)
    raw = bytearray(path.read_bytes())
    raw[16] ^= 0xFF  # flip a pulse-stream byte
    path.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="CRC"):
        load_pvqz(path, target=tree)


def test_pvqz_failed_write_preserves_existing_artifact(tmp_path):
    """write_pvqz goes through tmp + atomic rename: a write that dies midway
    (here: a forced codec that rejects the leaf) must leave the previous
    good artifact untouched and loadable."""
    pk = pack_flat(jnp.full((256,), 0.01).at[3].set(10.0), group=256, n_over_k=1.0)
    assert int(jnp.max(jnp.abs(pk.pulses))) > 7  # nibble codec will raise
    tree = {"w": {"kernel": pk}}
    path = tmp_path / "m.pvqz"
    write_pvqz(path, tree)
    good_bytes = path.read_bytes()
    with pytest.raises(ValueError, match="nibble"):
        write_pvqz(path, tree, codec="nibble")
    assert path.read_bytes() == good_bytes
    assert list(tmp_path.glob(".*tmp*")) == []  # failed write leaves no tmp
    _assert_packed_equal(pk, load_pvqz(path, target=tree)["w"]["kernel"])


def test_pvqz_rejects_non_artifact(tmp_path):
    path = tmp_path / "junk.pvqz"
    path.write_bytes(b"definitely not a pvqz file")
    with pytest.raises(ValueError, match="magic"):
        read_toc(path)


def test_iter_pvqz_streams_every_leaf(tmp_path):
    tree = _mixed_tree()
    path = tmp_path / "s.pvqz"
    write_pvqz(path, tree)
    seen = dict(iter_pvqz(path))
    assert len(seen) == 7
    assert is_packed(seen["a/kernel"])
    # nested load without a target
    nested = load_pvqz(path)
    assert is_packed(nested["a"]["kernel"])
    assert nested["stack"]["kernel"].pulses.shape == (3, 64, 64)


# ---------------------------------------------------------------------------
# checkpointer pvq-golomb codec
# ---------------------------------------------------------------------------


def test_checkpoint_pvq_golomb_bit_exact(tmp_path):
    pk = pack_matmul(
        jax.random.laplace(jax.random.PRNGKey(10), (100, 72)) * 0.1,
        group=64, n_over_k=4.0,
    )
    pe = pack_flat(
        jax.random.normal(jax.random.PRNGKey(11), (64, 32)) * 0.02,
        group=32, n_over_k=0.5, row_align=32,
    )
    state = {"params": {"w": {"kernel": pk}, "emb": {"embedding": pe}},
             "step": jnp.int32(3)}
    ck = Checkpointer(tmp_path, packed_codec="golomb")
    ck.save(1, state)
    restored, _ = ck.restore(state)
    _assert_packed_equal(pk, restored["params"]["w"]["kernel"])
    _assert_packed_equal(pe, restored["params"]["emb"]["embedding"])
    man = json.loads((tmp_path / "step_000000001" / "manifest.json").read_text())
    assert man["leaves"]["params/w/kernel"]["codec"] == "pvq-golomb"
    # entropy coding beats the nibble pack at rest (K/N = 1/4 here)
    golomb_bytes = (tmp_path / "step_000000001" / "params__w__kernel.pulses.bin").stat().st_size
    assert golomb_bytes < np.asarray(pk.pulses).size / 2  # nibble = size/2


def test_checkpointer_rejects_unknown_packed_codec(tmp_path):
    with pytest.raises(ValueError, match="packed_codec"):
        Checkpointer(tmp_path, packed_codec="zstd")


# ---------------------------------------------------------------------------
# end to end: export -> load -> serve, bit-exact vs the in-memory artifact
# ---------------------------------------------------------------------------


def test_export_load_serve_logits_bit_exact(tmp_path):
    """The acceptance gate: a .pvqz written from a packed model and loaded
    back serves IDENTICAL logits to the in-memory PackedPVQ pytree — the
    pulses/scales survive the entropy coding bit-for-bit."""
    from repro.configs import get_config
    from repro.nn.models import build_model

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=16)
    policy = QuantPolicy(
        rules=(("embedding", 0.5, 256), ("kernel", 1.0, 256)), scale_mode="ls"
    )
    qparams = quantize_params(params, policy)
    path = tmp_path / "model.pvqz"
    report = write_pvqz(path, qparams, meta={"arch": cfg.name})
    assert report["packed_numel"] > 0

    # load into a FRESH init (different seed: every leaf must come from disk)
    target = model.init(jax.random.PRNGKey(123), max_seq=16)
    restored = load_pvqz(path, target=target)
    for key, want in packed_leaves(qparams).items():
        _assert_packed_equal(want, packed_leaves(restored)[key])

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab_size)
    logits_mem, _ = model.prefill(qparams, {"tokens": toks}, cache_len=8)
    logits_art, _ = model.prefill(restored, {"tokens": toks}, cache_len=8)
    np.testing.assert_array_equal(np.asarray(logits_mem), np.asarray(logits_art))


def test_paper_net_fc_exports_under_2_bits(tmp_path):
    """§VI acceptance: a paper-net FC layer at N/K = 5 lands at
    <= 2.0 bits/weight in the artifact (paper Table 5: ~1.4 + scales)."""
    from repro.configs.paper_nets import PAPER_NETS
    from repro.nn.sequential import SequentialNet

    net = SequentialNet(PAPER_NETS["A"])
    params = net.init(jax.random.PRNGKey(0))
    kparams = net.pvq_kernel_encode(params, group=256)
    merged = dict(params)
    merged.update(kparams)
    report = write_pvqz(tmp_path / "a.pvqz", merged)
    assert report["bits_per_weight"] <= 2.0, report["bits_per_weight"]
    # and it restores bit-exact
    got = load_pvqz(tmp_path / "a.pvqz", target=merged)
    for key, want in packed_leaves(merged).items():
        _assert_packed_equal(want, packed_leaves(got)[key])


def test_packed_stats_entropy_matches_artifact(tmp_path):
    """The packed_stats size models ARE the .pvqz payload (golomb leaf)."""
    tree = {"a": {"kernel": jax.random.laplace(jax.random.PRNGKey(12), (128, 64)) * 0.1}}
    q = quantize_params(tree, QuantPolicy(rules=(("", 5.0, 64),), scale_mode="ls"))
    st_ = packed_stats(q)
    assert {"golomb_bits_per_weight", "rle_bits_per_weight",
            "enum_bits_per_weight", "entropy_bits_per_weight"} <= set(st_)
    report = write_pvqz(tmp_path / "x.pvqz", q, codec="golomb")
    got_bits = sum(v["pulse_bits"] for v in report["leaves"].values())
    assert got_bits == int(round(st_["golomb_bits_per_weight"] * 128 * 64))
    # entropy coding strictly beats the int8+f32 HBM footprint at rest
    assert st_["entropy_compression_ratio"] > st_["weight_compression_ratio"]
