"""Roofline parsing + data pipeline + optimizer unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.roofline import (
    HBM_BW,
    PEAK_FLOPS,
    analyze_corrected,
    collective_bytes,
    pvq_bytes_per_weight,
)
from repro.data import ClassifyTask, Prefetcher, TokenLoader, TokenTask
from repro.optim import AdamW, cosine_schedule, global_norm


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule test
ENTRY %main {
  %p0 = bf16[1024,512]{1,0} parameter(0)
  %ag = bf16[16384,512]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[1024,512]{1,0} all-reduce(%conv), to_apply=%sum
  %rs = f32[64,512]{1,0} reduce-scatter(%big), dimensions={0}
  %cp = bf16[1024,512]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
  %a2a = bf16[1024,512]{1,0} all-to-all(%p0), dimensions={0}
  %dot = f32[1024,1024]{1,0} dot(%p0, %p0)
}
"""


def test_collective_bytes_parsing():
    out = collective_bytes(HLO_SAMPLE)
    assert out["per_kind_counts"]["all-gather"] == 1
    assert out["per_kind_bytes"]["all-gather"] == 16384 * 512 * 2
    # all-reduce counted 2x (ring RS+AG)
    assert out["per_kind_bytes"]["all-reduce"] == 2 * 1024 * 512 * 4
    assert out["per_kind_counts"]["collective-permute"] == 1
    assert out["per_kind_counts"]["all-to-all"] == 1
    # dot must NOT be counted
    assert out["total_bytes"] == (
        16384 * 512 * 2 + 2 * 1024 * 512 * 4 + 64 * 512 * 4 + 1024 * 512 * 2 * 2
    )


def test_analyze_corrected_bottleneck():
    roof = analyze_corrected(
        flops=1e15, hbm_bytes=1e11, coll={"total_bytes": 1e12, "per_kind_bytes": {}, "per_kind_counts": {}},
        chips=256, model_flops=2e17,
    )
    assert roof.compute_s == pytest.approx(1e15 / PEAK_FLOPS)
    assert roof.memory_s == pytest.approx(1e11 / HBM_BW)
    assert roof.bottleneck == "collective"
    assert roof.useful_ratio == pytest.approx(2e17 / (1e15 * 256))


def test_pvq_bytes_per_weight():
    assert pvq_bytes_per_weight(256) == pytest.approx(1.015625)
    assert pvq_bytes_per_weight(256, nibble=True) == pytest.approx(0.515625)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_token_task_is_learnable_structure():
    task = TokenTask(vocab_size=64, seed=0)
    rng = np.random.default_rng(0)
    b = task.sample(rng, 8, 128)
    assert b["tokens"].shape == (8, 128)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    # chain structure: successors of a token concentrate on `branch` values
    succ_counts = {}
    toks, tgts = b["tokens"].ravel(), b["targets"].ravel()
    for t, n in zip(toks, tgts):
        succ_counts.setdefault(int(t), set()).add(int(n))
    common = [len(v) for k, v in succ_counts.items() if len(succ_counts[k]) > 0]
    assert np.median(common) <= task.branch + 8  # chain + unigram leakage


def test_loader_deterministic_restart():
    task = TokenTask(vocab_size=32, seed=1)
    l1 = TokenLoader(task, batch=4, seq=16, seed=7)
    l2 = TokenLoader(task, batch=4, seq=16, seed=7)
    b1 = l1.host_batch(42)
    b2 = l2.host_batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = l1.host_batch(43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_prefetcher_orders_batches():
    seen = []

    def make(step):
        return step * 10

    pf = Prefetcher(make, depth=2, start_step=5)
    vals = [pf.next() for _ in range(4)]
    pf.close()
    assert vals == [50, 60, 70, 80]


def test_classify_task_snr():
    task = ClassifyTask((64,), n_classes=4, noise=0.1, seed=0)
    rng = np.random.default_rng(0)
    b = task.sample(rng, 256)
    # at low noise, nearest-prototype classification is near-perfect
    d = ((b["x"][:, None, :] - task.prototypes[None]) ** 2).sum(-1)
    pred = d.argmin(1)
    assert (pred == b["y"]).mean() > 0.95


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    w = {"w": jnp.ones(8) * 5}
    st = opt.init(w)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
        w, st, _ = opt.update(g, st, w)
    assert float(jnp.max(jnp.abs(w["w"]))) < 1e-2


def test_adamw_weight_decay_only_matrices():
    opt = AdamW(lr=0.0, weight_decay=0.5, clip_norm=None)  # lr=0: pure decay visibility
    w = {"mat": jnp.ones((4, 4)), "vec": jnp.ones(4)}
    st = opt.init(w)
    g = jax.tree.map(jnp.zeros_like, w)
    w2, _, _ = opt.update(g, st, w)
    np.testing.assert_array_equal(np.asarray(w2["vec"]), 1.0)  # vectors not decayed
    np.testing.assert_array_equal(np.asarray(w2["mat"]), 1.0)  # lr=0 -> no change either


def test_grad_clipping():
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    w = {"w": jnp.zeros(4)}
    st = opt.init(w)
    g = {"w": jnp.ones(4) * 1e6}
    _, _, gnorm = opt.update(g, st, w)
    assert float(gnorm) == pytest.approx(2e6, rel=1e-3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert float(lr(jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(jnp.int32(55))) < float(lr(jnp.int32(20)))
