"""Fault-tolerance runner: checkpoint/restart on injected failures,
deterministic data resume, straggler flagging, elastic re-mesh planning."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.data import TokenLoader, TokenTask
from repro.optim import AdamW
from repro.runtime.fault_tolerance import ElasticPlan, StragglerPolicy, TrainingRunner


class ToyLoader:
    """Deterministic batch(step); counts calls for resume verification."""

    def __init__(self, dim=8):
        self.dim = dim
        self.calls = []

    def device_batch(self, step):
        self.calls.append(step)
        rng = np.random.default_rng(step)
        x = rng.normal(size=(4, self.dim)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(x.sum(-1, keepdims=True))}


def _toy_step():
    opt = AdamW(lr=1e-2, weight_decay=0.0)

    @jax.jit
    def step_fn(state, batch):
        params, opt_state = state

        def loss_fn(p):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["y"]) ** 2)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, gn = opt.update(grads, opt_state, params)
        return (params, opt_state), {"loss": loss, "grad_norm": gn}

    params = {"w": jnp.zeros((8, 1))}
    return step_fn, (params, opt.init(params))


def test_runner_trains_and_checkpoints(tmp_path):
    step_fn, state = _toy_step()
    loader = ToyLoader()
    ck = Checkpointer(tmp_path)
    runner = TrainingRunner(step_fn, state, loader, ck, ckpt_every=10)
    runner.run(40)
    assert runner.history[0]["loss"] > runner.history[-1]["loss"]
    assert ck.latest_step() == 39


def test_runner_recovers_from_injected_failures(tmp_path):
    step_fn, state = _toy_step()
    loader = ToyLoader()
    ck = Checkpointer(tmp_path)
    runner = TrainingRunner(step_fn, state, loader, ck, ckpt_every=5)

    crashed = {"done": False}

    def injector(step):
        if step == 17 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure")

    end = runner.run(30, failure_injector=injector)
    assert end == 30
    assert runner.restores == 1
    # must have resumed from the last committed step (14), i.e. step 15 reran
    resumed = [s for s in loader.calls if s == 15]
    assert len(resumed) >= 2 or 15 not in loader.calls[:16]
    # loss still decreased overall
    assert runner.history[-1]["loss"] < runner.history[0]["loss"]


def test_resume_across_runner_instances(tmp_path):
    """Simulates a full job restart: new runner picks up where the old died."""
    step_fn, state = _toy_step()
    ck = Checkpointer(tmp_path)
    r1 = TrainingRunner(step_fn, state, ToyLoader(), ck, ckpt_every=10)
    r1.run(20)
    final_w = np.asarray(r1.state[0]["w"]).copy()

    step_fn2, fresh_state = _toy_step()
    r2 = TrainingRunner(step_fn2, fresh_state, ToyLoader(), ck, ckpt_every=10)
    start = r2.resume_step()
    assert start == 20
    np.testing.assert_allclose(np.asarray(r2.state[0]["w"]), final_w, rtol=1e-6)


def test_straggler_flagging():
    pol = StragglerPolicy(window=16, factor=3.0)
    for s in range(12):
        pol.observe(s, 0.1)
    assert pol.observe(12, 0.9)  # 9x median -> flagged
    assert not pol.observe(13, 0.12)
    assert len(pol.flagged) == 1


def test_elastic_plan_divisibility():
    plan = ElasticPlan(global_batch=256)
    assert plan.pick(256) == (16, 16)
    assert plan.pick(255) == (8, 16)   # lost a chip -> half-data mesh
    assert plan.pick(128) == (8, 16)
    assert plan.pick(17) == (1, 16)
    assert plan.pick(8) is None        # nothing fits

    plan_odd = ElasticPlan(global_batch=24)  # batch forbids d=16
    assert plan_odd.pick(256) == (8, 16)
