"""End-to-end integration tests: train driver, serve driver, paper pipeline
(fast settings), and quantized-serving equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import TokenLoader, TokenTask
from repro.launch.serve import generate
from repro.launch.train import make_state_and_step
from repro.nn.models import build_model
from repro.optim import AdamW
from repro.runtime.fault_tolerance import TrainingRunner


def test_train_loop_loss_decreases(tmp_path):
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state, step_fn = make_state_and_step(model, opt)
    task = TokenTask(cfg.vocab_size, seed=0)
    loader = TokenLoader(task, batch=8, seq=32, seed=0)
    runner = TrainingRunner(step_fn, state, loader, Checkpointer(tmp_path), ckpt_every=25)
    runner.run(50)
    first = np.mean([h["loss"] for h in runner.history[:10]])
    last = np.mean([h["loss"] for h in runner.history[-10:]])
    assert last < first - 0.05


def test_pvq_qat_trains(tmp_path):
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    opt = AdamW(lr=3e-3, weight_decay=0.0)
    state, step_fn = make_state_and_step(model, opt, pvq_qat=True, pvq_k=128, pvq_group=256)
    task = TokenTask(cfg.vocab_size, seed=1)
    loader = TokenLoader(task, batch=8, seq=32, seed=1)
    runner = TrainingRunner(step_fn, state, loader, Checkpointer(tmp_path), ckpt_every=0)
    runner.run(30)
    assert runner.history[-1]["loss"] < runner.history[0]["loss"]
    assert np.isfinite(runner.history[-1]["grad_norm"])


@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-1.6b", "jamba-1.5-large-398b"])
def test_generate_roundtrip(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=48)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = generate(model, params, toks, gen=6, cache_len=16)
    assert out.shape == (2, 14)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_quantized_vs_float_generation_agreement():
    """At K=4N the PVQ-quantized model must generate near-identical tokens."""
    from repro.core.quantize import QuantPolicy, quantize_tree

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=32)
    qparams, codes, _ = quantize_tree(
        params, QuantPolicy(rules=(("", 0.25, 256),), scale_mode="ls")
    )
    assert codes, "nothing was quantized"
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab_size)
    out_f = generate(model, params, toks, gen=4, cache_len=16)
    out_q = generate(model, qparams, toks, gen=4, cache_len=16)
    agree = float(jnp.mean((out_f == out_q).astype(jnp.float32)))
    assert agree >= 0.75  # tiny logits gaps may flip rare argmax ties


def test_paper_pipeline_fast():
    from repro.paper.experiment import run_net

    r = run_net("A", steps=60, check_fold=True)
    assert r.acc_before > 0.5
    assert r.acc_after > 0.3
    assert r.fold_check["argmax_agreement"] > 0.99
    for lname, tab in r.weight_tables.items():
        assert tab["0_pct"] > 60  # N/K=5 -> sparse pulses
