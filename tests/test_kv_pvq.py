"""The PVQ-compressed KV cache (kernel v4): PackedKV container semantics,
packed-vs-f32 decode_attention agreement across GQA group counts and ragged
lengths, the in-flight partial tail block, the f32-cache dtype regression,
and kernel-version-keyed autotune invalidation."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed import PackedKV, is_packed_kv
from repro.core.quantize import KVQuant, default_kv_quant, kv_quant_scope
from repro.nn import attention as A

KVQ = KVQuant(block=32, group=32, k=127)


def _dense_kv(seed, b, s, n_kv, hd, scale=1.0):
    kk, kv = jax.random.split(jax.random.PRNGKey(seed))
    k = jax.random.normal(kk, (b, s, n_kv, hd), jnp.float32) * scale
    v = jax.random.normal(kv, (b, s, n_kv, hd), jnp.float32) * scale
    return k, v


# ---------------------------------------------------------------------------
# PackedKV container: roundtrip bound, tail exactness, append == from_dense
# ---------------------------------------------------------------------------


def test_packed_kv_roundtrip_bound_per_block():
    """Dequantized full blocks stay within a uniform relative error bound;
    the tail (partial block) region is EXACT (it is stored f32)."""
    b, s, n_kv, hd = 2, 71, 2, 64  # 2 full blocks + 7-row tail
    k, v = _dense_kv(0, b, s, n_kv, hd)
    pkv = PackedKV.from_dense(k, v, kvq=KVQ, dtype=jnp.float32)
    kd, vd = pkv.dense_kv(jnp.full((b,), s))
    pe = 64  # packed_end(71)
    # packed region: bounded relative error per (token, head, group) row
    for orig, deq in ((k, kd), (v, vd)):
        num = jnp.linalg.norm(deq[:, :pe] - orig[:, :pe])
        den = jnp.linalg.norm(orig[:, :pe])
        assert float(num / den) < 0.12
    # tail region: bit-exact f32
    np.testing.assert_array_equal(np.asarray(kd[:, pe:s]), np.asarray(k[:, pe:s]))
    np.testing.assert_array_equal(np.asarray(vd[:, pe:s]), np.asarray(v[:, pe:s]))


def test_packed_kv_append_matches_from_dense():
    """Streaming appends (with the encode-on-block-fill lax.cond) land in
    the same planes/tail as a one-shot from_dense of the same rows."""
    b, s, n_kv, hd = 1, 40, 2, 32  # crosses one block boundary at 32
    k, v = _dense_kv(1, b, s, n_kv, hd)
    ref = PackedKV.from_dense(k, v, kvq=KVQ, dtype=jnp.float32)

    pkv = PackedKV.init(b, 64, n_kv, hd, kvq=KVQ, dtype=jnp.float32)
    step = jax.jit(lambda c, kn, vn, p: c.append(kn, vn, p))
    for pos in range(s):
        pkv = step(pkv, k[:, pos : pos + 1], v[:, pos : pos + 1], pos)

    kd_a, vd_a = pkv.dense_kv(jnp.full((b,), s))
    kd_r, vd_r = ref.dense_kv(jnp.full((b,), s))
    np.testing.assert_allclose(
        np.asarray(kd_a[:, :s]), np.asarray(kd_r[:, :s]), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(vd_a[:, :s]), np.asarray(vd_r[:, :s]), atol=1e-5
    )


def test_packed_kv_partial_tail_positions_exact():
    """Every position of the in-flight partial block reads back exactly —
    the ring-slot rule (slot = pos % block) at each fill level."""
    b, n_kv, hd = 1, 1, 32
    pkv = PackedKV.init(b, 64, n_kv, hd, kvq=KVQ, dtype=jnp.float32)
    rows = jax.random.normal(jax.random.PRNGKey(2), (40, 1, 1, n_kv, hd))
    step = jax.jit(lambda c, kn, p: c.append(kn, kn, p))
    for pos in range(40):
        pkv = step(pkv, rows[pos], pos)
        kd, _ = pkv.dense_kv(jnp.full((b,), pos + 1))
        pe = ((pos + 1) // 32) * 32
        for t in range(pe, pos + 1):
            np.testing.assert_array_equal(
                np.asarray(kd[:, t]), np.asarray(rows[t][:, 0])
            )


def test_packed_kv_bytes_per_token():
    pkv = PackedKV.init(1, 32, 2, 64, kvq=KVQ, dtype=jnp.float32)
    # per kv-head pair: K+V pulse bytes (hd each) + f32 scales (4 * hd/group)
    assert pkv.packed_bytes_per_token == 2 * (64 + 4 * 2)
    assert pkv.dense_bytes_per_token == 2 * 64 * 4
    assert pkv.packed_bytes_per_token / pkv.dense_bytes_per_token <= 0.35


def test_packed_kv_is_pytree_with_stable_keys():
    pkv = PackedKV.init(1, 32, 1, 32, kvq=KVQ)
    leaves = jax.tree_util.tree_leaves_with_path(pkv)
    names = {str(p[-1]) for p, _ in leaves}
    assert names == {
        "['k_pulses']", "['k_scales']", "['v_pulses']", "['v_scales']",
        "['tail_k']", "['tail_v']",
    }
    assert is_packed_kv(pkv) and not is_packed_kv({"k": 1})


# ---------------------------------------------------------------------------
# decode agreement: packed vs f32 across GQA group counts + ragged lengths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_heads,n_kv", [(4, 4), (8, 2), (8, 1)])
def test_decode_attention_packed_agrees_across_gqa(n_heads, n_kv):
    b, s, hd = 2, 96, 64
    k, v = _dense_kv(3, b, s, n_kv, hd)
    q = jax.random.normal(jax.random.PRNGKey(4), (b, 1, n_heads, hd))
    length = jnp.array([96, 50])  # ragged: one row mid-block
    scale = 1.0 / np.sqrt(hd)
    y_f = A.decode_attention(q, k, v, scale=scale, length=length)
    pkv = PackedKV.from_dense(k, v, kvq=KVQ, dtype=jnp.float32)
    y_p = A.decode_attention_packed(q, pkv, scale=scale, length=length)
    rel = float(jnp.linalg.norm(y_p - y_f) / jnp.linalg.norm(y_f))
    assert rel < 0.08, rel


def test_decode_attention_packed_ragged_short_lengths():
    """Lengths inside the first block: the packed leg is empty (l=0) and
    the tail-only merge must still be well-defined and close to f32."""
    b, s, n_kv, hd = 2, 32, 2, 32
    k, v = _dense_kv(5, b, s, n_kv, hd)
    q = jax.random.normal(jax.random.PRNGKey(6), (b, 1, 4, hd))
    length = jnp.array([7, 1])
    scale = 1.0 / np.sqrt(hd)
    # keep tail == raw rows so the comparison is exact up to fp noise
    pkv = PackedKV.from_dense(k[:, :31], v[:, :31], kvq=KVQ, dtype=jnp.float32)
    y_f = A.decode_attention(q, k[:, :31], v[:, :31], scale=scale, length=length)
    y_p = A.decode_attention_packed(q, pkv, scale=scale, length=length)
    assert bool(jnp.all(jnp.isfinite(y_p)))
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_f), atol=1e-4)


def test_decode_attention_packed_exact_oracle_matches_dense():
    """REPRO_KV_PVQ_EXACT routes through dense_kv + the dense decode — on a
    tail-only cache that equals the f32 reference to fp tolerance."""
    b, s, n_kv, hd = 1, 16, 2, 32
    k, v = _dense_kv(7, b, s, n_kv, hd)
    q = jax.random.normal(jax.random.PRNGKey(8), (b, 1, 4, hd))
    length = jnp.full((b,), s)
    pkv = PackedKV.from_dense(k, v, kvq=KVQ, dtype=jnp.float32)
    y_exact = A.decode_attention_packed(
        q, pkv, scale=0.125, length=length, exact=True
    )
    kd, vd = pkv.dense_kv(length)
    y_dense = A.decode_attention(q, kd, vd, scale=0.125, length=length)
    np.testing.assert_array_equal(np.asarray(y_exact), np.asarray(y_dense))


def test_attention_decode_full_loop_packed_vs_dense():
    """attention_decode end to end: packed cache output stays close to the
    dense-cache output across a block boundary, and the cache object stays
    a PackedKV (never silently expanded)."""
    b, d, nh, nkv, hd, L = 2, 64, 8, 2, 64, 80
    p = A.init_attention(jax.random.PRNGKey(9), d, nh, nkv, hd)
    cd = A.init_kv_cache(b, L, nkv, hd, jnp.float32, quantized=False)
    cp = A.init_kv_cache(b, L, nkv, hd, jnp.float32, quantized=KVQ)
    assert is_packed_kv(cp)
    step = jax.jit(
        lambda c, xt, pos: A.attention_decode(
            p, xt, c, pos, n_heads=nh, n_kv_heads=nkv, head_dim=hd
        )
    )
    for pos in range(40):
        xt = jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(10), pos), (b, 1, d)
        ) * 0.5
        yd, cd = step(cd, xt, pos)
        yp, cp = step(cp, xt, pos)
        assert is_packed_kv(cp)
        rel = float(jnp.linalg.norm(yp - yd) / jnp.linalg.norm(yd))
        assert rel < 0.1, (pos, rel)


# ---------------------------------------------------------------------------
# init_kv_cache contract: dtype regression + quantized selection
# ---------------------------------------------------------------------------


def test_init_kv_cache_f32_not_downcast_on_append():
    """Regression (satellite): an explicitly f32 cache stays f32 through the
    decode append even though the projections run in another dtype — the
    cast follows the CACHE dtype, never the projection dtype."""
    b, d, nh, nkv, hd = 1, 32, 2, 2, 16
    p = A.init_attention(jax.random.PRNGKey(11), d, nh, nkv, hd)
    p = jax.tree.map(lambda x: x.astype(jnp.bfloat16), p)
    cache = A.init_kv_cache(b, 8, nkv, hd, jnp.float32, quantized=False)
    x = jax.random.normal(jax.random.PRNGKey(12), (b, 1, d), jnp.bfloat16)
    _, cache = A.attention_decode(
        p, x, cache, 0, n_heads=nh, n_kv_heads=nkv, head_dim=hd
    )
    assert cache["k"].dtype == jnp.float32
    assert cache["v"].dtype == jnp.float32
    # and the packed cache's exact tail obeys the same rule
    pc = A.init_kv_cache(b, 32, nkv, hd, jnp.float32, quantized=KVQ)
    _, pc = A.attention_decode(
        p, x, pc, 0, n_heads=nh, n_kv_heads=nkv, head_dim=hd
    )
    assert pc.tail_k.dtype == jnp.float32


def test_init_kv_cache_default_dtype_is_bf16():
    cache = A.init_kv_cache(1, 8, 1, 16)
    assert cache["k"].dtype == jnp.bfloat16


def test_init_kv_cache_quantized_dispatch():
    """quantized=None defers to the process default; False forces dense even
    inside a kv_quant_scope (the cross-attention rule)."""
    assert default_kv_quant() is None
    assert not is_packed_kv(A.init_kv_cache(1, 32, 1, 32))
    with kv_quant_scope(KVQ):
        assert is_packed_kv(A.init_kv_cache(1, 32, 1, 32))
        assert not is_packed_kv(A.init_kv_cache(1, 32, 1, 32, quantized=False))
    assert not is_packed_kv(A.init_kv_cache(1, 32, 1, 32))
    assert is_packed_kv(A.init_kv_cache(1, 32, 1, 32, quantized=True))


def test_prefill_cache_packed_under_scope():
    b, s, d, nh, nkv, hd = 1, 40, 32, 4, 2, 16
    p = A.init_attention(jax.random.PRNGKey(13), d, nh, nkv, hd)
    x = jax.random.normal(jax.random.PRNGKey(14), (b, s, d))
    with kv_quant_scope(KVQ):
        c = A.attention_prefill_cache(
            p, x, n_heads=nh, n_kv_heads=nkv, head_dim=hd
        )
    assert is_packed_kv(c)
    assert c.k_pulses.shape[1] == 64  # block-rounded
    c2 = A.attention_prefill_cache(p, x, n_heads=nh, n_kv_heads=nkv, head_dim=hd)
    assert not is_packed_kv(c2)


# ---------------------------------------------------------------------------
# autotune: kv4 schema keys — kv3 entries can never serve v4 dispatch
# ---------------------------------------------------------------------------


def test_attn_autotune_kv3_entries_never_served(tmp_path, monkeypatch):
    from repro.kernels import autotune as at
    from repro.kernels.pvq_matmul import KERNEL_VERSION

    assert KERNEL_VERSION == 4
    path = tmp_path / "tune.json"
    backend = jax.default_backend()
    key_v4 = at.attn_cache_key(1, 64, 256, 32, jnp.int8, backend)
    assert ":kv4:" in key_v4
    stale = key_v4.replace(":kv4:", ":kv3:")
    path.write_text(json.dumps({
        stale: {"bs": 512, "us": 1.0, "candidates": 1},
    }))
    monkeypatch.setenv("REPRO_PVQ_AUTOTUNE", "0")
    monkeypatch.setenv("REPRO_PVQ_TUNE_CACHE", str(path))
    at.clear_memory_cache()
    try:
        # stale kv3 bs=512 must NOT be served: dispatch falls to the heuristic
        assert at.get_attn_tiles(1, 64, 256, group=32) == at.heuristic_attn_bs(256)
        # a genuine kv4 entry IS served
        path.write_text(json.dumps({
            stale: {"bs": 512, "us": 1.0, "candidates": 1},
            key_v4: {"bs": 256, "us": 1.0, "candidates": 1},
        }))
        at.clear_memory_cache()
        assert at.get_attn_tiles(1, 64, 256, group=32) == 256
        # same invariant for the matmul tiles (the v3->v4 bump invalidates
        # every tile timed against the pre-attention kernel body)
        mk = at.cache_key(8, 256, 128, 128, jnp.float32, backend)
        assert ":kv4:" in mk
    finally:
        at.clear_memory_cache()


def test_attn_autotune_persists_and_hits(tmp_path, monkeypatch):
    from repro.kernels import autotune as at

    monkeypatch.setenv("REPRO_PVQ_TUNE_CACHE", str(tmp_path / "t.json"))
    at.clear_memory_cache()
    try:
        e = at.autotune_attn(2, 32, 64, group=32, reps=1, max_candidates=2)
        assert e["bs"] >= 8
        # second call is a pure cache hit (same entry object contents)
        assert at.autotune_attn(2, 32, 64, group=32, reps=1) == e
        assert at.get_attn_tiles(2, 32, 64, group=32) == e["bs"]
    finally:
        at.clear_memory_cache()
