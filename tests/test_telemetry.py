"""Observability layer tests: instrument semantics (counters, gauges,
exact-reservoir histogram percentiles), the disabled registry's true-no-op
contract (NOOP identity + zero allocations in the engine decode-step guard
pattern), Chrome trace-event well-formedness, metrics-JSONL schema
round-trip, trace-count metric parity with the ``TRACE_COUNTS`` compile
regressions, autotune hit/miss lookup counters, and the quant-quality
probes' eager-only (never-inside-jit) behavior."""

import gc
import json
import os
import subprocess
import sys
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import obs, telemetry
from repro.runtime.telemetry import (
    HISTOGRAM_FIELDS,
    METRICS_SCHEMA,
    Histogram,
    MetricsRegistry,
    snr_db,
    validate_chrome_trace,
    validate_dir,
    validate_metrics_jsonl,
)


@pytest.fixture()
def enabled_registry():
    """Flip the module registry on for one test, restore + clear after."""
    prev = obs.set_enabled(True)
    obs.registry().clear()
    yield obs.registry()
    obs.set_enabled(prev)
    obs.registry().clear()


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------


def test_histogram_exact_percentiles_match_numpy():
    vals = list(np.random.default_rng(0).normal(size=513))
    h = Histogram.from_values(vals, name="x")
    assert h.exact
    assert h.count == len(vals)
    assert h.total == pytest.approx(sum(vals))
    assert h.min == min(vals) and h.max == max(vals)
    for q in (50, 90, 99):
        assert h.percentile(q) == pytest.approx(float(np.percentile(vals, q)))
    snap = h.snapshot()
    assert snap["kind"] == "histogram"
    for field in HISTOGRAM_FIELDS:
        assert field in snap


def test_histogram_reservoir_caps_storage_keeps_exact_aggregates():
    h = Histogram("y", max_samples=128)
    vals = list(range(1000))
    h.record_many(vals)
    assert not h.exact  # past the cap: percentiles become sampled
    assert len(h._values) == 128
    assert h.count == 1000  # ...but count/sum/min/max stay exact
    assert h.total == sum(vals)
    assert h.min == 0 and h.max == 999
    # reservoir keeps a uniform sample: p50 should be roughly central
    assert 250 < h.percentile(50) < 750
    # deterministic: same inputs reproduce the same reservoir
    h2 = Histogram("y", max_samples=128)
    h2.record_many(vals)
    assert h._values == h2._values


def test_histogram_empty_percentile_is_zero():
    assert Histogram("z").percentile(99) == 0.0


def test_counter_gauge_labels_and_snapshot():
    reg = MetricsRegistry(enabled=True)
    reg.counter("hits").inc()
    reg.counter("hits").inc(2)
    reg.counter("hits", {"codec": "zlib"}).inc()  # distinct label set
    reg.gauge("depth").set(3)
    reg.gauge("depth").set(1)
    snaps = {((r["name"],) + tuple(sorted(r["labels"].items()))): r
             for r in reg.snapshot()}
    assert snaps[("hits",)]["value"] == 3
    assert snaps[("hits", ("codec", "zlib"))]["value"] == 1
    g = snaps[("depth",)]
    assert g["value"] == 1 and g["min"] == 1 and g["max"] == 3 and g["n"] == 2
    assert all(r["schema"] == METRICS_SCHEMA for r in snaps.values())


def test_snr_db():
    x = np.ones(64)
    assert snr_db(x, x) == 99.0  # exact reconstruction hits the cap
    assert snr_db(x, x * 0.9) == pytest.approx(20.0)
    assert snr_db(np.zeros(4), np.ones(4)) == 0.0


# ---------------------------------------------------------------------------
# disabled registry: a true no-op
# ---------------------------------------------------------------------------


def test_disabled_registry_returns_noop_singleton():
    reg = MetricsRegistry(enabled=False)
    assert reg.counter("a") is telemetry.NOOP
    assert reg.gauge("b") is telemetry.NOOP
    assert reg.histogram("c") is telemetry.NOOP
    assert reg.span("d") is telemetry.NOOP
    with reg.span("d"):  # NOOP doubles as a context manager
        pass
    reg.trace_counter("e", 1.0)
    reg.event("f")
    assert reg.snapshot() == []
    assert reg.chrome_trace()["traceEvents"] == []


def test_disabled_decode_step_guard_pattern_allocates_nothing():
    """The exact instrumentation shape PVQEngine.step uses: when the
    registry is disabled, repeated steps must not accumulate memory (no
    instruments, no events, no per-step garbage retained)."""
    assert not obs.enabled()

    def step_hook():
        span = obs.NOOP
        if obs.enabled():
            span = obs.span("engine/decode_step", args={"active": 1})
        with span:
            pass
        if obs.enabled():
            obs.gauge("engine.queue_depth").set(0)
            obs.counter("engine.decode_steps").inc()

    step_hook()  # warm any lazy import/attribute state
    gc.collect()
    tracemalloc.start()
    base = tracemalloc.get_traced_memory()[0]
    for _ in range(5000):
        step_hook()
    gc.collect()
    grown = tracemalloc.get_traced_memory()[0] - base
    tracemalloc.stop()
    # even one retained object per step would be tens of KB over 5000 steps
    assert grown < 2048, f"disabled telemetry retained {grown} bytes"


# ---------------------------------------------------------------------------
# export round-trips
# ---------------------------------------------------------------------------


def test_chrome_trace_well_formed(tmp_path):
    reg = MetricsRegistry(enabled=True)
    with reg.span("engine/decode_step", args={"active": 2}):
        pass
    reg.trace_counter("engine.queue_depth", 3.0)
    reg.event("engine/admit", args={"rid": 7})
    path = str(tmp_path / "trace.json")
    reg.export_chrome_trace(path)

    with open(path) as f:
        doc = json.load(f)  # plain JSON, perfetto-loadable
    assert doc["displayTimeUnit"] == "ms"
    events = validate_chrome_trace(path)
    by_ph = {e["ph"]: e for e in events}
    assert by_ph["X"]["name"] == "engine/decode_step"
    assert by_ph["X"]["dur"] >= 0 and by_ph["X"]["args"]["active"] == 2
    assert by_ph["C"]["args"]["value"] == 3.0
    assert by_ph["i"]["s"] == "p"


def test_metrics_jsonl_schema_round_trip(tmp_path):
    reg = MetricsRegistry(enabled=True)
    reg.counter("engine.decode_steps").inc(14)
    reg.gauge("engine.page_pool_free").set(9)
    reg.histogram("engine.request_latency_s").record_many([0.1, 0.2, 0.4])
    files = reg.write(str(tmp_path))
    recs = validate_metrics_jsonl(files["metrics"])
    by_name = {r["name"]: r for r in recs}
    assert by_name["engine.decode_steps"]["value"] == 14
    assert by_name["engine.page_pool_free"]["value"] == 9.0
    hist = by_name["engine.request_latency_s"]
    assert hist["count"] == 3 and hist["exact"] is True
    assert hist["p50"] == pytest.approx(0.2)
    assert validate_dir(str(tmp_path)) == {"metrics": 3, "trace_events": 0}


def test_validators_reject_malformed(tmp_path):
    bad_metrics = tmp_path / "metrics.jsonl"
    bad_metrics.write_text(json.dumps({"schema": "wrong", "kind": "counter",
                                       "name": "x", "labels": {}, "value": 1}) + "\n")
    with pytest.raises(ValueError, match="bad schema"):
        validate_metrics_jsonl(str(bad_metrics))
    bad_trace = tmp_path / "trace.json"
    bad_trace.write_text(json.dumps({"traceEvents": [{"name": "x", "ph": "Z", "ts": 0}]}))
    with pytest.raises(ValueError, match="bad phase"):
        validate_chrome_trace(str(bad_trace))


def test_validate_cli(tmp_path):
    reg = MetricsRegistry(enabled=True)
    reg.counter("n").inc()
    with reg.span("s"):
        pass
    reg.write(str(tmp_path))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.getcwd(), "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.runtime.telemetry", "--validate", str(tmp_path)],
        capture_output=True, text=True, env=env,
    )
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout)
    assert out["ok"] is True and out["metrics"] == 1 and out["trace_events"] == 1


# ---------------------------------------------------------------------------
# trace-count metric parity with TRACE_COUNTS
# ---------------------------------------------------------------------------


def test_decode_step_trace_counter_parity(enabled_registry):
    """The ``serve.decode_step_traces`` metric moves in lockstep with the
    ``TRACE_COUNTS['decode_step']`` regression counter: +1 per fresh
    compile, +0 on cache hits (same shapes), +1 again on a new batch
    shape — same contract test_engine's compile-count regressions pin."""
    from repro.launch import serve

    class _Toy:
        def decode_step(self, params, cache, tok, pos):
            del pos
            logits = jnp.zeros((tok.shape[0], 1, 8), jnp.float32) + params
            return logits, cache

    step = serve._jit_step(_Toy())
    params = jnp.float32(1.0)
    cache = jnp.zeros((1,), jnp.float32)
    before = serve.TRACE_COUNTS["decode_step"]

    step(params, cache, jnp.zeros((1, 1), jnp.int32), jnp.int32(0))
    step(params, cache, jnp.zeros((1, 1), jnp.int32), jnp.int32(1))  # cache hit
    step(params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(0))  # new shape

    delta = serve.TRACE_COUNTS["decode_step"] - before
    assert delta == 2
    assert obs.counter("serve.decode_step_traces").value == delta


# ---------------------------------------------------------------------------
# autotune lookup counters
# ---------------------------------------------------------------------------


def test_autotune_hit_miss_counters(tmp_path, monkeypatch, enabled_registry):
    from repro.kernels import autotune

    backend = jax.default_backend()
    key = autotune.cache_key(8, 64, 32, 32, jnp.float32, backend)
    cache_file = tmp_path / "tune.json"
    cache_file.write_text(json.dumps({key: {"bm": 8, "bn": 32, "bk": 32, "us": 1.0}}))
    monkeypatch.setenv("REPRO_PVQ_TUNE_CACHE", str(cache_file))
    monkeypatch.delenv("REPRO_PVQ_AUTOTUNE", raising=False)
    autotune.clear_memory_cache()
    autotune.reset_tune_stats()
    try:
        assert autotune.get_tiles(8, 64, 32, group=32, search=False) == (8, 32, 32)
        autotune.get_tiles(8, 128, 32, group=32, search=False)  # miss -> heuristic
        st = autotune.tune_stats()
        assert st["hits"] == 1 and st["misses"] == 1 and st["searches"] == 0
        assert st["by_key"][key]["hits"] == 1
        assert obs.counter("autotune.hit").value == 1
        assert obs.counter("autotune.miss").value == 1
        assert obs.counter("autotune.lookups").value == 2
    finally:
        autotune.clear_memory_cache()
        autotune.reset_tune_stats()


# ---------------------------------------------------------------------------
# quant-quality probes: eager-only, never inside jit traces
# ---------------------------------------------------------------------------


def test_act_quant_probe_eager_only(enabled_registry):
    from repro.core.quantize import ActQuant, quantize_activations

    aq = ActQuant()
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 32)), jnp.float32)
    quantize_activations(x, aq)
    assert obs.counter("quant.act_quant_calls").value == 1
    assert obs.registry().histogram("quant.act_clamp_frac").count == 1

    jitted = jax.jit(lambda y: quantize_activations(y, aq)[0])
    jitted(x)
    jitted(x)  # tracer path: the probe must stay silent
    assert obs.counter("quant.act_quant_calls").value == 1


def test_weight_pack_probe_records_snr(enabled_registry):
    from repro.core.packed import quantize_params
    from repro.core.quantize import QuantPolicy

    w = jnp.asarray(np.random.default_rng(2).normal(size=(8, 32)), jnp.float32)
    policy = QuantPolicy(rules=(("embedding", 1.0, 16),), scale_mode="ls")
    quantize_params({"embedding": w}, policy)
    assert obs.counter("quant.weight_leaves_packed").value == 1
    h = obs.registry().histogram("quant.weight_snr_db")
    assert h.count == 1
    assert h.percentile(50) > 0.0  # reconstruction beats zero-signal
    assert obs.counter("quant.weight_bytes_packed").value < \
        obs.counter("quant.weight_bytes_dense").value
