"""The unified PackedPVQ artifact: container semantics, tree transforms,
layer/model transparency, int8-native kernel equivalence, sharding rules,
grad-pipeline update semantics, and the jit-safe int8 boundary."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.packed import (
    PackedPVQ,
    dequantize_params,
    is_packed,
    materialize,
    pack_flat,
    pack_matmul,
    packed_leaves,
    packed_stats,
    packed_update,
    quantize_params,
)
from repro.core.packing import pack_nibbles, pulses_to_int8, unpack_nibbles
from repro.core.pvq import pvq_encode
from repro.core.quantize import QuantPolicy
from repro.kernels import ops


# ---------------------------------------------------------------------------
# container + pytree semantics
# ---------------------------------------------------------------------------


def _packed_2d(seed=0, d_in=100, d_out=72, group=64, n_over_k=2.0):
    w = jax.random.laplace(jax.random.PRNGKey(seed), (d_in, d_out)) * 0.1
    return w, pack_matmul(w, group=group, n_over_k=n_over_k)


def test_pack_matmul_layout_and_dequantize():
    w, pk = _packed_2d()
    assert pk.pulses.dtype == jnp.int8
    assert pk.pulses.shape == (128, 72)  # d_in=100 padded to group multiple
    assert pk.scales.shape == (2, 72)
    assert pk.shape == (100, 72) and pk.layout == "matmul"
    deq = pk.dequantize()
    assert deq.shape == (100, 72) and deq.dtype == jnp.float32
    rel = float(jnp.linalg.norm(deq - w) / jnp.linalg.norm(w))
    assert rel < 0.45  # N/K=2 quantization error regime


def test_packed_is_pytree_with_named_children():
    _, pk = _packed_2d()
    leaves, treedef = jax.tree_util.tree_flatten(pk)
    assert len(leaves) == 2
    pk2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert is_packed(pk2) and pk2.group == pk.group and pk2.shape == pk.shape
    # keyed paths expose pulses/scales (consumed by sharding + checkpointer)
    keyed = jax.tree_util.tree_flatten_with_path(pk)[0]
    names = {str(getattr(path[-1], "key", path[-1])) for path, _ in keyed}
    assert names == {"pulses", "scales"}


def test_packed_roundtrips_through_jit_and_scan():
    w3 = jax.random.laplace(jax.random.PRNGKey(3), (3, 64, 64)) * 0.1
    pk = pack_matmul(w3, group=64, n_over_k=2.0)  # stacked (repeats, ...)
    assert pk.pulses.shape == (3, 64, 64) and pk.scales.shape == (3, 1, 64)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 64))

    @jax.jit
    def scan_layers(pk, x):
        def body(h, layer):  # layer: PackedPVQ with 2-D children
            return ops.packed_matmul(h, layer, interpret=True), None

        out, _ = jax.lax.scan(body, x, pk)
        return out

    got = scan_layers(pk, x)
    want = x
    for i in range(3):
        want = want @ pk.dequantize()[i]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_pack_flat_row_aligned_gather():
    e = jax.random.normal(jax.random.PRNGKey(5), (64, 48)) * 0.02
    pe = pack_flat(e, group=256, n_over_k=0.5, row_align=48)
    assert pe.group == 16  # 256 shrunk to divide d=48
    assert pe.layout == "flat"
    deq = pe.dequantize()
    assert deq.shape == (64, 48)
    rel = float(jnp.linalg.norm(deq - e) / jnp.linalg.norm(e))
    assert rel < 0.25  # K = 2N


def test_large_k_clamp_refits_scale_from_stored_pulses():
    """K > 127 may clamp a dominant coordinate to +-127; the stored scale
    must be the ls-optimal fit for the CLAMPED pulses, not the unclamped
    ones, so the artifact stays self-consistent."""
    from repro.core.pvq import _scales

    # one coordinate carries most of the group's L1 mass -> >127 pulses
    w = jnp.full((256,), 0.01).at[3].set(10.0)
    pk = pack_flat(w, group=256, n_over_k=1.0)  # K = 256 > 127
    assert int(jnp.max(jnp.abs(pk.pulses))) == 127  # clamp engaged
    want = _scales(w.reshape(1, 256), pk.pulses.astype(jnp.int32), "ls")
    np.testing.assert_allclose(np.asarray(pk.scales), np.asarray(want), rtol=1e-6)
    # and the matmul layout path refits too
    wm = jnp.tile(w[:, None], (1, 4))
    pm = pack_matmul(wm, group=256, n_over_k=1.0)
    assert int(jnp.max(jnp.abs(pm.pulses))) == 127
    deq = pm.dequantize()
    # ls-refit scale keeps the dominant-coordinate error bounded
    rel = float(jnp.linalg.norm(deq - wm) / jnp.linalg.norm(wm))
    assert rel < 0.5


def test_materialize_passthrough_and_dequant():
    w, pk = _packed_2d()
    np.testing.assert_array_equal(np.asarray(materialize(w)), np.asarray(w))
    assert materialize(pk).shape == (100, 72)


# ---------------------------------------------------------------------------
# tree transforms
# ---------------------------------------------------------------------------


def _toy_tree(seed=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    return {
        "embed": {"embedding": jax.random.normal(k[0], (128, 64)) * 0.02},
        "blocks": {
            "wq": {"kernel": jax.random.laplace(k[1], (64, 64)) * 0.1},
            "wo": {"kernel": jax.random.laplace(k[2], (64, 64)) * 0.1,
                   "bias": jnp.zeros(64)},
        },
        "ln": {"rms_scale": jnp.ones(64)},
        "conv": {"conv_kernel": jax.random.normal(k[3], (4, 64))},
    }


POLICY = QuantPolicy(rules=(("", 1.0, 64),), scale_mode="ls")


def test_quantize_params_mixed_tree():
    tree = _toy_tree()
    q = quantize_params(tree, POLICY)
    pl = packed_leaves(q)
    assert set(pl) == {"embed/embedding", "blocks/wq/kernel", "blocks/wo/kernel"}
    # norm scale, bias, conv kernel untouched
    np.testing.assert_array_equal(np.asarray(q["ln"]["rms_scale"]), np.ones(64))
    assert not is_packed(q["conv"]["conv_kernel"])
    assert not is_packed(q["blocks"]["wo"]["bias"])


def test_quantize_params_idempotent():
    q = quantize_params(_toy_tree(), POLICY)
    q2 = quantize_params(q, POLICY)  # encode ONCE: packed leaves pass through
    for (p1, l1), (p2, l2) in zip(packed_leaves(q).items(), packed_leaves(q2).items()):
        assert p1 == p2
        np.testing.assert_array_equal(np.asarray(l1.pulses), np.asarray(l2.pulses))


def test_dequantize_params_inverts_structure():
    tree = _toy_tree()
    dq = dequantize_params(quantize_params(tree, POLICY))
    assert jax.tree_util.tree_structure(dq) == jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(dq)):
        assert a.shape == b.shape


def test_packed_stats_reports_compression():
    st_ = packed_stats(quantize_params(_toy_tree(), POLICY))
    assert st_["packed_tensors"] == 3
    assert st_["weight_compression_ratio"] > 2.0  # int8+scales vs f32


# ---------------------------------------------------------------------------
# layer / model transparency
# ---------------------------------------------------------------------------


def test_dense_accepts_packed_kernel():
    from repro.nn.layers import dense

    w, pk = _packed_2d(d_in=64, d_out=32)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 5, 64))
    got = dense({"kernel": pk, "bias": jnp.ones(32)}, x)
    want = x @ pk.dequantize() + 1.0
    assert got.shape == (2, 5, 32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_embed_and_unembed_packed_match_dequant():
    from repro.nn.layers import embed, unembed

    e = jax.random.normal(jax.random.PRNGKey(8), (128, 64)) * 0.02
    pe = pack_flat(e, group=64, n_over_k=0.5, row_align=64)
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 6), 0, 128)
    got = embed({"embedding": pe}, toks)
    want = jnp.take(pe.dequantize(), toks, axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    x = jax.random.normal(jax.random.PRNGKey(10), (2, 3, 64))
    got_l = unembed({"embedding": pe}, x)
    want_l = jnp.einsum("...d,vd->...v", x, pe.dequantize())
    np.testing.assert_allclose(np.asarray(got_l), np.asarray(want_l), rtol=1e-4, atol=1e-4)


def test_model_serves_packed_params_matches_dequant_sim():
    """prefill+decode on the packed artifact == the dequantized simulation."""
    from repro.configs import get_config
    from repro.launch.serve import generate
    from repro.nn.models import build_model

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=24)
    policy = QuantPolicy(
        rules=(("embedding", 0.5, 256), ("kernel", 1.0, 256)), scale_mode="ls"
    )
    qparams = quantize_params(params, policy)
    assert packed_leaves(qparams), "nothing was packed"
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab_size)
    out_packed = generate(model, qparams, toks, gen=4, cache_len=12)
    out_sim = generate(model, dequantize_params(qparams), toks, gen=4, cache_len=12)
    agree = float(jnp.mean((out_packed == out_sim).astype(jnp.float32)))
    assert agree >= 0.9, agree  # identical weights; rare argmax ties may flip


# ---------------------------------------------------------------------------
# int8-native kernel path
# ---------------------------------------------------------------------------


def test_packed_matmul_requires_matmul_layout():
    e = jax.random.normal(jax.random.PRNGKey(11), (16, 32))
    pe = pack_flat(e, group=32, n_over_k=1.0, row_align=32)
    with pytest.raises(ValueError):
        ops.packed_matmul(jnp.zeros((2, 32)), pe, interpret=True)


def test_packed_matmul_epilogue_fusion():
    w, pk = _packed_2d(d_in=128, d_out=64, group=64)
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 128))
    bias = jax.random.normal(jax.random.PRNGKey(13), (64,))
    got = ops.packed_matmul(x, pk, bias=bias, activation="relu", interpret=True)
    want = jax.nn.relu(x @ pk.dequantize() + bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_autotune_cache_key_carries_kernel_version():
    """Satellite: a kernel-body bump must invalidate stale tile timings."""
    from repro.kernels import autotune
    from repro.kernels.pvq_matmul import KERNEL_VERSION

    key = autotune.cache_key(8, 128, 128, 128, jnp.float32, "cpu")
    assert f":kv{KERNEL_VERSION}:" in key
    assert key.endswith(":v3")


# ---------------------------------------------------------------------------
# grad-pipeline update semantics
# ---------------------------------------------------------------------------


def test_grad_compress_passes_packed_leaves_through():
    from repro.optim.grad_compress import (
        CompressionConfig,
        compress_decompress,
        make_ef_compressor,
        wire_bytes,
    )

    cfg = CompressionConfig(group=64, n_over_k=2.0, min_size=16)
    w, pk = _packed_2d(d_in=64, d_out=32)
    g = {"dense": jax.random.laplace(jax.random.PRNGKey(14), (1024,)),
         "frozen": pk}
    assert compress_decompress(pk, cfg) is pk
    init, apply = make_ef_compressor(cfg)
    ef = init(g)
    dec, ef2 = apply(g, ef)
    assert dec["frozen"] is pk  # packed leaf untouched
    assert dec["dense"].shape == (1024,)
    comp, raw = wire_bytes(g, cfg)
    assert raw == 4 * 1024  # packed leaf never crosses the wire


def test_packed_update_reencodes_on_same_pyramid():
    w, pk = _packed_2d(d_in=64, d_out=32, n_over_k=1.0)
    delta = jax.random.normal(jax.random.PRNGKey(15), (64, 32)) * 0.01
    pk2 = packed_update(pk, delta)
    assert is_packed(pk2)
    assert (pk2.group, pk2.k, pk2.shape, pk2.layout) == (pk.group, pk.k, pk.shape, pk.layout)
    # the re-encoded artifact approximates dequant(pk) + delta
    target = pk.dequantize() + delta
    rel = float(jnp.linalg.norm(pk2.dequantize() - target) / jnp.linalg.norm(target))
    assert rel < 0.45


# ---------------------------------------------------------------------------
# sharding rules for packed children
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_packed_param_sharding_rules():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import ShardingPolicy, param_pspec

    mesh = _FakeMesh({"data": 16, "model": 16})
    pol = ShardingPolicy()
    # column-parallel packed kernel: pulses shard like the dense kernel
    assert param_pspec("mixer/wq/kernel/pulses", (4096, 4096), mesh, pol) == P(("data",), "model")
    assert param_pspec("mixer/wq/kernel/scales", (32, 4096), mesh, pol) == P(None, "model")
    # row-parallel
    assert param_pspec("mixer/wo/kernel/pulses", (4096, 4096), mesh, pol) == P("model", ("data",))
    # flat-layout embedding: leading group axis is vocab-major
    assert param_pspec("embed/embedding/pulses", (49152 * 32, 128), mesh, pol) == P("model", None)
    assert param_pspec("embed/embedding/scales", (49152 * 32,), mesh, pol) == P("model")
    # scan-stacked packed pulses get the leading None
    assert param_pspec("segments/seg0/b0/mixer/wq/kernel/pulses", (8, 4096, 4096), mesh, pol) == P(None, ("data",), "model")


# ---------------------------------------------------------------------------
# satellite: jit-safe int8 boundary + nibble packing properties
# ---------------------------------------------------------------------------


def test_pulses_to_int8_is_jit_safe():
    """The old int(maxabs) host sync raised TracerConversionError under jit."""
    w = jax.random.laplace(jax.random.PRNGKey(16), (8, 64))

    @jax.jit
    def encode_cast(w):
        code = pvq_encode(w, 32, "ls")
        return pulses_to_int8(code)

    p8, sc = encode_cast(w)
    assert p8.dtype == jnp.int8
    code = pvq_encode(w, 32, "ls")
    np.testing.assert_array_equal(np.asarray(p8), np.asarray(code.pulses, np.int8))


def test_pulses_to_int8_static_k_bound():
    w = jax.random.laplace(jax.random.PRNGKey(17), (512,))
    code = pvq_encode(w, 200, "ls")  # K > 127: statically rejected
    with pytest.raises(ValueError, match="K=200"):
        pulses_to_int8(code)


def test_pulses_to_int8_debug_check_runs_under_jit():
    w = jax.random.laplace(jax.random.PRNGKey(18), (8, 64))

    @jax.jit
    def f(w):
        return pulses_to_int8(pvq_encode(w, 16, "ls"), debug=True)[0]

    assert f(w).dtype == jnp.int8


def test_pack_nibbles_odd_length_roundtrip():
    p = np.array([-7, 7, 0, 1, -1], np.int64)  # odd count: padding nibble
    packed, shape = pack_nibbles(p)
    assert packed.size == 3
    np.testing.assert_array_equal(unpack_nibbles(packed, shape), p)


def test_pack_nibbles_boundary_magnitude():
    p = np.full((13,), 7, np.int64)
    np.testing.assert_array_equal(unpack_nibbles(*pack_nibbles(p)), p)
    np.testing.assert_array_equal(unpack_nibbles(*pack_nibbles(-p)), -p)
    with pytest.raises(ValueError):
        pack_nibbles(np.array([8]))
    with pytest.raises(ValueError):
        pack_nibbles(np.array([-8]))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 257),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_nibble_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    p = rng.integers(-7, 8, size=(n,))
    packed, shape = pack_nibbles(p)
    assert packed.size == (n + 1) // 2
    np.testing.assert_array_equal(unpack_nibbles(packed, shape), p)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 5), cols=st.integers(1, 9), seed=st.integers(0, 2**31 - 1)
)
def test_prop_nibble_roundtrip_2d(rows, cols, seed):
    rng = np.random.default_rng(seed)
    p = rng.integers(-7, 8, size=(rows, cols))
    packed, shape = pack_nibbles(p)
    assert shape == (rows, cols)
    np.testing.assert_array_equal(unpack_nibbles(packed, shape), p)


# ---------------------------------------------------------------------------
# expert-stacked packed MoE bank (PR 4)
# ---------------------------------------------------------------------------


def _moe_cfg(**kw):
    from repro.nn.moe import MoEConfig

    d = dict(n_experts=4, top_k=2, n_shared=0, d_expert=32, capacity_factor=2.0,
             group_size=32, activation="swiglu")
    d.update(kw)
    return MoEConfig(**d)


EXPERT_POLICY = QuantPolicy(rules=(("kernel|experts", 2.0, 64),), scale_mode="ls")


def test_pack_matmul_expert_stack_shapes():
    """(E, d, f) and scan-stacked (R, E, d, f) banks: stack axes ride along
    on pulses/scales, the static metadata stays the unstacked matrix."""
    w3 = jax.random.laplace(jax.random.PRNGKey(20), (4, 100, 32)) * 0.1
    pk3 = pack_matmul(w3, group=64, n_over_k=2.0)
    assert pk3.pulses.shape == (4, 128, 32) and pk3.scales.shape == (4, 2, 32)
    assert pk3.shape == (100, 32)
    w4 = jnp.stack([w3, w3 * 1.5])
    pk4 = pack_matmul(w4, group=64, n_over_k=2.0)
    assert pk4.pulses.shape == (2, 4, 128, 32) and pk4.scales.shape == (2, 4, 2, 32)
    # every stack entry is encoded independently: slice 0 == the 3-D pack
    np.testing.assert_array_equal(np.asarray(pk4.pulses[0]), np.asarray(pk3.pulses))
    deq = pk4.dequantize()
    assert deq.shape == (2, 4, 100, 32)
    np.testing.assert_allclose(
        np.asarray(deq[0]), np.asarray(pk3.dequantize()), rtol=1e-6, atol=1e-7
    )


def test_packed_matmul_stacked_matches_dequant():
    w = jax.random.laplace(jax.random.PRNGKey(21), (4, 96, 48)) * 0.1
    pk = pack_matmul(w, group=32, n_over_k=2.0)
    x = jax.random.normal(jax.random.PRNGKey(22), (4, 8, 96))
    got = ops.packed_matmul_stacked(x, pk, interpret=True)
    want = jnp.einsum("emk,ekn->emn", x, pk.dequantize())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    # fused epilogue activation
    got_act = ops.packed_matmul_stacked(x, pk, activation="silu", interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_act), np.asarray(jax.nn.silu(want)), rtol=1e-4, atol=1e-4
    )


def test_packed_matmul_stacked_validates_inputs():
    w = jax.random.laplace(jax.random.PRNGKey(23), (4, 64, 32)) * 0.1
    pk = pack_matmul(w, group=64, n_over_k=2.0)
    with pytest.raises(ValueError, match="matching the expert axis"):
        ops.packed_matmul_stacked(jnp.zeros((3, 8, 64)), pk, interpret=True)
    w2, pk2 = _packed_2d(d_in=64, d_out=32)
    with pytest.raises(ValueError, match="stacked expert bank"):
        ops.packed_matmul_stacked(jnp.zeros((4, 8, 64)), pk2, interpret=True)
    e = jax.random.normal(jax.random.PRNGKey(24), (16, 32))
    pe = pack_flat(e, group=32, n_over_k=1.0, row_align=32)
    with pytest.raises(ValueError, match="layout"):
        ops.packed_matmul_stacked(jnp.zeros((4, 8, 32)), pe, interpret=True)


def test_quantize_params_packs_expert_banks():
    from repro.nn.moe import init_moe

    p = init_moe(jax.random.PRNGKey(25), 16, _moe_cfg())
    q = quantize_params(p, EXPERT_POLICY)
    pl = packed_leaves(q)
    assert {"wi_up_experts", "wi_gate_experts", "wo_experts"} <= set(pl)
    assert all(leaf.layout == "matmul" for leaf in pl.values())
    # the router is raw-consumed by _routing and must never be packed
    assert not is_packed(q["router"]["kernel"])


def test_moe_forward_packed_matches_dequant():
    """Satellite: packed-vs-dense expert forward equivalence on a small MoE
    (same routing, same capacity — the expert matmuls are the only delta)."""
    from repro.nn.moe import init_moe, moe_forward

    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(26), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(27), (2, 32, 16))
    q = quantize_params(p, EXPERT_POLICY)
    out_pk, aux_pk = moe_forward(q, x, cfg)
    out_dq, aux_dq = moe_forward(dequantize_params(q), x, cfg)
    np.testing.assert_allclose(
        np.asarray(out_pk), np.asarray(out_dq), rtol=1e-4, atol=1e-5
    )
    assert float(aux_pk) == pytest.approx(float(aux_dq), rel=1e-6)


def test_moe_forward_packed_light_combine_parity():
    """Satellite: slot-gate (light) vs f32-combine routing on PACKED experts."""
    from repro.nn.moe import init_moe, moe_forward
    from repro.parallel import ShardingPolicy, sharding_policy

    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(28), 16, cfg)
    q = quantize_params(p, EXPERT_POLICY)
    x = jax.random.normal(jax.random.PRNGKey(29), (2, 32, 16))
    out_ref, aux_ref = moe_forward(q, x, cfg)
    with sharding_policy(ShardingPolicy(moe_light_combine=True)):
        out_light, aux_light = moe_forward(q, x, cfg)
    np.testing.assert_allclose(
        np.asarray(out_light), np.asarray(out_ref), rtol=2e-2, atol=1e-4
    )
    assert float(aux_light) == pytest.approx(float(aux_ref), rel=1e-6)


def test_moe_forward_packed_under_scan_stack():
    """Scan-stacked (R, E, d, f) expert leaves slice per layer inside
    lax.scan exactly like 2-D packed kernels do."""
    from repro.nn.moe import init_moe, moe_forward

    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(30), 16, cfg)
    p2 = jax.tree.map(lambda a: jnp.stack([a, a * 0.5]), p)
    q2 = quantize_params(p2, EXPERT_POLICY)
    assert packed_leaves(q2)["wi_up_experts"].pulses.ndim == 4
    x = jax.random.normal(jax.random.PRNGKey(31), (1, 32, 16))

    def body(h, layer):
        out, _ = moe_forward(layer, h, cfg)
        return h + out, None

    got, _ = jax.lax.scan(body, x, q2)
    want = x
    for r in range(2):
        # tree.map slices pulses/scales children, exactly like lax.scan
        layer = jax.tree.map(lambda t: t[r], q2)
        out, _ = moe_forward(layer, want, cfg)
        want = want + out
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_packed_expert_sharding_rules():
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import ShardingPolicy, param_pspec

    mesh = _FakeMesh({"data": 16, "model": 16})
    pol = ShardingPolicy()
    # train layout: EP on model, contraction (wi) / output (wo) dim on data
    assert param_pspec("ffn/wi_up_experts/pulses", (160, 5120, 1536), mesh, pol) == P("model", ("data",), None)
    assert param_pspec("ffn/wi_gate_experts/scales", (160, 20, 1536), mesh, pol) == P("model", None, None)
    assert param_pspec("ffn/wo_experts/pulses", (160, 1536, 5120), mesh, pol) == P("model", None, ("data",))
    assert param_pspec("ffn/wo_experts/scales", (160, 6, 5120), mesh, pol) == P("model", None, ("data",))
    # scan-stacked leaves get the leading None
    assert param_pspec("seg1/b0/ffn/wi_up_experts/pulses", (8, 160, 5120, 1536), mesh, pol) == P(None, "model", ("data",), None)
    # serve layout: no FSDP — expert hidden dim sharded over data instead
    spol = ShardingPolicy(serve_params=True)
    assert param_pspec("ffn/wi_up_experts/pulses", (160, 5120, 1536), mesh, spol) == P("model", None, "data")
    assert param_pspec("ffn/wo_experts/pulses", (160, 1536, 5120), mesh, spol) == P("model", "data", None)
    assert param_pspec("ffn/wo_experts/scales", (160, 6, 5120), mesh, spol) == P("model", None, None)


def test_deepseek_moe_serves_packed_end_to_end():
    """Acceptance: the deepseek-v2-lite MoE config serves with expert weights
    held as PackedPVQ end-to-end — no dense expert tensor at rest — and the
    greedy decodes match the dequantized-weight reference."""
    from repro.configs import get_config
    from repro.launch.serve import generate
    from repro.nn.models import build_model

    cfg = get_config("deepseek-v2-lite-16b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=12)
    policy = QuantPolicy(
        rules=(("embedding", 0.5, cfg.pvq.group),
               ("kernel|experts", 2.0, cfg.pvq.group)),
        scale_mode="ls",
    )
    qparams = quantize_params(params, policy)
    experts = {k: v for k, v in packed_leaves(qparams).items() if "_experts" in k}
    assert len(experts) == 3  # wi_up / wi_gate / wo, scan-stacked
    assert all(leaf.pulses.ndim == 4 for leaf in experts.values())
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab_size)
    out_packed = generate(model, qparams, toks, gen=4, cache_len=12)
    out_sim = generate(model, dequantize_params(qparams), toks, gen=4, cache_len=12)
    agree = float(jnp.mean((out_packed == out_sim).astype(jnp.float32)))
    assert agree >= 0.9, agree  # identical weights; rare argmax ties may flip


def test_packed_expert_checkpoint_bit_exact(tmp_path):
    from repro.checkpoint import Checkpointer
    from repro.nn.moe import init_moe

    p = init_moe(jax.random.PRNGKey(32), 16, _moe_cfg())
    p4 = jax.tree.map(lambda a: jnp.stack([a, a * 1.1]), p)  # scan stack
    q = quantize_params(p4, EXPERT_POLICY)
    for codec in ("packed", "golomb"):
        ck = Checkpointer(tmp_path / codec, packed_codec=codec)
        ck.save(1, q)
        restored, _ = ck.restore(q)
        for key, leaf in packed_leaves(q).items():
            got = packed_leaves(restored)[key]
            np.testing.assert_array_equal(np.asarray(got.pulses), np.asarray(leaf.pulses))
            np.testing.assert_array_equal(np.asarray(got.scales), np.asarray(leaf.scales))
            assert (got.group, got.k, got.shape, got.layout) == (
                leaf.group, leaf.k, leaf.shape, leaf.layout
            )
