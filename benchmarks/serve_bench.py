"""Packed-vs-f32 serving throughput: the artifact the paper promises.

Builds a reduced config, serves the same prompts with (a) float weights and
(b) the packed PVQ artifact (int8 pulses streamed into the int8-native
kernel), and reports decode tokens/s plus the weight-bytes ratio.  Rows go
to ``BENCH_serve.json`` via benchmarks.run for cross-PR perf trajectories.

On this CPU container the Pallas kernel runs interpret=True, so absolute
packed throughput is a correctness proxy, not a perf claim; the bytes
ratio and encode time are backend-independent.
"""

from __future__ import annotations

import time
from typing import Dict, List


def bench_serve_throughput(arch: str = "smollm-360m", *, batch: int = 2,
                           prompt_len: int = 8, gen: int = 8) -> List[Dict]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.packed import packed_stats, quantize_params
    from repro.core.quantize import QuantPolicy
    from repro.launch.serve import generate
    from repro.nn.models import build_model

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=prompt_len + gen)
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size
    )

    def timed(p):
        # one warmup generation (trace + compile), then the timed run
        generate(model, p, toks, gen=gen, cache_len=prompt_len + gen)
        t0 = time.perf_counter()
        out = generate(model, p, toks, gen=gen, cache_len=prompt_len + gen)
        jax.block_until_ready(out)
        return batch * gen / (time.perf_counter() - t0)

    tps_f32 = timed(params)

    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("kernel|experts", cfg.pvq.n_over_k, cfg.pvq.group)),
        scale_mode="ls",
    )
    t0 = time.perf_counter()
    qparams = quantize_params(params, policy)
    encode_s = time.perf_counter() - t0
    st = packed_stats(qparams)
    tps_packed = timed(qparams)

    # int8-activation leg (ISSUE 5): same packed artifact, the decode loop
    # quantizes activations per row and runs the int8 x int8 kernel v3 —
    # on CPU hosts an interpret-mode correctness proxy like the packed leg.
    from repro.core.quantize import ActQuant, act_quant_scope

    with act_quant_scope(ActQuant(mode="per_row")):
        tps_act_int8 = timed(qparams)

    return [{
        "bench": f"serve:{cfg.name}:b{batch}g{gen}",
        "us_per_call": round(1e6 / max(tps_packed, 1e-9), 1),
        "tokens_per_s_f32": round(tps_f32, 2),
        "tokens_per_s_packed": round(tps_packed, 2),
        "tokens_per_s_act_int8": round(tps_act_int8, 2),
        "packed_over_f32": round(tps_packed / max(tps_f32, 1e-9), 3),
        "act_int8_over_packed": round(tps_act_int8 / max(tps_packed, 1e-9), 3),
        "encode_s": round(encode_s, 2),
        "packed_tensors": st["packed_tensors"],
        "packed_bytes": st["packed_bytes"],
        "weight_compression_ratio": round(st["weight_compression_ratio"], 3),
    }]
