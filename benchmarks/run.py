"""Benchmark harness: one function per paper table + kernel/system benches.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only PREFIX]

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark), then a
human-readable table dump.  Kernel rows are additionally written to
``BENCH_kernels.json`` (us_per_call + bytes-ratios per kernel/shape), the
packed-vs-f32 serving rows to ``BENCH_serve.json``, the continuous-batching
engine rows (tok/s, p50/p99 latency, slot utilization) to
``BENCH_engine.json``, and the .pvqz codec rows (bits/weight +
encode/decode MB/s) to ``BENCH_artifact.json`` so future PRs can diff perf
trajectories.
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="EXPERIMENTS.md-scale settings")
    ap.add_argument("--only", default="", help="run only benches whose name starts with this")
    args = ap.parse_args()

    from benchmarks import (artifact_bench, attn_bench, engine_bench,
                            kernel_bench, moe_bench, paper_tables, serve_bench)

    all_rows = []

    def run(name, fn, *fa, **fk):
        if args.only and not name.startswith(args.only):
            return
        print(f"[bench] {name} ...", file=sys.stderr, flush=True)
        rows = fn(*fa, **fk)
        for r in rows:
            r["bench_group"] = name
        all_rows.extend(rows)

    steps = {"A": 600, "B": 400, "C": 400, "D": 250} if args.full else {"A": 300, "B": 250, "C": 250, "D": 150}
    run("paper_tables_1_4", paper_tables.bench_tables_1_to_4, steps, args.full)
    run("paper_tables_5_8", paper_tables.bench_tables_5_to_8)
    run("paper_opcount", paper_tables.bench_opcount_claim)
    run("kernel_pvq_matmul", kernel_bench.bench_pvq_matmul)
    run("kernel_pvq_encode", kernel_bench.bench_pvq_encode)
    run("serve_packed", serve_bench.bench_serve_throughput)
    run("engine_continuous_batching", engine_bench.bench_engine)
    run("attn_packed_decode", attn_bench.bench_attention_decode)
    run("moe_packed_experts", moe_bench.bench_moe_experts)
    run("artifact_codecs", artifact_bench.bench_artifact_codecs)

    # CSV contract: name,us_per_call,derived
    print("name,us_per_call,derived")
    for r in all_rows:
        name = r.get("bench") or f"{r['bench_group']}:{r.get('table', r.get('net', ''))}"
        us = r.get("us_per_call", "")
        derived = {k: v for k, v in r.items() if k not in ("bench_group", "bench", "us_per_call")}
        print(f"{name},{us},{json.dumps(derived, default=str).replace(',', ';')}")

    # perf-trajectory file: kernel rows only, stable schema for cross-PR diffs
    kernel_rows = [r for r in all_rows if r["bench_group"].startswith("kernel_")]
    if kernel_rows:
        import jax

        payload = {
            "schema": "bench-kernels-v1",
            "backend": jax.default_backend(),
            "rows": kernel_rows,
        }
        with open("BENCH_kernels.json", "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print("wrote BENCH_kernels.json", file=sys.stderr)

    # packed-vs-dense MoE expert-bank trajectory (throughput + weight bytes)
    moe_rows = [r for r in all_rows if r["bench_group"].startswith("moe_")]
    if moe_rows:
        import jax

        payload = {
            "schema": "bench-moe-v1",
            "backend": jax.default_backend(),
            "rows": moe_rows,
        }
        with open("BENCH_moe.json", "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print("wrote BENCH_moe.json", file=sys.stderr)

    # packed-vs-f32 serving trajectory (stable schema for cross-PR diffs)
    serve_rows = [r for r in all_rows if r["bench_group"].startswith("serve_")]
    if serve_rows:
        import jax

        payload = {
            "schema": "bench-serve-v1",
            "backend": jax.default_backend(),
            "rows": serve_rows,
        }
        with open("BENCH_serve.json", "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print("wrote BENCH_serve.json", file=sys.stderr)

    # continuous-batching engine trajectory (tok/s, p50/p99, slot util)
    engine_rows = [r for r in all_rows if r["bench_group"].startswith("engine_")]
    if engine_rows:
        import jax

        payload = {
            "schema": "bench-engine-v1",
            "backend": jax.default_backend(),
            "rows": engine_rows,
        }
        with open("BENCH_engine.json", "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print("wrote BENCH_engine.json", file=sys.stderr)

    # packed-vs-f32 KV-cache decode trajectory (bytes/token + us/token)
    attn_rows = [r for r in all_rows if r["bench_group"].startswith("attn_")]
    if attn_rows:
        import jax

        payload = {
            "schema": "bench-attention-v1",
            "backend": jax.default_backend(),
            "rows": attn_rows,
        }
        with open("BENCH_attention.json", "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print("wrote BENCH_attention.json", file=sys.stderr)

    # .pvqz codec trajectory: bits/weight + encode/decode MB/s per codec
    artifact_rows = [r for r in all_rows if r["bench_group"].startswith("artifact_")]
    if artifact_rows:
        payload = {"schema": "bench-artifact-v1", "rows": artifact_rows}
        with open("BENCH_artifact.json", "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print("wrote BENCH_artifact.json", file=sys.stderr)


if __name__ == "__main__":
    main()
