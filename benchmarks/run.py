"""Benchmark harness: one function per paper table + kernel/system benches.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only PREFIX]

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark), then a
human-readable table dump.  Kernel rows are additionally written to
``BENCH_kernels.json`` (us_per_call + bytes-ratios per kernel/shape), the
packed-vs-f32 serving rows to ``BENCH_serve.json``, the continuous-batching
engine rows (tok/s, p50/p99 latency, slot utilization) to
``BENCH_engine.json``, and the .pvqz codec rows (bits/weight +
encode/decode MB/s) to ``BENCH_artifact.json`` so future PRs can diff perf
trajectories.
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="EXPERIMENTS.md-scale settings")
    ap.add_argument("--only", default="", help="run only benches whose name starts with this")
    args = ap.parse_args()

    from benchmarks import (artifact_bench, attn_bench, engine_bench,
                            kernel_bench, moe_bench, paper_tables, serve_bench)

    all_rows = []

    def run(name, fn, *fa, **fk):
        if args.only and not name.startswith(args.only):
            return
        print(f"[bench] {name} ...", file=sys.stderr, flush=True)
        rows = fn(*fa, **fk)
        for r in rows:
            r["bench_group"] = name
        all_rows.extend(rows)

    steps = {"A": 600, "B": 400, "C": 400, "D": 250} if args.full else {"A": 300, "B": 250, "C": 250, "D": 150}
    run("paper_tables_1_4", paper_tables.bench_tables_1_to_4, steps, args.full)
    run("paper_tables_5_8", paper_tables.bench_tables_5_to_8)
    run("paper_opcount", paper_tables.bench_opcount_claim)
    run("kernel_pvq_matmul", kernel_bench.bench_pvq_matmul)
    run("kernel_pvq_encode", kernel_bench.bench_pvq_encode)
    run("serve_packed", serve_bench.bench_serve_throughput)
    run("engine_continuous_batching", engine_bench.bench_engine)
    run("engine_chunked_prefill", engine_bench.bench_chunked_prefill)
    run("attn_packed_decode", attn_bench.bench_attention_decode)
    run("moe_packed_experts", moe_bench.bench_moe_experts)
    run("artifact_codecs", artifact_bench.bench_artifact_codecs)

    # CSV contract: name,us_per_call,derived
    print("name,us_per_call,derived")
    for r in all_rows:
        name = r.get("bench") or f"{r['bench_group']}:{r.get('table', r.get('net', ''))}"
        us = r.get("us_per_call", "")
        derived = {k: v for k, v in r.items() if k not in ("bench_group", "bench", "us_per_call")}
        print(f"{name},{us},{json.dumps(derived, default=str).replace(',', ';')}")

    # perf-trajectory files, one per bench family, all through the shared
    # telemetry payload wrapper so every BENCH_*.json row carries one schema
    # shape ({schema, backend, rows}) for cross-PR diffs
    from repro.runtime.telemetry import bench_payload

    trajectories = {
        "kernel_": ("BENCH_kernels.json", "bench-kernels-v1"),
        "moe_": ("BENCH_moe.json", "bench-moe-v1"),
        "serve_": ("BENCH_serve.json", "bench-serve-v1"),
        "engine_": ("BENCH_engine.json", "bench-engine-v1"),
        "attn_": ("BENCH_attention.json", "bench-attention-v1"),
        "artifact_": ("BENCH_artifact.json", "bench-artifact-v1"),
    }
    for prefix, (fname, schema) in trajectories.items():
        rows = [r for r in all_rows if r["bench_group"].startswith(prefix)]
        if not rows:
            continue
        with open(fname, "w") as f:
            json.dump(bench_payload(schema, rows), f, indent=1, default=str)
        print(f"wrote {fname}", file=sys.stderr)


if __name__ == "__main__":
    main()
