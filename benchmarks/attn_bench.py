"""Packed-vs-f32 KV-cache decode attention: the ISSUE-6 perf artifact.

Builds matched caches — a dense f32 cache and a ``PackedKV`` container
holding the same K/V rows — and times one decode step through each path
(``decode_attention`` dense einsums vs ``decode_attention_packed`` kernel
v4).  Rows report decode us/token for both legs plus KV bytes/token
(packed vs f32), and go to ``BENCH_attention.json`` via benchmarks.run
for cross-PR perf trajectories.

On this CPU container the Pallas kernel runs interpret=True, so absolute
packed timing is a correctness proxy, not a perf claim; the bytes ratio
is backend-independent and is what the acceptance gate checks
(packed/f32 <= 0.35).
"""

from __future__ import annotations

from typing import Dict, List

# shared timing helper (was a local copy of the same loop)
from repro.runtime.telemetry import time_call_us as _time_us


def bench_attention_decode(*, batch: int = 2, seq: int = 96,
                           n_heads: int = 8, n_kv: int = 2,
                           head_dim: int = 64) -> List[Dict]:
    import jax
    import jax.numpy as jnp

    from repro.core.packed import PackedKV
    from repro.core.quantize import KVQuant
    from repro.nn import attention as A

    kvq = KVQuant(block=32, group=32, k=127)
    key = jax.random.PRNGKey(0)
    kk, kv_, kq = jax.random.split(key, 3)
    k = jax.random.normal(kk, (batch, seq, n_kv, head_dim), jnp.float32)
    v = jax.random.normal(kv_, (batch, seq, n_kv, head_dim), jnp.float32)
    q = jax.random.normal(kq, (batch, 1, n_heads, head_dim), jnp.float32)
    scale = head_dim ** -0.5
    length = jnp.full((batch,), seq, jnp.int32)

    packed = PackedKV.from_dense(k, v, kvq=kvq)

    dense_fn = jax.jit(
        lambda: A.decode_attention(q, k, v, scale=scale, length=length)
    )
    packed_fn = lambda: A.decode_attention_packed(
        q, packed, scale=scale, length=length
    )

    us_dense = _time_us(dense_fn)
    us_packed = _time_us(packed_fn)

    # bytes per token per kv-head pair: packed planes+scales vs f32 K+V rows
    bpt_packed = packed.packed_bytes_per_token
    bpt_f32 = 2 * head_dim * 4
    out_d = dense_fn()
    out_p = packed_fn()
    rel = float(
        jnp.linalg.norm(out_p.astype(jnp.float32) - out_d)
        / jnp.maximum(jnp.linalg.norm(out_d), 1e-9)
    )

    return [{
        "bench": f"attn:b{batch}s{seq}h{n_heads}kv{n_kv}d{head_dim}",
        "us_per_call": round(us_packed, 1),
        "us_per_call_f32": round(us_dense, 1),
        "kv_bytes_per_token_packed": bpt_packed,
        "kv_bytes_per_token_f32": bpt_f32,
        "kv_bytes_ratio_vs_f32": round(bpt_packed / bpt_f32, 3),
        "packed_rel_err_vs_f32": round(rel, 4),
    }]
