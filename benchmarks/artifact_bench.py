"""Artifact-compression benchmark: bits/weight + codec throughput (§VI).

Encodes real packed artifacts — a paper-net FC layer and the reduced smollm
config — under each pulse codec and reports the measured bits/weight plus
encode/decode throughput in dense-equivalent MB/s (numel * 4 bytes over the
wall time of the entropy codec alone).  Rows land in ``BENCH_artifact.json``
via benchmarks.run for cross-PR trajectories.

Throughput numbers on this CPU container measure the vectorized numpy
codecs themselves (the .pvqz path has no accelerator dependency); the
bits/weight columns are backend-independent ground truth.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

CODECS = ("golomb", "rle", "nibble", "int8")


def _bench_leaf(name: str, pk, reps: int = 3) -> List[Dict]:
    from repro.core import bitstream
    from repro.core.packed import pulse_stream

    stream = pulse_stream(pk)
    dense_mb = stream.size * 4 / 1e6
    scale_bits = 32 * int(np.prod(pk.scales.shape))
    rows = []
    for codec in CODECS:
        if codec == "nibble" and np.abs(stream).max(initial=0) > 7:
            continue
        t0 = time.perf_counter()
        for _ in range(reps):
            blob, info = bitstream.encode_pulses(stream, codec)
        enc_s = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for _ in range(reps):
            out = bitstream.decode_pulses(blob, info)
        dec_s = (time.perf_counter() - t0) / reps
        np.testing.assert_array_equal(out, stream)  # the bench IS a roundtrip
        rows.append({
            "bench": f"artifact:{name}:{codec}",
            "us_per_call": round(1e6 * (enc_s + dec_s), 1),
            "numel": int(stream.size),
            "bits_per_weight": round(info["nbits"] / stream.size, 4),
            "bits_per_weight_with_scales": round(
                (info["nbits"] + scale_bits) / stream.size, 4
            ),
            "encode_mb_s": round(dense_mb / enc_s, 2),
            "decode_mb_s": round(dense_mb / dec_s, 2),
        })
    return rows


def bench_artifact_codecs() -> List[Dict]:
    import jax

    from repro.configs import get_config
    from repro.configs.paper_nets import PAPER_NETS
    from repro.core.packed import packed_leaves, quantize_params
    from repro.core.quantize import QuantPolicy
    from repro.nn.models import build_model
    from repro.nn.sequential import SequentialNet

    rows: List[Dict] = []

    # paper net A, first FC layer (784x512 at the Table-1 N/K = 5)
    net = SequentialNet(PAPER_NETS["A"])
    params = net.init(jax.random.PRNGKey(0))
    kparams = net.pvq_kernel_encode(params, group=256)
    rows += _bench_leaf("paper-A-fc0", kparams["layer0"]["kernel"])

    # the reduced smollm config, biggest packed leaf (transformer-shaped)
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    mparams = model.init(jax.random.PRNGKey(0), max_seq=16)
    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("kernel|experts", cfg.pvq.n_over_k, cfg.pvq.group)),
        scale_mode="ls",
    )
    q = quantize_params(mparams, policy)
    leaves = packed_leaves(q)
    biggest = max(leaves, key=lambda p: int(np.prod(leaves[p].pulses.shape)))
    rows += _bench_leaf(f"smollm-reduced:{biggest.split('/')[-2]}", leaves[biggest])
    return rows
