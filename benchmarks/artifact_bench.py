"""Artifact-compression benchmark: bits/weight + codec throughput (§VI).

Encodes real packed artifacts — a paper-net FC layer, the reduced smollm
config, and a deepseek-v2-lite expert leaf (decode scaling) — under each
pulse codec and reports the measured bits/weight plus encode/decode
throughput in dense-equivalent MB/s (numel * 4 bytes over the wall time of
the entropy codec alone).  Rows land in ``BENCH_artifact.json`` via
benchmarks.run for cross-PR trajectories.

Throughput numbers on this CPU container measure the vectorized numpy
codecs themselves (the .pvqz path has no accelerator dependency); the
bits/weight columns are backend-independent ground truth.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

CODECS = ("golomb", "rle", "enum", "nibble", "int8")


def _bench_leaf(name: str, pk, reps: int = 3) -> List[Dict]:
    from repro.core import bitstream
    from repro.core.enumeration import enum_supported
    from repro.core.packed import pulse_groups, pulse_stream

    stream = pulse_stream(pk)
    groups = pulse_groups(pk)
    dense_mb = stream.size * 4 / 1e6
    scale_bits = 32 * int(np.prod(pk.scales.shape))
    rows = []
    for codec in CODECS:
        if codec == "nibble" and np.abs(stream).max(initial=0) > 7:
            continue
        if codec == "enum":
            sub = bitstream.enum_sub_width(groups.shape[-1])
            if not enum_supported(sub, int(pk.k)):
                continue
            symbols, numel = groups, int(groups.size)
        else:
            symbols, numel = stream, int(stream.size)
        width = groups.shape[-1] if codec == "enum" else None
        # warm the lru-cached enumeration tables: the bench prices codec
        # throughput, not the per-(n,k) one-time table build
        blob, info = bitstream.encode_pulses(symbols, codec, k_max=int(pk.k))
        bitstream.decode_pulses(blob, info, width)
        # min over reps: the noise-free estimate on a shared CPU box
        enc_s = dec_s = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            blob, info = bitstream.encode_pulses(symbols, codec, k_max=int(pk.k))
            enc_s = min(enc_s, time.perf_counter() - t0)
            t0 = time.perf_counter()
            out = bitstream.decode_pulses(blob, info, width)
            dec_s = min(dec_s, time.perf_counter() - t0)
        ref = groups if codec == "enum" else stream
        np.testing.assert_array_equal(out.ravel(), ref.ravel())  # bench IS a roundtrip
        dense_codec_mb = numel * 4 / 1e6
        rows.append({
            "bench": f"artifact:{name}:{codec}",
            "us_per_call": round(1e6 * (enc_s + dec_s), 1),
            "numel": numel,
            "bits_per_weight": round(info["nbits"] / numel, 4),
            "bits_per_weight_with_scales": round(
                (info["nbits"] + scale_bits) / numel, 4
            ),
            "encode_mb_s": round(dense_codec_mb / enc_s, 2),
            "decode_mb_s": round(dense_codec_mb / dec_s, 2),
        })
    return rows


def bench_artifact_codecs() -> List[Dict]:
    import jax

    from repro.configs import get_config
    from repro.configs.paper_nets import PAPER_NETS
    from repro.core.packed import packed_leaves, quantize_params
    from repro.core.quantize import QuantPolicy
    from repro.nn.models import build_model
    from repro.nn.sequential import SequentialNet

    rows: List[Dict] = []

    # paper net A, first FC layer (784x512 at the Table-1 N/K = 5)
    net = SequentialNet(PAPER_NETS["A"])
    params = net.init(jax.random.PRNGKey(0))
    kparams = net.pvq_kernel_encode(params, group=256)
    # extra reps on the headline row: the min-of-reps estimate on a shared
    # 1-core box needs a few more draws to reliably hit a quiet slice
    rows += _bench_leaf("paper-A-fc0", kparams["layer0"]["kernel"], reps=6)

    # the reduced smollm config, biggest packed leaf (transformer-shaped)
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    mparams = model.init(jax.random.PRNGKey(0), max_seq=16)
    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("kernel|experts", cfg.pvq.n_over_k, cfg.pvq.group)),
        scale_mode="ls",
    )
    q = quantize_params(mparams, policy)
    leaves = packed_leaves(q)
    biggest = max(leaves, key=lambda p: int(np.prod(leaves[p].pulses.shape)))
    rows += _bench_leaf(f"smollm-reduced:{biggest.split('/')[-2]}", leaves[biggest])

    # decode scaling at a deepseek-v2-lite expert leaf: the expert stack is
    # the largest single blob the MoE artifact path decodes at cold start
    dcfg = get_config("deepseek-v2-lite-16b").reduced()
    dmodel = build_model(dcfg)
    dparams = dmodel.init(jax.random.PRNGKey(1), max_seq=16)
    dpolicy = QuantPolicy(
        rules=(("embedding", dcfg.pvq.n_over_k_embed, dcfg.pvq.group),
               ("kernel|experts", dcfg.pvq.n_over_k, dcfg.pvq.group)),
        scale_mode="ls",
    )
    dq = quantize_params(dparams, dpolicy)
    dleaves = packed_leaves(dq)
    experts = {p: l for p, l in dleaves.items() if "experts" in p}
    pool = experts or dleaves
    big = max(pool, key=lambda p: int(np.prod(pool[p].pulses.shape)))
    rows += _bench_leaf(f"deepseek-lite-expert:{big.split('/')[-2]}", pool[big], reps=2)
    return rows
