"""Continuous-batching engine vs the sequential fixed-batch loop.

Serves one Poisson request trace two ways over the same PVQ-quantized
model (packed weights + PVQ KV cache): (a) through ``launch.engine``'s
slot-pool engine (paged KV, async admission, prefill/decode
disaggregation) and (b) through ``serve.generate`` run request-by-request
— what serving without continuous batching degenerates to under ragged
arrivals.  Reports tokens/s, p50/p99 request latency, and slot
utilization; rows land in ``BENCH_engine.json`` via ``benchmarks.run``.

On this CPU container the Pallas kernels run interpret=True, so absolute
throughput is a correctness proxy; the engine-vs-sequential ratio and the
slot-utilization/eviction accounting are what the trajectory tracks.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.runtime.telemetry import Histogram


def bench_engine(arch: str = "smollm-360m", *, n_requests: int = 6,
                 n_slots: int = 3, prompt_len: int = 12, gen: int = 8,
                 rate: float = 0.0) -> List[Dict]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.packed import quantize_params
    from repro.core.quantize import (
        KVQuant, QuantPolicy, kv_quant_scope,
    )
    from repro.launch.engine import PVQEngine, bucket_len, poisson_trace
    from repro.launch.serve import generate
    from repro.nn.models import build_model

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=2 * (prompt_len + gen))
    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("kernel|experts", cfg.pvq.n_over_k, cfg.pvq.group)),
        scale_mode="ls",
    )
    params = quantize_params(params, policy)

    kvq = KVQuant(block=8, group=16)
    rows: List[Dict] = []
    with kv_quant_scope(kvq):
        trace = poisson_trace(
            n_requests, rate=rate, vocab=cfg.vocab_size,
            prompt_lens=(max(prompt_len // 2, 1), prompt_len),
            max_new=gen, seed=2,
        )
        max_len = bucket_len(prompt_len + gen, kvq.block)
        eng = PVQEngine(model, params, n_slots=n_slots, max_len=max_len)
        eng.warmup(prompt_lens=[len(r.prompt) for r in trace])
        res = eng.run(trace)
        res.pop("outputs")

        # sequential fixed-batch baseline over the SAME trace, warmed;
        # per-request latencies go through the shared telemetry histogram
        # (the same percentile type engine.report() uses — no inline pct)
        base_lat = Histogram()
        prompts = {r.rid: jnp.asarray([r.prompt], jnp.int32) for r in trace}
        generate(model, params, prompts[trace[0].rid], gen=gen,
                 cache_len=len(trace[0].prompt) + gen)
        t0 = time.perf_counter()
        base_tokens = 0
        for r in trace:
            t_req = time.perf_counter()
            out = generate(model, params, prompts[r.rid], gen=gen,
                           cache_len=len(r.prompt) + gen)
            jax.block_until_ready(out)
            base_lat.record(time.perf_counter() - t_req)
            base_tokens += out.shape[1] - len(r.prompt)
        base_dt = time.perf_counter() - t0

    base_tps = base_tokens / max(base_dt, 1e-9)
    rows.append({
        "bench": f"engine:{cfg.name}:slots{n_slots}:req{n_requests}",
        "arch": cfg.name,
        "n_slots": n_slots,
        "n_requests": n_requests,
        "engine_tokens_per_s": round(res["tokens_per_s"], 2),
        "baseline_tokens_per_s": round(base_tps, 2),
        "speedup_vs_fixed_batch": round(res["tokens_per_s"] / max(base_tps, 1e-9), 3),
        "latency_p50_s": res["latency_p50_s"],
        "latency_p99_s": res["latency_p99_s"],
        "ttft_p50_s": res["ttft_p50_s"],
        "ttft_p99_s": res["ttft_p99_s"],
        "queue_wait_p50_s": res["queue_wait_p50_s"],
        "queue_wait_p99_s": res["queue_wait_p99_s"],
        "eviction_cost_total_s": res["eviction_cost_total_s"],
        "baseline_latency_p50_s": round(base_lat.percentile(50), 4),
        "baseline_latency_p99_s": round(base_lat.percentile(99), 4),
        "slot_utilization": res["slot_utilization"],
        "evictions": res["evictions"],
        "decode_steps": res["decode_steps"],
        "decode_traces": res["trace_counts"]["decode"],
        "kv_page": eng.page,
        "n_pages": eng.n_pages,
    })
    return rows


def bench_chunked_prefill(arch: str = "smollm-360m", *, n_requests: int = 6,
                          n_slots: int = 2, shared_prefix: int = 16,
                          prompt_lens=(12, 40), gen: int = 8) -> List[Dict]:
    """Chunked + batched admission vs monolithic prefill on the SAME
    mixed long/short trace with a shared prompt prefix.

    Two rows land in ``BENCH_engine.json``: the monolithic scheduler
    (whole-prompt prefill blocks decode for its full duration) and the
    chunked one (``prefill_chunk`` pages per step interleaved with
    decode, batched same-bucket admission, prefix page cache).  The
    columns the trajectory tracks: p99 inter-token latency measured on
    decode steps that shared an iteration with prefill work
    (``itl_with_prefill_p99_s`` — the decode-interference gauge), the
    TTFT decomposition, and the prefix-cache hit rate."""
    import jax

    from repro.configs import get_config
    from repro.core.packed import quantize_params
    from repro.core.quantize import (
        KVQuant, QuantPolicy, kv_quant_scope,
    )
    from repro.launch.engine import PVQEngine, bucket_len, poisson_trace

    cfg = get_config(arch).reduced()
    from repro.nn.models import build_model
    model = build_model(cfg)
    max_prompt = shared_prefix + prompt_lens[1]
    params = model.init(jax.random.PRNGKey(0), max_seq=2 * (max_prompt + gen))
    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("kernel|experts", cfg.pvq.n_over_k, cfg.pvq.group)),
        scale_mode="ls",
    )
    params = quantize_params(params, policy)

    kvq = KVQuant(block=8, group=16)
    max_len = bucket_len(max_prompt + gen, kvq.block)
    variants = (
        ("monolithic", dict()),
        ("chunked", dict(prefill_chunk=2, prefill_batch=2)),
    )
    rows: List[Dict] = []
    for name, opts in variants:
        with kv_quant_scope(kvq):
            trace = poisson_trace(
                n_requests, rate=0.0, vocab=cfg.vocab_size,
                prompt_lens=prompt_lens, max_new=gen, seed=7,
                shared_prefix=shared_prefix,
            )
            eng = PVQEngine(model, params, n_slots=n_slots,
                            max_len=max_len, **opts)
            eng.warmup(prompt_lens=[len(r.prompt) for r in trace])
            res = eng.run(trace)
            res.pop("outputs")
        full_pages = sum(len(r.prompt) // eng.page for r in trace)
        rows.append({
            "bench": f"engine_prefill:{cfg.name}:{name}",
            "arch": cfg.name,
            "scheduler": name,
            "n_slots": n_slots,
            "n_requests": n_requests,
            "shared_prefix": shared_prefix,
            "tokens_per_s": round(res["tokens_per_s"], 2),
            "ttft_p50_s": res["ttft_p50_s"],
            "ttft_p99_s": res["ttft_p99_s"],
            "queue_wait_p99_s": res["queue_wait_p99_s"],
            "prefill_compute_p99_s": res["prefill_compute_p99_s"],
            "chunk_wait_p99_s": res["chunk_wait_p99_s"],
            "itl_p99_s": res["itl_p99_s"],
            "itl_with_prefill_p99_s": res["itl_with_prefill_p99_s"],
            "itl_with_prefill_samples": res["itl_with_prefill_samples"],
            "chunks": res["chunks"],
            "prefill_batches": res["prefill_batches"],
            "prefix_hits": res["prefix_hits"],
            "prefix_hit_rate": round(
                res["prefix_hits"] / max(full_pages, 1), 3),
            "prefix_pages_shared": res["prefix_pages_shared"],
            "decode_traces": res["trace_counts"]["decode"],
            "chunk_traces": res["trace_counts"].get("chunk", 0),
        })
    return rows
