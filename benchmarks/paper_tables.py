"""Benchmarks reproducing the paper's tables.

T1-T4: nets A-D accuracy before/after per-layer PVQ (paper §VII).
T5-T8: pulse distribution + bits/weight per layer (paper §VI/§VII).
Additionally: the §III op-count claim and §II enumeration sizes.

Fast mode (default) trains short; --full uses the EXPERIMENTS.md settings.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np


def bench_tables_1_to_4(steps: Dict[str, int] | None = None, refine: bool = False) -> List[dict]:
    from repro.paper.experiment import run_net

    steps = steps or {"A": 300, "B": 250, "C": 250, "D": 150}
    rows = []
    for net_id, n in steps.items():
        t0 = time.time()
        r = run_net(net_id, steps=n, check_fold=(net_id in "AB"),
                    refine_steps=(100 if refine else 0))
        rows.append({
            "table": {"A": "T1", "B": "T2", "C": "T3", "D": "T4"}[net_id],
            "net": net_id,
            "acc_before_pct": round(100 * r.acc_before, 2),
            "acc_after_pct": round(100 * r.acc_after, 2),
            "drop_pts": round(r.drop_pct, 2),
            "acc_ls_pct": round(100 * r.acc_after_ls, 2),
            "acc_refined_pct": round(100 * r.acc_refined, 2) if r.acc_refined else None,
            "fold_rel_err": (r.fold_check or {}).get("rel_err"),
            "us_per_call": round(1e6 * (time.time() - t0), 1),
        })
    return rows


def bench_tables_5_to_8() -> List[dict]:
    """Pulse statistics at the paper's N/K ratios on Laplacian weights."""
    from repro.core.codes import compression_report, pulse_histogram
    from repro.core.pvq import pvq_encode_np

    rows = []
    rng = np.random.default_rng(0)
    for n, n_over_k, label in (
        (401920, 5.0, "T5:FC0(A)"),
        (9248, 1.0, "T6:CONV1(B)"),
        (2097664, 4.0, "T6:FC4(B)"),
        (401920, 2.5, "T7:FC0(C)"),
        (896, 0.4, "T8:CONV0(D)"),
    ):
        t0 = time.time()
        w = rng.laplace(size=n)
        k = max(int(round(n / n_over_k)), 1)
        y, _ = pvq_encode_np(w, k)
        h = pulse_histogram(y)
        rep = compression_report(y)
        rows.append({
            "table": label, "N": n, "K": k,
            "zeros_pct": round(h["0_pct"], 2),
            "pm1_pct": round(h["+-1_pct"], 2),
            "pm23_pct": round(h["+-2..3_pct"], 2),
            "golomb_bits_per_weight": round(rep["golomb_bits_per_weight"], 3),
            "rle_bits_per_weight": round(rep["rle_bits_per_weight"], 3),
            "us_per_call": round(1e6 * (time.time() - t0), 1),
        })
    return rows


def bench_opcount_claim() -> List[dict]:
    """§III: dot product cost K-1 adds + 1 mul; §II: N_p(8,4)=2816."""
    import jax.numpy as jnp

    from repro.core import dot_op_counts, index_bits, num_points, pvq_encode

    t0 = time.time()
    rows = []
    for n, k in ((1024, 128), (4096, 512), (256, 256)):
        w = jnp.asarray(np.random.default_rng(n).laplace(size=n).astype(np.float32))
        code = pvq_encode(w, k)
        c = dot_op_counts(code)
        rows.append({
            "table": "S3:opcount", "N": n, "K": k,
            "pvq_adds": c["pvq_adds"], "pvq_muls": c["pvq_muls"],
            "naive_adds": c["naive_adds"], "naive_muls": c["naive_muls"],
            "mult_reduction": round(c["naive_muls"] / max(c["pvq_muls"], 1), 1),
            "us_per_call": round(1e6 * (time.time() - t0), 1),
        })
    rows.append({
        "table": "S2:enumeration", "N": 8, "K": 4,
        "num_points": num_points(8, 4), "bits": index_bits(8, 4),
        "expected": 2816, "us_per_call": round(1e6 * (time.time() - t0), 1),
    })
    return rows
