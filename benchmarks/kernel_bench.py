"""Kernel micro-benchmarks (interpret on CPU; Mosaic on TPU) + the
bandwidth-model table for the PVQ dequant-matmul (the §VIII hardware story
adapted to TPU: bytes-from-HBM per weight vs bf16/f32 baselines).

Every bench warms up (trace+compile excluded) and reports steady-state
us_per_call; rows land in BENCH_kernels.json via benchmarks.run so perf
regressions are trackable across PRs.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, reps: int) -> float:
    fn()  # warmup: trace + compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _mode() -> str:
    return "interpret" if jax.default_backend() != "tpu" else "mosaic"


def bench_pvq_matmul(reps: int = 3) -> List[dict]:
    from repro.kernels import ops

    rows = []
    for m, k, n, group in ((8, 512, 512, 128), (128, 512, 512, 128)):
        kx, kw, ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(kx, (m, k), jnp.float32)
        pulses = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
        scales = jnp.abs(jax.random.normal(ks, (k // group, n))) * 0.05
        # tuned dispatch: first call may search (persisting the tile cache),
        # later calls hit the cache
        dt = _timeit(
            lambda: ops.pvq_matmul(x, pulses, scales, group=group, tune=True)
            .block_until_ready(),
            reps,
        )
        from repro.kernels import autotune

        bm, bn, bk = autotune.get_tiles(m, k, n, group=group, dtype=x.dtype)
        # HBM traffic model (TPU): int8 pulses + f32 group scales vs bf16 w
        bytes_pvq = k * n * 1 + (k // group) * n * 4 + m * k * 4 + m * n * 4
        bytes_bf16 = k * n * 2 + m * k * 4 + m * n * 4
        rows.append({
            "bench": f"pvq_matmul_{m}x{k}x{n}",
            "us_per_call": round(1e6 * dt, 1),
            "tiles": f"{bm}x{bn}x{bk}",
            "weight_bytes_ratio_vs_bf16": round((k * n + (k // group) * n * 4) / (k * n * 2), 3),
            "total_bytes_ratio_vs_bf16": round(bytes_pvq / bytes_bf16, 3),
            "mode": _mode(),
        })

    # int8-activation kernel v3 (ISSUE 5): same GEMMs, quantized activations
    # — int8 x int8 on the MXU with int32 accumulation.  us_per_call includes
    # the per-row activation quantize (that IS the serving path); the bytes
    # model adds the activation-bandwidth win (1 byte/act + 4/row scale).
    from repro.core.quantize import ActQuant

    for m, k, n, group in ((8, 512, 512, 128), (128, 512, 512, 128)):
        kx, kw, ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(kx, (m, k), jnp.float32)
        pulses = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
        scales = jnp.abs(jax.random.normal(ks, (k // group, n))) * 0.05
        dt = _timeit(
            lambda: ops.pvq_matmul(
                x, pulses, scales, group=group, act_quant=ActQuant(), tune=True
            ).block_until_ready(),
            reps,
        )
        bytes_int8act = k * n * 1 + (k // group) * n * 4 + m * k * 1 + m * 4 + m * n * 4
        bytes_f32act = k * n * 1 + (k // group) * n * 4 + m * k * 4 + m * n * 4
        rows.append({
            "bench": f"pvq_matmul_int8act_{m}x{k}x{n}",
            "us_per_call": round(1e6 * dt, 1),
            "act_bytes_ratio_vs_f32act": round((m * k * 1 + m * 4) / (m * k * 4), 3),
            "total_bytes_ratio_vs_f32act": round(bytes_int8act / bytes_f32act, 3),
            "mode": _mode(),
        })

    # fused epilogue: bias + relu inside the final store (one HBM round-trip)
    m, k, n, group = (128, 512, 512, 128)
    kx, kw, ks, kb = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
    scales = jnp.abs(jax.random.normal(ks, (k // group, n))) * 0.05
    bias = jax.random.normal(kb, (n,))
    dt = _timeit(
        lambda: ops.pvq_matmul(
            x, pulses, scales, group=group, bias=bias, activation="relu"
        ).block_until_ready(),
        reps,
    )
    rows.append({
        "bench": f"pvq_matmul_bias_relu_{m}x{k}x{n}",
        "us_per_call": round(1e6 * dt, 1),
        "mode": _mode(),
    })

    # ragged decode shape: exercises the pad-or-fallback path
    m, k, n, group = (5, 384, 257, 128)
    kx, kw, ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    pulses = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
    scales = jnp.abs(jax.random.normal(ks, (k // group, n))) * 0.05
    dt = _timeit(
        lambda: ops.pvq_matmul(x, pulses, scales, group=group).block_until_ready(),
        reps,
    )
    rows.append({
        "bench": f"pvq_matmul_ragged_{m}x{k}x{n}",
        "us_per_call": round(1e6 * dt, 1),
        "mode": _mode(),
    })
    return rows


def bench_pvq_encode(reps: int = 3) -> List[dict]:
    from repro.kernels import ops

    rows = []
    for g, n, k_pulses in ((64, 256, 128), (8, 1024, 256)):
        w = jax.random.laplace(jax.random.PRNGKey(1), (g, n))
        dt = _timeit(
            lambda: ops.pvq_encode(w, k_pulses=k_pulses)[0].block_until_ready(),
            reps,
        )
        rows.append({
            "bench": f"pvq_encode_{g}x{n}_K{k_pulses}",
            "us_per_call": round(1e6 * dt, 1),
            "dims_per_s": round(g * n / dt),
            "mode": _mode(),
        })
    # the big-layer encoder path (largest-remainder, pure jnp — the paper
    # needed CUDA for this size; one sort suffices)
    from repro.core.pvq import pvq_quantize_direction

    w = jax.random.laplace(jax.random.PRNGKey(2), (2_097_664,))
    dt = _timeit(
        lambda: pvq_quantize_direction(w, 524_416).block_until_ready(), reps
    )
    rows.append({
        "bench": "pvq_encode_2.1M_dims_K524k",
        "us_per_call": round(1e6 * dt, 1),
        "dims_per_s": round(w.size / dt),
        "mode": "jnp",
    })
    return rows
