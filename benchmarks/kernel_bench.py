"""Kernel micro-benchmarks (interpret on CPU; Mosaic on TPU) + the
bandwidth-model table for the PVQ dequant-matmul (the §VIII hardware story
adapted to TPU: bytes-from-HBM per weight vs bf16/f32 baselines)."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def bench_pvq_matmul(reps: int = 3) -> List[dict]:
    from repro.kernels import ops

    rows = []
    for m, k, n, group in ((8, 512, 512, 128), (128, 512, 512, 128)):
        kx, kw = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(kx, (m, k), jnp.float32)
        pulses = jax.random.randint(kw, (k, n), -3, 4, jnp.int8)
        scales = jnp.abs(jax.random.normal(kw, (k // group, n))) * 0.05
        y = ops.pvq_matmul(x, pulses, scales, group=group, bm=min(m, 128))
        y.block_until_ready()
        t0 = time.time()
        for _ in range(reps):
            ops.pvq_matmul(x, pulses, scales, group=group, bm=min(m, 128)).block_until_ready()
        dt = (time.time() - t0) / reps
        # HBM traffic model (TPU): int8 pulses + f32 group scales vs bf16 w
        bytes_pvq = k * n * 1 + (k // group) * n * 4 + m * k * 4 + m * n * 4
        bytes_bf16 = k * n * 2 + m * k * 4 + m * n * 4
        rows.append({
            "bench": f"pvq_matmul_{m}x{k}x{n}",
            "us_per_call": round(1e6 * dt, 1),
            "weight_bytes_ratio_vs_bf16": round((k * n + (k // group) * n * 4) / (k * n * 2), 3),
            "total_bytes_ratio_vs_bf16": round(bytes_pvq / bytes_bf16, 3),
            "mode": "interpret" if jax.default_backend() != "tpu" else "mosaic",
        })
    return rows


def bench_pvq_encode(reps: int = 3) -> List[dict]:
    from repro.kernels import ops

    rows = []
    for g, n, k_pulses in ((64, 256, 128), (8, 1024, 256)):
        w = jax.random.laplace(jax.random.PRNGKey(1), (g, n))
        p, r = ops.pvq_encode(w, k_pulses=k_pulses)
        p.block_until_ready()
        t0 = time.time()
        for _ in range(reps):
            ops.pvq_encode(w, k_pulses=k_pulses)[0].block_until_ready()
        dt = (time.time() - t0) / reps
        rows.append({
            "bench": f"pvq_encode_{g}x{n}_K{k_pulses}",
            "us_per_call": round(1e6 * dt, 1),
            "dims_per_s": round(g * n / dt),
            "mode": "interpret" if jax.default_backend() != "tpu" else "mosaic",
        })
    # the big-layer encoder path (largest-remainder, pure jnp — the paper
    # needed CUDA for this size; one sort suffices)
    from repro.core.pvq import pvq_quantize_direction

    w = jax.random.laplace(jax.random.PRNGKey(2), (2_097_664,))
    t0 = time.time()
    y = pvq_quantize_direction(w, 524_416)
    y.block_until_ready()
    dt = time.time() - t0
    rows.append({
        "bench": "pvq_encode_2.1M_dims_K524k",
        "us_per_call": round(1e6 * dt, 1),
        "dims_per_s": round(w.size / dt),
        "mode": "jnp",
    })
    return rows
