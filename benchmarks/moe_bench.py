"""Packed-vs-dense MoE expert bank: throughput + weight-bytes (PR 4).

Serves the deepseek-v2-lite MoE config (reduced on CPU hosts) with dense
f32 expert tensors vs the expert-stacked ``PackedPVQ`` bank, and times the
bare ``moe_forward`` layer both ways.  Rows go to ``BENCH_moe.json`` via
benchmarks.run for cross-PR perf trajectories.

On this CPU container the batched Pallas kernel runs interpret=True, so
packed throughput is a correctness proxy, not a perf claim; the expert
weight-bytes ratio (the 472GB DeepSeek-236B headline) is
backend-independent.
"""

from __future__ import annotations

import time
from typing import Dict, List


def bench_moe_experts(arch: str = "deepseek-v2-lite-16b", *, batch: int = 2,
                      prompt_len: int = 8, gen: int = 8) -> List[Dict]:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.packed import expert_leaves, quantize_params
    from repro.core.quantize import QuantPolicy
    from repro.launch.serve import generate
    from repro.nn import moe as moe_lib
    from repro.nn.models import build_model

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=prompt_len + gen)
    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("kernel|experts", 2.0, cfg.pvq.group)),
        scale_mode="ls",
    )
    t0 = time.perf_counter()
    qparams = quantize_params(params, policy)
    encode_s = time.perf_counter() - t0
    experts = expert_leaves(qparams)
    assert experts, "no expert leaves were packed"
    expert_packed_bytes = sum(leaf.nbytes_packed for leaf in experts.values())
    expert_dense_bytes = sum(leaf.nbytes_dense for leaf in experts.values())

    toks = jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab_size
    )

    def timed_serve(p):
        generate(model, p, toks, gen=gen, cache_len=prompt_len + gen)  # warmup
        t0 = time.perf_counter()
        out = generate(model, p, toks, gen=gen, cache_len=prompt_len + gen)
        jax.block_until_ready(out)
        return batch * gen / (time.perf_counter() - t0)

    tps_dense = timed_serve(params)
    tps_packed = timed_serve(qparams)

    # bare MoE layer (prefill-shaped tokens), dense vs packed expert bank
    mo = cfg.moe

    def layer_of(tree):
        """One (unstacked) MoE ffn param dict out of the segment pytree."""
        for seg in tree["segments"].values():
            for block in seg.values():
                if "ffn" in block and "wi_up_experts" in block["ffn"]:
                    return jax.tree.map(lambda t: t[0], block["ffn"])
        raise KeyError("no MoE ffn in this config")

    x = jax.random.normal(jax.random.PRNGKey(2), (batch, prompt_len, cfg.d_model))

    def timed_layer(p_layer):
        fwd = jax.jit(lambda px, xx: moe_lib.moe_forward(px, xx, mo)[0])
        jax.block_until_ready(fwd(p_layer, x))  # warmup
        t0 = time.perf_counter()
        for _ in range(5):
            out = fwd(p_layer, x)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 5 * 1e6

    us_dense = timed_layer(layer_of(params))
    us_packed = timed_layer(layer_of(qparams))

    return [{
        "bench": f"moe:{cfg.name}:b{batch}g{gen}",
        "us_per_call": round(us_packed, 1),
        "moe_layer_us_dense": round(us_dense, 1),
        "moe_layer_us_packed": round(us_packed, 1),
        "tokens_per_s_dense": round(tps_dense, 2),
        "tokens_per_s_packed": round(tps_packed, 2),
        "packed_over_dense": round(tps_packed / max(tps_dense, 1e-9), 3),
        "encode_s": round(encode_s, 2),
        "expert_tensors": len(experts),
        "expert_weight_bytes_dense": expert_dense_bytes,
        "expert_weight_bytes_packed": expert_packed_bytes,
        "expert_compression_ratio": round(
            expert_dense_bytes / max(expert_packed_bytes, 1), 3
        ),
    }]
