"""End-to-end LM training driver (deliverable (b)): trains a ~100M-class
model for a few hundred steps on the synthetic token task with the full
substrate — AdamW, deterministic sharded data pipeline, async checkpointing,
fault-tolerant runner — and optionally the paper's PVQ-QAT.

    # fast smoke (reduced config):
    PYTHONPATH=src python examples/train_lm.py --steps 60 --reduced

    # real ~360M model, a few hundred steps (slow on CPU):
    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m --steps 300
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.exit(main())
