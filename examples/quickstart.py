"""Quickstart: PVQ in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. encode a weight vector on the pyramid P(N, K)
2. the dot-product trick (K-1 adds + ONE multiply)
3. compress the code (enumeration + Golomb)
4. quantize a whole model pytree with a policy
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    dot_op_counts,
    index_bits,
    num_points,
    pvq_dot,
    pvq_encode,
    quantize_tree,
    QuantPolicy,
)

# --- 1. product PVQ encoding ------------------------------------------------
key = jax.random.PRNGKey(0)
w = jax.random.laplace(key, (256,))  # NN weights are ~Laplacian (paper §II)
code = pvq_encode(w, k=128)  # N/K = 2
print("pulses on P(256,128): L1 =", int(jnp.abs(code.pulses).sum()), " rho =", float(code.scale))
rel = float(jnp.linalg.norm(code.dequantize() - w) / jnp.linalg.norm(w))
print(f"relative quantization error: {100*rel:.1f}%")

# --- 2. the cheap dot product (paper §III) -----------------------------------
x = jax.random.normal(jax.random.PRNGKey(1), (256,))
print("pvq_dot == dequant dot:", np.allclose(float(pvq_dot(code, x)), float(code.dequantize() @ x), rtol=1e-5))
print("op counts:", dot_op_counts(code))

# --- 3. compression (paper §II/§VI) ------------------------------------------
print(f"N_p(8,4) = {num_points(8, 4)} -> {index_bits(8, 4)} bits (paper: 2816, <12 bits)")

# --- 4. whole-model quantization (paper §IV procedure) ------------------------
params = {
    "layer0": {"kernel": jax.random.laplace(key, (64, 64)), "bias": jnp.zeros(64)},
    "norm": {"scale": jnp.ones(64)},
}
qparams, codes, stats = quantize_tree(params, QuantPolicy(rules=(("kernel", 2.0, None),)))
for path, st in stats.items():
    print(f"{path}: N={st['N']} K={st['K']} rel_err={st['rel_err']:.3f}")
print("norm scale untouched:", bool(jnp.all(qparams["norm"]["scale"] == 1.0)))
