"""Model compression walkthrough (paper §VI): quantize a full LM, report
per-layer pulse statistics, bits/weight under each coding scheme, and write
a PVQ-compressed checkpoint, then restore and compare.

    PYTHONPATH=src python examples/compress_model.py [--arch smollm-360m]
"""

import argparse
import tempfile

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core.quantize import QuantPolicy, quantize_tree, total_bits, tree_compression_report
from repro.nn.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--full", action="store_true", help="use the full (non-reduced) config")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), max_seq=128)

    policy = QuantPolicy(
        rules=(("embedding", cfg.pvq.n_over_k_embed, cfg.pvq.group),
               ("", cfg.pvq.n_over_k, cfg.pvq.group)),
        scale_mode="ls",
    )
    qparams, codes, stats = quantize_tree(params, policy)

    print(f"== {cfg.name}: PVQ-quantized {len(codes)} tensors ==")
    rep = tree_compression_report(codes)
    for path in list(rep)[:8]:
        r = rep[path]
        print(f"  {path}: zeros {r['0_pct']:.1f}%  golomb {r['golomb_bits_per_weight']:.2f} b/w")
    agg = total_bits(codes, "golomb")
    print(f"model: {agg['bits_per_weight']:.2f} bits/weight -> {agg['vs_bf16_ratio']:.1f}x smaller than bf16")

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, compress="pvq", pvq_group=cfg.pvq.group or 256)
        ck.save(0, {"params": params})
        restored, _ = ck.restore({"params": params})
        leaves0 = jax.tree.leaves(params)
        leaves1 = jax.tree.leaves(restored["params"])
        errs = [
            float(np.linalg.norm(np.asarray(a, np.float32) - np.asarray(b, np.float32))
                  / max(np.linalg.norm(np.asarray(a, np.float32)), 1e-9))
            for a, b in zip(leaves0, leaves1) if a.ndim >= 2
        ]
        print(f"PVQ checkpoint roundtrip: median rel err {np.median(errs):.3f} over {len(errs)} tensors")


if __name__ == "__main__":
    main()
