"""Reproduce the paper's §VII experiments (nets A-D) end to end.

    PYTHONPATH=src python examples/paper_repro.py [--nets A,C] [--steps 600]
        [--refine 150]

Trains each net on the synthetic MNIST/CIFAR stand-ins (offline container),
applies the paper's per-layer PVQ procedure, and prints the Tables 1-8
equivalents: accuracy before/after, pulse histograms, bits/weight, and the
§V integer-net folding check.
"""

import argparse

from repro.paper.experiment import format_result, run_net


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nets", default="A,B,C,D")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--refine", type=int, default=0)
    args = ap.parse_args()

    for net_id in args.nets.split(","):
        r = run_net(
            net_id.strip(),
            steps=args.steps,
            check_fold=(net_id in "AB"),  # ReLU nets: homogeneous folding
            refine_steps=args.refine,
        )
        print(format_result(r))
        print()


if __name__ == "__main__":
    main()
