"""Batched serving example (deliverable (b)): prefill + autoregressive decode
with KV/SSM caches, optionally with PVQ-quantized weights — the paper's
inference story (compressed weights, cheap dot products) on the serving path.

    PYTHONPATH=src python examples/serve_decode.py --arch smollm-360m --reduced --pvq
    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-1.6b --reduced
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main())
